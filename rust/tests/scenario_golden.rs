//! Golden determinism suite: each scenario preset runs twice and the
//! serialized (Debug-formatted) report must be byte-identical — the
//! determinism contract stated in DESIGN.md §4/§10/§11, checked at the
//! serialization level so even float formatting drift would trip it.
//!
//! The paper presets and the batch scale128 run at full size.  The
//! request-heavy service presets run here as scaled-down clones (these
//! tests run in debug builds); their full-size determinism is gated in
//! release builds by benches/bench_traffic.rs, benches/bench_colocate.rs
//! and examples/scenario_suite.rs.

use sector_sphere::scenario::{run_scenario, ScenarioSpec};
use sector_sphere::service::ArrivalProcess;
use sector_sphere::util::bytes::GB;

fn assert_golden(spec: &ScenarioSpec) {
    let a = run_scenario(spec).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    let b = run_scenario(spec).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "{}: serialized reports must be byte-identical",
        spec.name
    );
}

#[test]
fn golden_paper_wan6() {
    assert_golden(&ScenarioSpec::paper_wan6());
}

#[test]
fn golden_paper_lan8() {
    assert_golden(&ScenarioSpec::paper_lan8());
}

#[test]
fn golden_scale128() {
    assert_golden(&ScenarioSpec::scale128());
}

#[test]
fn golden_traffic_scale128_scaled() {
    let mut spec = ScenarioSpec::traffic_scale128();
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 4_000;
    t.clients = 20_000;
    t.arrival = ArrivalProcess::Open { rps: 2_000.0 };
    assert_golden(&spec);
}

#[test]
fn golden_colocate_scale128_scaled() {
    let mut spec = ScenarioSpec::colocate_scale128();
    spec.workload.as_mut().expect("workload preset").bytes_per_node = 0.25 * GB as f64;
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 3_000;
    t.clients = 20_000;
    t.arrival = ArrivalProcess::Open { rps: 1_500.0 };
    assert_golden(&spec);
}

#[test]
fn golden_compare_wan4() {
    assert_golden(&ScenarioSpec::compare_wan4());
}

#[test]
fn golden_compare_scale128() {
    // Full size: both engines are event-driven and finish a 128-node
    // faulted run in debug-build milliseconds (like golden_scale128).
    assert_golden(&ScenarioSpec::compare_scale128());
}

#[test]
fn golden_compare_toml_matches_preset_shape() {
    // The shipped TOMLs must stay in sync with the built-in presets.
    for (file, preset) in [
        ("compare_wan4.toml", ScenarioSpec::compare_wan4()),
        ("compare_scale128.toml", ScenarioSpec::compare_scale128()),
    ] {
        let text = std::fs::read_to_string(format!(
            "{}/config/scenarios/{file}",
            env!("CARGO_MANIFEST_DIR")
        ))
        .expect("preset TOML readable");
        let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
        assert_eq!(from_toml.name, preset.name);
        assert_eq!(from_toml.topology.nodes(), preset.topology.nodes());
        assert_eq!(from_toml.compare, preset.compare, "{file}");
        assert_eq!(from_toml.faults.len(), preset.faults.len(), "{file}");
        for f in &preset.faults {
            assert!(from_toml.faults.contains(f), "{file} missing fault {f:?}");
        }
        assert_eq!(
            from_toml.workload.as_ref().map(|w| w.kind),
            preset.workload.as_ref().map(|w| w.kind),
        );
    }
}

#[test]
fn golden_colocate_toml_matches_preset_shape() {
    // The shipped TOML must stay in sync with the built-in preset:
    // same topology, fault plan, colocation knobs and tenant mix.
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/config/scenarios/colocate_scale128.toml"
    ))
    .expect("preset TOML readable");
    let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
    let preset = ScenarioSpec::colocate_scale128();
    assert_eq!(from_toml.name, preset.name);
    assert_eq!(from_toml.topology.nodes(), preset.topology.nodes());
    // TOML fault subsections parse in name order; compare as a set.
    assert_eq!(from_toml.faults.len(), preset.faults.len());
    for f in &preset.faults {
        assert!(from_toml.faults.contains(f), "TOML missing fault {f:?}");
    }
    assert_eq!(from_toml.colocation, preset.colocation);
    assert_eq!(
        from_toml.traffic.as_ref().map(|t| (t.requests, t.clients, t.tenants.len())),
        preset.traffic.as_ref().map(|t| (t.requests, t.clients, t.tenants.len())),
    );
}

//! Golden determinism suite: each scenario preset runs twice and the
//! serialized (Debug-formatted) report must be byte-identical — the
//! determinism contract stated in DESIGN.md §4/§10/§11, checked at the
//! serialization level so even float formatting drift would trip it.
//!
//! The paper presets and the batch scale128 run at full size.  The
//! request-heavy service presets run here as scaled-down clones (these
//! tests run in debug builds); their full-size determinism is gated in
//! release builds by benches/bench_traffic.rs, benches/bench_colocate.rs
//! and examples/scenario_suite.rs.
//!
//! Beyond the same-process run-twice check, every preset's report is
//! pinned against a committed fixture under rust/tests/golden/ — the
//! cross-refactor equivalence contract for the shared engine core
//! (DESIGN.md §14).  A missing fixture is blessed on first run (commit
//! the generated file); any later divergence fails with a diff pointer.

use std::fs;
use std::path::PathBuf;

use sector_sphere::scenario::{run_scenario, run_sweep, FaultSpec, ScenarioSpec, SweepSpec};
use sector_sphere::service::ArrivalProcess;
use sector_sphere::util::bytes::GB;

fn fixture_path(name: &str) -> PathBuf {
    let safe: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(format!("{safe}.txt"))
}

fn assert_golden(spec: &ScenarioSpec) {
    let a = run_scenario(spec).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    let b = run_scenario(spec).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    let text = format!("{a:?}");
    assert_eq!(
        text,
        format!("{b:?}"),
        "{}: serialized reports must be byte-identical",
        spec.name
    );
    let path = fixture_path(&spec.name);
    match fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            text, want,
            "{}: report diverged from the committed fixture {} — an \
             engine-core or workload change altered observable behavior; \
             if intentional, delete the fixture and re-run to re-bless",
            spec.name,
            path.display()
        ),
        Err(_) => {
            fs::create_dir_all(path.parent().expect("fixture dir has parent"))
                .expect("create fixture dir");
            fs::write(&path, &text).expect("bless fixture");
        }
    }
}

#[test]
fn golden_paper_wan6() {
    assert_golden(&ScenarioSpec::paper_wan6());
}

#[test]
fn golden_paper_lan8() {
    assert_golden(&ScenarioSpec::paper_lan8());
}

#[test]
fn golden_scale128() {
    assert_golden(&ScenarioSpec::scale128());
}

#[test]
fn golden_traffic_scale128_scaled() {
    let mut spec = ScenarioSpec::traffic_scale128();
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 4_000;
    t.clients = 20_000;
    t.arrival = ArrivalProcess::Open { rps: 2_000.0 };
    assert_golden(&spec);
}

#[test]
fn golden_colocate_scale128_scaled() {
    let mut spec = ScenarioSpec::colocate_scale128();
    spec.workload.as_mut().expect("workload preset").bytes_per_node = 0.25 * GB as f64;
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 3_000;
    t.clients = 20_000;
    t.arrival = ArrivalProcess::Open { rps: 1_500.0 };
    assert_golden(&spec);
}

#[test]
fn golden_traffic_elastic512_scaled() {
    // Debug-scaled clone of the elastic preset (same topology, tenants
    // and watermark policy; fewer requests; crash pulled inside the
    // shortened horizon).  Pins the full report — including the
    // embedded-baseline tenant deltas and the replica timeline —
    // against a committed fixture.
    let mut spec = ScenarioSpec::traffic_elastic512();
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 4_000;
    t.clients = 40_000;
    t.arrival = ArrivalProcess::Open { rps: 2_000.0 };
    for f in &mut spec.faults {
        if let FaultSpec::SlaveCrash { at_secs, .. } = f {
            *at_secs = 1.0;
        }
    }
    assert_golden(&spec);
}

#[test]
fn golden_compare_wan4() {
    assert_golden(&ScenarioSpec::compare_wan4());
}

#[test]
fn golden_compare_scale128() {
    // Full size: both engines are event-driven and finish a 128-node
    // faulted run in debug-build milliseconds (like golden_scale128).
    assert_golden(&ScenarioSpec::compare_scale128());
}

#[test]
fn golden_angle_wan4() {
    assert_golden(&ScenarioSpec::angle_wan4());
}

#[test]
fn golden_angle_scale128() {
    // Full size: the staged pipeline is event-driven end to end and the
    // 128-node faulted run stays in debug-build seconds (the cluster
    // stage is 16 tasks, the feature shuffle ~2k flows).
    assert_golden(&ScenarioSpec::angle_scale128());
}

#[test]
fn golden_churn_wan32() {
    // Full size: 32 nodes at 1 GB/node with the seeded churn episode —
    // every leave/join instant and the resulting re-replication flows
    // are pinned through the report fixture.
    assert_golden(&ScenarioSpec::churn_wan32());
}

#[test]
fn golden_weather_compare16() {
    // Full size: both engines under the same 6-epoch WAN weather trace.
    assert_golden(&ScenarioSpec::weather_compare16());
}

#[test]
fn golden_wide_area_toml_matches_preset_shape() {
    // The shipped TOMLs must stay in sync with the built-in presets:
    // same topology, workload, and — the wide-area additions — the
    // [churn] block, the [weather] trace and the compare half.
    for (file, preset) in [
        ("churn_wan32.toml", ScenarioSpec::churn_wan32()),
        ("weather_compare16.toml", ScenarioSpec::weather_compare16()),
    ] {
        let text = std::fs::read_to_string(format!(
            "{}/config/scenarios/{file}",
            env!("CARGO_MANIFEST_DIR")
        ))
        .expect("preset TOML readable");
        let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
        assert_eq!(from_toml.name, preset.name, "{file}");
        assert_eq!(from_toml.topology.nodes(), preset.topology.nodes(), "{file}");
        assert_eq!(from_toml.churn, preset.churn, "{file}: [churn] block");
        assert_eq!(from_toml.weather, preset.weather, "{file}: [weather] block");
        assert_eq!(from_toml.compare, preset.compare, "{file}: compare half");
        assert_eq!(
            from_toml.cfg.sphere_transport, preset.cfg.sphere_transport,
            "{file}: transport knob"
        );
        assert_eq!(
            from_toml.workload.as_ref().map(|w| w.kind),
            preset.workload.as_ref().map(|w| w.kind),
            "{file}"
        );
        let (a, b) = (
            from_toml.workload.as_ref().unwrap().bytes_per_node,
            preset.workload.as_ref().unwrap().bytes_per_node,
        );
        assert!((a - b).abs() < 1.0, "{file}: bytes_per_node {a} vs {b}");
        // Both presets' hand-written fault lists are empty — the plan
        // comes entirely from the churn/weather expansion, which the
        // shape equality above pins exactly.
        assert_eq!(from_toml.faults, preset.faults, "{file}");
        assert_eq!(
            from_toml.effective_faults().len(),
            preset.effective_faults().len(),
            "{file}: expanded plans must line up"
        );
    }
}

#[test]
fn angle_recall_holds_under_the_fault_plan() {
    // The §7.1 regime shifts (scan at window 5, exfiltration at 11)
    // must still be detected while the crash re-homes a window, the 4x
    // straggler's cluster task gets speculated, and the WAN brown-out
    // squeezes the feature shuffle: faults perturb timing and
    // placement, never the mined content (data survives on replicas).
    let spec = ScenarioSpec::angle_scale128();
    let r = run_scenario(&spec).unwrap();
    let an = r.angle.as_ref().expect("angle report present");
    assert_eq!(an.emergent_planted, vec![5, 11]);
    assert_eq!(
        an.recall, 1.0,
        "planted shifts missed: found {:?}, deltas {:?}",
        an.emergent_found, an.deltas
    );
    assert_eq!(r.nodes_crashed, 1, "the crash fired");
    assert!(r.faults_injected >= 3, "all three faults counted");
    assert!(r.reassignments > 0, "the crash re-assigned mining work");
    assert!(
        r.speculative_launched > 0 && r.speculative_won > 0,
        "node 16 hosts a window: its 4x-slow cluster task must be rescued \
         ({} launched, {} won)",
        r.speculative_launched,
        r.speculative_won
    );
    // The whole mining half ran on the substrate: five stages' worth of
    // segments (extract 128 + cluster 16) and real cross-tier traffic.
    assert_eq!(r.segments, 128 + 16, "extract segments + window tasks");
    assert!(an.model_tier.wan > 0.0, "models crossed the WAN to sensor sites");
    // And the fault-free wan4 preset detects with recall 1.0 too.
    let clean = run_scenario(&ScenarioSpec::angle_wan4()).unwrap();
    assert_eq!(clean.angle.as_ref().unwrap().recall, 1.0);
    assert_eq!(clean.faults_injected, 0);
}

#[test]
fn angle_staged_model_tracks_the_table3_oracle_at_300k_files() {
    // `simulate_angle_clustering` stays the calibration oracle
    // (DESIGN.md §13): at Table 3's 300,000-file / 10^8-record cell the
    // staged pipeline's serialized mining work (per-file opens + the
    // iteration-scaled cluster cost) must sit within the documented
    // [0.75, 1.25] band of the oracle.
    use sector_sphere::mining::simulate_angle_clustering;
    let r = run_scenario(&ScenarioSpec::angle_scale128()).unwrap();
    let an = r.angle.as_ref().expect("angle report present");
    assert_eq!(an.files, 300_000);
    let oracle = simulate_angle_clustering(1.0e8, 300_000.0);
    assert!(
        (an.oracle_secs - oracle).abs() < 1e-6 * oracle,
        "report must embed the oracle at its own (records, files) point: \
         {} vs {}",
        an.oracle_secs,
        oracle
    );
    let ratio = an.staged_work_secs / an.oracle_secs;
    assert!(
        (0.75..=1.25).contains(&ratio),
        "staged/oracle = {ratio:.3} left the documented [0.75, 1.25] band \
         (staged {:.0} s, oracle {:.0} s)",
        an.staged_work_secs,
        an.oracle_secs
    );
}

#[test]
fn golden_angle_toml_matches_preset_shape() {
    for (file, preset) in [
        ("angle_wan4.toml", ScenarioSpec::angle_wan4()),
        ("angle_scale128.toml", ScenarioSpec::angle_scale128()),
    ] {
        let text = std::fs::read_to_string(format!(
            "{}/config/scenarios/{file}",
            env!("CARGO_MANIFEST_DIR")
        ))
        .expect("preset TOML readable");
        let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
        assert_eq!(from_toml.name, preset.name);
        assert_eq!(from_toml.topology.nodes(), preset.topology.nodes());
        assert_eq!(from_toml.angle, preset.angle, "{file}");
        assert_eq!(
            from_toml.workload.as_ref().map(|w| w.kind.name()),
            preset.workload.as_ref().map(|w| w.kind.name()),
        );
        let (a, b) = (
            from_toml.workload.as_ref().unwrap().bytes_per_node,
            preset.workload.as_ref().unwrap().bytes_per_node,
        );
        assert!((a - b).abs() < 1.0, "{file}: bytes_per_node {a} vs {b}");
        assert_eq!(from_toml.faults.len(), preset.faults.len(), "{file}");
        for f in &preset.faults {
            assert!(from_toml.faults.contains(f), "{file} missing fault {f:?}");
        }
    }
}

#[test]
fn golden_compare_toml_matches_preset_shape() {
    // The shipped TOMLs must stay in sync with the built-in presets.
    for (file, preset) in [
        ("compare_wan4.toml", ScenarioSpec::compare_wan4()),
        ("compare_scale128.toml", ScenarioSpec::compare_scale128()),
    ] {
        let text = std::fs::read_to_string(format!(
            "{}/config/scenarios/{file}",
            env!("CARGO_MANIFEST_DIR")
        ))
        .expect("preset TOML readable");
        let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
        assert_eq!(from_toml.name, preset.name);
        assert_eq!(from_toml.topology.nodes(), preset.topology.nodes());
        assert_eq!(from_toml.compare, preset.compare, "{file}");
        assert_eq!(from_toml.faults.len(), preset.faults.len(), "{file}");
        for f in &preset.faults {
            assert!(from_toml.faults.contains(f), "{file} missing fault {f:?}");
        }
        assert_eq!(
            from_toml.workload.as_ref().map(|w| w.kind),
            preset.workload.as_ref().map(|w| w.kind),
        );
    }
}

#[test]
fn golden_elastic_toml_matches_preset_shape() {
    // The shipped TOML must stay in sync with the built-in preset:
    // same topology, traffic mix, fault plan and [replication] block.
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/config/scenarios/traffic_elastic512.toml"
    ))
    .expect("preset TOML readable");
    let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
    let preset = ScenarioSpec::traffic_elastic512();
    assert_eq!(from_toml.name, preset.name);
    assert_eq!(from_toml.topology.nodes(), preset.topology.nodes());
    assert_eq!(from_toml.replication, preset.replication);
    // Tenant subsections parse in name order; compare as a set, and
    // the scalar traffic knobs directly.
    let (a, b) = (
        from_toml.traffic.as_ref().expect("TOML traffic"),
        preset.traffic.as_ref().expect("preset traffic"),
    );
    assert_eq!(
        (a.clients, a.requests, a.files, a.zipf_theta, a.arrival, a.shape),
        (b.clients, b.requests, b.files, b.zipf_theta, b.arrival, b.shape),
    );
    assert_eq!(a.tenants.len(), b.tenants.len());
    for tenant in &b.tenants {
        assert!(a.tenants.contains(tenant), "TOML missing tenant {tenant:?}");
    }
    assert_eq!(from_toml.faults.len(), preset.faults.len());
    for f in &preset.faults {
        assert!(from_toml.faults.contains(f), "TOML missing fault {f:?}");
    }
}

/// Debug-scaled clone of the fig5 sweep: same axes, smaller grid and
/// data sizes so the whole sweep finishes in debug-build milliseconds.
fn scaled_fig5_sweep() -> SweepSpec {
    let mut spec = SweepSpec::fig5_scaling();
    spec.name = "sweep-fig5-scaled".to_string();
    spec.axes = SweepSpec::from_toml(
        r#"
        name = "sweep-fig5-scaled"
        [topology]
        sites = 4
        racks_per_site = 4
        nodes_per_rack = 8
        [workload]
        kind = "terasort"
        bytes_per_node = "1GB"
        [sweep]
        nodes = [16, 32]
        total_bytes = ["8GB"]
        "#,
    )
    .expect("scaled sweep TOML parses")
    .axes;
    spec
}

#[test]
fn golden_sweep_fig5_scaled() {
    // The sweep-level determinism contract (DESIGN.md §17): the full
    // SweepReport JSON — axes, per-point fingerprints, determinism
    // digests and metrics — runs twice byte-identical and is pinned
    // against a committed fixture like every scenario preset.
    let spec = scaled_fig5_sweep();
    let a = run_sweep(&spec).expect("scaled sweep runs");
    let b = run_sweep(&spec).expect("scaled sweep reruns");
    let text = a.to_json();
    assert_eq!(
        text,
        b.to_json(),
        "sweep-fig5-scaled: SweepReport JSON must be byte-identical"
    );
    assert_eq!(a.records.len(), 2);
    assert!(
        a.records[1].makespan_secs <= a.records[0].makespan_secs,
        "fixed total: 32 nodes ({:.1} s) must not be slower than 16 ({:.1} s)",
        a.records[1].makespan_secs,
        a.records[0].makespan_secs
    );
    let path = fixture_path("sweep-fig5-scaled");
    match fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            text,
            want,
            "sweep-fig5-scaled: report diverged from the committed fixture {} — \
             if intentional, delete the fixture and re-run to re-bless",
            path.display()
        ),
        Err(_) => {
            fs::create_dir_all(path.parent().expect("fixture dir has parent"))
                .expect("create fixture dir");
            fs::write(&path, &text).expect("bless fixture");
        }
    }
}

#[test]
fn golden_sweep_toml_matches_preset_shape() {
    // The shipped sweep TOMLs must stay in sync with the built-in
    // SweepSpec presets: name, workers, grid shape and base scenario.
    for (file, preset) in [
        ("sweep_fig5_scaling.toml", SweepSpec::fig5_scaling()),
        ("sweep_speedup_wan.toml", SweepSpec::speedup_wan()),
    ] {
        let text = std::fs::read_to_string(format!(
            "{}/config/scenarios/{file}",
            env!("CARGO_MANIFEST_DIR")
        ))
        .expect("sweep TOML readable");
        let from_toml = SweepSpec::from_toml(&text).expect("sweep TOML parses");
        assert_eq!(from_toml.name, preset.name, "{file}");
        assert_eq!(from_toml.workers, preset.workers, "{file}");
        assert_eq!(from_toml.points(), preset.points(), "{file}");
        assert_eq!(from_toml.axes.len(), preset.axes.len(), "{file}");
        for (a, b) in from_toml.axes.iter().zip(&preset.axes) {
            assert_eq!(a.key(), b.key(), "{file}: axis order");
            assert_eq!(a.labels(), b.labels(), "{file}: axis {} values", a.key());
        }
        assert_eq!(
            from_toml.base.topology.nodes(),
            preset.base.topology.nodes(),
            "{file}"
        );
        assert_eq!(
            from_toml.base.compare.is_some(),
            preset.base.compare.is_some(),
            "{file}: compare block presence"
        );
    }
}

#[test]
fn golden_colocate_toml_matches_preset_shape() {
    // The shipped TOML must stay in sync with the built-in preset:
    // same topology, fault plan, colocation knobs and tenant mix.
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/config/scenarios/colocate_scale128.toml"
    ))
    .expect("preset TOML readable");
    let from_toml = ScenarioSpec::from_toml(&text).expect("preset TOML parses");
    let preset = ScenarioSpec::colocate_scale128();
    assert_eq!(from_toml.name, preset.name);
    assert_eq!(from_toml.topology.nodes(), preset.topology.nodes());
    // TOML fault subsections parse in name order; compare as a set.
    assert_eq!(from_toml.faults.len(), preset.faults.len());
    for f in &preset.faults {
        assert!(from_toml.faults.contains(f), "TOML missing fault {f:?}");
    }
    assert_eq!(from_toml.colocation, preset.colocation);
    assert_eq!(
        from_toml.traffic.as_ref().map(|t| (t.requests, t.clients, t.tenants.len())),
        preset.traffic.as_ref().map(|t| (t.requests, t.clients, t.tenants.len())),
    );
}

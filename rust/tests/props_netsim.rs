//! Property suite for the incremental max-min allocator (DESIGN.md
//! §14).
//!
//! Drives randomized link topologies through flow churn — starts,
//! cancellations, capacity changes, partial and completing time
//! advances — and asserts after every few mutations that:
//!
//!   1. the incremental component-scoped recomputation agrees with the
//!      retained global allocator [`NetSim::oracle_rates`] within 1e-9;
//!   2. no link carries more than its capacity;
//!   3. no flow exceeds its protocol/application rate cap;
//!   4. no flow is starved below its guaranteed max-min floor,
//!      `min(cap, min over its path of capacity_l / flows_on_l)`.
//!
//! The `set_full_recompute` bench baseline is also replayed against the
//! incremental path to pin timeline equality, not just instantaneous
//! rates.

use std::collections::BTreeMap;

use sector_sphere::sim::netsim::{FlowId, LinkId, NetSim};
use sector_sphere::testkit::forall;
use sector_sphere::util::rng::Pcg64;

/// Live-flow shadow the properties are computed from: path + rate cap,
/// maintained alongside the simulator by the op script.
type Shadow = BTreeMap<FlowId, (Vec<LinkId>, f64)>;

/// The four pinned properties, checked against the current state.
fn check_invariants(net: &mut NetSim, live: &Shadow) -> Result<(), String> {
    // 1. Incremental rates equal the retained global oracle.
    let oracle = net.oracle_rates();
    if oracle.len() != live.len() {
        return Err(format!(
            "oracle sees {} flows, shadow tracks {}",
            oracle.len(),
            live.len()
        ));
    }
    for (id, want) in &oracle {
        let got = net.flow_rate(*id);
        if (got - want).abs() > 1e-9 {
            return Err(format!("flow {id:?}: incremental {got} vs oracle {want}"));
        }
    }
    // Per-link active-flow counts for properties 2 and 4.
    let mut on_link: BTreeMap<usize, usize> = BTreeMap::new();
    for (path, _) in live.values() {
        for l in path {
            *on_link.entry(l.0).or_insert(0) += 1;
        }
    }
    // 2. No link oversubscribed.
    for &l in on_link.keys() {
        let load = net.link_load(LinkId(l));
        let cap = net.link_capacity(LinkId(l));
        if load > cap + 1e-6 {
            return Err(format!("link {l} oversubscribed: {load} > {cap}"));
        }
    }
    for (id, (path, cap)) in live {
        let rate = net.flow_rate(*id);
        // 3. No flow above its cap.
        if rate > cap + 1e-9 {
            return Err(format!("flow {id:?} above its cap: {rate} > {cap}"));
        }
        // 4. No flow below its bottleneck fair share: max-min guarantees
        // at least min(cap, min over the path of capacity/flow-count) —
        // flows frozen earlier only leave MORE headroom, never less.
        let mut share = f64::INFINITY;
        for l in path {
            share = share.min(net.link_capacity(*l) / on_link[&l.0] as f64);
        }
        let floor = cap.min(share);
        if floor.is_finite() && rate + 1e-9 < floor {
            return Err(format!("flow {id:?} starved: {rate} < max-min floor {floor}"));
        }
    }
    Ok(())
}

/// One scripted churn episode: a random topology (4–27 links), then
/// `steps` random operations — flow starts (50%), cancellations,
/// capacity changes, completing and partial time advances — with the
/// invariants re-checked every `check_every` ops and once at the end.
fn churn_episode(seed: u64, steps: usize, check_every: usize) -> Result<(), String> {
    let mut rng = Pcg64::new(seed);
    let mut net = NetSim::new();
    let n_links = 4 + rng.gen_range(24) as usize;
    let links: Vec<LinkId> = (0..n_links)
        .map(|_| net.add_link(rng.gen_range_f64(10.0, 1000.0)))
        .collect();
    let mut live: Shadow = BTreeMap::new();
    for step in 0..steps {
        match rng.gen_range(10) {
            0..=4 => {
                let mut path: Vec<LinkId> = (0..1 + rng.gen_range(3))
                    .map(|_| links[rng.gen_range(n_links as u64) as usize])
                    .collect();
                path.sort_unstable();
                path.dedup();
                let bytes = rng.gen_range_f64(1e2, 1e5);
                let cap = rng.gen_range_f64(20.0, 2000.0);
                let id = net.start_flow(&path, bytes, cap);
                live.insert(id, (path, cap));
            }
            5 => {
                let pick = rng.gen_range(live.len().max(1) as u64) as usize;
                if let Some(&id) = live.keys().nth(pick) {
                    net.cancel_flow(id);
                    live.remove(&id);
                }
            }
            6 => {
                let l = links[rng.gen_range(n_links as u64) as usize];
                net.set_link_capacity(l, rng.gen_range_f64(10.0, 1000.0));
            }
            7..=8 => {
                if let Some((t, _)) = net.next_completion() {
                    for id in net.advance_to(t) {
                        live.remove(&id);
                    }
                }
            }
            _ => {
                // Partial advance; slow-tail flows may still finish.
                let t = net.now() + rng.gen_range_f64(0.0, 2.0);
                for id in net.advance_to(t) {
                    live.remove(&id);
                }
            }
        }
        if step % check_every == 0 {
            check_invariants(&mut net, &live).map_err(|e| format!("step {step}: {e}"))?;
        }
    }
    check_invariants(&mut net, &live).map_err(|e| format!("final: {e}"))
}

#[test]
fn prop_incremental_matches_oracle_under_churn() {
    forall(
        "incremental rates = oracle; max-min invariants hold",
        20,
        |rng: &mut Pcg64| rng.next_u64(),
        |&seed| churn_episode(seed, 100, 5),
    );
}

/// Replay the same op script under the `set_full_recompute` baseline
/// and the incremental path: the timelines must agree (same completion
/// count, same final clock, same delivered bytes) — the optimization
/// may not change WHAT the simulator computes, only how fast.
fn scripted_timeline(seed: u64, full: bool) -> (usize, f64, f64) {
    let mut rng = Pcg64::new(seed);
    let mut net = NetSim::new();
    net.set_full_recompute(full);
    let n_links = 3 + rng.gen_range(10) as usize;
    let links: Vec<LinkId> = (0..n_links)
        .map(|_| net.add_link(rng.gen_range_f64(50.0, 500.0)))
        .collect();
    let mut completed = 0usize;
    for _ in 0..60 {
        match rng.gen_range(4) {
            0..=1 => {
                let mut path: Vec<LinkId> = (0..1 + rng.gen_range(3))
                    .map(|_| links[rng.gen_range(n_links as u64) as usize])
                    .collect();
                path.sort_unstable();
                path.dedup();
                net.start_flow(
                    &path,
                    rng.gen_range_f64(1e3, 1e5),
                    rng.gen_range_f64(30.0, 800.0),
                );
            }
            2 => {
                let l = links[rng.gen_range(n_links as u64) as usize];
                net.set_link_capacity(l, rng.gen_range_f64(50.0, 500.0));
            }
            _ => {
                if let Some((t, _)) = net.next_completion() {
                    completed += net.advance_to(t).len();
                }
            }
        }
    }
    while let Some((t, _)) = net.next_completion() {
        completed += net.advance_to(t).len();
    }
    (completed, net.now(), net.delivered_bytes)
}

#[test]
fn prop_full_recompute_baseline_replays_identically() {
    forall(
        "full-recompute knob changes cost, not results",
        12,
        |rng: &mut Pcg64| rng.next_u64(),
        |&seed| {
            let (c_inc, t_inc, d_inc) = scripted_timeline(seed, false);
            let (c_full, t_full, d_full) = scripted_timeline(seed, true);
            if c_inc != c_full {
                return Err(format!("completions: incremental {c_inc} vs full {c_full}"));
            }
            if (t_inc - t_full).abs() > 1e-6 {
                return Err(format!("final clock: {t_inc} vs {t_full}"));
            }
            if (d_inc - d_full).abs() > 1e-3 {
                return Err(format!("delivered bytes: {d_inc} vs {d_full}"));
            }
            Ok(())
        },
    );
}

/// A cancellation storm over one fully shared component: every flow
/// crosses the trunk link, so each cancellation dirties the whole
/// component and the incremental path must re-fill it exactly.
#[test]
fn cancellation_storm_stays_on_the_oracle() {
    let mut rng = Pcg64::new(0x5EC7_0354);
    let mut net = NetSim::new();
    let trunk = net.add_link(400.0);
    let spokes: Vec<LinkId> = (0..8).map(|_| net.add_link(90.0)).collect();
    let mut live: Shadow = BTreeMap::new();
    for i in 0..40 {
        let path = vec![trunk, spokes[i % spokes.len()]];
        let cap = rng.gen_range_f64(10.0, 300.0);
        let id = net.start_flow(&path, 1e6, cap);
        live.insert(id, (path, cap));
    }
    check_invariants(&mut net, &live).unwrap();
    while !live.is_empty() {
        let pick = rng.gen_range(live.len() as u64) as usize;
        let id = *live.keys().nth(pick).expect("pick < len");
        net.cancel_flow(id);
        live.remove(&id);
        check_invariants(&mut net, &live).unwrap();
    }
    assert_eq!(net.active_flows(), 0);
}

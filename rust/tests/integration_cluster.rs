//! Integration tests: whole-system flows over the in-process cluster —
//! storage lifecycle, failure recovery, the full Terasort pipeline,
//! Sphere-vs-MapReduce cross-checks, and sim determinism.

use sector_sphere::cluster::Cluster;
use sector_sphere::config::SimConfig;
use sector_sphere::hadoop::{run_mapreduce, Hdfs, Kv, MapReduceJob};
use sector_sphere::mining::terasort::{generate_records, record_index, RECORD_BYTES};
use sector_sphere::mining::{run_pipeline, AngleScenario};
use sector_sphere::sector::{RecordIndex, ReplicationManager, SectorCloud};
use sector_sphere::sphere::simjob::simulate_sphere_row;
use sector_sphere::sphere::{run_job, CatOp, FaultPlan, JobSpec, Stream};
use sector_sphere::topology::Testbed;
use sector_sphere::util::bytes::GB;

const IP: &str = "10.0.0.77";

#[test]
fn storage_lifecycle_upload_replicate_fail_recover() {
    let cloud = SectorCloud::builder()
        .nodes(5)
        .replicas(3)
        .seed(101)
        .build()
        .unwrap();
    let ip = IP.parse().unwrap();
    for i in 0..12 {
        let data = vec![i as u8; 4096];
        let idx = RecordIndex::fixed(64, 4096);
        cloud
            .upload(ip, &format!("d{i:02}.dat"), &data, Some(&idx), None)
            .unwrap();
    }
    let mut mgr = ReplicationManager::new(86_400.0);
    mgr.check_all(&cloud);
    for name in cloud.list() {
        assert_eq!(cloud.stat(&name).unwrap().locations.len(), 3);
    }
    // Kill a slave; every file must still be downloadable and the next
    // check restores full replication on the survivors.
    cloud.fail_slave(2);
    for name in cloud.list() {
        let data = cloud.download(0, &name).unwrap();
        assert_eq!(data.len(), 4096);
    }
    mgr.check_all(&cloud);
    for name in cloud.list() {
        let meta = cloud.stat(&name).unwrap();
        assert_eq!(meta.locations.len(), 3);
        assert!(!meta.locations.contains(&2));
    }
}

#[test]
fn full_terasort_with_injected_spe_failures() {
    let cluster = Cluster::builder().nodes(4).seed(202).build().unwrap();
    let inputs = cluster.load_terasort_input(1000).unwrap();
    let stream = Stream::from_cloud(&cluster.cloud, &inputs).unwrap();
    // fail the first 5 segments once each
    let faults = FaultPlan {
        fail_first_attempt: (0..5).collect(),
    };
    let res = run_job(
        &cluster.cloud,
        &CatOp,
        &stream,
        &JobSpec {
            seg_min_bytes: 10_000,
            seg_max_bytes: 50_000,
            ..JobSpec::default()
        },
        &faults,
    )
    .unwrap();
    assert_eq!(res.to_client.len(), 4000, "all records despite failures");
    assert!(res.spe_failures >= 5);
}

#[test]
fn terasort_end_to_end_is_correct_and_deterministic() {
    let r1 = Cluster::builder()
        .nodes(3)
        .seed(303)
        .build()
        .unwrap()
        .terasort_e2e(800)
        .unwrap();
    let r2 = Cluster::builder()
        .nodes(3)
        .seed(303)
        .build()
        .unwrap()
        .terasort_e2e(800)
        .unwrap();
    assert!(r1.globally_sorted);
    assert_eq!(r1.records, 2400);
    assert_eq!(r1.split_index, r2.split_index, "deterministic split");
    assert!((r1.split_gain_bits - r2.split_gain_bits).abs() < 1e-12);
    assert_eq!(r1.bucket_files, r2.bucket_files);
}

/// Identity MapReduce terasort: map emits (key, payload), the engine's
/// per-partition sort does the work.
struct MrTerasort;

impl MapReduceJob for MrTerasort {
    fn map(&self, block: &[u8], emit: &mut dyn FnMut(Kv)) {
        for rec in block.chunks_exact(RECORD_BYTES) {
            emit((rec[..10].to_vec(), rec[10..].to_vec()));
        }
    }

    fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: &mut dyn FnMut(Kv)) {
        for v in values {
            emit((key.to_vec(), v.clone()));
        }
    }

    // Range partition so partition order == key order (like Terasort).
    fn partition(&self, key: &[u8], r: u32) -> u32 {
        sector_sphere::mining::terasort::key_bucket(key, r)
    }
}

#[test]
fn sphere_and_hadoop_baselines_agree_on_sorted_output() {
    // Same input through both engines; identical global key sequence.
    let records = 2000;
    let data = generate_records(records, 404);

    // Sphere path
    let cluster = Cluster::builder().nodes(2).seed(404).build().unwrap();
    let ip = IP.parse().unwrap();
    cluster
        .cloud
        .upload(ip, "in.dat", &data, Some(&record_index(&data)), Some(0))
        .unwrap();
    let report = {
        // reuse the e2e pipeline over a single pre-uploaded file
        let stream = Stream::from_cloud(&cluster.cloud, &["in.dat".into()]).unwrap();
        let part = run_job(
            &cluster.cloud,
            &sector_sphere::mining::terasort::TeraPartitionOp { buckets: 8 },
            &stream,
            &JobSpec {
                output_name: "x/bucket".into(),
                seg_min_bytes: 10_000,
                seg_max_bytes: 100_000,
                ..JobSpec::default()
            },
            &FaultPlan::default(),
        )
        .unwrap();
        let bstream = Stream::from_cloud(&cluster.cloud, &part.output_files).unwrap();
        run_job(
            &cluster.cloud,
            &sector_sphere::mining::terasort::TeraSortOp,
            &bstream,
            &JobSpec {
                output_name: "x/sorted".into(),
                seg_min_bytes: u64::MAX / 4,
                seg_max_bytes: u64::MAX / 2,
                ..JobSpec::default()
            },
            &FaultPlan::default(),
        )
        .unwrap()
    };
    let mut sphere_keys = Vec::new();
    let mut files = report.output_files.clone();
    files.sort();
    for f in files {
        let bytes = cluster.cloud.download(0, &f).unwrap();
        for rec in bytes.chunks_exact(RECORD_BYTES) {
            sphere_keys.push(rec[..10].to_vec());
        }
    }

    // Hadoop path
    let hdfs = Hdfs::new(64 * 100, 1, vec![0, 0], 404);
    hdfs.put(0, "in.dat", &data).unwrap();
    let (parts, stats) = run_mapreduce(&hdfs, &MrTerasort, &["in.dat".into()], 8).unwrap();
    let hadoop_keys: Vec<Vec<u8>> = parts
        .iter()
        .flatten()
        .map(|(k, _)| k.clone())
        .collect();
    assert_eq!(stats.shuffled_records, records as u64);

    assert_eq!(sphere_keys.len(), hadoop_keys.len());
    assert_eq!(sphere_keys, hadoop_keys, "both engines yield identical order");
}

#[test]
fn angle_pipeline_detects_and_is_seed_stable() {
    let run = |seed: u64| {
        let cloud = SectorCloud::builder().nodes(3).seed(seed).build().unwrap();
        let scenario = AngleScenario {
            sensors: 2,
            sources_per_sensor: 20,
            windows: 7,
            packets_per_source: 30,
            anomalies: vec![(4, 2, sector_sphere::mining::Regime::Scan)],
            seed,
            k: 4,
        };
        run_pipeline(&cloud, &scenario, None).unwrap()
    };
    let a = run(55);
    let b = run(55);
    assert_eq!(a.emergent_window_ids, b.emergent_window_ids);
    assert_eq!(a.analysis.deltas, b.analysis.deltas, "bit-identical reruns");
    assert!(a.emergent_window_ids.contains(&4));
}

#[test]
fn simulation_is_deterministic_and_monotone_in_data() {
    let t = Testbed::wan_testbed(4);
    let cfg = SimConfig::wan_default();
    let a = simulate_sphere_row(&t, &cfg, 10.0 * GB as f64);
    let b = simulate_sphere_row(&t, &cfg, 10.0 * GB as f64);
    assert_eq!(a.terasort_secs, b.terasort_secs, "same inputs, same timeline");
    let half = simulate_sphere_row(&t, &cfg, 5.0 * GB as f64);
    assert!(half.terasort_secs < a.terasort_secs);
    assert!(half.terasplit_secs < a.terasplit_secs);
}

#[test]
fn acl_blocks_everything_but_allowed_ranges() {
    let cloud = SectorCloud::builder()
        .nodes(2)
        .allow_writers(&["10.1.0.0/16"])
        .seed(7)
        .build()
        .unwrap();
    assert!(cloud
        .upload("10.1.2.3".parse().unwrap(), "ok.dat", b"x", None, Some(0))
        .is_ok());
    assert!(cloud
        .upload("10.2.2.3".parse().unwrap(), "no.dat", b"x", None, Some(0))
        .is_err());
    // public read of the successful upload still works
    assert_eq!(cloud.download(1, "ok.dat").unwrap(), b"x");
}

//! PJRT artifact correctness: the AOT-compiled JAX/Pallas executables
//! must agree with the host oracles.  Requires `make artifacts` and a
//! `--features pjrt` build (DESIGN.md §8); without the feature this
//! whole test target compiles to nothing.
//!
//! One PJRT client per process (the CPU plugin dislikes repeated
//! clients), so everything shares a lazily-loaded runtime.
#![cfg(feature = "pjrt")]

use sector_sphere::mining::emergent::{delta_host, score_host, EmergentCluster};
use sector_sphere::mining::kmeans::{fit, step_host};
use sector_sphere::mining::terasplit::best_split_host;
use sector_sphere::runtime::Runtime;
use sector_sphere::util::rng::Pcg64;

// The PJRT client is not Send/Sync (Rc internals), so all checks share
// one runtime inside a single #[test] running sequentially.
#[test]
fn pjrt_artifacts_match_host_oracles() {
    let rt = &Runtime::load(&Runtime::default_dir())
        .expect("run `make artifacts` before `cargo test`");
    kmeans_step_matches_host_oracle(rt);
    kmeans_fit_via_pjrt_matches_host_fit(rt);
    split_gain_matches_host_oracle(rt);
    split_gain_rejects_contract_violations(rt);
    delta_stat_matches_host(rt);
    score_matches_host(rt);
    runtime_reports_platform(rt);
}

fn kmeans_step_matches_host_oracle(rt: &Runtime) {
    let mut rng = Pcg64::new(1);
    for (n, d, k) in [(100usize, 4usize, 3usize), (4096, 16, 32), (513, 8, 5)] {
        let points: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian() as f32).collect();
        let centers: Vec<f32> = (0..k * d).map(|_| rng.next_gaussian() as f32).collect();
        let (sums, counts, inertia) = rt.kmeans_step(&points, &centers, d, k).unwrap();
        let (hs, hc, hi) = step_host(&points, &centers, d, k);
        assert_eq!(counts.len(), k);
        for (a, b) in sums.iter().zip(&hs) {
            assert!((a - b).abs() < 1e-2, "sums {a} vs {b} (n={n},d={d},k={k})");
        }
        for (a, b) in counts.iter().zip(&hc) {
            assert_eq!(*a, *b, "counts (n={n},d={d},k={k})");
        }
        assert!(
            (inertia - hi).abs() / hi.max(1.0) < 1e-3,
            "inertia {inertia} vs {hi}"
        );
    }
}

fn kmeans_fit_via_pjrt_matches_host_fit(rt: &Runtime) {
    let mut rng = Pcg64::new(2);
    // 3 separated blobs in 4-D
    let mut points = Vec::new();
    for blob in 0..3 {
        for _ in 0..60 {
            for j in 0..4 {
                let center = if j == blob { 10.0 } else { 0.0 };
                points.push(center + rng.next_gaussian() as f32 * 0.3);
            }
        }
    }
    let host = fit(&points, 4, 3, 25, 9, None).unwrap();
    let pjrt = fit(&points, 4, 3, 25, 9, Some(rt)).unwrap();
    assert_eq!(host.counts, pjrt.counts, "identical assignment history");
    for (a, b) in host.centers.iter().zip(&pjrt.centers) {
        assert!((a - b).abs() < 1e-3, "centers {a} vs {b}");
    }
    assert!((host.inertia - pjrt.inertia).abs() / host.inertia < 1e-3);
}

fn split_gain_matches_host_oracle(rt: &Runtime) {
    let mut rng = Pcg64::new(3);
    // sorted-ish labels with a planted boundary
    for n in [500usize, 5000, 32768] {
        let mut labels: Vec<u8> = (0..n)
            .map(|i| if i < n / 3 { rng.gen_range(2) as u8 } else { 2 + rng.gen_range(3) as u8 })
            .collect();
        labels.sort_unstable(); // fully feature-sorted stream
        let (gain, idx) = rt.split_gain(&labels).unwrap();
        let (hg, hi) = best_split_host(&labels, 8);
        assert!(
            (gain as f64 - hg).abs() < 1e-3,
            "n={n}: gain {gain} vs host {hg}"
        );
        // positions must agree up to gain ties
        if idx != hi {
            let labels_f: Vec<u8> = labels.clone();
            let (g2, _) = best_split_host(&labels_f[..=idx.max(1)], 8);
            assert!(g2.is_finite());
        }
    }
}

fn split_gain_rejects_contract_violations(rt: &Runtime) {
    assert!(rt.split_gain(&vec![0u8; 40_000]).is_err(), "too long");
    assert!(rt.split_gain(&[9u8; 10]).is_err(), "class out of range");
}

fn delta_stat_matches_host(rt: &Runtime) {
    let mut rng = Pcg64::new(4);
    for (d, ka, kb) in [(4usize, 3usize, 5usize), (16, 32, 32), (8, 1, 7)] {
        let a: Vec<f32> = (0..ka * d).map(|_| rng.next_gaussian() as f32).collect();
        let b: Vec<f32> = (0..kb * d).map(|_| rng.next_gaussian() as f32).collect();
        let got = rt.delta_stat(&a, &b, d, ka, kb).unwrap() as f64;
        let want = delta_host(&a, &b, d);
        assert!(
            (got - want).abs() / want.max(1e-9) < 1e-4,
            "delta {got} vs {want} (d={d},ka={ka},kb={kb})"
        );
    }
}

fn score_matches_host(rt: &Runtime) {
    let mut rng = Pcg64::new(5);
    let d = 16;
    let k = 3;
    let clusters: Vec<EmergentCluster> = (0..k)
        .map(|_| EmergentCluster {
            center: (0..d).map(|_| rng.next_gaussian() as f32).collect(),
            sigma2: 0.5 + rng.next_f32(),
            theta: 1.0 / k as f32,
            lambda: 1.0,
        })
        .collect();
    let xs: Vec<f32> = (0..100 * d).map(|_| rng.next_gaussian() as f32).collect();
    let centers: Vec<f32> = clusters.iter().flat_map(|c| c.center.clone()).collect();
    let sigma2: Vec<f32> = clusters.iter().map(|c| c.sigma2).collect();
    let theta: Vec<f32> = clusters.iter().map(|c| c.theta).collect();
    let lam: Vec<f32> = clusters.iter().map(|c| c.lambda).collect();
    let got = rt
        .score(&xs, &centers, &sigma2, &theta, &lam, d, k)
        .unwrap();
    assert_eq!(got.len(), 100);
    for (i, &g) in got.iter().enumerate() {
        let h = score_host(&xs[i * d..(i + 1) * d], &clusters);
        assert!((g - h).abs() < 1e-5, "x{i}: {g} vs {h}");
    }
}

fn runtime_reports_platform(rt: &Runtime) {
    assert!(rt.platform().to_lowercase().contains("cpu"));
    assert_eq!(rt.shapes.n_points, 4096);
}

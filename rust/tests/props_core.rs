//! Property-based tests over the coordinator invariants (DESIGN.md §7),
//! using the from-scratch `testkit` substrate.

use sector_sphere::mining::terasort::{generate_records, key_bucket, RECORD_BYTES};
use sector_sphere::routing::chord::ChordRing;
use sector_sphere::sector::RecordIndex;
use sector_sphere::sim::netsim::NetSim;
use sector_sphere::sphere::{segment_stream, Scheduler, Segment, Stream, StreamFile};
use sector_sphere::testkit::{forall, range_f64, range_u64, range_usize, vec_of, Gen};
use sector_sphere::util::rng::Pcg64;

// ---------------------------------------------------------------- netsim

#[test]
fn prop_netsim_capacity_and_pareto() {
    // Random link/flow topologies: (1) no link over capacity;
    // (2) every flow is bottlenecked by its cap or a saturated link;
    // (3) all bytes are eventually delivered.
    let gen = |rng: &mut Pcg64| {
        let n_links = 1 + rng.gen_range(6) as usize;
        let n_flows = 1 + rng.gen_range(12) as usize;
        let caps: Vec<f64> = (0..n_links).map(|_| 10.0 + rng.next_f64() * 990.0).collect();
        let flows: Vec<(Vec<usize>, f64, f64)> = (0..n_flows)
            .map(|_| {
                let path_len = 1 + rng.gen_range((n_links as u64).min(3)) as usize;
                let path = rng.sample_indices(n_links, path_len);
                (path, 10.0 + rng.next_f64() * 1000.0, 1.0 + rng.next_f64() * 500.0)
            })
            .collect();
        (caps, flows)
    };
    forall("netsim capacity/pareto/conservation", 60, gen, |(caps, flows)| {
        let mut net = NetSim::new();
        let links: Vec<_> = caps.iter().map(|&c| net.add_link(c)).collect();
        let mut total_bytes = 0.0;
        let ids: Vec<_> = flows
            .iter()
            .map(|(path, bytes, cap)| {
                total_bytes += bytes;
                let p: Vec<_> = path.iter().map(|&i| links[i]).collect();
                net.start_flow(&p, *bytes, *cap)
            })
            .collect();
        // capacity invariant
        for (i, l) in links.iter().enumerate() {
            let load = net.link_load(*l);
            if load > caps[i] * (1.0 + 1e-6) {
                return Err(format!("link {i} over capacity: {load} > {}", caps[i]));
            }
        }
        // pareto: every flow rate-capped or on a saturated link
        for (fid, (path, _, cap)) in ids.iter().zip(flows) {
            let rate = net.flow_rate(*fid);
            let capped = rate >= cap * (1.0 - 1e-6);
            let saturated = path.iter().any(|&i| {
                net.link_load(links[i]) >= caps[i] * (1.0 - 1e-6)
            });
            if !capped && !saturated {
                return Err(format!("flow {fid:?} at {rate} neither capped ({cap}) nor bottlenecked"));
            }
        }
        // conservation
        net.run_to_idle();
        if (net.delivered_bytes - total_bytes).abs() > 1e-3 * total_bytes.max(1.0) {
            return Err(format!(
                "delivered {} of {total_bytes}",
                net.delivered_bytes
            ));
        }
        Ok(())
    });
}

// ------------------------------------------------------------ chord ring

#[test]
fn prop_chord_lookup_equals_naive_successor() {
    let gen = |rng: &mut Pcg64| {
        let n = 2 + rng.gen_range(60) as usize;
        let mut ids: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        ids.sort_unstable();
        ids.dedup();
        let keys: Vec<u64> = (0..20).map(|_| rng.next_u64()).collect();
        (ids, keys)
    };
    forall("chord lookup == naive successor", 80, gen, |(ids, keys)| {
        if ids.len() < 2 {
            return Ok(());
        }
        let ring = ChordRing::build(ids);
        for &k in keys {
            let (owner, hops) = ring.lookup(ids[0], k).ok_or("lookup failed")?;
            let expect = ring.naive_successor(k).unwrap();
            if owner != expect {
                return Err(format!("key {k}: owner {owner} != successor {expect}"));
            }
            let bound = 2 * (ids.len() as f64).log2().ceil() as u32 + 4;
            if hops > bound {
                return Err(format!("{hops} hops > O(log n) bound {bound}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------- segmentation

#[test]
fn prop_segmentation_covers_exactly_once_within_bounds() {
    let gen = |rng: &mut Pcg64| {
        let files = 1 + rng.gen_range(8) as usize;
        let sizes: Vec<(u64, u64)> = (0..files)
            .map(|_| {
                let recs = 1 + rng.gen_range(400);
                let rec_size = 10 + rng.gen_range(190);
                (recs * rec_size, recs)
            })
            .collect();
        let n_spes = 1 + rng.gen_range(16) as usize;
        let smin = 100 + rng.gen_range(2000);
        let smax = smin + 1 + rng.gen_range(50_000);
        (sizes, (n_spes as u64, smin, smax))
    };
    forall(
        "segmentation covers stream exactly once",
        80,
        gen,
        |(sizes, (n_spes, smin, smax))| {
            let stream = Stream {
                files: sizes
                    .iter()
                    .enumerate()
                    .map(|(i, &(size, recs))| StreamFile {
                        name: format!("f{i}.dat"),
                        size_bytes: size,
                        n_records: recs,
                        locations: vec![(i % 4) as u32],
                    })
                    .collect(),
            };
            let segs = segment_stream(&stream, *n_spes as usize, *smin, *smax, |name| {
                stream
                    .files
                    .iter()
                    .find(|f| f.name == name)
                    .map(|f| RecordIndex::fixed(f.size_bytes / f.n_records, f.size_bytes))
            });
            // exactly-once coverage, contiguity per file
            for f in &stream.files {
                let mut next = 0u64;
                let mut bytes = 0u64;
                for s in segs.iter().filter(|s| s.file == f.name) {
                    if s.first_record != next {
                        return Err(format!("{}: gap at record {next}", f.name));
                    }
                    next += s.n_records;
                    bytes += s.bytes;
                }
                if next != f.n_records || bytes != f.size_bytes {
                    return Err(format!(
                        "{}: covered {next}/{} records {bytes}/{} bytes",
                        f.name, f.n_records, f.size_bytes
                    ));
                }
            }
            // bounds: every segment <= smax + one record slack; >= smin
            // except per-file tails (and single-record oversize is legal)
            for s in &segs {
                let rec = s.bytes / s.n_records.max(1);
                if s.bytes > smax + rec {
                    return Err(format!("segment {} bytes {} > smax {smax}", s.id, s.bytes));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------- scheduler

#[test]
fn prop_scheduler_never_idles_spe_and_drains() {
    let gen = |rng: &mut Pcg64| {
        let n_segs = 1 + rng.gen_range(60) as usize;
        let nodes = 1 + rng.gen_range(8) as u32;
        let segs: Vec<(u64, u64)> = (0..n_segs)
            .map(|_| (rng.gen_range(6), rng.gen_range(nodes as u64)))
            .collect();
        (segs, nodes as u64)
    };
    forall("scheduler drains, never refuses an idle SPE", 80, gen, |(segs, nodes)| {
        let segments: Vec<Segment> = segs
            .iter()
            .enumerate()
            .map(|(id, &(file, loc))| Segment {
                id,
                file: format!("f{file}"),
                first_record: 0,
                n_records: 10,
                bytes: 1000,
                locations: vec![loc as u32],
                whole_file: false,
            })
            .collect();
        let total = segments.len();
        let mut sched = Scheduler::new(segments, true);
        let mut done = 0usize;
        let mut i = 0u64;
        while done < total {
            let node = (i % nodes) as u32;
            i += 1;
            // an idle SPE with pending work must get a segment
            match sched.assign(node) {
                Some(s) => {
                    sched.complete(&s);
                    done += 1;
                }
                None => {
                    if sched.pending_count() > 0 {
                        return Err(format!(
                            "idle SPE on node {node} refused with {} pending",
                            sched.pending_count()
                        ));
                    }
                    break;
                }
            }
        }
        if done != total {
            return Err(format!("drained {done}/{total}"));
        }
        Ok(())
    });
}

// ------------------------------------------------------------- terasort

#[test]
fn prop_bucket_partition_preserves_key_order_and_mass() {
    forall(
        "bucket partition is order-preserving and lossless",
        30,
        |rng: &mut Pcg64| (rng.gen_range(5000) + 10, 1 + rng.gen_range(255)),
        |&(n_records, buckets)| {
            let data = generate_records(n_records as usize, n_records ^ buckets);
            let buckets = buckets as u32;
            let mut per_bucket: Vec<Vec<&[u8]>> = vec![Vec::new(); buckets as usize];
            for rec in data.chunks_exact(RECORD_BYTES) {
                per_bucket[key_bucket(&rec[..10], buckets) as usize].push(rec);
            }
            let total: usize = per_bucket.iter().map(Vec::len).sum();
            if total != n_records as usize {
                return Err(format!("lost records: {total}/{n_records}"));
            }
            // cross-bucket order: max key of bucket i <= min key of bucket j>i
            let mut last_max: Option<&[u8]> = None;
            for b in &per_bucket {
                if b.is_empty() {
                    continue;
                }
                let min = b.iter().map(|r| &r[..10]).min().unwrap();
                let max = b.iter().map(|r| &r[..10]).max().unwrap();
                if let Some(prev) = last_max {
                    if prev > min {
                        return Err("bucket ranges overlap".into());
                    }
                }
                last_max = Some(max);
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ rng extras

#[test]
fn prop_gen_range_uniformity_rough() {
    forall(
        "gen_range hits all residues",
        20,
        |rng: &mut Pcg64| (rng.next_u64(), 2 + rng.gen_range(14)),
        |&(seed, bound)| {
            let mut rng = Pcg64::new(seed);
            let mut seen = vec![0u32; bound as usize];
            for _ in 0..(bound * 300) {
                seen[rng.gen_range(bound) as usize] += 1;
            }
            let expect = 300.0;
            for (i, &c) in seen.iter().enumerate() {
                if (c as f64) < expect * 0.5 || (c as f64) > expect * 1.6 {
                    return Err(format!("residue {i}: {c} of expected ~{expect}"));
                }
            }
            Ok(())
        },
    );
}

// A couple of generator-combinator smoke checks (testkit's own API).
#[test]
fn testkit_combinators_produce_in_range() {
    let mut rng = Pcg64::new(1);
    for _ in 0..100 {
        let v = range_u64(5, 10).generate(&mut rng);
        assert!((5..10).contains(&v));
        let f = range_f64(-1.0, 1.0).generate(&mut rng);
        assert!((-1.0..1.0).contains(&f));
        let n = range_usize(0, 3).generate(&mut rng);
        assert!(n < 3);
        let xs = vec_of(range_u64(0, 4), 2, 5).generate(&mut rng);
        assert!((2..=5).contains(&xs.len()));
    }
}

//! Scheduler invariants under crash/speculation interleavings
//! (DESIGN.md §11), via the from-scratch `testkit::forall` harness:
//!
//!   * every segment completes exactly once, no matter how crashes and
//!     speculative backups interleave (first-finisher-wins);
//!   * attempts never exceed `max_attempts`, and an exhausted segment
//!     is recorded — an explicit job failure, never a silent drop;
//!   * rule 3 (same-file exclusion) is only waived when the SPE would
//!     otherwise idle on something worse (rank minimality).

use std::collections::HashMap;

use sector_sphere::sphere::{Scheduler, Segment};
use sector_sphere::testkit::forall;
use sector_sphere::util::rng::Pcg64;

fn make_seg(id: usize, file: usize, locations: Vec<u32>) -> Segment {
    Segment {
        id,
        file: format!("f{file:02}"),
        first_record: 0,
        n_records: 1,
        bytes: 1000,
        locations,
        whole_file: false,
    }
}

/// Randomized driver: assign / complete / crash / speculate in any
/// order, mirroring what the colocation engine does, and check the
/// exactly-once + attempt-budget invariants at every step.
fn drive_chaos(seed: u64, n_segs: usize, n_nodes: usize) -> Result<(), String> {
    let mut rng = Pcg64::new(seed);
    let files = (n_segs / 3).max(1);
    let segs: Vec<Segment> = (0..n_segs)
        .map(|i| {
            let a = (i % n_nodes) as u32;
            let b = ((i + 1) % n_nodes) as u32;
            let locs = if a == b { vec![a] } else { vec![a, b] };
            make_seg(i, i % files, locs)
        })
        .collect();
    let mut sched = Scheduler::new(segs, true);
    sched.max_attempts = 3;
    // (segment, executing node) per live attempt.
    let mut inflight: Vec<(Segment, u32)> = Vec::new();
    let mut completions: HashMap<usize, u32> = HashMap::new();
    let mut aborted = false;
    for _step in 0..20_000 {
        if aborted || (sched.is_drained() && inflight.is_empty()) {
            break;
        }
        match rng.gen_range(10) {
            // Bias toward assign + complete so every run drains.
            0..=3 => {
                let node = rng.gen_range(n_nodes as u64) as u32;
                if let Some(s) = sched.assign(node) {
                    if sched.attempts_of(s.id) > sched.max_attempts {
                        return Err(format!("segment {} over budget at assign", s.id));
                    }
                    inflight.push((s, node));
                }
            }
            4..=7 => {
                // Complete a random attempt; its siblings lose.
                if inflight.is_empty() {
                    continue;
                }
                let k = rng.gen_range(inflight.len() as u64) as usize;
                let (s, _) = inflight.remove(k);
                let first = sched.complete(&s);
                let mut i = 0;
                while i < inflight.len() {
                    if inflight[i].0.id == s.id {
                        let (loser, _) = inflight.remove(i);
                        sched.cancel_attempt(&loser);
                    } else {
                        i += 1;
                    }
                }
                if !first {
                    return Err(format!("segment {} completed twice", s.id));
                }
                *completions.entry(s.id).or_insert(0) += 1;
            }
            8 => {
                // Crash the attempt's node: re-queue unless a sibling
                // (speculative backup) survives elsewhere.
                if inflight.is_empty() {
                    continue;
                }
                let k = rng.gen_range(inflight.len() as u64) as usize;
                let (s, _) = inflight.remove(k);
                if inflight.iter().any(|(o, _)| o.id == s.id) {
                    sched.cancel_attempt(&s);
                } else {
                    let id = s.id;
                    let attempts = sched.attempts_of(id);
                    if !sched.fail(s) {
                        if attempts < sched.max_attempts {
                            return Err(format!(
                                "segment {id} aborted early at {attempts} attempts"
                            ));
                        }
                        if !sched.exhausted().contains(&id) {
                            return Err(format!(
                                "segment {id}: abort not recorded in exhausted()"
                            ));
                        }
                        aborted = true;
                    }
                }
            }
            _ => {
                // Speculate a backup for a random single-attempt segment.
                if inflight.is_empty() {
                    continue;
                }
                let k = rng.gen_range(inflight.len() as u64) as usize;
                let (s, node) = inflight[k].clone();
                if inflight.iter().filter(|(o, _)| o.id == s.id).count() > 1 {
                    continue;
                }
                let backup = s
                    .locations
                    .iter()
                    .copied()
                    .find(|&l| l != node)
                    .unwrap_or((node + 1) % n_nodes as u32);
                if sched.speculate(&s, backup) {
                    if sched.attempts_of(s.id) > sched.max_attempts {
                        return Err(format!("segment {} over budget at speculate", s.id));
                    }
                    inflight.push((s, backup));
                }
            }
        }
    }
    if !aborted {
        if !(sched.is_drained() && inflight.is_empty()) {
            return Err("driver did not drain in 20k steps".into());
        }
        for id in 0..n_segs {
            let got = completions.get(&id).copied().unwrap_or(0);
            if got != 1 {
                return Err(format!("segment {id} completed {got} times (want 1)"));
            }
        }
    }
    for id in 0..n_segs {
        if sched.attempts_of(id) > sched.max_attempts {
            return Err(format!("segment {id}: attempts exceed max_attempts"));
        }
    }
    Ok(())
}

#[test]
fn prop_exactly_once_and_budget_under_chaos() {
    forall(
        "segments complete exactly once; attempts never exceed the budget",
        120,
        |rng: &mut Pcg64| {
            (
                rng.next_u64(),
                1 + rng.gen_range(20) as usize,
                1 + rng.gen_range(6) as usize,
            )
        },
        |&(seed, n_segs, n_nodes)| drive_chaos(seed, n_segs.max(1), n_nodes.max(1)),
    );
}

fn rank(s: &Segment, node: u32, busy: &HashMap<String, usize>) -> u32 {
    let local = s.locations.contains(&node);
    let clear = !busy.contains_key(&s.file);
    match (local, clear) {
        (true, true) => 0,
        (true, false) => 1,
        (false, true) => 2,
        (false, false) => 3,
    }
}

/// Rule-3 formalization: a segment whose file is in flight (rank 1/3)
/// is assigned only when nothing of better rank was pending — i.e. the
/// same-file exclusion is waived exactly when the SPE would otherwise
/// idle on that preference level.
fn drive_rule3(seed: u64, n_segs: usize, n_nodes: usize) -> Result<(), String> {
    let mut rng = Pcg64::new(seed);
    let n_files = 1 + n_segs / 2;
    let segs: Vec<Segment> = (0..n_segs)
        .map(|i| {
            let file = rng.gen_range(n_files as u64) as usize;
            let loc = rng.gen_range(n_nodes as u64) as u32;
            make_seg(i, file, vec![loc])
        })
        .collect();
    let mut pending_mirror: Vec<Segment> = segs.clone();
    let mut busy: HashMap<String, usize> = HashMap::new();
    let mut inflight: Vec<Segment> = Vec::new();
    let mut sched = Scheduler::new(segs, true);
    for _ in 0..(4 * n_segs) {
        if sched.is_drained() {
            break;
        }
        let node = rng.gen_range(n_nodes as u64) as u32;
        let Some(got) = sched.assign(node) else {
            return Err("plain assign declined with segments pending".into());
        };
        let got_rank = rank(&got, node, &busy);
        let best = pending_mirror
            .iter()
            .map(|s| rank(s, node, &busy))
            .min()
            .expect("mirror tracks pending");
        if got_rank != best {
            return Err(format!(
                "segment {} assigned at rank {got_rank}, but rank {best} was \
                 pending (file {:?} busy: {}) — rule 3 waived while a better \
                 choice existed",
                got.id,
                got.file,
                busy.contains_key(&got.file),
            ));
        }
        pending_mirror.retain(|s| s.id != got.id);
        *busy.entry(got.file.clone()).or_insert(0) += 1;
        inflight.push(got);
        // Randomly complete an in-flight segment to release its file.
        if !inflight.is_empty() && rng.next_f64() < 0.5 {
            let k = rng.gen_range(inflight.len() as u64) as usize;
            let s = inflight.remove(k);
            sched.complete(&s);
            if let Some(n) = busy.get_mut(&s.file) {
                *n -= 1;
                if *n == 0 {
                    busy.remove(&s.file);
                }
            }
        }
    }
    Ok(())
}

#[test]
fn prop_rule3_waived_only_when_spe_would_idle() {
    forall(
        "same-file exclusion waived only when the SPE would idle",
        150,
        |rng: &mut Pcg64| {
            (
                rng.next_u64(),
                1 + rng.gen_range(16) as usize,
                1 + rng.gen_range(4) as usize,
            )
        },
        |&(seed, n_segs, n_nodes)| drive_rule3(seed, n_segs.max(1), n_nodes.max(1)),
    );
}

//! Trace determinism + artifact schema suite (DESIGN.md §15).
//!
//! Every preset runs twice with tracing enabled: the JSONL event log
//! and the Chrome `trace_event` file must come out byte-identical, and
//! the report's timeline digest must match across runs AND match the
//! digest embedded in the artifact's meta header.  The service presets
//! run as the same scaled-down clones the golden suite uses (debug
//! builds); batch presets run at full size.
//!
//! Also pinned here: enabling `--trace` never moves the digest (the
//! recorder digests the same emissions whether or not it captures),
//! a different seed moves it, and the ring buffer bounds retention on
//! the 128-node preset.

use std::fs;
use std::path::PathBuf;

use sector_sphere::scenario::trace::validate_jsonl;
use sector_sphere::scenario::{run_scenario, FaultSpec, ScenarioSpec, TraceSpec};
use sector_sphere::service::ArrivalProcess;
use sector_sphere::util::bytes::GB;

/// Per-(test, run) artifact paths under the system temp dir; the tag
/// keeps concurrently-running tests from clobbering each other.
fn trace_paths(tag: &str) -> (PathBuf, PathBuf) {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let chrome = dir.join(format!("sector-sphere-trace-{pid}-{tag}.json"));
    let jsonl = dir.join(format!("sector-sphere-trace-{pid}-{tag}.jsonl"));
    (chrome, jsonl)
}

/// Run `spec` with tracing to a temp path; return (digest, jsonl
/// bytes, chrome bytes) and clean the files up.
fn run_traced(mut spec: ScenarioSpec, tag: &str) -> (String, String, String) {
    let (chrome_path, jsonl_path) = trace_paths(tag);
    spec.trace = Some(TraceSpec {
        path: Some(chrome_path.to_string_lossy().into_owned()),
        ..TraceSpec::default()
    });
    let r = run_scenario(&spec).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    let jsonl = fs::read_to_string(&jsonl_path).expect("jsonl artifact written");
    let chrome = fs::read_to_string(&chrome_path).expect("chrome artifact written");
    let _ = fs::remove_file(&jsonl_path);
    let _ = fs::remove_file(&chrome_path);
    (r.trace_digest, jsonl, chrome)
}

fn assert_trace_deterministic(spec: &ScenarioSpec) {
    let (d1, j1, c1) = run_traced(spec.clone(), &format!("{}-a", spec.name));
    let (d2, j2, c2) = run_traced(spec.clone(), &format!("{}-b", spec.name));
    assert_eq!(d1, d2, "{}: digest must not move across reruns", spec.name);
    assert_eq!(j1, j2, "{}: JSONL must be byte-identical", spec.name);
    assert_eq!(c1, c2, "{}: Chrome trace must be byte-identical", spec.name);
    let lines = validate_jsonl(&j1).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    assert!(lines > 0, "{}: trace captured no events", spec.name);
    assert!(
        j1.lines().next().unwrap().contains(&format!("\"digest\":\"{d1}\"")),
        "{}: meta header digest must match the report's",
        spec.name
    );
    assert!(
        c1.starts_with("{\"traceEvents\":[") && c1.trim_end().ends_with("]}"),
        "{}: Chrome artifact must be a trace_event JSON object",
        spec.name
    );
}

/// The golden suite's scaled-down service clones (full size is a
/// release-build bench concern, not a debug-build test one).
fn traffic_scaled() -> ScenarioSpec {
    let mut spec = ScenarioSpec::traffic_scale128();
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 4_000;
    t.clients = 20_000;
    t.arrival = ArrivalProcess::Open { rps: 2_000.0 };
    spec
}

fn colocate_scaled() -> ScenarioSpec {
    let mut spec = ScenarioSpec::colocate_scale128();
    spec.workload.as_mut().expect("workload preset").bytes_per_node = 0.25 * GB as f64;
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 3_000;
    t.clients = 20_000;
    t.arrival = ArrivalProcess::Open { rps: 1_500.0 };
    spec
}

/// Debug-scaled clone of the elastic 512-node preset: same topology,
/// tenants, shape and watermark policy; fewer requests, and the crash
/// pulled inside the shortened horizon so re-replication races the
/// fault plan here too.
fn elastic_scaled() -> ScenarioSpec {
    let mut spec = ScenarioSpec::traffic_elastic512();
    let t = spec.traffic.as_mut().expect("traffic preset");
    t.requests = 4_000;
    t.clients = 40_000;
    t.arrival = ArrivalProcess::Open { rps: 2_000.0 };
    for f in &mut spec.faults {
        if let FaultSpec::SlaveCrash { at_secs, .. } = f {
            *at_secs = 1.0;
        }
    }
    spec
}

#[test]
fn traced_paper_wan6_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::paper_wan6());
}

#[test]
fn traced_paper_lan8_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::paper_lan8());
}

#[test]
fn traced_scale128_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::scale128());
}

#[test]
fn traced_traffic_is_deterministic() {
    assert_trace_deterministic(&traffic_scaled());
}

#[test]
fn traced_colocate_is_deterministic() {
    assert_trace_deterministic(&colocate_scaled());
}

#[test]
fn traced_compare_wan4_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::compare_wan4());
}

#[test]
fn traced_compare_scale128_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::compare_scale128());
}

#[test]
fn traced_angle_wan4_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::angle_wan4());
}

#[test]
fn traced_angle_scale128_is_deterministic() {
    assert_trace_deterministic(&ScenarioSpec::angle_scale128());
}

#[test]
fn traced_elastic_is_deterministic() {
    // Satellite contract: the debug-scaled elastic preset's JSONL and
    // Chrome artifacts are byte-identical across reruns and the
    // embedded digest matches the report's — with the scaler ticking,
    // re-replication flows in flight and a mid-run crash.
    assert_trace_deterministic(&elastic_scaled());
}

#[test]
fn elastic_digest_moves_with_the_seed() {
    let a = run_scenario(&elastic_scaled()).unwrap();
    let mut spec = elastic_scaled();
    spec.cfg.seed ^= 0x5eed_5eed;
    let b = run_scenario(&spec).unwrap();
    assert_ne!(
        a.trace_digest, b.trace_digest,
        "a different seed must reshuffle the elastic timeline"
    );
}

#[test]
fn traced_churn_wan32_is_deterministic() {
    // The churn preset's leave/join instants are part of the digested
    // timeline: byte-identical reruns, and the instants visible in the
    // JSONL artifact.
    let spec = ScenarioSpec::churn_wan32();
    assert_trace_deterministic(&spec);
    let (_, jsonl, _) = run_traced(spec, "churn-instants");
    assert!(
        jsonl.contains("\"kind\":\"fault\",\"name\":\"leave\""),
        "churn departures must be traced as fault instants"
    );
    assert!(
        jsonl.contains("\"kind\":\"fault\",\"name\":\"join\""),
        "churn re-joins must be traced as fault instants"
    );
}

#[test]
fn traced_weather_compare16_is_deterministic() {
    let spec = ScenarioSpec::weather_compare16();
    assert_trace_deterministic(&spec);
    let (_, jsonl, _) = run_traced(spec, "weather-instants");
    assert!(
        jsonl.contains("\"name\":\"weather site"),
        "weather trace points must be traced as fault instants"
    );
}

#[test]
fn churn_digest_moves_with_the_churn_seed() {
    let a = run_scenario(&ScenarioSpec::churn_wan32()).unwrap();
    let mut spec = ScenarioSpec::churn_wan32();
    spec.churn.as_mut().expect("churn preset").seed ^= 0x5eed_5eed;
    let b = run_scenario(&spec).unwrap();
    assert_ne!(
        a.trace_digest, b.trace_digest,
        "a different churn seed must move the departure instants"
    );
}

#[test]
fn weather_digest_moves_with_the_weather_seed() {
    let a = run_scenario(&ScenarioSpec::weather_compare16()).unwrap();
    let mut spec = ScenarioSpec::weather_compare16();
    spec.weather.as_mut().expect("weather preset").seed ^= 0x5eed_5eed;
    let b = run_scenario(&spec).unwrap();
    assert_ne!(
        a.trace_digest, b.trace_digest,
        "a different weather seed must redraw the capacity trace"
    );
}

#[test]
fn enabling_trace_never_moves_the_digest() {
    // The digest is computed on every run — artifact capture and the
    // gauge sampler must not change what gets folded into it.
    let spec = ScenarioSpec::compare_wan4();
    let plain = run_scenario(&spec).unwrap();
    let (traced_digest, _, _) = run_traced(spec, "digest-invariance");
    assert_eq!(plain.trace_digest, traced_digest);
}

#[test]
fn digest_moves_with_the_seed() {
    let a = run_scenario(&traffic_scaled()).unwrap();
    let mut spec = traffic_scaled();
    spec.cfg.seed ^= 0x5eed_5eed;
    let b = run_scenario(&spec).unwrap();
    assert_ne!(
        a.trace_digest, b.trace_digest,
        "a different seed produces a different timeline"
    );
}

/// Pull an integer field out of the JSONL meta header.
fn meta_u64(meta: &str, key: &str) -> u64 {
    let tag = format!("\"{key}\":");
    let start = meta.find(&tag).unwrap_or_else(|| panic!("meta lacks {key}")) + tag.len();
    meta[start..]
        .split(&[',', '}'][..])
        .next()
        .unwrap()
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("meta {key} not an integer"))
}

#[test]
fn ring_buffer_bounds_capture_on_scale128() {
    let mut spec = ScenarioSpec::scale128();
    let (chrome_path, jsonl_path) = trace_paths("ring");
    spec.trace = Some(TraceSpec {
        path: Some(chrome_path.to_string_lossy().into_owned()),
        sample_secs: 0.0,
        max_events: 512,
    });
    let r = run_scenario(&spec).unwrap();
    let jsonl = fs::read_to_string(&jsonl_path).expect("jsonl written");
    let _ = fs::remove_file(&jsonl_path);
    let _ = fs::remove_file(&chrome_path);
    let meta = jsonl.lines().next().expect("meta header");
    let seen = meta_u64(meta, "events_seen");
    let captured = meta_u64(meta, "captured");
    let dropped = meta_u64(meta, "dropped");
    let open_at_end = meta_u64(meta, "open_at_end");
    assert!(
        seen > 512,
        "the 128-node preset must overflow a 512-event ring (seen {seen})"
    );
    assert!(dropped > 0, "overflow must be visible as dropped events");
    assert!(
        captured <= 512 + open_at_end,
        "retention bounded by max_events (+ synthesized tail): {captured}"
    );
    let lines = validate_jsonl(&jsonl).expect("truncated artifact still validates");
    assert_eq!(lines as u64, captured);
    // The digest still covers the FULL timeline, not just the ring.
    let full = run_scenario(&ScenarioSpec::scale128()).unwrap();
    assert_eq!(r.trace_digest, full.trace_digest);
}

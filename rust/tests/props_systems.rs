//! Second property suite: transport models, replication, shuffle
//! conservation, GMP delivery under loss/reorder, and Terasplit
//! oracle agreement.

use sector_sphere::config::TransportKind;
use sector_sphere::mining::terasplit::{aggregate_labels, best_split_host};
use sector_sphere::sector::{RecordIndex, ReplicationManager, SectorCloud};
use sector_sphere::sphere::{bucket_home, ShuffleWriter};
use sector_sphere::testkit::forall;
use sector_sphere::transport::gmp::GmpEndpoint;
use sector_sphere::transport::{TcpModel, TransportModels, UdtModel};
use sector_sphere::util::rng::Pcg64;

#[test]
fn prop_transport_caps_bounded_and_monotone() {
    forall(
        "transport caps within [0, link]; tcp monotone in rtt",
        100,
        |rng: &mut Pcg64| {
            (
                1e6 + rng.next_f64() * 2e9,        // link bytes/s
                1e-5 + rng.next_f64() * 0.2,       // rtt secs
                rng.next_f64() * 0.19 + 0.001,     // extra rtt
            )
        },
        |&(link, rtt, extra)| {
            let m = TransportModels::default();
            for kind in [TransportKind::Udt, TransportKind::Tcp] {
                let cap = m.rate_cap_for(kind, link, rtt);
                if cap <= 0.0 || cap > link * (1.0 + 1e-9) {
                    return Err(format!("{kind:?} cap {cap} outside (0, {link}]"));
                }
            }
            let t1 = m.rate_cap_for(TransportKind::Tcp, link, rtt);
            let t2 = m.rate_cap_for(TransportKind::Tcp, link, rtt + extra);
            if t2 > t1 * (1.0 + 1e-9) {
                return Err(format!("tcp cap grew with rtt: {t1} -> {t2}"));
            }
            // UDT stays within 15% across the same rtt change (its
            // control loop is SYN-clocked, only the loss model drifts)
            let u1 = m.rate_cap_for(TransportKind::Udt, link, rtt);
            let u2 = m.rate_cap_for(TransportKind::Udt, link, rtt + extra);
            if u2 > u1 {
                return Err("udt cap grew with rtt".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_udt_converges_under_any_seed() {
    forall(
        "UdtCc converges to >=85% of any link",
        25,
        |rng: &mut Pcg64| (rng.next_u64(), 1e8 + rng.next_f64() * 2e9),
        |&(seed, link)| {
            let mut cc = sector_sphere::transport::UdtCc::new(link);
            let mut rng = Pcg64::new(seed);
            cc.run(30.0, 0.0, &mut rng);
            let frac = cc.rate_bps() / link;
            if frac < 0.85 {
                return Err(format!("converged to {frac:.2} of link"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_setup_secs_cached_never_slower() {
    forall(
        "cached connections never pay more setup",
        100,
        |rng: &mut Pcg64| rng.next_f64() * 0.2,
        |&rtt| {
            let udt = UdtModel::default();
            let tcp = TcpModel::default();
            if udt.setup_secs(rtt, true) > udt.setup_secs(rtt, false) + 1e-12 {
                return Err("udt cached slower".into());
            }
            if tcp.setup_secs(rtt, true) > tcp.setup_secs(rtt, false) + 1e-12 {
                return Err("tcp cached slower".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_replication_reaches_target_for_any_cloud_shape() {
    forall(
        "replication converges to min(target, nodes)",
        25,
        |rng: &mut Pcg64| {
            (
                2 + rng.gen_range(7),          // nodes
                1 + rng.gen_range(5),          // target
                1 + rng.gen_range(20) as usize, // files
            )
        },
        |&(nodes, target, files)| {
            let cloud = SectorCloud::builder()
                .nodes(nodes as usize)
                .replicas(target as usize)
                .seed(nodes * 31 + target)
                .build()
                .map_err(|e| e.to_string())?;
            let ip = "10.0.0.1".parse().unwrap();
            for i in 0..files {
                cloud
                    .upload(ip, &format!("f{i}.dat"), &[1, 2, 3], None, None)
                    .map_err(|e| e.to_string())?;
            }
            let mut mgr = ReplicationManager::new(1.0);
            mgr.check_all(&cloud);
            let expect = (target as usize).min(nodes as usize);
            for name in cloud.list() {
                let locs = cloud.stat(&name).unwrap().locations;
                if locs.len() != expect {
                    return Err(format!("{name}: {} replicas, want {expect}", locs.len()));
                }
                let mut dedup = locs.clone();
                dedup.sort_unstable();
                dedup.dedup();
                if dedup.len() != locs.len() {
                    return Err(format!("{name}: duplicate locations {locs:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shuffle_writer_conserves_records_and_routes_home() {
    forall(
        "shuffle conserves records; buckets land on home nodes",
        30,
        |rng: &mut Pcg64| {
            let nodes = 1 + rng.gen_range(8) as usize;
            let buckets = 1 + rng.gen_range(32);
            let recs: Vec<(u64, u64)> = (0..rng.gen_range(200))
                .map(|_| (rng.gen_range(buckets), 1 + rng.gen_range(40)))
                .collect();
            (nodes, buckets, recs)
        },
        |(nodes, buckets, recs)| {
            let cloud = SectorCloud::builder()
                .nodes(*nodes)
                .seed(42)
                .build()
                .map_err(|e| e.to_string())?;
            let mut w = ShuffleWriter::new("out", *buckets as u32);
            for (b, len) in recs {
                w.add(*b as u32, &vec![7u8; *len as usize])
                    .map_err(|e| e.to_string())?;
            }
            let files = w.finalize(&cloud).map_err(|e| e.to_string())?;
            let total: u64 = files
                .iter()
                .map(|f| cloud.stat(f).unwrap().n_records)
                .sum();
            if total != recs.len() as u64 {
                return Err(format!("{total} records out of {}", recs.len()));
            }
            for f in &files {
                let meta = cloud.stat(f).unwrap();
                // name is "out.NNNNN.dat"
                let bucket: u32 = f[4..9].parse().unwrap();
                let home = bucket_home(bucket, *nodes);
                if meta.locations != vec![home] {
                    return Err(format!("{f} on {:?}, home {home}", meta.locations));
                }
                // index must parse and cover the file
                let idx = cloud.load_index(f).ok_or("missing idx")?;
                if idx.total_bytes() != meta.size_bytes {
                    return Err(format!("{f}: idx covers {} of {}", idx.total_bytes(), meta.size_bytes));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gmp_delivers_in_order_under_loss_and_reorder() {
    forall(
        "GMP: lossy, reordering network still yields FIFO delivery",
        30,
        |rng: &mut Pcg64| (rng.next_u64(), 1 + rng.gen_range(40) as usize, rng.next_f64() * 0.4),
        |&(seed, n_msgs, loss)| {
            let mut rng = Pcg64::new(seed);
            let mut a = GmpEndpoint::new(1, 0.05);
            let mut b = GmpEndpoint::new(2, 0.05);
            let mut wire: Vec<sector_sphere::transport::Datagram> = Vec::new();
            for i in 0..n_msgs {
                wire.push(a.send(0.0, 2, format!("m{i}").into_bytes()));
            }
            let mut now = 0.0;
            for _round in 0..400 {
                now += 0.06;
                // random loss + reorder
                rng.shuffle(&mut wire);
                let mut next_wire = Vec::new();
                for d in wire.drain(..) {
                    if rng.next_f64() < loss {
                        continue; // dropped
                    }
                    let replies = if d.dst == 2 {
                        b.on_datagram(d)
                    } else {
                        a.on_datagram(d)
                    };
                    next_wire.extend(replies);
                }
                wire = next_wire;
                wire.extend(a.tick(now));
                if a.unacked_count() == 0 && b.delivered.len() == n_msgs {
                    break;
                }
            }
            if b.delivered.len() != n_msgs {
                return Err(format!("delivered {} of {n_msgs}", b.delivered.len()));
            }
            for (i, (src, payload)) in b.delivered.iter().enumerate() {
                if *src != 1 || payload != format!("m{i}").as_bytes() {
                    return Err(format!("message {i} out of order: {payload:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_terasplit_aggregation_gain_close_to_exact() {
    forall(
        "pooled split gain within 20% of exact for structured streams",
        25,
        |rng: &mut Pcg64| (rng.next_u64(), 2000 + rng.gen_range(30_000) as usize),
        |&(seed, n)| {
            let mut rng = Pcg64::new(seed);
            // structured stream: class depends on position with noise
            let labels: Vec<u8> = (0..n)
                .map(|i| {
                    if rng.next_f64() < 0.15 {
                        rng.gen_range(4) as u8
                    } else if i < n / 2 {
                        0
                    } else {
                        1
                    }
                })
                .collect();
            let (exact_gain, exact_idx) = best_split_host(&labels, 4);
            let (pooled, factor) = aggregate_labels(&labels, 4, 1024);
            let (pooled_gain, pooled_idx) = best_split_host(&pooled, 4);
            // Majority pooling denoises, so the pooled gain may exceed
            // the exact gain — but it must stay a valid entropy gain and
            // must locate the same boundary (within one pooling window
            // + 10% of the stream).
            if !(0.0..=2.0 + 1e-9).contains(&pooled_gain) {
                return Err(format!("pooled gain {pooled_gain} out of range"));
            }
            if exact_gain > 0.2 {
                let exact_pos = exact_idx as f64;
                let pooled_pos = (pooled_idx as f64 + 0.5) * factor as f64;
                if (pooled_pos - exact_pos).abs() > factor as f64 + 0.1 * n as f64 {
                    return Err(format!(
                        "pooled split at {pooled_pos:.0} vs exact {exact_pos:.0} (n={n})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_record_index_roundtrip_arbitrary_lengths() {
    forall(
        "RecordIndex wire format round-trips",
        60,
        |rng: &mut Pcg64| {
            (0..rng.gen_range(200))
                .map(|_| 1 + rng.gen_range(10_000))
                .collect::<Vec<u64>>()
        },
        |lengths| {
            let idx = RecordIndex::from_lengths(lengths);
            let back = RecordIndex::from_bytes(&idx.to_bytes()).map_err(|e| e)?;
            if back != idx {
                return Err("round-trip mismatch".into());
            }
            let total: u64 = lengths.iter().sum();
            if idx.total_bytes() != total {
                return Err(format!("covers {} of {total}", idx.total_bytes()));
            }
            Ok(())
        },
    );
}

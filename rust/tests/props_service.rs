//! Service-layer property suite (DESIGN.md §16): forall random
//! topologies × arrival traces × fault plans, the elastic serving
//! engine must keep its replica-management invariants:
//!
//!   1. replica counts stay within the configured [min, max] bounds;
//!   2. no replica survives on a crashed node;
//!   3. shed data is never read after removal (drain accounting);
//!   4. every admitted request is served exactly once or explicitly
//!      rejected — totals and per-tenant counts both conserve;
//!   5. a spec with no [replication] block is byte-equivalent to the
//!      static-policy scaler (scaler-off ≡ static baseline);
//!   6. every run is deterministic: same spec, identical report.
//!
//! Invariants 1–3 are checked continuously inside the engine (every
//! pin, unpin, grow completion and crash purge) and surface as
//! `ElasticityReport::invariant_violations`; the properties here
//! assert that counter is zero and re-check the bounds from the
//! report's own aggregates.

use sector_sphere::scenario::{run_scenario, FaultSpec, ScenarioSpec};
use sector_sphere::service::{
    ArrivalProcess, ArrivalShape, ReplicationSpec, ScalerPolicy, TenantSpec, TrafficSpec,
};
use sector_sphere::testkit::forall;
use sector_sphere::util::rng::Pcg64;

/// A case descriptor: ((sites, racks/site, extra nodes/rack),
/// (requests, derivation seed, fault mask)).  Everything else —
/// tenants, shape, watermark knobs, fault placement — derives from the
/// seed, so shrinking works over plain integers.
type Case = ((u64, u64, u64), (u64, u64, u64));

fn gen_case(rng: &mut Pcg64) -> Case {
    (
        (rng.gen_range(3), rng.gen_range(3), rng.gen_range(3)),
        (
            200 + rng.gen_range(2_300),
            rng.next_u64(),
            rng.gen_range(4),
        ),
    )
}

/// Build a watermark-policy scenario from a case descriptor.
fn elastic_case(case: &Case) -> ScenarioSpec {
    let ((sites, racks, extra), (requests, seed, fault_mask)) = *case;
    let sites = 1 + (sites % 3) as usize;
    let racks = 1 + (racks % 3) as usize;
    let per_rack = 2 + (extra % 3) as usize;
    let nodes = sites * racks * per_rack;
    let mut d = Pcg64::new(seed ^ 0x9E37_79B9_7F4A_7C15);

    let mut spec = ScenarioSpec::traffic_scale128();
    spec.name = "props-elastic".into();
    spec.topology = sector_sphere::topology::TopologySpec::scale_out(sites, racks, per_rack);
    spec.cfg.seed = seed;

    spec.faults = Vec::new();
    if fault_mask & 1 != 0 {
        spec.faults.push(FaultSpec::Straggler {
            node: (d.next_u64() % nodes as u64) as usize,
            factor: 0.3 + d.next_f64() * 0.5,
        });
    }
    if fault_mask & 2 != 0 {
        let node = (d.next_u64() % nodes as u64) as usize;
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 0.3 + d.next_f64() * 1.5,
            node,
        });
    }

    let shape = match d.gen_range(3) {
        0 => ArrivalShape::Flat,
        1 => ArrivalShape::Diurnal {
            period_secs: 2.0 + d.next_f64() * 8.0,
            amplitude: d.next_f64(),
        },
        _ => {
            let period = 2.0 + d.next_f64() * 8.0;
            ArrivalShape::Bursty {
                period_secs: period,
                burst_secs: 0.1 + d.next_f64() * (period - 0.1),
                amplitude: d.next_f64() * 2.0,
            }
        }
    };
    let n_tenants = 1 + d.gen_range(3) as usize;
    let tenants = (0..n_tenants)
        .map(|i| TenantSpec {
            name: format!("t{i}"),
            weight: 0.2 + d.next_f64(),
            write_fraction: d.next_f64() * 0.3,
            object_bytes: (0.5 + d.next_f64() * 4.0) * 1.0e6,
            priority: d.gen_range(3) as u8,
        })
        .collect();
    spec.traffic = Some(TrafficSpec {
        clients: 2_000 + d.gen_range(30_000) as usize,
        requests: requests.clamp(64, 3_000),
        files: 24 + d.gen_range(160) as usize,
        zipf_theta: 0.7 + d.next_f64() * 0.8,
        arrival: ArrivalProcess::Open {
            rps: 300.0 + d.next_f64() * 1_200.0,
        },
        shape,
        tenants,
    });

    let min = 1 + d.gen_range(2) as u32; // 1..=2
    let low = d.next_f64() * 0.3;
    spec.replication = Some(ReplicationSpec {
        policy: ScalerPolicy::Watermark,
        min_replicas: min,
        max_replicas: 2 + d.gen_range(4) as u32, // 2..=5, always >= min
        interval_secs: 0.2 + d.next_f64() * 0.5,
        high_reads_per_sec: low + 0.5 + d.next_f64() * 4.0,
        low_reads_per_sec: low,
        max_grows_per_tick: 2 + d.gen_range(10) as u32,
        max_sheds_per_tick: 2 + d.gen_range(10) as u32,
    });
    spec
}

#[test]
fn prop_elastic_invariants_and_conservation() {
    forall(
        "replica bounds, crash safety, drain accounting, conservation",
        10,
        gen_case,
        |case| {
            let spec = elastic_case(case);
            let r = run_scenario(&spec)?;
            let t = r.traffic.as_ref().ok_or("no traffic report")?;
            let e = r.elasticity.as_ref().ok_or("no elasticity report")?;
            if e.invariant_violations != 0 {
                return Err(format!(
                    "{} invariant violations (bounds / dead-node replica / \
                     read-after-shed)",
                    e.invariant_violations
                ));
            }
            let rs = spec.replication.as_ref().unwrap();
            let cap = spec.traffic.as_ref().unwrap().files as u64 * rs.max_replicas as u64;
            if e.peak_replicas > cap {
                return Err(format!("peak {} exceeds files*max {cap}", e.peak_replicas));
            }
            if e.final_replicas > e.peak_replicas {
                return Err(format!(
                    "final {} exceeds peak {}",
                    e.final_replicas, e.peak_replicas
                ));
            }
            if e.drained_sheds > e.sheds {
                return Err(format!(
                    "drained {} exceeds total sheds {}",
                    e.drained_sheds, e.sheds
                ));
            }
            // Every request resolves exactly once: totals...
            if t.completed + t.rejected + t.unavailable != t.requests {
                return Err(format!(
                    "{} + {} + {} != {} requests",
                    t.completed, t.rejected, t.unavailable, t.requests
                ));
            }
            // ...and again per tenant, summing back to the totals.
            let mut sum = 0;
            for ten in &t.tenants {
                if ten.completed + ten.rejected + ten.unavailable != ten.requests {
                    return Err(format!("tenant {}: counts do not conserve", ten.name));
                }
                sum += ten.requests;
            }
            if sum != t.requests {
                return Err(format!("tenant requests sum {sum} != total {}", t.requests));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_elastic_runs_are_deterministic() {
    forall(
        "same spec, identical report (scaler ticks included)",
        5,
        gen_case,
        |case| {
            let spec = elastic_case(case);
            let a = run_scenario(&spec)?;
            let b = run_scenario(&spec)?;
            if a != b {
                return Err("reports diverged across reruns".into());
            }
            if format!("{a:?}") != format!("{b:?}") {
                return Err("serialized reports diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scaler_off_equals_static_baseline() {
    // Dropping the [replication] block entirely and running the static
    // policy must produce the same observable service behavior: the
    // static scaler issues no directives and schedules no ticks, so
    // the request timeline is untouched.
    forall(
        "no [replication] block ≡ static policy",
        5,
        gen_case,
        |case| {
            let mut off = elastic_case(case);
            off.replication = None;
            let mut stat = elastic_case(case);
            stat.replication = Some(ReplicationSpec::with_policy(ScalerPolicy::Static));
            let a = run_scenario(&off)?;
            let b = run_scenario(&stat)?;
            if a.elasticity.is_some() {
                return Err("scaler-off run must carry no elasticity report".into());
            }
            let e = b.elasticity.as_ref().ok_or("static run lacks elasticity report")?;
            if e.policy != "static" || e.grows != 0 || e.sheds != 0 {
                return Err(format!(
                    "static policy acted: policy {} grows {} sheds {}",
                    e.policy, e.grows, e.sheds
                ));
            }
            if a.traffic != b.traffic {
                return Err("SLO reports differ between scaler-off and static".into());
            }
            if a.events != b.events || a.makespan_secs != b.makespan_secs {
                return Err(format!(
                    "timelines differ: {} vs {} events, {} vs {} s",
                    a.events, b.events, a.makespan_secs, b.makespan_secs
                ));
            }
            Ok(())
        },
    );
}

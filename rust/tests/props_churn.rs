//! Wide-area churn property suite (DESIGN.md §18).
//!
//! The ISSUE-10 contract, property by property: Chord ring membership
//! converges after EVERY leave/join of an arbitrary churn sequence; no
//! task span survives on a departed node (observed through the JSONL
//! trace — cancelled work is never emitted, and a dead node gets no
//! new work until it re-joins); Sector replica counts return to bounds
//! after fail/revive churn plus a replication pass; churned runs are
//! deterministic end to end; and the inert wide-area blocks — churn at
//! rate 0 plus a flat weather trace — reproduce the plain fault-plan
//! timeline byte-identically.

use std::collections::BTreeSet;
use std::fs;
use std::path::PathBuf;

use sector_sphere::routing::chord::ChordRing;
use sector_sphere::scenario::{
    run_scenario, ChurnSpec, FaultSpec, ScenarioSpec, TraceSpec, WeatherSpec,
};
use sector_sphere::sector::{ReplicationManager, SectorCloud};
use sector_sphere::testkit::forall;
use sector_sphere::util::rng::Pcg64;

#[test]
fn prop_ring_membership_converges_after_any_churn_sequence() {
    forall(
        "chord ring stays at the stabilized fixed point through churn",
        20,
        |rng: &mut Pcg64| {
            let n = 4 + rng.gen_range(12) as usize;
            let ids: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let ops = 6 + rng.gen_range(14) as usize;
            (ids, ops, rng.next_u64())
        },
        |(ids, ops, seed)| {
            let mut ids: Vec<u64> = ids.clone();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() < 2 {
                return Ok(()); // shrunk below the interesting regime
            }
            let mut ring = ChordRing::build(&ids);
            let mut present: BTreeSet<u64> = ids.iter().copied().collect();
            let mut away: Vec<u64> = Vec::new();
            let mut rng = Pcg64::new(*seed);
            for step in 0..*ops {
                // Leave while >2 present; re-join a departed id otherwise
                // (and sometimes by choice), mirroring the churn plan's
                // leave/re-join pairing.
                let rejoin = !away.is_empty() && (present.len() <= 2 || rng.next_f64() < 0.4);
                if rejoin {
                    let id = away.remove(rng.gen_range(away.len() as u64) as usize);
                    ring.join(id);
                    present.insert(id);
                } else {
                    let live: Vec<u64> = present.iter().copied().collect();
                    let id = live[rng.gen_range(live.len() as u64) as usize];
                    if !ring.leave(id) {
                        return Err(format!("step {step}: leave({id:#x}) found nothing"));
                    }
                    present.remove(&id);
                    away.push(id);
                }
                // Convergence after EVERY op: membership matches, and a
                // finger-table walk from any node owns every key exactly
                // as the ground-truth successor does.
                let members: Vec<u64> = ring.node_ids().collect();
                if members != present.iter().copied().collect::<Vec<u64>>() {
                    return Err(format!("step {step}: membership diverged"));
                }
                let start = members[rng.gen_range(members.len() as u64) as usize];
                for _ in 0..20 {
                    let key = rng.next_u64();
                    let (owner, _) = ring
                        .lookup(start, key)
                        .ok_or_else(|| format!("step {step}: lookup failed"))?;
                    let want = ring.naive_successor(key).unwrap();
                    if owner != want {
                        return Err(format!(
                            "step {step}: key {key:#x} routed to {owner:#x}, owner {want:#x}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_replica_counts_return_to_bounds_after_churn() {
    forall(
        "sector replicas restore to min(target, live) after fail/revive churn",
        15,
        |rng: &mut Pcg64| {
            let target = 2 + rng.gen_range(2) as usize; // 2..=3
            let nodes = target + 3 + rng.gen_range(5) as usize;
            let files = 3 + rng.gen_range(10) as usize;
            ((nodes, target), (files, rng.next_u64()))
        },
        |&((nodes, target), (files, seed))| {
            if target < 2 || nodes < target + 2 {
                return Ok(()); // shrunk below the interesting regime
            }
            let cloud = SectorCloud::builder()
                .nodes(nodes)
                .replicas(target)
                .seed(seed)
                .build()
                .map_err(|e| e.to_string())?;
            let ip = "10.0.0.1".parse().unwrap();
            for i in 0..files {
                cloud
                    .upload(ip, &format!("f{i}.dat"), &[9, 9, 9], None, None)
                    .map_err(|e| e.to_string())?;
            }
            let mut mgr = ReplicationManager::new(1.0);
            mgr.check_all(&cloud);
            let mut rng = Pcg64::new(seed ^ 0xc4u64);
            let mut dead: Vec<u32> = Vec::new();
            for _ in 0..12 {
                // Never let churn outrun the replica chain: at most
                // target-1 slaves away at once (the ChurnSpec
                // max_fraction rationale at storage scale).
                if !dead.is_empty() && (dead.len() >= target - 1 || rng.next_f64() < 0.4) {
                    let back = dead.remove(rng.gen_range(dead.len() as u64) as usize);
                    cloud.revive_slave(back);
                } else {
                    let victim = loop {
                        let v = rng.gen_range(nodes as u64) as u32;
                        if !dead.contains(&v) {
                            break v;
                        }
                    };
                    cloud.fail_slave(victim);
                    dead.push(victim);
                }
                // The daily check runs after each membership change.
                mgr.check_all(&cloud);
                let live = nodes - dead.len();
                let expect = target.min(live);
                for name in cloud.list() {
                    let locs = cloud.stat(&name).unwrap().locations;
                    if locs.len() != expect {
                        return Err(format!(
                            "{name}: {} replicas with {live} live, want {expect}",
                            locs.len()
                        ));
                    }
                    let mut dedup = locs.clone();
                    dedup.sort_unstable();
                    dedup.dedup();
                    if dedup.len() != locs.len() {
                        return Err(format!("{name}: duplicate locations {locs:?}"));
                    }
                    if let Some(d) = locs.iter().find(|l| cloud.is_dead(**l)) {
                        return Err(format!("{name}: replica on dead slave {d}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------- trace-level props

/// One parsed JSONL event — just the fields these properties need.
struct Ev {
    t: f64,
    dur: f64,
    ph: String,
    kind: String,
    name: String,
    node: i64,
}

fn jstr(line: &str, key: &str) -> String {
    let tag = format!("\"{key}\":\"");
    let start = line.find(&tag).unwrap_or_else(|| panic!("missing {key}: {line}")) + tag.len();
    line[start..].split('"').next().unwrap().to_string()
}

fn jnum(line: &str, key: &str) -> f64 {
    let tag = format!("\"{key}\":");
    let start = line.find(&tag).unwrap_or_else(|| panic!("missing {key}: {line}")) + tag.len();
    line[start..]
        .split(&[',', '}'][..])
        .next()
        .unwrap()
        .trim()
        .parse()
        .unwrap_or_else(|_| panic!("bad {key}: {line}"))
}

/// Run `spec` traced; return the parsed JSONL events (meta line
/// skipped) and clean the artifacts up.
fn traced_events(mut spec: ScenarioSpec, tag: &str) -> Vec<Ev> {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let chrome: PathBuf = dir.join(format!("sector-sphere-churn-{pid}-{tag}.json"));
    let jsonl: PathBuf = dir.join(format!("sector-sphere-churn-{pid}-{tag}.jsonl"));
    spec.trace = Some(TraceSpec {
        path: Some(chrome.to_string_lossy().into_owned()),
        ..TraceSpec::default()
    });
    run_scenario(&spec).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
    let text = fs::read_to_string(&jsonl).expect("jsonl artifact written");
    let _ = fs::remove_file(&jsonl);
    let _ = fs::remove_file(&chrome);
    text.lines()
        .skip(1) // meta header
        .map(|l| Ev {
            t: jnum(l, "t"),
            dur: jnum(l, "dur"),
            ph: jstr(l, "ph"),
            kind: jstr(l, "kind"),
            name: jstr(l, "name"),
            node: jnum(l, "node") as i64,
        })
        .collect()
}

#[test]
fn prop_no_task_survives_a_departed_node() {
    let events = traced_events(ScenarioSpec::churn_wan32(), "departed");
    let leaves: Vec<&Ev> = events
        .iter()
        .filter(|e| e.kind == "fault" && e.name == "leave")
        .collect();
    assert!(
        !leaves.is_empty(),
        "churn_wan32 must generate at least one departure"
    );
    // Per node: sorted alternating leave/join instants -> away windows.
    let nodes: BTreeSet<i64> = leaves.iter().map(|e| e.node).collect();
    let mut windows: Vec<(i64, f64, f64)> = Vec::new();
    for &n in &nodes {
        let mut instants: Vec<(f64, bool)> = events
            .iter()
            .filter(|e| e.kind == "fault" && e.node == n && (e.name == "leave" || e.name == "join"))
            .map(|e| (e.t, e.name == "leave"))
            .collect();
        instants.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut open: Option<f64> = None;
        for (t, is_leave) in instants {
            match (is_leave, open) {
                (true, None) => open = Some(t),
                (false, Some(l)) => {
                    windows.push((n, l, t));
                    open = None;
                }
                (pat, _) => panic!("node {n}: unpaired churn instant (leave={pat}) at {t}"),
            }
        }
        if let Some(l) = open {
            windows.push((n, l, f64::INFINITY)); // never came back
        }
    }
    // No completed task span on a node may overlap its away window:
    // in-flight work is unwound at the leave (and so never emitted),
    // and a departed node gets nothing new before its join.
    let eps = 1e-6;
    for ev in events.iter().filter(|e| e.ph == "X" && e.kind == "task") {
        for &(n, l, j) in &windows {
            if ev.node == n {
                assert!(
                    ev.t + ev.dur <= l + eps || ev.t >= j - eps,
                    "task [{:.3}, {:.3}] on node {n} overlaps its absence [{l:.3}, {j:.3})",
                    ev.t,
                    ev.t + ev.dur,
                );
            }
        }
    }
}

#[test]
fn prop_churned_runs_are_deterministic() {
    for spec in [
        ScenarioSpec::churn_wan32(),
        ScenarioSpec::weather_compare16(),
    ] {
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "{}: run-twice reports must match bytewise", spec.name);
        assert!(!a.trace_digest.is_empty());
    }
}

#[test]
fn prop_inert_wide_area_blocks_reproduce_the_plain_timeline() {
    // THE acceptance property: churn at rate 0 plus a flat weather
    // trace must not move a single byte of the timeline relative to
    // the same scenario without the blocks — digest AND full report.
    let mut plain = ScenarioSpec::churn_wan32();
    plain.churn = None;
    let mut inert = plain.clone();
    inert.churn = Some(ChurnSpec {
        rate_per_100s: 0.0,
        ..ChurnSpec::default()
    });
    inert.weather = Some(WeatherSpec {
        amplitude: 0.0,
        steps: 0,
        ..WeatherSpec::default()
    });
    let a = run_scenario(&plain).unwrap();
    let b = run_scenario(&inert).unwrap();
    assert_eq!(a, b, "inert churn/weather blocks changed the run");
    // And with a real fault plan alongside: the blocks stay invisible.
    let mut faulted_plain = plain.clone();
    faulted_plain.name = "churn-inert-faulted".into();
    faulted_plain.faults = vec![
        FaultSpec::Straggler {
            node: 17,
            factor: 0.5,
        },
        FaultSpec::SlaveCrash {
            at_secs: 3.0,
            node: 7,
        },
        FaultSpec::LinkDegrade {
            at_secs: 5.0,
            duration_secs: 20.0,
            site: 2,
            factor: 0.25,
        },
    ];
    let mut faulted_inert = faulted_plain.clone();
    faulted_inert.churn = inert.churn;
    faulted_inert.weather = inert.weather;
    let fa = run_scenario(&faulted_plain).unwrap();
    let fb = run_scenario(&faulted_inert).unwrap();
    assert_eq!(fa, fb, "inert blocks changed a faulted run");
    assert!(fa.faults_injected > 0, "the borrowed fault plan must fire");
}

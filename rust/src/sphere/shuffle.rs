//! Output-stream routing (paper §3.2): "the resulting stream can be
//! returned to the Sector node where it originated, written to a local
//! node, or 'shuffled' to a list of nodes, depending upon how the output
//! stream is defined."
//!
//! The shuffle writer gathers (bucket, record) pairs from all SPEs,
//! materializes one Sector file per bucket on the bucket's home node,
//! and registers the files (with record indexes) so a follow-up
//! `sphere.run` can consume them — Terasort's stage boundary.

use std::collections::BTreeMap;

use crate::sector::{RecordIndex, SectorCloud, SlaveId};

/// Home node of a bucket: round-robin over nodes (deterministic, even).
pub fn bucket_home(bucket: u32, n_nodes: usize) -> SlaveId {
    assert!(n_nodes > 0);
    bucket % n_nodes as u32
}

/// Accumulates shuffle output across SPE results.
#[derive(Debug)]
pub struct ShuffleWriter {
    output_name: String,
    buckets: u32,
    /// bucket -> (concatenated bytes, per-record lengths)
    data: BTreeMap<u32, (Vec<u8>, Vec<u64>)>,
    pub records_in: u64,
}

impl ShuffleWriter {
    pub fn new(output_name: &str, buckets: u32) -> Self {
        assert!(buckets > 0);
        Self {
            output_name: output_name.to_string(),
            buckets,
            data: BTreeMap::new(),
            records_in: 0,
        }
    }

    pub fn add(&mut self, bucket: u32, record: &[u8]) -> Result<(), String> {
        if bucket >= self.buckets {
            return Err(format!(
                "bucket {bucket} out of range (buckets = {})",
                self.buckets
            ));
        }
        let entry = self.data.entry(bucket).or_default();
        entry.0.extend_from_slice(record);
        entry.1.push(record.len() as u64);
        self.records_in += 1;
        Ok(())
    }

    /// Standard bucket-file name: `<output>.<bucket>.dat`.
    pub fn bucket_file_name(output_name: &str, bucket: u32) -> String {
        format!("{output_name}.{bucket:05}.dat")
    }

    /// Write every bucket to its home node as an indexed Sector file.
    /// Empty buckets produce no file. Returns the created file names.
    pub fn finalize(self, cloud: &SectorCloud) -> Result<Vec<String>, String> {
        let n_nodes = cloud.n_slaves();
        let mut created = Vec::new();
        for (bucket, (bytes, lengths)) in self.data {
            if lengths.is_empty() {
                continue;
            }
            let name = Self::bucket_file_name(&self.output_name, bucket);
            let index = RecordIndex::from_lengths(&lengths);
            let home = bucket_home(bucket, n_nodes);
            cloud.system_put(&name, &bytes, Some(&index), home)?;
            cloud.metrics.add("sphere.shuffle_bytes", bytes.len() as u64);
            created.push(name);
        }
        Ok(created)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_home_round_robin() {
        assert_eq!(bucket_home(0, 4), 0);
        assert_eq!(bucket_home(5, 4), 1);
        assert_eq!(bucket_home(7, 4), 3);
    }

    #[test]
    fn writer_groups_and_materializes() {
        let c = SectorCloud::builder().nodes(4).seed(3).build().unwrap();
        let mut w = ShuffleWriter::new("sorted", 8);
        w.add(3, b"record-a").unwrap();
        w.add(3, b"rb").unwrap();
        w.add(6, b"record-c").unwrap();
        assert!(w.add(99, b"x").is_err());
        assert_eq!(w.records_in, 3);
        let files = w.finalize(&c).unwrap();
        assert_eq!(
            files,
            vec!["sorted.00003.dat".to_string(), "sorted.00006.dat".to_string()]
        );
        // bucket 3 landed on node 3, with a 2-record index
        let meta = c.stat("sorted.00003.dat").unwrap();
        assert_eq!(meta.locations, vec![3]);
        assert_eq!(meta.n_records, 2);
        let idx = c.load_index("sorted.00003.dat").unwrap();
        assert_eq!(idx.get(0).unwrap().size, 8);
        assert_eq!(idx.get(1).unwrap().size, 2);
        assert_eq!(c.download(0, "sorted.00003.dat").unwrap(), b"record-arb");
    }

    #[test]
    fn empty_writer_creates_nothing() {
        let c = SectorCloud::builder().nodes(2).seed(3).build().unwrap();
        let w = ShuffleWriter::new("out", 4);
        assert!(w.finalize(&c).unwrap().is_empty());
        assert!(c.list().is_empty());
    }
}

//! Sphere job orchestration — the client-visible `sphere.run(a, p)`
//! (paper §3.1) over the in-process real-mode cluster.
//!
//! A job segments its input stream (§3.2 rule 1), starts
//! `spes_per_node` SPE workers per node (real threads), drives the
//! locality-aware scheduler (rules 2–3), re-executes segments whose SPE
//! failed, and routes the output stream per the operator's
//! `OutputMode`: collected at the client, written to node-local Sector
//! files, or shuffled into bucket files across the cloud.

use std::collections::HashSet;
use std::sync::Mutex;

use crate::sector::{RecordIndex, SectorCloud};

use super::scheduler::Scheduler;
use super::segment::segment_stream;
use super::shuffle::ShuffleWriter;
use super::spe::{Spe, SpeResult};
use super::stream::Stream;
use super::udf::{OpCtx, OutputMode, SphereOp};

/// Job parameters.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Name for output files (ignored for ToClient operators).
    pub output_name: String,
    /// Opaque parameters passed to the operator.
    pub params: Vec<u8>,
    /// SPEs per node (paper's Terasort used 1).
    pub spes_per_node: usize,
    /// Segmentation bounds (paper's S_min / S_max).
    pub seg_min_bytes: u64,
    pub seg_max_bytes: u64,
    /// Locality-aware scheduling (ablation lever).
    pub locality: bool,
}

impl Default for JobSpec {
    fn default() -> Self {
        let p = crate::config::SphereParams::default();
        Self {
            output_name: "sphere-out".into(),
            params: Vec::new(),
            spes_per_node: p.spes_per_node,
            seg_min_bytes: p.seg_min_bytes,
            seg_max_bytes: p.seg_max_bytes,
            locality: p.locality_scheduling,
        }
    }
}

/// Fault-injection plan: each listed segment id fails on its first
/// attempt (the SPE "dies"), exercising re-execution.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub fail_first_attempt: HashSet<usize>,
}

/// What the client gets back.
#[derive(Debug, Default)]
pub struct JobResult {
    /// Records returned to the client (ToClient mode), in (bucket,
    /// segment-id) order.
    pub to_client: Vec<(u32, Vec<u8>)>,
    /// Sector files created (Local / Shuffle modes).
    pub output_files: Vec<String>,
    pub segments_total: usize,
    pub bytes_read: u64,
    pub locality_fraction: f64,
    pub spe_failures: u64,
}

/// Run a Sphere job to completion on the in-process cluster.
pub fn run_job(
    cloud: &SectorCloud,
    op: &dyn SphereOp,
    input: &Stream,
    spec: &JobSpec,
    faults: &FaultPlan,
) -> Result<JobResult, String> {
    if input.is_empty() {
        return Err("empty input stream".into());
    }
    let n_nodes = cloud.n_slaves();
    let n_spes = n_nodes * spec.spes_per_node.max(1);
    let segments = segment_stream(
        input,
        n_spes,
        spec.seg_min_bytes,
        spec.seg_max_bytes,
        |name| cloud.load_index(name),
    );
    let segments_total = segments.len();
    let scheduler = Mutex::new(Scheduler::new(segments, spec.locality));
    let in_flight = Mutex::new(0usize);
    let results: Mutex<Vec<SpeResult>> = Mutex::new(Vec::new());
    let failed_once: Mutex<HashSet<usize>> = Mutex::new(HashSet::new());
    let abort: Mutex<Option<String>> = Mutex::new(None);
    let ctx = OpCtx {
        params: spec.params.clone(),
    };

    std::thread::scope(|scope| {
        for node in 0..n_nodes as u32 {
            for slot in 0..spec.spes_per_node.max(1) {
                let scheduler = &scheduler;
                let in_flight = &in_flight;
                let results = &results;
                let failed_once = &failed_once;
                let abort = &abort;
                let ctx = &ctx;
                scope.spawn(move || {
                    let spe = Spe::new(node, slot);
                    // Delay scheduling: decline remote work this many
                    // times while other nodes still have local segments.
                    let mut patience = 2u32;
                    loop {
                        if abort.lock().unwrap().is_some() {
                            return;
                        }
                        let seg = {
                            let mut sched = scheduler.lock().unwrap();
                            let local_only = patience > 0;
                            match sched.assign_filtered(node, local_only) {
                                Some(s) => {
                                    *in_flight.lock().unwrap() += 1;
                                    Some(s)
                                }
                                None => {
                                    if local_only && sched.pending_count() > 0 {
                                        patience -= 1;
                                    }
                                    None
                                }
                            }
                        };
                        let Some(seg) = seg else {
                            // Drained AND nothing in flight => done; else
                            // a failure may still requeue work.
                            let pending = scheduler.lock().unwrap().pending_count();
                            let busy = *in_flight.lock().unwrap();
                            if pending == 0 && busy == 0 {
                                return;
                            }
                            std::thread::yield_now();
                            continue;
                        };
                        // Fault injection: first attempt of listed ids dies.
                        let injected = faults.fail_first_attempt.contains(&seg.id)
                            && failed_once.lock().unwrap().insert(seg.id);
                        let outcome = if injected {
                            Err(format!("SPE {node}:{slot} died (injected)"))
                        } else {
                            spe.run_segment(cloud, op, ctx, seg.clone())
                        };
                        let mut sched = scheduler.lock().unwrap();
                        *in_flight.lock().unwrap() -= 1;
                        match outcome {
                            Ok(res) => {
                                sched.complete(&res.segment);
                                results.lock().unwrap().push(res);
                                patience = 2; // prefer local again
                            }
                            Err(e) => {
                                cloud.metrics.incr("sphere.spe_failures");
                                if !sched.fail(seg) {
                                    *abort.lock().unwrap() =
                                        Some(format!("segment retries exhausted: {e}"));
                                    return;
                                }
                            }
                        }
                    }
                });
            }
        }
    });

    if let Some(e) = abort.into_inner().unwrap() {
        return Err(e);
    }
    let mut results = results.into_inner().unwrap();
    let scheduler = scheduler.into_inner().unwrap();
    debug_assert_eq!(results.len(), segments_total, "every segment completed once");
    // Deterministic output order regardless of thread interleaving.
    results.sort_by_key(|r| r.segment.id);

    let bytes_read = results.iter().map(|r| r.bytes_read).sum();
    let mut out = JobResult {
        segments_total,
        bytes_read,
        locality_fraction: scheduler.locality_fraction(),
        spe_failures: cloud.metrics.get("sphere.spe_failures"),
        ..JobResult::default()
    };

    match op.output_mode() {
        OutputMode::ToClient => {
            for r in results {
                out.to_client.extend(r.emitted);
            }
        }
        OutputMode::Local => {
            // One output file per segment, on the node that produced it
            // (co-located with its input when the read was local).
            for r in results {
                if r.emitted.is_empty() {
                    continue;
                }
                let name = format!("{}.seg{:05}.dat", spec.output_name, r.segment.id);
                let mut bytes = Vec::new();
                let mut lengths = Vec::new();
                for (_, rec) in &r.emitted {
                    bytes.extend_from_slice(rec);
                    lengths.push(rec.len() as u64);
                }
                let index = RecordIndex::from_lengths(&lengths);
                let home = r.segment.locations.first().copied().unwrap_or(0);
                cloud.system_put(&name, &bytes, Some(&index), home)?;
                out.output_files.push(name);
            }
        }
        OutputMode::Shuffle { buckets } => {
            let mut writer = ShuffleWriter::new(&spec.output_name, buckets);
            for r in &results {
                for (bucket, rec) in &r.emitted {
                    writer.add(*bucket, rec)?;
                }
            }
            out.output_files = writer.finalize(cloud)?;
        }
    }
    cloud.metrics.incr("sphere.jobs_completed");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::{RecordIndex, SectorCloud};
    use crate::sphere::udf::{CatOp, GrepOp, OpOutput, SegmentData};

    fn cloud_with_data(nodes: usize, files: usize, recs_per_file: u64) -> SectorCloud {
        let c = SectorCloud::builder().nodes(nodes).seed(9).build().unwrap();
        let ip = "10.0.0.2".parse().unwrap();
        for f in 0..files {
            let mut data = Vec::new();
            for r in 0..recs_per_file {
                data.extend_from_slice(format!("file{f:02}-rec{r:04}\n").as_bytes());
            }
            let rec_len = data.len() as u64 / recs_per_file;
            let idx = RecordIndex::fixed(rec_len, data.len() as u64);
            c.upload(
                ip,
                &format!("in{f:02}.dat"),
                &data,
                Some(&idx),
                Some((f % nodes) as u32),
            )
            .unwrap();
        }
        c
    }

    fn input_stream(c: &SectorCloud) -> Stream {
        Stream::from_cloud(c, &c.list().into_iter().filter(|n| n.starts_with("in")).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn cat_job_returns_all_records() {
        let c = cloud_with_data(4, 4, 50);
        let spec = JobSpec {
            seg_min_bytes: 64,
            seg_max_bytes: 400,
            ..JobSpec::default()
        };
        let res = run_job(&c, &CatOp, &input_stream(&c), &spec, &FaultPlan::default()).unwrap();
        assert_eq!(res.to_client.len(), 200);
        assert!(res.segments_total > 4, "stream was actually segmented");
        assert!(
            res.locality_fraction >= 0.5,
            "delay scheduling keeps most reads local (got {})",
            res.locality_fraction
        );
        assert_eq!(res.bytes_read, input_stream(&c).total_bytes());
    }

    #[test]
    fn grep_job_filters() {
        let c = cloud_with_data(2, 2, 30);
        let spec = JobSpec {
            params: b"rec0001".to_vec(),
            seg_min_bytes: 64,
            seg_max_bytes: 256,
            ..JobSpec::default()
        };
        let res = run_job(&c, &GrepOp, &input_stream(&c), &spec, &FaultPlan::default()).unwrap();
        assert_eq!(res.to_client.len(), 2, "one match per file");
    }

    /// Emits each record into bucket = first digit of its record number.
    struct BucketByRec;

    impl SphereOp for BucketByRec {
        fn name(&self) -> &str {
            "bucket-by-rec"
        }

        fn output_mode(&self) -> OutputMode {
            OutputMode::Shuffle { buckets: 10 }
        }

        fn process(
            &self,
            data: &SegmentData,
            _ctx: &OpCtx,
            out: &mut OpOutput,
        ) -> Result<(), String> {
            for r in &data.records {
                // record text "fileXX-recYYYY\n"
                let digit = r[12] - b'0'; // tens digit of YYYY
                out.emit(digit as u32, r.clone());
            }
            Ok(())
        }
    }

    #[test]
    fn shuffle_job_creates_bucket_files() {
        let c = cloud_with_data(3, 3, 40);
        let spec = JobSpec {
            output_name: "bkt".into(),
            seg_min_bytes: 64,
            seg_max_bytes: 512,
            ..JobSpec::default()
        };
        let res =
            run_job(&c, &BucketByRec, &input_stream(&c), &spec, &FaultPlan::default()).unwrap();
        assert!(!res.output_files.is_empty());
        // All 120 records land somewhere; recounts must conserve.
        let total: u64 = res
            .output_files
            .iter()
            .map(|f| c.stat(f).unwrap().n_records)
            .sum();
        assert_eq!(total, 120);
        // Records 0000-0039 -> first digits 0-3 -> buckets 0..4 exist.
        assert!(c.stat("bkt.00000.dat").is_some());
        assert!(c.stat("bkt.00003.dat").is_some());
        assert!(c.stat("bkt.00009.dat").is_none());
    }

    #[test]
    fn injected_spe_failures_are_retried() {
        let c = cloud_with_data(2, 2, 40);
        let spec = JobSpec {
            seg_min_bytes: 64,
            seg_max_bytes: 256,
            ..JobSpec::default()
        };
        let segments_expected = {
            // dry run to learn segment ids
            let res =
                run_job(&c, &CatOp, &input_stream(&c), &spec, &FaultPlan::default()).unwrap();
            res.segments_total
        };
        let faults = FaultPlan {
            fail_first_attempt: (0..segments_expected.min(3)).collect(),
        };
        let res = run_job(&c, &CatOp, &input_stream(&c), &spec, &faults).unwrap();
        assert_eq!(res.to_client.len(), 80, "output complete despite failures");
        assert!(res.spe_failures >= 1);
    }

    #[test]
    fn empty_stream_rejected() {
        let c = cloud_with_data(2, 1, 10);
        let err = run_job(
            &c,
            &CatOp,
            &Stream::default(),
            &JobSpec::default(),
            &FaultPlan::default(),
        )
        .unwrap_err();
        assert!(err.contains("empty"));
    }
}

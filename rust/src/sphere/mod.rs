//! Sphere — the compute cloud (paper §3).
//!
//! "If a user defines a function p on a distributed data set a managed
//! by Sector, then invoking the command sphere.run(a, p) applies the
//! user defined function p to each data record in the dataset a."
//!
//! `stream` + `segment` implement the data model, `udf` the operator
//! interface, `spe` the processing element loop, `scheduler` the
//! locality-aware assignment, `shuffle` the output-stream routing and
//! `job` the orchestration (`run_job` == `sphere.run`).  `simjob`
//! replays the same coordination logic against the discrete-event
//! testbed models to regenerate the paper-scale tables.

pub mod job;
pub mod scheduler;
pub mod segment;
pub mod shuffle;
pub mod simjob;
pub mod spe;
pub mod stream;
pub mod udf;

pub use job::{run_job, FaultPlan, JobResult, JobSpec};
pub use scheduler::Scheduler;
pub use segment::{segment_stream, target_segment_bytes, Segment};
pub use shuffle::{bucket_home, ShuffleWriter};
pub use spe::{Spe, SpeResult};
pub use stream::{Stream, StreamFile};
pub use udf::{CatOp, GrepOp, OpCtx, OpOutput, OpRegistry, OutputMode, SegmentData, SphereOp};

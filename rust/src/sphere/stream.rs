//! Sphere streams (paper §3.2): "A Sphere dataset consists of one or
//! more physical files ... Sphere streams are split into one or more
//! data segments that are processed by ... SPEs."

use crate::sector::{SectorCloud, SlaveId};

/// One physical file participating in a stream.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamFile {
    pub name: String,
    pub size_bytes: u64,
    /// 0 when the file has no record index (file-granular processing).
    pub n_records: u64,
    pub locations: Vec<SlaveId>,
}

/// An ordered set of Sector files presented to `sphere.run`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Stream {
    pub files: Vec<StreamFile>,
}

impl Stream {
    /// Resolve file names against the cloud's metadata (paper's
    /// `sdss.init(...)`).
    pub fn from_cloud(cloud: &SectorCloud, names: &[String]) -> Result<Stream, String> {
        let mut files = Vec::with_capacity(names.len());
        for name in names {
            let meta = cloud
                .stat(name)
                .ok_or_else(|| format!("stream references unknown file {name:?}"))?;
            if meta.locations.is_empty() {
                return Err(format!("file {name:?} has no live replicas"));
            }
            files.push(StreamFile {
                name: meta.name,
                size_bytes: meta.size_bytes,
                n_records: meta.n_records,
                locations: meta.locations,
            });
        }
        Ok(Stream { files })
    }

    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.size_bytes).sum()
    }

    pub fn total_records(&self) -> u64 {
        self.files.iter().map(|f| f.n_records).sum()
    }

    pub fn n_files(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::{RecordIndex, SectorCloud};

    #[test]
    fn resolves_from_cloud() {
        let c = SectorCloud::builder().nodes(3).seed(1).build().unwrap();
        let ip = "10.0.0.5".parse().unwrap();
        let idx = RecordIndex::fixed(10, 50);
        c.upload(ip, "a.dat", &[1u8; 50], Some(&idx), Some(0)).unwrap();
        c.upload(ip, "b.dat", &[2u8; 30], None, Some(1)).unwrap();
        let s = Stream::from_cloud(&c, &["a.dat".into(), "b.dat".into()]).unwrap();
        assert_eq!(s.n_files(), 2);
        assert_eq!(s.total_bytes(), 80);
        assert_eq!(s.total_records(), 5); // b.dat has no index
        assert_eq!(s.files[0].locations, vec![0]);
        assert!(Stream::from_cloud(&c, &["missing.dat".into()]).is_err());
    }

    #[test]
    fn empty_stream() {
        let s = Stream::default();
        assert!(s.is_empty());
        assert_eq!(s.total_bytes(), 0);
    }
}

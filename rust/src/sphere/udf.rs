//! Sphere operators — the user-defined functions (paper §3.1): "Sphere
//! allows arbitrary user defined operations to replace both the map and
//! reduce operations."  An operator consumes a data segment and emits
//! records to an output stream which is returned to the client, written
//! locally, or shuffled to a list of nodes (§3.2).
//!
//! Operators are registered by name, mirroring the paper's
//! dynamic-library deployment (`myproc->run(sdss, "findBrownDwarf")`);
//! the registry stands in for uploading `.so` files to slaves.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::segment::Segment;

/// Where an operator's output stream goes (paper §3.2: "returned to the
/// Sector node where it originated, written to a local node, or
/// 'shuffled' to a list of nodes").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputMode {
    /// Collected at the client (paper's `myproc->read(result)`).
    ToClient,
    /// Written as new Sector files on the processing node.
    Local,
    /// Hash/range-partitioned into `buckets` files spread over nodes.
    Shuffle { buckets: u32 },
}

/// A segment's materialized records, handed to the operator.
#[derive(Clone, Debug)]
pub struct SegmentData {
    pub segment: Segment,
    /// One entry per record; for whole-file segments, a single entry
    /// holding the raw file bytes.
    pub records: Vec<Vec<u8>>,
}

/// Sink the operator writes into.
#[derive(Debug, Default)]
pub struct OpOutput {
    /// (bucket, record). Bucket is ignored for ToClient/Local modes
    /// except as an ordering hint.
    pub emitted: Vec<(u32, Vec<u8>)>,
}

impl OpOutput {
    pub fn emit(&mut self, bucket: u32, record: Vec<u8>) {
        self.emitted.push((bucket, record));
    }
}

/// Job-scoped context available to operators.
#[derive(Clone, Debug, Default)]
pub struct OpCtx {
    /// Opaque client parameters (paper: "additional parameters" in the
    /// segment handshake).
    pub params: Vec<u8>,
}

/// The Sphere operator interface.
pub trait SphereOp: Send + Sync {
    fn name(&self) -> &str;
    fn output_mode(&self) -> OutputMode;
    /// Process one data segment (paper §3.2 SPE step 3).
    fn process(&self, data: &SegmentData, ctx: &OpCtx, out: &mut OpOutput) -> Result<(), String>;
}

/// Name -> operator registry (the dynamic-library store).
#[derive(Clone, Default)]
pub struct OpRegistry {
    ops: BTreeMap<String, Arc<dyn SphereOp>>,
}

impl OpRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, op: Arc<dyn SphereOp>) -> Result<(), String> {
        let name = op.name().to_string();
        if self.ops.contains_key(&name) {
            return Err(format!("operator {name:?} already registered"));
        }
        self.ops.insert(name, op);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn SphereOp>, String> {
        self.ops
            .get(name)
            .cloned()
            .ok_or_else(|| format!("no such operator {name:?}"))
    }

    pub fn names(&self) -> Vec<String> {
        self.ops.keys().cloned().collect()
    }
}

// -------------------------------------------------------- stock operators

/// Identity pass-through to the client (testing / `cat`).
pub struct CatOp;

impl SphereOp for CatOp {
    fn name(&self) -> &str {
        "cat"
    }

    fn output_mode(&self) -> OutputMode {
        OutputMode::ToClient
    }

    fn process(&self, data: &SegmentData, _ctx: &OpCtx, out: &mut OpOutput) -> Result<(), String> {
        for r in &data.records {
            out.emit(0, r.clone());
        }
        Ok(())
    }
}

/// Grep-style filter: emit records containing the needle in `params`
/// (the paper's findBrownDwarf shape: per-record predicate).
pub struct GrepOp;

impl SphereOp for GrepOp {
    fn name(&self) -> &str {
        "grep"
    }

    fn output_mode(&self) -> OutputMode {
        OutputMode::ToClient
    }

    fn process(&self, data: &SegmentData, ctx: &OpCtx, out: &mut OpOutput) -> Result<(), String> {
        let needle = &ctx.params;
        if needle.is_empty() {
            return Err("grep requires a non-empty needle in params".into());
        }
        for r in &data.records {
            if r.windows(needle.len()).any(|w| w == &needle[..]) {
                out.emit(0, r.clone());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::SlaveId;

    pub(crate) fn seg_data(records: Vec<Vec<u8>>) -> SegmentData {
        SegmentData {
            segment: Segment {
                id: 0,
                file: "t.dat".into(),
                first_record: 0,
                n_records: records.len() as u64,
                bytes: records.iter().map(|r| r.len() as u64).sum(),
                locations: vec![0 as SlaveId],
                whole_file: false,
            },
            records,
        }
    }

    #[test]
    fn registry_register_and_lookup() {
        let mut reg = OpRegistry::new();
        reg.register(Arc::new(CatOp)).unwrap();
        reg.register(Arc::new(GrepOp)).unwrap();
        assert!(reg.register(Arc::new(CatOp)).is_err(), "duplicate name");
        assert_eq!(reg.names(), vec!["cat".to_string(), "grep".to_string()]);
        assert!(reg.get("cat").is_ok());
        assert!(reg.get("nope").is_err());
    }

    #[test]
    fn cat_passes_everything() {
        let data = seg_data(vec![b"a".to_vec(), b"b".to_vec()]);
        let mut out = OpOutput::default();
        CatOp.process(&data, &OpCtx::default(), &mut out).unwrap();
        assert_eq!(out.emitted.len(), 2);
    }

    #[test]
    fn grep_filters_by_needle() {
        let data = seg_data(vec![
            b"brown dwarf candidate".to_vec(),
            b"main sequence".to_vec(),
            b"very brown indeed".to_vec(),
        ]);
        let ctx = OpCtx {
            params: b"brown".to_vec(),
        };
        let mut out = OpOutput::default();
        GrepOp.process(&data, &ctx, &mut out).unwrap();
        assert_eq!(out.emitted.len(), 2);
        let empty = OpCtx::default();
        let mut out2 = OpOutput::default();
        assert!(GrepOp.process(&data, &empty, &mut out2).is_err());
    }
}

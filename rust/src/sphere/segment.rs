//! Stream segmentation (paper §3.2, rule 1):
//!
//! "The total data size S and the total number of records R is computed.
//! Say the number of SPEs available for the job is N.  Roughly speaking,
//! the number of records that equals S/N should be assigned to each SPE.
//! The user specifies a minimum and maximum data size S_min and S_max
//! ... If S/N is between these user defined limits, the associated
//! number of records is assigned to each SPE.  Otherwise the nearest
//! boundary S_min or S_max is used instead."
//!
//! Segments never span files and always fall on record boundaries.
//! Files without a record index become one whole-file segment (§4).

use crate::sector::{RecordIndex, SlaveId};

use super::stream::Stream;

/// A unit of work handed to one SPE.
#[derive(Clone, Debug, PartialEq)]
pub struct Segment {
    /// Dense id, stable across reschedules.
    pub id: usize,
    pub file: String,
    pub first_record: u64,
    pub n_records: u64,
    pub bytes: u64,
    /// Slaves holding the file (for locality scheduling).
    pub locations: Vec<SlaveId>,
    /// File-granular segment (no index): the UDF parses the raw file.
    pub whole_file: bool,
}

/// Compute the target segment size per §3.2.
pub fn target_segment_bytes(total_bytes: u64, n_spes: usize, smin: u64, smax: u64) -> u64 {
    assert!(n_spes > 0);
    assert!(smin > 0 && smin <= smax);
    let ideal = total_bytes / n_spes as u64;
    ideal.clamp(smin, smax)
}

/// Split a stream into segments. `index_of` fetches a file's record
/// index (None => whole-file segment).
pub fn segment_stream(
    stream: &Stream,
    n_spes: usize,
    smin: u64,
    smax: u64,
    index_of: impl Fn(&str) -> Option<RecordIndex>,
) -> Vec<Segment> {
    let target = target_segment_bytes(stream.total_bytes(), n_spes, smin, smax);
    let mut segments = Vec::new();
    for f in &stream.files {
        if f.size_bytes == 0 {
            continue;
        }
        let idx = if f.n_records > 0 { index_of(&f.name) } else { None };
        match idx {
            None => segments.push(Segment {
                id: segments.len(),
                file: f.name.clone(),
                first_record: 0,
                n_records: f.n_records,
                bytes: f.size_bytes,
                locations: f.locations.clone(),
                whole_file: true,
            }),
            Some(idx) => {
                debug_assert_eq!(idx.len() as u64, f.n_records, "index mismatch for {}", f.name);
                let mut first = 0usize;
                while first < idx.len() {
                    // Greedily take records until the target is reached,
                    // always at least one record.
                    let mut bytes = 0u64;
                    let mut count = 0usize;
                    while first + count < idx.len() {
                        let sz = idx.get(first + count).unwrap().size;
                        if count > 0 && bytes + sz > target {
                            break;
                        }
                        bytes += sz;
                        count += 1;
                        if bytes >= target {
                            break;
                        }
                    }
                    segments.push(Segment {
                        id: segments.len(),
                        file: f.name.clone(),
                        first_record: first as u64,
                        n_records: count as u64,
                        bytes,
                        locations: f.locations.clone(),
                        whole_file: false,
                    });
                    first += count;
                }
            }
        }
    }
    segments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sphere::stream::StreamFile;

    fn stream_of(sizes: &[(u64, u64)]) -> Stream {
        // (size_bytes, n_records) per file, fixed-size records
        Stream {
            files: sizes
                .iter()
                .enumerate()
                .map(|(i, &(size, recs))| StreamFile {
                    name: format!("f{i}.dat"),
                    size_bytes: size,
                    n_records: recs,
                    locations: vec![i as SlaveId],
                })
                .collect(),
        }
    }

    /// Index factory for streams built by `stream_of`: fixed `rec_size`
    /// records, file size looked up from the stream itself.
    fn fixed_index(s: &Stream, rec_size: u64) -> impl Fn(&str) -> Option<RecordIndex> + '_ {
        move |name| {
            s.files
                .iter()
                .find(|f| f.name == name)
                .map(|f| RecordIndex::fixed(rec_size, f.size_bytes))
        }
    }

    #[test]
    fn target_clamps_to_bounds() {
        assert_eq!(target_segment_bytes(1000, 10, 50, 500), 100);
        assert_eq!(target_segment_bytes(1000, 100, 50, 500), 50); // clamped up
        assert_eq!(target_segment_bytes(10_000, 2, 50, 500), 500); // clamped down
    }

    #[test]
    fn covers_stream_exactly_once() {
        let s = stream_of(&[(1000, 100), (500, 50)]);
        let segs = segment_stream(&s, 4, 100, 400, fixed_index(&s, 10));
        let total_bytes: u64 = segs.iter().map(|g| g.bytes).sum();
        let total_recs: u64 = segs.iter().map(|g| g.n_records).sum();
        assert_eq!(total_bytes, 1500);
        assert_eq!(total_recs, 150);
        // contiguity per file
        for f in ["f0.dat", "f1.dat"] {
            let mut next = 0;
            for g in segs.iter().filter(|g| g.file == f) {
                assert_eq!(g.first_record, next);
                next += g.n_records;
            }
        }
        // ids dense
        for (i, g) in segs.iter().enumerate() {
            assert_eq!(g.id, i);
        }
    }

    #[test]
    fn segment_sizes_respect_bounds() {
        let s = stream_of(&[(10_000, 1000)]);
        let segs = segment_stream(&s, 7, 300, 2000, fixed_index(&s, 10));
        for g in &segs {
            assert!(g.bytes <= 2000);
            // all but the per-file tail reach smin
            let is_tail = g.first_record + g.n_records == 1000;
            if !is_tail {
                assert!(g.bytes >= 300, "segment {} bytes {}", g.id, g.bytes);
            }
        }
    }

    #[test]
    fn unindexed_file_is_whole_segment() {
        let s = stream_of(&[(5000, 0)]);
        let segs = segment_stream(&s, 4, 10, 100, |_| None);
        assert_eq!(segs.len(), 1);
        assert!(segs[0].whole_file);
        assert_eq!(segs[0].bytes, 5000);
    }

    #[test]
    fn variable_records_never_split_mid_record() {
        let lengths = [100u64, 900, 50, 50, 400, 500];
        let idx = RecordIndex::from_lengths(&lengths);
        let s = Stream {
            files: vec![StreamFile {
                name: "v.dat".into(),
                size_bytes: 2000,
                n_records: 6,
                locations: vec![0],
            }],
        };
        let segs = segment_stream(&s, 4, 400, 600, move |_| Some(idx.clone()));
        let total: u64 = segs.iter().map(|g| g.bytes).sum();
        assert_eq!(total, 2000);
        for g in &segs {
            assert!(g.n_records >= 1);
            // a 900-byte record alone may exceed the target; that's legal
        }
        let recs: u64 = segs.iter().map(|g| g.n_records).sum();
        assert_eq!(recs, 6);
    }

    #[test]
    fn empty_and_zero_byte_files_skipped() {
        let s = stream_of(&[(0, 0), (100, 10)]);
        let segs = segment_stream(&s, 2, 10, 1000, fixed_index(&s, 10));
        assert!(segs.iter().all(|g| g.file == "f1.dat"));
    }
}

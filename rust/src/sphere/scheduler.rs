//! Segment → SPE assignment (paper §3.2, rules 2–3):
//!
//!   2. "each data segment is assigned to a SPE on the same machine
//!      whenever possible."
//!   3. "Data segments from the same file are not processed at the same
//!      time, unless not doing so would result in an idle SPE."
//!
//! The scheduler also re-queues segments whose SPE failed (fault
//! handling), grants *speculative* backup attempts for straggling
//! segments (§3.2's slow-node handling, the mechanism behind
//! Hadoop-style speculative execution — DESIGN.md §11), and tracks
//! locality statistics for the benches.
//!
//! Completion is idempotent per segment: with speculation two attempts
//! of one segment can be in flight, the first finisher wins
//! (`complete` returns `true` exactly once per segment id) and the
//! loser is released with `cancel_attempt`.  Segments that exhaust
//! `max_attempts` are recorded in `exhausted` so the driving engine can
//! surface an explicit job failure instead of silently losing work.

use std::collections::{HashMap, HashSet};

use crate::sector::SlaveId;

use super::segment::Segment;

#[derive(Clone, Debug)]
pub struct Scheduler {
    pending: Vec<Segment>,
    /// files currently being processed by some SPE (rule 3).
    in_flight_files: HashMap<String, usize>,
    /// segment id -> attempt count (fault handling + speculation).
    attempts: HashMap<usize, u32>,
    /// segment ids that finished at least once (first-finisher-wins).
    completed: HashSet<usize>,
    /// segment ids that ran out of attempts — an explicit job failure
    /// the engine must report, never a silent drop.
    exhausted: Vec<usize>,
    pub locality_enabled: bool,
    pub max_attempts: u32,
    pub local_assignments: u64,
    pub remote_assignments: u64,
    /// Speculative backup attempts granted (`speculate`).
    pub speculative_launched: u64,
    /// Segments whose *backup* attempt finished first.
    pub speculative_won: u64,
}

impl Scheduler {
    pub fn new(segments: Vec<Segment>, locality_enabled: bool) -> Self {
        Self {
            pending: segments,
            in_flight_files: HashMap::new(),
            attempts: HashMap::new(),
            completed: HashSet::new(),
            exhausted: Vec::new(),
            locality_enabled,
            max_attempts: 4,
            local_assignments: 0,
            remote_assignments: 0,
            speculative_launched: 0,
            speculative_won: 0,
        }
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn is_drained(&self) -> bool {
        self.pending.is_empty()
    }

    /// Attempts consumed so far by segment `id`.
    pub fn attempts_of(&self, id: usize) -> u32 {
        *self.attempts.get(&id).unwrap_or(&0)
    }

    /// Segment ids that exhausted their retry budget, in failure order.
    pub fn exhausted(&self) -> &[usize] {
        &self.exhausted
    }

    /// Segments completed exactly once so far.
    pub fn completed_count(&self) -> usize {
        self.completed.len()
    }

    /// Pick the next segment for an idle SPE on `node`.
    ///
    /// Preference order:
    ///   1. local (node holds a replica) + file not in flight
    ///   2. local + file in flight        (rule 3 waived: SPE would idle
    ///                                     — but only if nothing else fits)
    ///   3. remote + file not in flight
    ///   4. remote + file in flight       (last resort)
    ///
    /// With locality disabled (ablation), "local" stops being preferred.
    pub fn assign(&mut self, node: SlaveId) -> Option<Segment> {
        self.assign_filtered(node, false)
    }

    /// Like `assign`, but with `local_only` refuse remote segments — the
    /// "delay scheduling" knob the job driver uses: an SPE briefly
    /// declines remote work while another node still has local pending
    /// segments, instead of stealing them (paper rule 2: "assigned to a
    /// SPE on the same machine whenever possible").
    pub fn assign_filtered(&mut self, node: SlaveId, local_only: bool) -> Option<Segment> {
        if self.pending.is_empty() {
            return None;
        }
        if local_only
            && self.locality_enabled
            && !self.pending.iter().any(|s| s.locations.contains(&node))
        {
            return None;
        }
        let rank = |seg: &Segment| -> u32 {
            let local = seg.locations.contains(&node);
            let clear = !self.in_flight_files.contains_key(&seg.file);
            match (local && self.locality_enabled, clear) {
                (true, true) => 0,
                (true, false) => 1,
                (false, true) => 2,
                (false, false) => 3,
            }
        };
        // Scan with early exit: rank 0 (local + file clear) cannot be
        // beaten, and ties resolve to the lowest index — the first rank-0
        // hit wins outright. (§Perf: this halves the assignment scan in
        // the common locality-rich case.)
        let mut best: Option<(u32, usize)> = None;
        for (i, seg) in self.pending.iter().enumerate() {
            let r = rank(seg);
            if best.map(|(br, _)| r < br).unwrap_or(true) {
                best = Some((r, i));
                if r == 0 {
                    break;
                }
            }
        }
        let best = best?.1;
        let seg = self.pending.remove(best);
        *self.in_flight_files.entry(seg.file.clone()).or_insert(0) += 1;
        *self.attempts.entry(seg.id).or_insert(0) += 1;
        if seg.locations.contains(&node) {
            self.local_assignments += 1;
        } else {
            self.remote_assignments += 1;
        }
        Some(seg)
    }

    /// Would [`Self::speculate`] grant a backup for segment `id` right
    /// now?  Lets an engine skip the backup-node search for segments
    /// that already finished or spent their budget (the Hadoop baseline
    /// engine scans its whole in-flight set on every check — DESIGN.md
    /// §12).
    pub fn speculatable(&self, id: usize) -> bool {
        !self.completed.contains(&id) && self.attempts_of(id) < self.max_attempts
    }

    /// Grant a speculative backup attempt for an already-running
    /// segment (DESIGN.md §11): the engine noticed the primary attempt
    /// straggling and wants a second copy on `node`.  Refused when the
    /// segment already finished or its attempt budget is spent — the
    /// speculation policy may be eager, the budget is still law.
    pub fn speculate(&mut self, seg: &Segment, node: SlaveId) -> bool {
        if self.completed.contains(&seg.id) {
            return false;
        }
        if self.attempts_of(seg.id) >= self.max_attempts {
            return false;
        }
        *self.in_flight_files.entry(seg.file.clone()).or_insert(0) += 1;
        *self.attempts.entry(seg.id).or_insert(0) += 1;
        if seg.locations.contains(&node) {
            self.local_assignments += 1;
        } else {
            self.remote_assignments += 1;
        }
        self.speculative_launched += 1;
        true
    }

    /// Release the rule-3 file hold of one attempt without completing
    /// the segment (a cancelled speculation loser, or a crashed attempt
    /// whose sibling is still running).
    pub fn cancel_attempt(&mut self, seg: &Segment) {
        self.release_file(seg);
    }

    fn release_file(&mut self, seg: &Segment) {
        if let Some(n) = self.in_flight_files.get_mut(&seg.file) {
            *n -= 1;
            if *n == 0 {
                self.in_flight_files.remove(&seg.file);
            }
        }
    }

    /// An SPE finished a segment. Returns `true` iff this is the first
    /// completion of the segment id — with speculation, the first
    /// finisher wins and later finishers of the same segment are
    /// no-ops the caller must discard.
    pub fn complete(&mut self, seg: &Segment) -> bool {
        self.release_file(seg);
        self.completed.insert(seg.id)
    }

    /// Record that the winning attempt of `id` was the speculative
    /// backup, not the original (counter surfaced in ScenarioReport).
    pub fn record_speculative_win(&mut self) {
        self.speculative_won += 1;
    }

    /// An SPE died processing `seg`: re-queue unless attempts exhausted.
    /// The attempt count is carried in the `attempts` map keyed by
    /// segment id, so a crash-time re-queue preserves it.  Returns
    /// false when the job must abort — the id is also recorded in
    /// `exhausted()` so the failure is reportable, never silent.
    pub fn fail(&mut self, seg: Segment) -> bool {
        self.release_file(&seg);
        let attempts = self.attempts_of(seg.id);
        if attempts >= self.max_attempts {
            self.exhausted.push(seg.id);
            return false;
        }
        self.pending.push(seg);
        true
    }

    /// Fraction of assignments that were node-local.
    pub fn locality_fraction(&self) -> f64 {
        let total = self.local_assignments + self.remote_assignments;
        if total == 0 {
            0.0
        } else {
            self.local_assignments as f64 / total as f64
        }
    }

    /// Invariant check used by property tests: every pending file id is
    /// unique.
    pub fn pending_ids(&self) -> HashSet<usize> {
        self.pending.iter().map(|s| s.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(id: usize, file: &str, loc: &[SlaveId]) -> Segment {
        Segment {
            id,
            file: file.into(),
            first_record: 0,
            n_records: 10,
            bytes: 100,
            locations: loc.to_vec(),
            whole_file: false,
        }
    }

    #[test]
    fn prefers_local_segments() {
        let mut s = Scheduler::new(
            vec![seg(0, "a", &[1]), seg(1, "b", &[0]), seg(2, "c", &[1])],
            true,
        );
        let got = s.assign(1).unwrap();
        assert_eq!(got.id, 0, "node 1 takes its local segment first");
        let got2 = s.assign(0).unwrap();
        assert_eq!(got2.id, 1);
        assert_eq!(s.local_assignments, 2);
        assert_eq!(s.locality_fraction(), 1.0);
    }

    #[test]
    fn same_file_anti_affinity_unless_idle() {
        // Two segments of file "a" (local to node 0) + one of file "b".
        let mut s = Scheduler::new(
            vec![seg(0, "a", &[0]), seg(1, "a", &[0]), seg(2, "b", &[0])],
            true,
        );
        let first = s.assign(0).unwrap();
        assert_eq!(first.file, "a");
        // "a" is in flight: rule 3 steers to "b" even though a#1 is earlier.
        let second = s.assign(0).unwrap();
        assert_eq!(second.file, "b");
        // Only "a" remains: the SPE would idle, so the rule is waived.
        let third = s.assign(0).unwrap();
        assert_eq!(third.file, "a");
        assert!(s.is_drained());
    }

    #[test]
    fn remote_assignment_when_nothing_local() {
        let mut s = Scheduler::new(vec![seg(0, "a", &[5])], true);
        let got = s.assign(1).unwrap();
        assert_eq!(got.id, 0);
        assert_eq!(s.remote_assignments, 1);
    }

    #[test]
    fn locality_disabled_is_fifo() {
        let mut s = Scheduler::new(
            vec![seg(0, "a", &[9]), seg(1, "b", &[1])],
            false,
        );
        // node 1 would prefer seg 1 with locality on; off -> takes seg 0.
        assert_eq!(s.assign(1).unwrap().id, 0);
    }

    #[test]
    fn complete_releases_file() {
        let mut s = Scheduler::new(vec![seg(0, "a", &[0]), seg(1, "a", &[0])], true);
        let first = s.assign(0).unwrap();
        assert!(s.complete(&first), "first completion wins");
        let second = s.assign(0).unwrap();
        assert_eq!(second.file, "a");
        assert_eq!(s.pending_count(), 0);
    }

    #[test]
    fn delay_scheduling_declines_remote_work() {
        // Only remote segments pending: a local_only request must come
        // back empty (the SPE waits its patience out), while a plain
        // assign hands the remote segment over.
        let mut s = Scheduler::new(vec![seg(0, "a", &[5]), seg(1, "b", &[5])], true);
        assert!(s.assign_filtered(1, true).is_none(), "declined while local_only");
        assert_eq!(s.pending_count(), 2, "nothing was consumed by the refusal");
        let got = s.assign_filtered(1, false).unwrap();
        assert_eq!(got.id, 0);
        assert_eq!(s.remote_assignments, 1);
    }

    #[test]
    fn delay_scheduling_still_serves_local_segments() {
        // A remote segment sits first in the queue; with local_only the
        // node must skip it and take its own.
        let mut s = Scheduler::new(vec![seg(0, "a", &[5]), seg(1, "b", &[1])], true);
        let got = s.assign_filtered(1, true).unwrap();
        assert_eq!(got.id, 1, "local segment wins under local_only");
        assert_eq!(s.local_assignments, 1);
    }

    #[test]
    fn delay_scheduling_is_inert_with_locality_disabled() {
        // The ablation switch turns rule 2 off entirely: local_only
        // must not starve the SPE when locality scheduling is disabled.
        let mut s = Scheduler::new(vec![seg(0, "a", &[5])], false);
        let got = s.assign_filtered(1, true).unwrap();
        assert_eq!(got.id, 0);
    }

    #[test]
    fn rule3_waiver_prefers_busy_local_over_clear_remote() {
        // Rank order check: (local, file-in-flight) beats
        // (remote, file-clear) — rule 3 is waived before rule 2 is.
        let mut s = Scheduler::new(
            vec![seg(0, "a", &[0]), seg(1, "a", &[0]), seg(2, "b", &[9])],
            true,
        );
        let first = s.assign(0).unwrap();
        assert_eq!(first.id, 0, "local + clear wins outright");
        let second = s.assign(0).unwrap();
        assert_eq!(
            second.id, 1,
            "file 'a' is in flight, but the local copy still beats remote 'b'"
        );
        assert_eq!(s.local_assignments, 2);
        assert_eq!(s.remote_assignments, 0);
    }

    #[test]
    fn rule3_waiver_releases_after_complete() {
        // Once the in-flight segment completes, the same file is rank-0
        // again: the waiver path must not leave the file marked busy.
        let mut s = Scheduler::new(vec![seg(0, "a", &[0]), seg(1, "a", &[0])], true);
        let first = s.assign(0).unwrap();
        let second = s.assign(0).unwrap(); // waiver: same file, SPE would idle
        s.complete(&first);
        s.complete(&second);
        let mut s2 = Scheduler::new(vec![seg(0, "a", &[0]), seg(1, "b", &[9])], true);
        let a = s2.assign(0).unwrap();
        assert_eq!(a.file, "a");
        s2.complete(&a);
        // "a" fully released: its fail() requeue re-enters at rank 0
        // (local + clear) and beats the earlier-queued remote "b".
        assert!(s2.fail(a.clone()), "requeue after release is accepted");
        let next = s2.assign(0).unwrap();
        assert_eq!(next.file, "a", "released file is clear again");
    }

    #[test]
    fn fail_requeues_until_attempts_exhausted() {
        let mut s = Scheduler::new(vec![seg(0, "a", &[0])], true);
        s.max_attempts = 2;
        let a1 = s.assign(0).unwrap();
        assert!(s.fail(a1), "first failure requeues");
        assert_eq!(s.pending_count(), 1);
        let a2 = s.assign(0).unwrap();
        assert!(!s.fail(a2), "attempts exhausted aborts the job");
        assert_eq!(s.exhausted(), &[0], "exhaustion is recorded, not silent");
    }

    #[test]
    fn requeue_preserves_attempt_count() {
        // Regression: a crash-time re-queue must not reset the budget —
        // the attempt count lives in the id-keyed map, not the segment.
        let mut s = Scheduler::new(vec![seg(0, "a", &[0])], true);
        s.max_attempts = 3;
        let a1 = s.assign(0).unwrap();
        assert_eq!(s.attempts_of(0), 1);
        assert!(s.fail(a1));
        let a2 = s.assign(0).unwrap();
        assert_eq!(s.attempts_of(0), 2, "requeue kept the first attempt");
        assert!(s.fail(a2));
        let a3 = s.assign(0).unwrap();
        assert_eq!(s.attempts_of(0), 3);
        assert!(!s.fail(a3), "third failure exhausts max_attempts = 3");
    }

    #[test]
    fn speculation_first_finisher_wins() {
        let mut s = Scheduler::new(vec![seg(0, "a", &[0, 3])], true);
        let primary = s.assign(0).unwrap();
        assert!(s.speculate(&primary, 3), "backup granted on the replica");
        assert_eq!(s.speculative_launched, 1);
        assert_eq!(s.attempts_of(0), 2, "speculation consumes an attempt");
        // Backup finishes first: it wins...
        assert!(s.complete(&primary), "first finisher wins");
        s.record_speculative_win();
        // ...and the loser is a cancelled attempt, then a late no-op.
        s.cancel_attempt(&primary);
        assert!(!s.complete(&primary), "second completion is discarded");
        assert_eq!(s.completed_count(), 1, "segment completed exactly once");
        assert_eq!(s.speculative_won, 1);
    }

    #[test]
    fn speculation_respects_budget_and_completion() {
        let mut s = Scheduler::new(vec![seg(0, "a", &[0, 3])], true);
        s.max_attempts = 2;
        let primary = s.assign(0).unwrap();
        assert!(s.speculatable(0), "one attempt used, budget allows a backup");
        assert!(s.speculate(&primary, 3));
        assert!(!s.speculatable(0), "budget spent");
        assert!(
            !s.speculate(&primary, 3),
            "budget spent: a third attempt is refused"
        );
        s.complete(&primary);
        s.cancel_attempt(&primary);
        assert!(!s.speculatable(0), "completed segments never respeculate");
        assert!(!s.speculate(&primary, 3), "completed segments never respeculate");
    }

    #[test]
    fn speculation_releases_rule3_holds() {
        // Two attempts of "a" in flight hold the file twice; both the
        // win and the cancel must release, or "a"'s sibling segment
        // would see a stale in-flight mark forever.
        let mut s = Scheduler::new(
            vec![seg(0, "a", &[0, 3]), seg(1, "a", &[0]), seg(2, "b", &[0])],
            true,
        );
        let primary = s.assign(0).unwrap();
        assert_eq!(primary.id, 0);
        assert!(s.speculate(&primary, 3));
        s.complete(&primary);
        s.cancel_attempt(&primary);
        // "a" is clear again: segment 1 (file a, local) outranks "b".
        let next = s.assign(0).unwrap();
        assert_eq!(next.id, 1, "file hold fully released after win+cancel");
    }
}

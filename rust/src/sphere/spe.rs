//! The Sphere Processing Element (paper §3.2): "The SPE runs in a loop
//! and consists of the following four steps":
//!
//!   1. accept a new data segment from the client (file name, offset,
//!      number of rows, parameters);
//!   2. read the segment and its record index from local disk or from a
//!      remote disk managed by Sector;
//!   3. run the Sphere operator over the segment, periodically sending
//!      progress acknowledgments;
//!   4. write results to the destinations the output stream specifies
//!      and acknowledge completion.
//!
//! Steps 1 and 4's routing live in `job.rs`; this module implements the
//! data path (2–3).

use crate::sector::{SectorCloud, SlaveId};

use super::segment::Segment;
use super::udf::{OpCtx, OpOutput, SegmentData, SphereOp};

/// Progress acks are sent every this many records (paper: "periodically
/// sends acknowledgments ... about the progress of the processing").
pub const ACK_EVERY_RECORDS: u64 = 10_000;

/// One Sphere Processing Element bound to a node.
#[derive(Clone, Copy, Debug)]
pub struct Spe {
    pub node: SlaveId,
    /// Slot index on the node (spes_per_node may be > 1).
    pub slot: usize,
}

/// Outcome of one segment execution.
#[derive(Debug)]
pub struct SpeResult {
    pub segment: Segment,
    pub emitted: Vec<(u32, Vec<u8>)>,
    pub bytes_read: u64,
    /// Whether the read was node-local (locality accounting).
    pub read_local: bool,
    /// Progress acks that would have been sent (metrics).
    pub acks_sent: u64,
}

impl Spe {
    pub fn new(node: SlaveId, slot: usize) -> Self {
        Self { node, slot }
    }

    /// Execute steps 2–3 for one segment.
    pub fn run_segment(
        &self,
        cloud: &SectorCloud,
        op: &dyn SphereOp,
        ctx: &OpCtx,
        segment: Segment,
    ) -> Result<SpeResult, String> {
        // ---- step 2: read the data segment (local replica preferred) ----
        let read_local = segment.locations.contains(&self.node);
        let src = if read_local {
            self.node
        } else {
            *segment
                .locations
                .first()
                .ok_or_else(|| format!("segment {} has no locations", segment.id))?
        };
        let slave = cloud.slave(src);

        let records: Vec<Vec<u8>> = if segment.whole_file {
            vec![slave.get_file(&segment.file)?]
        } else {
            let index = slave
                .get_index(&segment.file)
                .ok_or_else(|| format!("missing .idx for {}", segment.file))?;
            let first = segment.first_record as usize;
            let count = segment.n_records as usize;
            if first + count > index.len() {
                return Err(format!(
                    "segment {} spans records [{first}, {}) but {} has {}",
                    segment.id,
                    first + count,
                    segment.file,
                    index.len()
                ));
            }
            let start = index.get(first).unwrap().offset;
            let span = index.span_bytes(first, count);
            let bytes = slave.get_range(&segment.file, start, span)?;
            // Split the contiguous span back into records.
            let mut records = Vec::with_capacity(count);
            let mut cursor = 0usize;
            for i in first..first + count {
                let sz = index.get(i).unwrap().size as usize;
                records.push(bytes[cursor..cursor + sz].to_vec());
                cursor += sz;
            }
            records
        };
        let bytes_read: u64 = records.iter().map(|r| r.len() as u64).sum();

        // ---- step 3: run the operator, counting progress acks ----
        let data = SegmentData {
            segment: segment.clone(),
            records,
        };
        let mut out = OpOutput::default();
        op.process(&data, ctx, &mut out)?;
        let acks_sent = segment.n_records / ACK_EVERY_RECORDS + 1; // final ack

        cloud.metrics.incr("sphere.segments_processed");
        cloud.metrics.add("sphere.bytes_read", bytes_read);
        if read_local {
            cloud.metrics.incr("sphere.local_reads");
        } else {
            cloud.metrics.incr("sphere.remote_reads");
        }

        Ok(SpeResult {
            segment,
            emitted: out.emitted,
            bytes_read,
            read_local,
            acks_sent,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::{RecordIndex, SectorCloud};
    use crate::sphere::udf::CatOp;

    fn cloud_with_file() -> SectorCloud {
        let c = SectorCloud::builder().nodes(3).seed(5).build().unwrap();
        let ip = "10.0.0.7".parse().unwrap();
        let data: Vec<u8> = (0..60u8).collect();
        let idx = RecordIndex::fixed(10, 60);
        c.upload(ip, "f.dat", &data, Some(&idx), Some(1)).unwrap();
        c
    }

    fn seg(first: u64, n: u64) -> Segment {
        Segment {
            id: 0,
            file: "f.dat".into(),
            first_record: first,
            n_records: n,
            bytes: n * 10,
            locations: vec![1],
            whole_file: false,
        }
    }

    #[test]
    fn local_read_of_middle_records() {
        let c = cloud_with_file();
        let spe = Spe::new(1, 0);
        let r = spe
            .run_segment(&c, &CatOp, &OpCtx::default(), seg(2, 3))
            .unwrap();
        assert!(r.read_local);
        assert_eq!(r.bytes_read, 30);
        assert_eq!(r.emitted.len(), 3);
        assert_eq!(r.emitted[0].1, (20..30).collect::<Vec<u8>>());
        assert_eq!(r.acks_sent, 1);
    }

    #[test]
    fn remote_read_when_not_local() {
        let c = cloud_with_file();
        let spe = Spe::new(0, 0); // data lives on node 1
        let r = spe
            .run_segment(&c, &CatOp, &OpCtx::default(), seg(0, 6))
            .unwrap();
        assert!(!r.read_local);
        assert_eq!(r.emitted.len(), 6);
        assert_eq!(c.metrics.get("sphere.remote_reads"), 1);
    }

    #[test]
    fn out_of_range_segment_rejected() {
        let c = cloud_with_file();
        let spe = Spe::new(1, 0);
        let err = spe
            .run_segment(&c, &CatOp, &OpCtx::default(), seg(4, 5))
            .unwrap_err();
        assert!(err.contains("spans records"), "{err}");
    }

    #[test]
    fn whole_file_segment_reads_raw_bytes() {
        let c = cloud_with_file();
        let spe = Spe::new(1, 0);
        let mut s = seg(0, 6);
        s.whole_file = true;
        let r = spe.run_segment(&c, &CatOp, &OpCtx::default(), s).unwrap();
        assert_eq!(r.emitted.len(), 1, "one raw-file record");
        assert_eq!(r.emitted[0].1.len(), 60);
    }
}

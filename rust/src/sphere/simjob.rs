//! Paper-scale Sphere job simulation (Tables 1–2 substitute).
//!
//! The real-mode `job::run_job` proves the coordination code on MB-scale
//! data; this module runs the *same workload structure* — two-stage
//! Terasort (partition+shuffle, then local sort), single-client
//! Terasplit, and file generation — at the paper's 10 GB/node scale
//! against the discrete-event testbed models.
//!
//! Mechanisms modelled (all physical; constants fitted only to the
//! single-node table cells, see DESIGN.md §3):
//!
//!   * disk: sequential read/write rates, serialized spindle ops, an
//!     interleaving penalty when many network streams land on one disk
//!     *and* memory is too small to buffer them (the 4 GB WAN servers
//!     suffer this; the 16 GB LAN servers absorb it in page cache);
//!   * network: max-min fair bandwidth sharing over NIC/site links with
//!     per-flow caps from the transport models (UDT: RTT-independent
//!     but with efficiency degrading mildly on long lossy paths; TCP:
//!     window/Mathis-limited);
//!   * external sort: a second read+write pass when a node's partition
//!     exceeds memory;
//!   * coordination: per-segment GMP/Chord lookup cost scaling with
//!     log(n) hops × RTT.

use crate::config::{SimConfig, TransportKind};
use crate::sim::netsim::NetSim;
use crate::topology::Testbed;
use crate::transport::TransportModels;

/// Outcome of one simulated benchmark run.
#[derive(Clone, Debug)]
pub struct SortSimResult {
    pub terasort_secs: f64,
    pub terasplit_secs: f64,
    /// Stage breakdown for the ablation benches.
    pub stage_a_secs: f64,
    pub stage_b_secs: f64,
    pub shuffle_gbytes: f64,
}

/// UDT efficiency on a path: the base efficiency degrades mildly with
/// RTT (loss recovery and receive-buffer pressure on long paths; the
/// paper's own SDSS transfer measured 0.81 across the continent vs
/// ~0.9 tuned single-site).
pub fn udt_efficiency(base: f64, rtt_secs: f64) -> f64 {
    (base - 2.2 * rtt_secs).max(0.35)
}

/// Effective disk write rate at a node receiving `streams` concurrent
/// network streams: interleaved writes seek unless memory can buffer.
fn interleaved_write_bps(cfg: &SimConfig, bytes_per_node: f64, streams: usize) -> f64 {
    let base = cfg.hardware.disk_write_bps * cfg.sphere.io_efficiency;
    if streams <= 1 || fits_in_cache(cfg, bytes_per_node) {
        base
    } else {
        // Each extra stream adds seek interleaving; 2008 SATA arrays under
        // memory pressure degrade steeply (calibrated to the Table 1
        // Sphere column; the 16 GB LAN boxes never hit this path).
        base / (1.0 + 0.35 * (streams as f64 - 1.0).min(8.0))
    }
}

/// Memory large enough for the page cache to absorb/re-order IO?
fn fits_in_cache(cfg: &SimConfig, bytes_per_node: f64) -> bool {
    bytes_per_node <= 0.7 * cfg.hardware.mem_bytes as f64
}

/// Stage-B first-pass read rate: the received bucket data is fragmented
/// across the disk when many senders interleaved (seeky reads), unless
/// memory buffered the writes.
fn fragmented_read_bps(cfg: &SimConfig, bytes_per_node: f64, streams: usize) -> f64 {
    let base = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
    if streams <= 1 || fits_in_cache(cfg, bytes_per_node) {
        base
    } else {
        base / (1.0 + 0.30 * (streams as f64 - 1.0).min(8.0))
    }
}

/// Per-segment coordination cost: GMP handshake + Chord lookup hops.
fn coordination_secs(testbed: &Testbed, n_segments_per_node: f64) -> f64 {
    let n = testbed.nodes() as f64;
    let hops = (n.log2().ceil()).max(1.0);
    let mean_rtt = {
        let mut acc = 0.0f64;
        let mut cnt = 0.0f64;
        for a in 0..testbed.nodes() {
            for b in 0..testbed.nodes() {
                acc += testbed.rtt_secs(a, b);
                cnt += 1.0;
            }
        }
        acc / cnt.max(1.0)
    };
    // lookup + SPE handshake + completion ack, serialized per SPE.
    n_segments_per_node * (hops * mean_rtt + 2.0 * mean_rtt)
}

/// Simulate two-stage Sphere Terasort: every node holds
/// `bytes_per_node`; stage A reads, hash-partitions and shuffles; stage
/// B sorts each node's received partition locally.
pub fn simulate_sphere_terasort(
    testbed: &Testbed,
    cfg: &SimConfig,
    bytes_per_node: f64,
) -> SortSimResult {
    let n = testbed.nodes();
    let models = TransportModels::default();
    let b = bytes_per_node;
    let read_bps = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;

    // ---------------- stage A: partition + shuffle ----------------
    // Each node streams B bytes off disk, emits B/n to each destination.
    // The same spindle also absorbs B incoming bytes; ops serialize.
    let streams_in = n - 1;
    let write_bps = interleaved_write_bps(cfg, b, streams_in.max(1));
    let disk_secs_a = b / read_bps + b / write_bps;

    // Network: n*(n-1) flows of B/n bytes with UDT caps.
    let mut net = NetSim::new();
    let links = testbed.build_network(&mut net);
    let mut max_setup: f64 = 0.0;
    if n > 1 {
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let path = testbed.path(&links, src, dst);
                let bottleneck = testbed.bottleneck_bps(&net, &path);
                let rtt = testbed.rtt_secs(src, dst);
                let cap = match cfg.sphere_transport {
                    TransportKind::Udt => {
                        udt_efficiency(models.udt.efficiency, rtt) * bottleneck
                    }
                    TransportKind::Tcp => models.tcp.rate_cap(bottleneck, rtt),
                }
                // The sender reads from one disk feeding n destinations.
                .min(read_bps / (n as f64 - 1.0))
                // The receiver's disk splits across incoming streams.
                .min(write_bps / (n as f64 - 1.0).max(1.0));
                net.start_flow(&path, b / n as f64, cap);
                let setup =
                    models.setup_secs_for(cfg.sphere_transport, rtt, cfg.sector.connection_cache);
                max_setup = max_setup.max(setup);
            }
        }
    }
    let net_secs = if n > 1 { net.run_to_idle() + max_setup } else { 0.0 };

    // CPU partitioning overlaps the read; only binds if slower than disk.
    let cpu_secs_a = b / cfg.cpu.partition_bps;
    // Reads/writes overlap sends in the SPE pipeline; stage time is the
    // max of the resource totals (all are busy concurrently).
    let seg_bytes = (b / (n as f64 * cfg.sphere.spes_per_node as f64))
        .clamp(cfg.sphere.seg_min_bytes as f64, cfg.sphere.seg_max_bytes as f64);
    let segs_per_node = (b / seg_bytes).ceil();
    let coord = coordination_secs(testbed, segs_per_node);
    let stage_a = disk_secs_a.max(net_secs).max(cpu_secs_a) + coord;

    // ---------------- stage B: local sort ----------------
    let external = !fits_in_cache(cfg, b);
    let write_bps_b = cfg.hardware.disk_write_bps * cfg.sphere.io_efficiency;
    // First pass reads the (possibly fragmented) shuffle output; the
    // external-sort merge pass reads back sequential runs.
    let read1_bps = fragmented_read_bps(cfg, b, streams_in.max(1));
    let io_secs_b = if external {
        b / read1_bps + b / write_bps_b + b / read_bps + b / write_bps_b
    } else {
        b / read1_bps + b / write_bps_b
    };
    // Paper §6.4: Sphere's Terasort used ONE of the cores.
    let cpu_secs_b = b / (cfg.cpu.sort_bps * cfg.sphere.spes_per_node as f64);
    let o = cfg.sphere.io_overlap;
    let stage_b =
        io_secs_b.max(cpu_secs_b) + (1.0 - o) * io_secs_b.min(cpu_secs_b) + coord;

    SortSimResult {
        terasort_secs: stage_a + stage_b,
        terasplit_secs: 0.0,
        stage_a_secs: stage_a,
        stage_b_secs: stage_b,
        shuffle_gbytes: b * (n as f64 - 1.0) / 1e9,
    }
}

/// Simulate Terasplit over Sphere-sorted data: a single client reads the
/// distributed sorted files *sequentially* (the paper's version "read
/// (possibly distributed) data into a single client to compute the
/// split") and streams them through the entropy scan.
pub fn simulate_sphere_terasplit(
    testbed: &Testbed,
    cfg: &SimConfig,
    bytes_per_node: f64,
) -> f64 {
    let models = TransportModels::default();
    let read_bps = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
    let mut total = 0.0;
    // Client sits at node 0's site.
    for src in 0..testbed.nodes() {
        let rtt = testbed.rtt_secs(0, src);
        let net_cap = if src == 0 {
            f64::INFINITY // local file: disk-bound
        } else {
            match cfg.sphere_transport {
                TransportKind::Udt => {
                    udt_efficiency(models.udt.efficiency, rtt) * testbed.nic_bps
                }
                TransportKind::Tcp => models.tcp.rate_cap(testbed.nic_bps, rtt),
            }
        };
        let rate = read_bps.min(net_cap).min(cfg.cpu.scan_bps);
        let setup =
            models.setup_secs_for(cfg.sphere_transport, rtt, cfg.sector.connection_cache);
        total += bytes_per_node / rate + setup;
    }
    // Split evaluation on the gathered histogram is negligible (PJRT
    // split_gain runs in ms); the scan dominates.
    total
}

/// Simulate Sphere file generation (§6.3): each node writes
/// `bytes_per_node` of synthetic records to its local disk.
pub fn simulate_sphere_filegen(cfg: &SimConfig, bytes_per_node: f64) -> f64 {
    let write_bps = cfg.hardware.disk_write_bps * cfg.sphere.io_efficiency;
    let gen_bps = cfg.cpu.partition_bps; // record synthesis is partition-like
    bytes_per_node / write_bps.min(gen_bps)
}

/// Full Table-1/2 row: Terasort + Terasplit for one node count.
pub fn simulate_sphere_row(testbed: &Testbed, cfg: &SimConfig, bytes_per_node: f64) -> SortSimResult {
    let mut r = simulate_sphere_terasort(testbed, cfg, bytes_per_node);
    r.terasplit_secs = simulate_sphere_terasplit(testbed, cfg, bytes_per_node);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GB;

    fn wan(n: usize) -> (Testbed, SimConfig) {
        (Testbed::wan_testbed(n), SimConfig::wan_default())
    }

    fn lan(n: usize) -> (Testbed, SimConfig) {
        (Testbed::lan_testbed(n), SimConfig::lan_default())
    }

    #[test]
    fn single_node_wan_near_paper() {
        let (t, c) = wan(1);
        let r = simulate_sphere_row(&t, &c, 10.0 * GB as f64);
        // Paper Table 1: Sphere Terasort 905 s, Terasplit 110 s.
        assert!(
            (r.terasort_secs - 905.0).abs() / 905.0 < 0.25,
            "terasort {:.0} s vs paper 905 s",
            r.terasort_secs
        );
        assert!(
            (r.terasplit_secs - 110.0).abs() / 110.0 < 0.35,
            "terasplit {:.0} s vs paper 110 s",
            r.terasplit_secs
        );
    }

    #[test]
    fn single_node_lan_near_paper() {
        let (t, c) = lan(1);
        let r = simulate_sphere_row(&t, &c, 10.0 * GB as f64);
        // Paper Table 2: Sphere Terasort 408 s, Terasplit 96 s.
        assert!(
            (r.terasort_secs - 408.0).abs() / 408.0 < 0.25,
            "terasort {:.0} s vs paper 408 s",
            r.terasort_secs
        );
        assert!(
            (r.terasplit_secs - 96.0).abs() / 96.0 < 0.35,
            "terasplit {:.0} s vs paper 96 s",
            r.terasplit_secs
        );
    }

    #[test]
    fn wan_degrades_with_sites_lan_stays_flat() {
        let b = 10.0 * GB as f64;
        let (t1, c) = wan(1);
        let (t6, _) = wan(6);
        let r1 = simulate_sphere_terasort(&t1, &c, b);
        let r6 = simulate_sphere_terasort(&t6, &c, b);
        assert!(
            r6.terasort_secs > 1.2 * r1.terasort_secs,
            "WAN 6-node should degrade: {:.0} vs {:.0}",
            r6.terasort_secs,
            r1.terasort_secs
        );
        let (l1, lc) = lan(1);
        let (l8, _) = lan(8);
        let s1 = simulate_sphere_terasort(&l1, &lc, b);
        let s8 = simulate_sphere_terasort(&l8, &lc, b);
        assert!(
            s8.terasort_secs < 1.25 * s1.terasort_secs,
            "LAN should stay nearly flat: {:.0} vs {:.0}",
            s8.terasort_secs,
            s1.terasort_secs
        );
    }

    #[test]
    fn terasplit_grows_linearly_with_nodes() {
        let b = 10.0 * GB as f64;
        let (t2, c) = wan(2);
        let (t4, _) = wan(4);
        let s2 = simulate_sphere_terasplit(&t2, &c, b);
        let s4 = simulate_sphere_terasplit(&t4, &c, b);
        assert!(s4 > 1.7 * s2, "sequential client reads: {s4:.0} vs {s2:.0}");
    }

    #[test]
    fn filegen_near_paper() {
        // Paper §6.3: Sphere generated a 10 GB file in 68 s per node.
        let c = SimConfig::lan_default();
        let secs = simulate_sphere_filegen(&c, 10.0 * GB as f64);
        assert!((secs - 68.0).abs() / 68.0 < 0.2, "filegen {secs:.0} s vs 68 s");
    }

    #[test]
    fn tcp_transport_ablation_hurts_on_wan() {
        let b = 10.0 * GB as f64;
        let (t, mut c) = wan(6);
        // Terasort is disk-bound, so the transport swap costs little
        // there; Terasplit streams across the WAN and shows the paper's
        // UDT-vs-TCP asymmetry directly.
        let udt_sort = simulate_sphere_terasort(&t, &c, b);
        let udt_split = simulate_sphere_terasplit(&t, &c, b);
        c.sphere_transport = TransportKind::Tcp;
        let tcp_sort = simulate_sphere_terasort(&t, &c, b);
        let tcp_split = simulate_sphere_terasplit(&t, &c, b);
        assert!(
            tcp_sort.terasort_secs >= udt_sort.terasort_secs,
            "tcp sort {:.0} vs udt {:.0}",
            tcp_sort.terasort_secs,
            udt_sort.terasort_secs
        );
        assert!(
            tcp_split > 2.0 * udt_split,
            "WAN split over tcp {tcp_split:.0} vs udt {udt_split:.0}"
        );
    }
}

//! # Sector/Sphere — high-performance data-cloud data mining
//!
//! A full reproduction of *"Data Mining Using High Performance Data
//! Clouds: Experimental Studies Using Sector and Sphere"* (Grossman &
//! Gu, KDD 2008) as a three-layer Rust + JAX + Pallas stack.
//!
//! ## Architecture map
//!
//! Production stack (the system under study):
//!
//! * [`sector`] — the storage cloud: distributed, replicated, indexed
//!   files located through a peer-to-peer routing layer, with ACL-gated
//!   writes (paper §4).
//! * [`sphere`] — the compute cloud: Sphere Processing Elements apply
//!   user-defined functions to stream segments with locality-aware
//!   scheduling ([`sphere::scheduler`], rules 2–3), shuffled output
//!   streams, crash re-queue and speculative re-execution (paper §3).
//! * [`transport`] / [`routing`] — the networking layer: UDT rate-based
//!   transport, the Group Messaging Protocol, connection caching, and
//!   Chord routing (paper §5).
//! * [`service`] — the service layer: client sessions walking the §4
//!   access flow and a multi-tenant traffic engine serving up to
//!   millions of simulated clients with admission control and SLO
//!   reporting (DESIGN.md §10).
//! * [`cluster`] — the in-process "real mode" cluster used by the
//!   examples: real files, real threads, emulated network.
//!
//! Workloads and baselines (what the paper measures):
//!
//! * [`mining`] — the evaluation workloads on real bytes: Terasort
//!   ([`mining::terasort`]), Terasplit ([`mining::terasplit`]), and
//!   the Angle application (paper §6–7) — synthetic sensor traces
//!   ([`mining::pcap`]), feature extraction ([`mining::features`]),
//!   windowed k-means ([`mining::kmeans`]) and emergent-cluster
//!   detection/scoring ([`mining::emergent`]), tied together by
//!   [`mining::angle`].
//! * [`hadoop`] — the comparison baseline: an HDFS-like block store, a
//!   MapReduce engine with Hadoop 0.16's cost structure (paper §6),
//!   and an event-driven baseline engine that runs on the same
//!   scenario substrate as Sphere for the `[compare]` head-to-head
//!   (DESIGN.md §12).
//!
//! Experiment substrate (how paper-scale runs are produced):
//!
//! * [`sim`] — the discrete-event substrate: max-min fair flow network
//!   ([`sim::netsim`]), virtual clock ([`sim::event`]), disk and CPU
//!   models — standing in for the paper's physical testbeds
//!   (substitutions: DESIGN.md §2).
//! * [`topology`] — parameterized testbeds: sites × racks × nodes with
//!   three link tiers, paper presets included.
//! * [`scenario`] — the scenario engine (DESIGN.md §4): TOML-described
//!   runs composing a topology, a workload and a fault plan into one
//!   deterministic experiment.  Sub-drivers: [`scenario::colocate`]
//!   (compute + serving on one substrate, DESIGN.md §11),
//!   [`scenario::compare`] (Sphere vs Hadoop head-to-head, §12) and
//!   [`scenario::angle`] (the five-stage Angle pipeline — ingest,
//!   extract, aggregate, cluster, score — fault-visible end to end,
//!   §13).
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/
//!   Pallas artifacts (`artifacts/*.hlo.txt`) and executes them on the
//!   request path without Python (DESIGN.md §8).
//!
//! The remaining modules are offline-environment substrates built from
//! scratch: [`cli`], [`config`], [`bench`], [`testkit`], [`metrics`],
//! [`util`].
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/`
//! for the reproduction of every table and figure in the paper
//! (experiment index: DESIGN.md §5; README "Reproducing the paper"
//! for the preset/CLI/bench matrix).

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod hadoop;
pub mod metrics;
pub mod mining;
pub mod routing;
pub mod runtime;
pub mod scenario;
pub mod sector;
pub mod service;
pub mod sim;
pub mod sphere;
pub mod testkit;
pub mod topology;
pub mod transport;
pub mod util;

//! # Sector/Sphere — high-performance data-cloud data mining
//!
//! A full reproduction of *"Data Mining Using High Performance Data
//! Clouds: Experimental Studies Using Sector and Sphere"* (Grossman &
//! Gu, KDD 2008) as a three-layer Rust + JAX + Pallas stack:
//!
//! * [`sector`] — the storage cloud: distributed, replicated, indexed
//!   files located through a peer-to-peer routing layer, with ACL-gated
//!   writes (paper §4).
//! * [`sphere`] — the compute cloud: Sphere Processing Elements apply
//!   user-defined functions to stream segments with locality-aware
//!   scheduling and shuffled output streams (paper §3).
//! * [`transport`] / [`routing`] — the networking layer: UDT rate-based
//!   transport, the Group Messaging Protocol, connection caching, and
//!   Chord routing (paper §5).
//! * [`hadoop`] — the comparison baseline: an HDFS-like block store, a
//!   MapReduce engine with Hadoop 0.16's cost structure (paper §6),
//!   and an event-driven baseline engine that runs on the same
//!   scenario substrate as Sphere for the `[compare]` head-to-head
//!   (DESIGN.md §12).
//! * [`mining`] — the evaluation workloads: Terasort, Terasplit, and
//!   the Angle anomaly-detection application (paper §6–7).
//! * [`sim`] — the discrete-event testbed simulator standing in for the
//!   paper's 6-node WAN and 8-node rack (substitutions: DESIGN.md §2).
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/
//!   Pallas artifacts (`artifacts/*.hlo.txt`) and executes them on the
//!   request path without Python.
//! * [`cluster`] — the in-process "real mode" cluster used by the
//!   examples: real files, real threads, emulated network.
//! * [`scenario`] — the scenario engine: TOML-described runs composing
//!   a generated topology ([`topology`]), a workload and a fault plan
//!   into one deterministic paper-scale experiment (DESIGN.md §4).
//! * [`service`] — the service layer: client sessions walking the §4
//!   access flow and a multi-tenant traffic engine serving up to
//!   millions of simulated clients with admission control and SLO
//!   reporting (DESIGN.md §10).
//!
//! The remaining modules are offline-environment substrates built from
//! scratch: [`cli`], [`config`], [`bench`], [`testkit`], [`metrics`],
//! [`util`].
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/`
//! for the reproduction of every table and figure in the paper
//! (experiment index: DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod hadoop;
pub mod metrics;
pub mod mining;
pub mod routing;
pub mod runtime;
pub mod scenario;
pub mod sector;
pub mod service;
pub mod sim;
pub mod sphere;
pub mod testkit;
pub mod topology;
pub mod transport;
pub mod util;

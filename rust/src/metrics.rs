//! Lightweight metrics registry: counters, gauges and timers, shared
//! across threads.  The coordinator exposes one registry per cluster;
//! `report()` renders the table the CLI prints at job completion.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::stats::LogHist;

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    /// Log-bucketed histograms: O(1) memory per timer no matter how
    /// many samples a long run records (was an unbounded `Vec<f64>`).
    timers: Mutex<BTreeMap<String, LogHist>>,
}

/// Cheap-to-clone handle to a shared metrics registry.
#[derive(Clone, Default)]
pub struct Metrics {
    inner: Arc<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.inner.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counter(name).load(Ordering::Relaxed)
    }

    pub fn gauge_set(&self, name: &str, v: i64) {
        let mut m = self.inner.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_default()
            .store(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> i64 {
        let m = self.inner.gauges.lock().unwrap();
        m.get(name).map(|g| g.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Record a duration sample in seconds under `name`.
    pub fn observe_secs(&self, name: &str, secs: f64) {
        let mut m = self.inner.timers.lock().unwrap();
        m.entry(name.to_string()).or_default().observe(secs);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe_secs(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Snapshot of a timer's histogram, or `None` if never observed.
    pub fn timer_stats(&self, name: &str) -> Option<LogHist> {
        let m = self.inner.timers.lock().unwrap();
        m.get(name).cloned()
    }

    /// How many samples a timer has recorded.
    pub fn timer_count(&self, name: &str) -> u64 {
        self.timer_stats(name).map(|h| h.count()).unwrap_or(0)
    }

    /// Render all metrics as an aligned text table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.inner.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {}\n", v.load(Ordering::Relaxed)));
            }
        }
        let gauges = self.inner.gauges.lock().unwrap();
        if !gauges.is_empty() {
            out.push_str("gauges:\n");
            for (k, v) in gauges.iter() {
                out.push_str(&format!("  {k:<40} {}\n", v.load(Ordering::Relaxed)));
            }
        }
        let timers = self.inner.timers.lock().unwrap();
        if !timers.is_empty() {
            out.push_str("timers (secs):\n");
            for (k, h) in timers.iter() {
                if h.count() > 0 {
                    out.push_str(&format!(
                        "  {k:<40} n={} mean={:.4} p50={:.4} p99={:.4} max={:.4}\n",
                        h.count(),
                        h.mean(),
                        h.quantile(0.50),
                        h.quantile(0.99),
                        h.max()
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn counters_accumulate_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m.add("sector.uploads", 2);
        m2.incr("sector.uploads");
        assert_eq!(m.get("sector.uploads"), 3);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn concurrent_increments() {
        let m = Metrics::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.get("x"), 8000);
    }

    #[test]
    fn timers_and_report() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        m.observe_secs("op", 0.5);
        m.gauge_set("spes", 6);
        let r = m.report();
        assert!(r.contains("op"));
        assert!(r.contains("spes"));
        assert_eq!(m.timer_count("op"), 2);
        assert_eq!(m.timer_count("missing"), 0);
    }

    #[test]
    fn timer_memory_stays_bounded_under_a_million_samples() {
        let m = Metrics::new();
        m.observe_secs("hot", 0.25);
        let before = m.timer_stats("hot").unwrap().footprint_bytes();
        for i in 0..1_000_000u32 {
            m.observe_secs("hot", (i % 1000) as f64 * 1e-4);
        }
        let h = m.timer_stats("hot").unwrap();
        assert_eq!(h.count(), 1_000_001);
        assert_eq!(
            h.footprint_bytes(),
            before,
            "timer storage must not grow with sample count"
        );
        let r = m.report();
        assert!(r.contains("hot") && r.contains("n=1000001"));
    }
}

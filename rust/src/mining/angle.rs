//! The Angle application (paper §7): sensors produce anonymized packet
//! files; Sector manages them; Sphere extracts features and the client
//! clusters windows, computes delta_j, flags emergent clusters and
//! scores feature vectors.
//!
//! `run_pipeline` is the real end-to-end path (examples/angle_pipeline
//! drives it, optionally through PJRT); `simulate_angle_clustering`
//! carries the cost model to Table 3's 300,000-file scale and serves
//! as the calibration oracle for the staged scenario pipeline
//! (`crate::scenario::angle`, DESIGN.md §13), which runs the same
//! mining machinery fault-visibly on the scenario substrate.

use crate::mining::emergent::{
    analyze_windows, emergent_clusters, emergent_windows, score_batch, EmergentCluster,
    WindowAnalysis,
};
use crate::mining::features::{AngleFeatureOp, FeatureVector, FEATURE_RECORD_BYTES};
use crate::mining::pcap::{Regime, TraceGen};
use crate::runtime::Runtime;
use crate::sector::SectorCloud;
use crate::sphere::{run_job, FaultPlan, JobSpec, Stream};

/// Scenario description for a synthetic Angle run.
#[derive(Clone, Debug)]
pub struct AngleScenario {
    pub sensors: u32,
    pub sources_per_sensor: usize,
    pub windows: u64,
    pub packets_per_source: usize,
    /// (window, source-index, regime) regime shifts to plant.
    pub anomalies: Vec<(u64, usize, Regime)>,
    pub seed: u64,
    pub k: usize,
}

impl Default for AngleScenario {
    fn default() -> Self {
        Self {
            sensors: 4, // the paper's four sensor sites
            sources_per_sensor: 25,
            windows: 8,
            packets_per_source: 40,
            anomalies: vec![(5, 3, Regime::Scan), (5, 7, Regime::Scan)],
            seed: 20080824,
            k: 6,
        }
    }
}

/// Pipeline output.
pub struct AngleReport {
    pub feature_files: usize,
    pub features_total: usize,
    pub analysis: WindowAnalysis,
    pub emergent_window_ids: Vec<usize>,
    pub clusters: Vec<EmergentCluster>,
    /// (src, window, score) of the top-scored feature vectors.
    pub top_scores: Vec<(u64, u64, f32)>,
}

/// Generate traces, upload to Sector, extract features via Sphere, and
/// run the emergent-cluster analysis on the client.
pub fn run_pipeline(
    cloud: &SectorCloud,
    scenario: &AngleScenario,
    runtime: Option<&Runtime>,
) -> Result<AngleReport, String> {
    let ip = "10.0.0.40".parse().unwrap();
    // ---- sensors write one pcap file per (sensor, window) ----
    let mut n_files = 0usize;
    for sensor in 0..scenario.sensors {
        let mut gen = TraceGen::new(sensor, scenario.sources_per_sensor, scenario.seed);
        for w in 0..scenario.windows {
            let anomalous: Vec<(usize, Regime)> = scenario
                .anomalies
                .iter()
                .filter(|(aw, _, _)| *aw == w)
                .map(|(_, s, r)| (*s, *r))
                .collect();
            let (bytes, _) = gen.window_file(w, scenario.packets_per_source, &anomalous);
            let name = format!("angle/s{sensor:02}-w{w:04}.pcap");
            let target = (sensor % cloud.n_slaves() as u32) as u32;
            cloud
                .upload(ip, &name, &bytes, None, Some(target))
                .map_err(|e| format!("upload {name}: {e}"))?;
            n_files += 1;
        }
    }

    // ---- Sphere feature extraction, one job per window ----
    let mut windows: Vec<Vec<FeatureVector>> = Vec::with_capacity(scenario.windows as usize);
    for w in 0..scenario.windows {
        let names: Vec<String> = (0..scenario.sensors)
            .map(|s| format!("angle/s{s:02}-w{w:04}.pcap"))
            .collect();
        let stream = Stream::from_cloud(cloud, &names)?;
        let spec = JobSpec {
            output_name: format!("angle-feat-w{w}"),
            params: w.to_le_bytes().to_vec(),
            ..JobSpec::default()
        };
        let res = run_job(cloud, &AngleFeatureOp, &stream, &spec, &FaultPlan::default())?;
        let mut feats = Vec::with_capacity(res.to_client.len());
        for (_, rec) in res.to_client {
            if rec.len() != FEATURE_RECORD_BYTES {
                return Err(format!("bad feature record of {} bytes", rec.len()));
            }
            feats.push(FeatureVector::from_bytes(&rec)?);
        }
        feats.sort_by_key(|f| f.src);
        windows.push(feats);
    }
    let features_total = windows.iter().map(Vec::len).sum();

    // ---- client-side temporal analysis (PJRT-backed when available) ----
    let analysis = analyze_windows(&windows, scenario.k, scenario.seed, runtime)?;
    let emergent_ids = emergent_windows(&analysis.deltas, 2, 3.0);
    let clusters = match emergent_ids.first() {
        Some(&w) if w >= 1 => {
            emergent_clusters(&analysis.models[w - 1], &analysis.models[w], 1.0)
        }
        _ => Vec::new(),
    };
    // score the flagged window's vectors
    let mut top_scores = Vec::new();
    if let Some(&w) = emergent_ids.first() {
        let xs = &windows[w];
        let scores = score_batch(xs, &clusters, runtime)?;
        let mut scored: Vec<(u64, u64, f32)> = xs
            .iter()
            .zip(scores)
            .map(|(f, s)| (f.src, f.window, s))
            .collect();
        scored.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        scored.truncate(10);
        top_scores = scored;
    }

    Ok(AngleReport {
        feature_files: n_files,
        features_total,
        analysis,
        emergent_window_ids: emergent_ids,
        clusters,
        top_scores,
    })
}

/// Per-file cost of the Table 3 model: Sector lookup + GMP handshake +
/// UDT open + feature-file read.  Shared with the staged scenario
/// pipeline (`scenario::angle`), which pays it in the window-aggregate
/// stage, so the two models stay calibrated to the same constant.
pub const PER_FILE_SECS: f64 = 1.45;
/// Per-record cost of the Table 3 model: aggregation + the cluster
/// iterations of a fully-spent k-means budget.
pub const PER_RECORD_SECS: f64 = 0.55e-3;

/// Table 3 cost model: clustering time vs (records, Sector files).
/// Dominated by per-file costs (lookup, connection, open, feature-file
/// fetch) plus a per-record scan/cluster cost — fitted to the table's
/// four cells (DESIGN.md §3):
///   500 rec / 1 file = 1.9 s; 1e3 / 3 = 4.2 s;
///   1e6 / 2850 = 85 min; 1e8 / 300000 = 178 h.
///
/// Retained as the *calibration oracle* for the staged substrate
/// pipeline (DESIGN.md §13): `scenario::angle` reports its serialized
/// mining work next to this formula at the same (records, files)
/// point, and a regression test pins the ratio.
pub fn simulate_angle_clustering(n_records: f64, n_files: f64) -> f64 {
    n_files * PER_FILE_SECS + n_records * PER_RECORD_SECS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_detects_planted_scan() {
        let cloud = SectorCloud::builder().nodes(4).seed(3).build().unwrap();
        let scenario = AngleScenario::default();
        let report = run_pipeline(&cloud, &scenario, None).unwrap();
        assert_eq!(report.feature_files, 32, "4 sensors x 8 windows");
        // 4 sensors x 25 sources x 8 windows = 800 feature vectors
        assert_eq!(report.features_total, 800);
        assert_eq!(report.analysis.deltas.len(), 7);
        assert!(
            report.emergent_window_ids.contains(&5),
            "planted shift at window 5; flagged {:?} deltas {:?}",
            report.emergent_window_ids,
            report.analysis.deltas
        );
        assert!(!report.clusters.is_empty());
        // top-scored sources are the scanners (sensor-local source ids 3, 7)
        assert!(!report.top_scores.is_empty());
        let scanners: std::collections::HashSet<u64> = (0..4)
            .flat_map(|sensor| {
                [
                    crate::mining::pcap::anonymize_ip([10, sensor, 0, 3], scenario.seed),
                    crate::mining::pcap::anonymize_ip([10, sensor, 0, 7], scenario.seed),
                ]
            })
            .collect();
        let top2: Vec<u64> = report.top_scores.iter().take(2).map(|t| t.0).collect();
        assert!(
            top2.iter().all(|s| scanners.contains(s)),
            "top scores {top2:?} should be planted scanners"
        );
    }

    #[test]
    fn table3_model_matches_paper_cells() {
        // (records, files, paper seconds)
        let cells = [
            (500.0, 1.0, 1.9),
            (1000.0, 3.0, 4.2),
            (1.0e6, 2850.0, 85.0 * 60.0),
            (1.0e8, 300_000.0, 178.0 * 3600.0),
        ];
        for (recs, files, paper) in cells {
            let got = simulate_angle_clustering(recs, files);
            let rel = (got - paper).abs() / paper;
            assert!(
                rel < 0.30,
                "cell ({recs}, {files}): {got:.1} vs paper {paper:.1} ({:.0}%)",
                rel * 100.0
            );
        }
    }
}

//! The evaluation workloads (paper §6–7): Terasort, Terasplit, and the
//! Angle anomaly-detection application, plus the clustering/statistics
//! machinery they share.  All are real implementations — the Sphere
//! operators run on actual bytes — with simulation cost models carrying
//! them to paper scale.
//!
//! The Angle chain (paper §7.1) reads left to right:
//!
//! * [`pcap`] generates each sensor site's anonymized packet windows
//!   with plantable regime shifts (scan, exfiltration);
//! * [`features`] aggregates packets into per-source 16-D feature
//!   vectors (the [`features::AngleFeatureOp`] Sphere operator);
//! * [`kmeans`] clusters each temporal window (host oracle, optionally
//!   the PJRT Pallas kernel);
//! * [`emergent`] computes the delta_j series, flags emergent windows
//!   and scores feature vectors against the new clusters;
//! * [`angle`] ties them into the end-to-end pipeline
//!   ([`angle::run_pipeline`] on the in-process cloud) and retains the
//!   Table 3 cost oracle ([`angle::simulate_angle_clustering`]).
//!
//! The same machinery drives the *staged* Angle scenario workload
//! ([`crate::scenario::angle`], DESIGN.md §13), where the five
//! pipeline stages run event-driven on the fault-injected scenario
//! substrate.

pub mod angle;
pub mod emergent;
pub mod features;
pub mod kmeans;
pub mod pcap;
pub mod terasort;
pub mod terasplit;

pub use angle::{run_pipeline, simulate_angle_clustering, AngleReport, AngleScenario};
pub use emergent::{
    analyze_windows, delta_host, emergent_clusters, emergent_windows, score_batch,
    score_host, EmergentCluster, WindowAnalysis,
};
pub use features::{extract_features, AngleFeatureOp, FeatureVector, FEATURE_DIM};
pub use kmeans::{fit, seed_centers, step_host, KmeansModel};
pub use pcap::{anonymize_ip, Packet, Regime, TraceGen, PACKET_BYTES};
pub use terasort::{
    generate_records, key_bucket, record_index, validate_sorted, TeraPartitionOp, TeraSortOp,
    KEY_BYTES, RECORD_BYTES,
};
pub use terasplit::{aggregate_labels, best_split_host, labels_of, record_label};

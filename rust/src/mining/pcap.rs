//! Synthetic Angle sensor traces (substitution for the paper's pcap
//! feeds; DESIGN.md §2).
//!
//! The paper's Angle sensors "zero out the content, hash the source and
//! destination IP to preserve privacy, package moving windows of
//! anonymized packets in pcap files".  We generate behaviourally
//! structured traces directly: a population of background sources with
//! stable flow statistics, plus *injected regime shifts* (port-scan and
//! exfiltration behaviours switching on at known times) so the
//! emergent-cluster detector has planted ground truth to find.
//!
//! Consumed by both Angle drivers: the in-process pipeline
//! (`crate::mining::angle::run_pipeline`) and the staged scenario
//! workload (`crate::scenario::angle`, DESIGN.md §13), whose recall
//! gate measures detection against the planted shifts.

use crate::util::rng::Pcg64;

/// One anonymized packet record (fixed 32-byte wire encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Packet {
    /// Microseconds since trace start.
    pub ts_us: u64,
    /// Salted hash of source IP (anonymization, paper §7.1).
    pub src: u64,
    /// Salted hash of destination IP.
    pub dst: u64,
    pub sport: u16,
    pub dport: u16,
    pub len: u16,
    /// TCP flags (SYN = 0x02 matters for scan detection).
    pub flags: u8,
    pub _pad: u8,
}

pub const PACKET_BYTES: usize = 32;

impl Packet {
    pub fn to_bytes(&self) -> [u8; PACKET_BYTES] {
        let mut out = [0u8; PACKET_BYTES];
        out[0..8].copy_from_slice(&self.ts_us.to_le_bytes());
        out[8..16].copy_from_slice(&self.src.to_le_bytes());
        out[16..24].copy_from_slice(&self.dst.to_le_bytes());
        out[24..26].copy_from_slice(&self.sport.to_le_bytes());
        out[26..28].copy_from_slice(&self.dport.to_le_bytes());
        out[28..30].copy_from_slice(&self.len.to_le_bytes());
        out[30] = self.flags;
        out[31] = self._pad;
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Packet, String> {
        if b.len() != PACKET_BYTES {
            return Err(format!("packet record must be {PACKET_BYTES} bytes, got {}", b.len()));
        }
        Ok(Packet {
            ts_us: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            src: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            dst: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            sport: u16::from_le_bytes(b[24..26].try_into().unwrap()),
            dport: u16::from_le_bytes(b[26..28].try_into().unwrap()),
            len: u16::from_le_bytes(b[28..30].try_into().unwrap()),
            flags: b[30],
            _pad: b[31],
        })
    }
}

/// Salted IP anonymization (what the sensor applies before shipping).
pub fn anonymize_ip(ip: [u8; 4], salt: u64) -> u64 {
    let mut h = salt ^ 0xcbf2_9ce4_8422_2325;
    for b in ip {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Behavioural regime of a source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Normal traffic: few destinations, normal packet sizes.
    Background,
    /// Port scan: many destinations/ports, tiny SYN packets.
    Scan,
    /// Exfiltration: one destination, large sustained transfers.
    Exfil,
}

/// Trace generator for one sensor site.
pub struct TraceGen {
    pub sensor_id: u32,
    pub n_sources: usize,
    rng: Pcg64,
    salt: u64,
}

impl TraceGen {
    pub fn new(sensor_id: u32, n_sources: usize, seed: u64) -> Self {
        Self {
            sensor_id,
            n_sources,
            rng: Pcg64::new(seed ^ (sensor_id as u64) << 32),
            salt: seed,
        }
    }

    /// Generate one time-window's packets. `anomalous_sources` switch to
    /// the given regime in this window (the planted emergent behaviour).
    pub fn window(
        &mut self,
        window_idx: u64,
        packets_per_source: usize,
        anomalous: &[(usize, Regime)],
    ) -> Vec<Packet> {
        let mut out = Vec::new();
        let window_us = 600_000_000u64; // 10-minute windows (paper Fig 5)
        let t0 = window_idx * window_us;
        for s in 0..self.n_sources {
            let regime = anomalous
                .iter()
                .find(|(idx, _)| *idx == s)
                .map(|(_, r)| *r)
                .unwrap_or(Regime::Background);
            let src = anonymize_ip(
                [10, self.sensor_id as u8, (s / 250) as u8, (s % 250) as u8],
                self.salt,
            );
            let n = match regime {
                Regime::Background => packets_per_source,
                Regime::Scan => packets_per_source * 4, // scans are chatty
                Regime::Exfil => packets_per_source * 2,
            };
            for _ in 0..n {
                let ts_us = t0 + (self.rng.next_f64() * window_us as f64) as u64;
                let p = match regime {
                    Regime::Background => {
                        // a handful of favourite destinations, normal sizes
                        let dst_idx = self.rng.gen_range(5);
                        Packet {
                            ts_us,
                            src,
                            dst: anonymize_ip([192, 168, 1, dst_idx as u8], self.salt),
                            sport: 32768 + self.rng.gen_range(28000) as u16,
                            dport: [80u16, 443, 22, 25, 53][self.rng.gen_range(5) as usize],
                            len: (self.rng.next_pareto(80.0, 1.3).min(1500.0)) as u16,
                            flags: if self.rng.next_f64() < 0.05 { 0x02 } else { 0x10 },
                            _pad: 0,
                        }
                    }
                    Regime::Scan => Packet {
                        ts_us,
                        src,
                        // fresh destination + port almost every packet
                        dst: anonymize_ip(
                            [172, 16, self.rng.gen_range(255) as u8, self.rng.gen_range(255) as u8],
                            self.salt,
                        ),
                        sport: 40000 + self.rng.gen_range(20000) as u16,
                        dport: self.rng.gen_range(65535) as u16,
                        len: 40 + self.rng.gen_range(4) as u16,
                        flags: 0x02, // SYN
                        _pad: 0,
                    },
                    Regime::Exfil => Packet {
                        ts_us,
                        src,
                        dst: anonymize_ip([203, 0, 113, 7], self.salt),
                        sport: 51234,
                        dport: 443,
                        len: 1400 + self.rng.gen_range(100) as u16,
                        flags: 0x10,
                        _pad: 0,
                    },
                };
                out.push(p);
            }
        }
        out.sort_by_key(|p| p.ts_us);
        out
    }

    /// Serialize a window to a Sector-ready byte buffer + record count.
    pub fn window_file(
        &mut self,
        window_idx: u64,
        packets_per_source: usize,
        anomalous: &[(usize, Regime)],
    ) -> (Vec<u8>, usize) {
        let pkts = self.window(window_idx, packets_per_source, anomalous);
        let mut bytes = Vec::with_capacity(pkts.len() * PACKET_BYTES);
        for p in &pkts {
            bytes.extend_from_slice(&p.to_bytes());
        }
        (bytes, pkts.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_codec_roundtrip() {
        let p = Packet {
            ts_us: 123456789,
            src: 0xdeadbeef,
            dst: 0xfeedface,
            sport: 5555,
            dport: 443,
            len: 1200,
            flags: 0x12,
            _pad: 0,
        };
        assert_eq!(Packet::from_bytes(&p.to_bytes()).unwrap(), p);
        assert!(Packet::from_bytes(&[0u8; 31]).is_err());
    }

    #[test]
    fn anonymization_is_salted_and_stable() {
        let a = anonymize_ip([10, 0, 0, 1], 7);
        assert_eq!(a, anonymize_ip([10, 0, 0, 1], 7));
        assert_ne!(a, anonymize_ip([10, 0, 0, 1], 8), "salt matters");
        assert_ne!(a, anonymize_ip([10, 0, 0, 2], 7));
    }

    #[test]
    fn background_window_shape() {
        let mut g = TraceGen::new(1, 20, 42);
        let pkts = g.window(0, 50, &[]);
        assert_eq!(pkts.len(), 20 * 50);
        // sorted by time, inside the window
        for w in pkts.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us);
        }
        assert!(pkts.last().unwrap().ts_us < 600_000_000);
        // ~5% SYN in background traffic
        let syn = pkts.iter().filter(|p| p.flags == 0x02).count();
        assert!(syn < pkts.len() / 10);
    }

    #[test]
    fn scan_regime_looks_like_a_scan() {
        let mut g = TraceGen::new(2, 10, 43);
        let pkts = g.window(0, 40, &[(3, Regime::Scan)]);
        let scanner = anonymize_ip([10, 2, 0, 3], 43);
        let scan_pkts: Vec<&Packet> = pkts.iter().filter(|p| p.src == scanner).collect();
        assert_eq!(scan_pkts.len(), 160, "scans are 4x chattier");
        assert!(scan_pkts.iter().all(|p| p.flags == 0x02));
        assert!(scan_pkts.iter().all(|p| p.len < 50));
        let distinct_dst: std::collections::HashSet<u64> =
            scan_pkts.iter().map(|p| p.dst).collect();
        assert!(distinct_dst.len() > 100, "scan hits many destinations");
    }

    #[test]
    fn window_file_roundtrips() {
        let mut g = TraceGen::new(3, 5, 44);
        let (bytes, n) = g.window_file(2, 10, &[]);
        assert_eq!(bytes.len(), n * PACKET_BYTES);
        let p0 = Packet::from_bytes(&bytes[..PACKET_BYTES]).unwrap();
        assert!(p0.ts_us >= 2 * 600_000_000);
    }
}

//! Terasplit (paper §6.2): "Terasplit takes data that has been sorted,
//! for example by Terasort, and computes a single split for a tree
//! based upon entropy" — one CART split (Breiman et al.) over the
//! key-sorted stream.
//!
//! The class label of a record is derived from its payload (a hash into
//! C classes); sorting by key gives the feature ordering.  The host
//! implementation here is the oracle; the hot path goes through the
//! PJRT `split_gain` artifact (L1 Pallas scan inside), with
//! `aggregate_labels` shrinking arbitrarily long streams to the
//! artifact's block contract first.

use crate::mining::terasort::{KEY_BYTES, RECORD_BYTES};

/// Derive a class label in [0, classes) from a sorted record: a cheap
/// payload hash (labels must NOT correlate perfectly with the sort key,
/// or every split is trivial).
pub fn record_label(record: &[u8], classes: u8) -> u8 {
    debug_assert_eq!(record.len(), RECORD_BYTES);
    // Hash the low digits of the record-number tag (the leading digits
    // are constant for realistic record counts).
    let mut h = 0xcbu8;
    for &b in &record[KEY_BYTES + 12..KEY_BYTES + 20] {
        h = h.wrapping_mul(31).wrapping_add(b);
    }
    h % classes
}

/// Labels of a concatenated sorted-record buffer.
pub fn labels_of(data: &[u8], classes: u8) -> Vec<u8> {
    data.chunks_exact(RECORD_BYTES)
        .map(|r| record_label(r, classes))
        .collect()
}

/// Host oracle: best split position + gain (bits) of a label sequence.
/// O(n·c); the PJRT artifact computes the same thing blocked.
pub fn best_split_host(labels: &[u8], classes: u8) -> (f64, usize) {
    let c = classes as usize;
    let n = labels.len();
    if n < 2 {
        return (0.0, 0);
    }
    let mut total = vec![0f64; c];
    for &l in labels {
        total[l as usize] += 1.0;
    }
    let entropy = |h: &[f64]| -> f64 {
        let s: f64 = h.iter().sum();
        if s <= 0.0 {
            return 0.0;
        }
        -h.iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| {
                let p = x / s;
                p * p.log2()
            })
            .sum::<f64>()
    };
    let parent = entropy(&total);
    let mut left = vec![0f64; c];
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (i, &l) in labels.iter().enumerate().take(n - 1) {
        left[l as usize] += 1.0;
        let n_l = (i + 1) as f64;
        let n_r = (n - i - 1) as f64;
        let right: Vec<f64> = total.iter().zip(&left).map(|(t, l)| t - l).collect();
        let gain = parent - (n_l * entropy(&left) + n_r * entropy(&right)) / n as f64;
        if gain > best.0 {
            best = (gain, i);
        }
    }
    best
}

/// Shrink a long label stream to at most `max_len` by majority-pooling
/// fixed-width windows — the pre-aggregation used before calling the
/// fixed-shape PJRT artifact. Split positions scale back up by the
/// pooling factor.
pub fn aggregate_labels(labels: &[u8], classes: u8, max_len: usize) -> (Vec<u8>, usize) {
    assert!(max_len > 0);
    if labels.len() <= max_len {
        return (labels.to_vec(), 1);
    }
    let factor = labels.len().div_ceil(max_len);
    let mut out = Vec::with_capacity(labels.len() / factor + 1);
    for window in labels.chunks(factor) {
        let mut counts = vec![0u32; classes as usize];
        for &l in window {
            counts[l as usize] += 1;
        }
        let majority = counts
            .iter()
            .enumerate()
            .max_by_key(|(i, &c)| (c, usize::MAX - i))
            .unwrap()
            .0;
        out.push(majority as u8);
    }
    (out, factor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::terasort::generate_records;

    #[test]
    fn labels_are_deterministic_and_bounded() {
        let data = generate_records(200, 5);
        let l1 = labels_of(&data, 8);
        let l2 = labels_of(&data, 8);
        assert_eq!(l1, l2);
        assert_eq!(l1.len(), 200);
        assert!(l1.iter().all(|&l| l < 8));
        // multiple classes actually occur
        let distinct: std::collections::HashSet<u8> = l1.iter().copied().collect();
        assert!(distinct.len() >= 4, "labels too degenerate: {distinct:?}");
    }

    #[test]
    fn perfect_split_detected() {
        let mut labels = vec![0u8; 100];
        labels.extend(vec![1u8; 100]);
        let (gain, idx) = best_split_host(&labels, 2);
        assert!((gain - 1.0).abs() < 1e-9, "gain {gain}");
        assert_eq!(idx, 99);
    }

    #[test]
    fn pure_stream_has_no_gain() {
        let labels = vec![3u8; 64];
        let (gain, _) = best_split_host(&labels, 4);
        assert!(gain.abs() < 1e-12);
        assert_eq!(best_split_host(&[1], 2).0, 0.0, "degenerate input");
    }

    #[test]
    fn gain_is_nonnegative_and_bounded_by_parent_entropy() {
        let data = generate_records(500, 11);
        let labels = labels_of(&data, 8);
        let (gain, idx) = best_split_host(&labels, 8);
        assert!(gain >= -1e-12);
        assert!(gain <= 3.0 + 1e-9, "<= log2(8)");
        assert!(idx < labels.len() - 1);
    }

    #[test]
    fn aggregation_preserves_structure() {
        let mut labels = vec![0u8; 1000];
        labels.extend(vec![1u8; 1000]);
        let (small, factor) = aggregate_labels(&labels, 2, 100);
        assert!(small.len() <= 100);
        assert_eq!(factor, 20);
        // boundary survives pooling
        let (g_small, i_small) = best_split_host(&small, 2);
        assert!((g_small - 1.0).abs() < 1e-9);
        assert_eq!((i_small + 1) * factor, 1000);
        // short streams pass through untouched
        let (same, f1) = aggregate_labels(&labels[..50], 2, 100);
        assert_eq!(f1, 1);
        assert_eq!(same.len(), 50);
    }
}

//! Feature extraction (paper §7.1): "Sphere aggregates the pcap files
//! by source IP (or other specified entity) and computes files
//! containing features."
//!
//! Per (source, window) we compute a fixed FEATURE_DIM-dimensional
//! vector of flow statistics, log/ratio-scaled so k-means distances are
//! meaningful.  Also the Sphere operator that runs this extraction over
//! packet-file segments.

use std::collections::HashMap;

use crate::mining::pcap::{Packet, PACKET_BYTES};
use crate::sphere::{OpCtx, OpOutput, OutputMode, SegmentData, SphereOp};

/// Matches the PJRT artifact contract (runtime::SHAPES.n_dim).
pub const FEATURE_DIM: usize = 16;

/// One source's behaviour inside one window.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureVector {
    pub src: u64,
    pub window: u64,
    pub values: [f32; FEATURE_DIM],
}

pub const FEATURE_RECORD_BYTES: usize = 16 + FEATURE_DIM * 4;

impl FeatureVector {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(FEATURE_RECORD_BYTES);
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.window.to_le_bytes());
        for v in self.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<FeatureVector, String> {
        if b.len() != FEATURE_RECORD_BYTES {
            return Err(format!(
                "feature record must be {FEATURE_RECORD_BYTES} bytes, got {}",
                b.len()
            ));
        }
        let mut values = [0.0f32; FEATURE_DIM];
        for (i, v) in values.iter_mut().enumerate() {
            *v = f32::from_le_bytes(b[16 + i * 4..20 + i * 4].try_into().unwrap());
        }
        Ok(FeatureVector {
            src: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            window: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            values,
        })
    }
}

/// Aggregate packets (one window's worth) into per-source features.
pub fn extract_features(packets: &[Packet], window: u64) -> Vec<FeatureVector> {
    struct Acc {
        pkts: f64,
        bytes: f64,
        dsts: std::collections::HashSet<u64>,
        dports: std::collections::HashSet<u16>,
        syns: f64,
        small: f64,
        large: f64,
        max_len: f64,
        first_us: u64,
        last_us: u64,
    }
    let mut by_src: HashMap<u64, Acc> = HashMap::new();
    for p in packets {
        let a = by_src.entry(p.src).or_insert_with(|| Acc {
            pkts: 0.0,
            bytes: 0.0,
            dsts: Default::default(),
            dports: Default::default(),
            syns: 0.0,
            small: 0.0,
            large: 0.0,
            max_len: 0.0,
            first_us: p.ts_us,
            last_us: p.ts_us,
        });
        a.pkts += 1.0;
        a.bytes += p.len as f64;
        a.dsts.insert(p.dst);
        a.dports.insert(p.dport);
        if p.flags & 0x02 != 0 {
            a.syns += 1.0;
        }
        if p.len < 100 {
            a.small += 1.0;
        }
        if p.len > 1000 {
            a.large += 1.0;
        }
        a.max_len = a.max_len.max(p.len as f64);
        a.first_us = a.first_us.min(p.ts_us);
        a.last_us = a.last_us.max(p.ts_us);
    }
    let mut out: Vec<FeatureVector> = by_src
        .into_iter()
        .map(|(src, a)| {
            let dur_s = ((a.last_us - a.first_us) as f64 / 1e6).max(1e-3);
            let mut values = [0.0f32; FEATURE_DIM];
            let f = [
                (a.pkts + 1.0).ln(),                  // 0 log packet count
                (a.bytes + 1.0).ln(),                 // 1 log byte count
                a.bytes / a.pkts,                     // 2 mean packet size
                (a.dsts.len() as f64 + 1.0).ln(),     // 3 log distinct dsts
                (a.dports.len() as f64 + 1.0).ln(),   // 4 log distinct dports
                a.syns / a.pkts,                      // 5 SYN fraction
                a.small / a.pkts,                     // 6 small-packet frac
                a.large / a.pkts,                     // 7 large-packet frac
                a.max_len / 1500.0,                   // 8 max size (norm)
                (a.bytes / dur_s / 1e3 + 1.0).ln(),   // 9 log KB/s rate
                a.dsts.len() as f64 / a.pkts,         // 10 dst fan-out ratio
                a.dports.len() as f64 / a.pkts,       // 11 port fan-out ratio
            ];
            for (i, &v) in f.iter().enumerate() {
                values[i] = v as f32;
            }
            // dims 12..16 reserved (zero) — the artifact contract is 16-D
            FeatureVector {
                src,
                window,
                values,
            }
        })
        .collect();
    out.sort_by_key(|fv| fv.src);
    out
}

/// Scale feature 2 (mean size) into a comparable range; applied before
/// clustering so no single dimension dominates Euclidean distance.
pub fn normalize(features: &mut [FeatureVector]) {
    for fv in features {
        fv.values[2] /= 1500.0;
    }
}

/// Sphere operator: packet-file segments -> feature records.  The
/// window id rides in `params` (8 LE bytes).
pub struct AngleFeatureOp;

impl SphereOp for AngleFeatureOp {
    fn name(&self) -> &str {
        "angle-features"
    }

    fn output_mode(&self) -> OutputMode {
        OutputMode::ToClient
    }

    fn process(&self, data: &SegmentData, ctx: &OpCtx, out: &mut OpOutput) -> Result<(), String> {
        let window = if ctx.params.len() >= 8 {
            u64::from_le_bytes(ctx.params[..8].try_into().unwrap())
        } else {
            0
        };
        let mut packets = Vec::new();
        for r in &data.records {
            // whole-file segments hold many packets; indexed ones hold one
            if r.len() % PACKET_BYTES != 0 {
                return Err(format!("record not packet-aligned: {} bytes", r.len()));
            }
            for chunk in r.chunks_exact(PACKET_BYTES) {
                packets.push(Packet::from_bytes(chunk)?);
            }
        }
        let mut feats = extract_features(&packets, window);
        normalize(&mut feats);
        for fv in feats {
            out.emit(0, fv.to_bytes());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::pcap::{Regime, TraceGen};

    #[test]
    fn feature_codec_roundtrip() {
        let fv = FeatureVector {
            src: 42,
            window: 7,
            values: [1.5; FEATURE_DIM],
        };
        assert_eq!(FeatureVector::from_bytes(&fv.to_bytes()).unwrap(), fv);
        assert!(FeatureVector::from_bytes(&[0u8; 3]).is_err());
    }

    #[test]
    fn one_vector_per_source() {
        let mut g = TraceGen::new(1, 12, 5);
        let pkts = g.window(0, 30, &[]);
        let feats = extract_features(&pkts, 0);
        assert_eq!(feats.len(), 12);
        assert!(feats.windows(2).all(|w| w[0].src < w[1].src), "sorted");
        for f in &feats {
            assert_eq!(f.window, 0);
            assert!(f.values.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn scan_features_separate_from_background() {
        let mut g = TraceGen::new(1, 10, 6);
        let pkts = g.window(0, 50, &[(0, Regime::Scan)]);
        let feats = extract_features(&pkts, 0);
        let scanner = crate::mining::pcap::anonymize_ip([10, 1, 0, 0], 6);
        let scan = feats.iter().find(|f| f.src == scanner).unwrap();
        let bg: Vec<&FeatureVector> = feats.iter().filter(|f| f.src != scanner).collect();
        // scanner: SYN fraction ~1, fan-out ~1, small packets ~1
        assert!(scan.values[5] > 0.9, "SYN frac {}", scan.values[5]);
        assert!(scan.values[6] > 0.9, "small frac {}", scan.values[6]);
        assert!(scan.values[3] > bg[0].values[3] + 1.0, "more distinct dsts");
        for b in bg {
            assert!(b.values[5] < 0.3, "background SYN frac {}", b.values[5]);
        }
    }

    #[test]
    fn feature_op_over_whole_file_segment() {
        let mut g = TraceGen::new(2, 4, 7);
        let (bytes, n) = g.window_file(3, 20, &[]);
        assert_eq!(n, 80);
        let seg = SegmentData {
            segment: crate::sphere::Segment {
                id: 0,
                file: "w3.pcap".into(),
                first_record: 0,
                n_records: 0,
                bytes: bytes.len() as u64,
                locations: vec![0],
                whole_file: true,
            },
            records: vec![bytes],
        };
        let ctx = OpCtx {
            params: 3u64.to_le_bytes().to_vec(),
        };
        let mut out = OpOutput::default();
        AngleFeatureOp.process(&seg, &ctx, &mut out).unwrap();
        assert_eq!(out.emitted.len(), 4, "one feature vector per source");
        let fv = FeatureVector::from_bytes(&out.emitted[0].1).unwrap();
        assert_eq!(fv.window, 3);
    }
}

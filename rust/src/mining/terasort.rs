//! Terasort (paper §6): gensort-style records — 100 bytes each, a
//! 10-byte key followed by 90 bytes of payload — range-partitioned on
//! the key, shuffled, and locally sorted.  Implemented as two Sphere
//! operators (partition, sort) plus generation/validation helpers, so
//! the examples run the *actual* benchmark the tables simulate.

use crate::sector::RecordIndex;
use crate::sphere::{OpCtx, OpOutput, OutputMode, SegmentData, SphereOp};
use crate::util::rng::Pcg64;

pub const RECORD_BYTES: usize = 100;
pub const KEY_BYTES: usize = 10;

/// Generate `n` records with uniformly random keys (deterministic seed).
pub fn generate_records(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed);
    let mut out = vec![0u8; n * RECORD_BYTES];
    for i in 0..n {
        let rec = &mut out[i * RECORD_BYTES..(i + 1) * RECORD_BYTES];
        rng.fill_bytes(&mut rec[..KEY_BYTES]);
        // Payload: record number + filler, as gensort does.
        let tag = format!("{i:020}");
        rec[KEY_BYTES..KEY_BYTES + 20].copy_from_slice(tag.as_bytes());
        for (j, b) in rec[KEY_BYTES + 20..].iter_mut().enumerate() {
            *b = b'A' + ((i + j) % 26) as u8;
        }
    }
    out
}

/// The record index for a generated buffer.
pub fn record_index(data: &[u8]) -> RecordIndex {
    RecordIndex::fixed(RECORD_BYTES as u64, data.len() as u64)
}

/// Range partition: bucket by the key's leading 16 bits, scaled to
/// `buckets`.  Preserves key order across buckets (bucket i's keys all
/// precede bucket i+1's), which is what makes stage-B local sorts
/// compose into a global order.
pub fn key_bucket(key: &[u8], buckets: u32) -> u32 {
    let hi = ((key[0] as u32) << 8) | key[1] as u32;
    ((hi as u64 * buckets as u64) >> 16) as u32
}

/// Stage-A Sphere operator: emit each record into its key-range bucket.
pub struct TeraPartitionOp {
    pub buckets: u32,
}

impl SphereOp for TeraPartitionOp {
    fn name(&self) -> &str {
        "tera-partition"
    }

    fn output_mode(&self) -> OutputMode {
        OutputMode::Shuffle {
            buckets: self.buckets,
        }
    }

    fn process(&self, data: &SegmentData, _ctx: &OpCtx, out: &mut OpOutput) -> Result<(), String> {
        for r in &data.records {
            if r.len() != RECORD_BYTES {
                return Err(format!("bad record length {}", r.len()));
            }
            out.emit(key_bucket(&r[..KEY_BYTES], self.buckets), r.clone());
        }
        Ok(())
    }
}

/// Stage-B Sphere operator: sort a bucket's records by key, writing the
/// sorted run locally (co-located with the bucket file).
pub struct TeraSortOp;

impl SphereOp for TeraSortOp {
    fn name(&self) -> &str {
        "tera-sort"
    }

    fn output_mode(&self) -> OutputMode {
        OutputMode::Local
    }

    fn process(&self, data: &SegmentData, _ctx: &OpCtx, out: &mut OpOutput) -> Result<(), String> {
        // §Perf: precompute the 10-byte key as a big-endian u128 so the
        // sort compares one integer instead of a byte-slice memcmp per
        // comparison (~2.4x on the 100k-record bench), and use an
        // unstable sort (keys are effectively unique).
        let mut keyed: Vec<(u128, &Vec<u8>)> = data
            .records
            .iter()
            .map(|r| {
                let mut k = [0u8; 16];
                k[..KEY_BYTES].copy_from_slice(&r[..KEY_BYTES]);
                (u128::from_be_bytes(k), r)
            })
            .collect();
        keyed.sort_unstable_by_key(|(k, _)| *k);
        for (_, r) in keyed {
            out.emit(0, r.clone());
        }
        Ok(())
    }
}

/// Validate that `data` (concatenated records) is key-sorted; returns
/// the record count.
pub fn validate_sorted(data: &[u8]) -> Result<usize, String> {
    if data.len() % RECORD_BYTES != 0 {
        return Err(format!("{} bytes is not whole records", data.len()));
    }
    let n = data.len() / RECORD_BYTES;
    for i in 1..n {
        let prev = &data[(i - 1) * RECORD_BYTES..(i - 1) * RECORD_BYTES + KEY_BYTES];
        let cur = &data[i * RECORD_BYTES..i * RECORD_BYTES + KEY_BYTES];
        if prev > cur {
            return Err(format!("records {} and {} out of order", i - 1, i));
        }
    }
    Ok(n)
}

/// Extract the first key of a record buffer (global-order checks).
pub fn first_key(data: &[u8]) -> Option<&[u8]> {
    if data.len() >= RECORD_BYTES {
        Some(&data[..KEY_BYTES])
    } else {
        None
    }
}

pub fn last_key(data: &[u8]) -> Option<&[u8]> {
    if data.len() >= RECORD_BYTES && data.len() % RECORD_BYTES == 0 {
        let i = data.len() / RECORD_BYTES - 1;
        Some(&data[i * RECORD_BYTES..i * RECORD_BYTES + KEY_BYTES])
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic_and_sized() {
        let a = generate_records(100, 7);
        let b = generate_records(100, 7);
        let c = generate_records(100, 8);
        assert_eq!(a.len(), 100 * RECORD_BYTES);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(record_index(&a).len(), 100);
    }

    #[test]
    fn buckets_preserve_key_order() {
        let mut rng = Pcg64::new(3);
        for _ in 0..1000 {
            let mut k1 = [0u8; KEY_BYTES];
            let mut k2 = [0u8; KEY_BYTES];
            rng.fill_bytes(&mut k1);
            rng.fill_bytes(&mut k2);
            let (lo, hi) = if k1 <= k2 { (k1, k2) } else { (k2, k1) };
            assert!(
                key_bucket(&lo, 64) <= key_bucket(&hi, 64),
                "bucket order violates key order"
            );
        }
    }

    #[test]
    fn bucket_range_is_valid() {
        let mut rng = Pcg64::new(4);
        for buckets in [1u32, 2, 7, 64, 256] {
            for _ in 0..200 {
                let mut k = [0u8; KEY_BYTES];
                rng.fill_bytes(&mut k);
                assert!(key_bucket(&k, buckets) < buckets);
            }
        }
        assert_eq!(key_bucket(&[0xFF; KEY_BYTES], 64), 63);
        assert_eq!(key_bucket(&[0x00; KEY_BYTES], 64), 0);
    }

    #[test]
    fn sort_op_orders_records() {
        let data = generate_records(50, 9);
        let records: Vec<Vec<u8>> = data
            .chunks_exact(RECORD_BYTES)
            .map(|c| c.to_vec())
            .collect();
        let seg = SegmentData {
            segment: crate::sphere::Segment {
                id: 0,
                file: "b.dat".into(),
                first_record: 0,
                n_records: 50,
                bytes: data.len() as u64,
                locations: vec![0],
                whole_file: false,
            },
            records,
        };
        let mut out = OpOutput::default();
        TeraSortOp.process(&seg, &OpCtx::default(), &mut out).unwrap();
        let sorted: Vec<u8> = out.emitted.iter().flat_map(|(_, r)| r.clone()).collect();
        assert_eq!(validate_sorted(&sorted).unwrap(), 50);
        assert!(validate_sorted(&data).is_err(), "random input is unsorted");
    }

    #[test]
    fn validate_rejects_ragged() {
        assert!(validate_sorted(&[0u8; 150]).is_err());
        assert_eq!(validate_sorted(&[]).unwrap(), 0);
    }

    #[test]
    fn first_last_keys() {
        let data = generate_records(3, 1);
        assert_eq!(first_key(&data).unwrap().len(), KEY_BYTES);
        assert_eq!(last_key(&data).unwrap().len(), KEY_BYTES);
        assert!(first_key(&[0u8; 10]).is_none());
    }
}

//! Emergent-cluster detection (paper §7.1):
//!
//!   "One way is for Sphere to aggregate feature files into temporal
//!   windows w_1, w_2, w_3 ... For each window w_j, clusters are
//!   computed with centers a_{j,1}, ..., a_{j,k} and the temporal
//!   evolution of these clusters is used to identify ... emergent
//!   clusters."
//!
//! delta_j = sum_n min_m ||a_{j,n} - a_{j+1,m}||^2 is the movement
//! statistic (Figs 5-6); a window whose delta spikes against the
//! trailing history flags its new clusters as emergent, and the scoring
//! function rho(x) ranks feature vectors against them.

use crate::mining::features::{FeatureVector, FEATURE_DIM};
use crate::mining::kmeans::{fit, KmeansModel};
use crate::runtime::Runtime;
use crate::util::stats::Welford;

/// Host delta_j (oracle; the PJRT artifact computes the same).
pub fn delta_host(a: &[f32], b: &[f32], d: usize) -> f64 {
    let ka = a.len() / d;
    let kb = b.len() / d;
    let mut total = 0.0f64;
    for i in 0..ka {
        let mut best = f64::MAX;
        for j in 0..kb {
            let mut dist = 0.0f64;
            for x in 0..d {
                let diff = (a[i * d + x] - b[j * d + x]) as f64;
                dist += diff * diff;
            }
            best = best.min(dist);
        }
        total += best;
    }
    total
}

/// Cluster every window and compute the delta series (len = windows-1).
/// `runtime`: route k-means steps and delta through PJRT when given.
pub struct WindowAnalysis {
    pub models: Vec<KmeansModel>,
    pub deltas: Vec<f64>,
}

pub fn analyze_windows(
    windows: &[Vec<FeatureVector>],
    k: usize,
    seed: u64,
    runtime: Option<&Runtime>,
) -> Result<WindowAnalysis, String> {
    let d = FEATURE_DIM;
    let mut models = Vec::with_capacity(windows.len());
    for (i, w) in windows.iter().enumerate() {
        let pts: Vec<f32> = w.iter().flat_map(|f| f.values).collect();
        let k_eff = k.min(w.len().max(1));
        if w.is_empty() {
            return Err(format!("window {i} has no feature vectors"));
        }
        models.push(fit(&pts, d, k_eff, 30, seed + i as u64, runtime)?);
    }
    let mut deltas = Vec::with_capacity(models.len().saturating_sub(1));
    for pair in models.windows(2) {
        // Symmetrized statistic: the paper's formula sums, for each
        // center of w_j, the distance to its nearest center of w_{j+1};
        // a cluster *appearing* in w_{j+1} is invisible in that
        // direction (every old center still has a near neighbour), so we
        // add the reverse term as well — this flags the window where the
        // behaviour emerges rather than the one where it vanishes.
        let (fwd, bwd) = match runtime {
            Some(rt) => (
                rt.delta_stat(&pair[0].centers, &pair[1].centers, d, pair[0].k, pair[1].k)
                    .map_err(|e| format!("pjrt delta_stat: {e}"))? as f64,
                rt.delta_stat(&pair[1].centers, &pair[0].centers, d, pair[1].k, pair[0].k)
                    .map_err(|e| format!("pjrt delta_stat: {e}"))? as f64,
            ),
            None => (
                delta_host(&pair[0].centers, &pair[1].centers, d),
                delta_host(&pair[1].centers, &pair[0].centers, d),
            ),
        };
        deltas.push(fwd + bwd);
    }
    Ok(WindowAnalysis { models, deltas })
}

/// Identify emergent windows: delta_j more than `z_thresh` standard
/// deviations above the trailing mean (paper: "statistically
/// significant change in the clusters in w_{alpha+1}").  Returns
/// window indices (j+1, the window where the new clusters appear).
pub fn emergent_windows(deltas: &[f64], warmup: usize, z_thresh: f64) -> Vec<usize> {
    let mut out = Vec::new();
    let mut stats = Welford::new();
    for (j, &delta) in deltas.iter().enumerate() {
        if stats.count() >= warmup as u64 {
            let sd = stats.std_dev().max(1e-12);
            if (delta - stats.mean()) / sd > z_thresh {
                out.push(j + 1);
                continue; // a spike should not poison the baseline
            }
        }
        stats.push(delta);
    }
    out
}

/// Parameters of the paper's scoring function for one emergent cluster.
#[derive(Clone, Debug)]
pub struct EmergentCluster {
    pub center: Vec<f32>,
    pub sigma2: f32,
    pub theta: f32,
    pub lambda: f32,
}

/// Build scoring clusters from an emergent window's model: clusters
/// whose centers are far (>= `novelty`) from every center of the
/// previous window are the emergent ones; theta_k weights sum to 1.
pub fn emergent_clusters(
    prev: &KmeansModel,
    cur: &KmeansModel,
    novelty: f64,
) -> Vec<EmergentCluster> {
    let d = cur.d;
    let sigma2 = cur.sigma2();
    let mut picked = Vec::new();
    for i in 0..cur.k {
        if cur.counts[i] == 0.0 {
            continue;
        }
        let c = &cur.centers[i * d..(i + 1) * d];
        let dist = delta_host(c, &prev.centers, d);
        if dist >= novelty {
            picked.push((i, cur.counts[i]));
        }
    }
    let total: f32 = picked.iter().map(|(_, c)| c).sum();
    picked
        .into_iter()
        .map(|(i, count)| EmergentCluster {
            center: cur.centers[i * d..(i + 1) * d].to_vec(),
            sigma2: sigma2[i],
            theta: if total > 0.0 { count / total } else { 0.0 },
            lambda: 1.0,
        })
        .collect()
}

/// Host rho(x) = max_k theta_k exp(-lambda_k^2 ||x-a_k||^2 / 2 sigma_k^2).
pub fn score_host(x: &[f32], clusters: &[EmergentCluster]) -> f32 {
    let mut best = 0.0f32;
    for c in clusters {
        let mut d2 = 0.0f32;
        for (xi, ci) in x.iter().zip(&c.center) {
            d2 += (xi - ci) * (xi - ci);
        }
        let rho = c.theta * (-(c.lambda * c.lambda) * d2 / (2.0 * c.sigma2.max(1e-12))).exp();
        best = best.max(rho);
    }
    best
}

/// Score a batch through the PJRT artifact (or host fallback).
pub fn score_batch(
    xs: &[FeatureVector],
    clusters: &[EmergentCluster],
    runtime: Option<&Runtime>,
) -> Result<Vec<f32>, String> {
    if clusters.is_empty() {
        return Ok(vec![0.0; xs.len()]);
    }
    match runtime {
        None => Ok(xs.iter().map(|f| score_host(&f.values, clusters)).collect()),
        Some(rt) => {
            let d = FEATURE_DIM;
            let k = clusters.len();
            let centers: Vec<f32> = clusters.iter().flat_map(|c| c.center.clone()).collect();
            let sigma2: Vec<f32> = clusters.iter().map(|c| c.sigma2).collect();
            let theta: Vec<f32> = clusters.iter().map(|c| c.theta).collect();
            let lam: Vec<f32> = clusters.iter().map(|c| c.lambda).collect();
            let mut out = Vec::with_capacity(xs.len());
            for chunk in xs.chunks(rt.shapes.score_batch) {
                let flat: Vec<f32> = chunk.iter().flat_map(|f| f.values).collect();
                let scores = rt
                    .score(&flat, &centers, &sigma2, &theta, &lam, d, k)
                    .map_err(|e| format!("pjrt score: {e}"))?;
                out.extend(scores);
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fv(window: u64, src: u64, bias: f32, seed: u64) -> FeatureVector {
        let mut rng = crate::util::rng::Pcg64::new(seed ^ src);
        let mut values = [0.0f32; FEATURE_DIM];
        for v in values.iter_mut().take(6) {
            *v = bias + rng.next_gaussian() as f32 * 0.1;
        }
        FeatureVector { src, window, values }
    }

    fn stable_window(w: u64, n: usize) -> Vec<FeatureVector> {
        (0..n).map(|s| fv(w, s as u64, 1.0, 99)).collect()
    }

    #[test]
    fn delta_zero_for_identical_models() {
        let c = vec![1.0f32, 2.0, 3.0, 4.0];
        assert!(delta_host(&c, &c, 2) < 1e-12);
        // translation moves every center
        let shifted: Vec<f32> = c.iter().map(|x| x + 1.0).collect();
        assert!(delta_host(&c, &shifted, 2) > 0.0);
    }

    #[test]
    fn stable_windows_have_small_deltas() {
        let windows: Vec<Vec<FeatureVector>> = (0..6).map(|w| stable_window(w, 40)).collect();
        let a = analyze_windows(&windows, 4, 7, None).unwrap();
        assert_eq!(a.deltas.len(), 5);
        for &d in &a.deltas {
            assert!(d < 1.0, "stable regime delta {d}");
        }
        assert!(emergent_windows(&a.deltas, 2, 4.0).is_empty());
    }

    #[test]
    fn regime_shift_spikes_delta_and_flags_window() {
        let mut windows: Vec<Vec<FeatureVector>> =
            (0..8).map(|w| stable_window(w, 40)).collect();
        // window 5: a third of sources jump to a new behaviour region
        for f in windows[5].iter_mut().take(13) {
            for v in f.values.iter_mut().take(6) {
                *v += 8.0;
            }
        }
        let a = analyze_windows(&windows, 4, 7, None).unwrap();
        let flagged = emergent_windows(&a.deltas, 2, 4.0);
        assert!(
            flagged.contains(&5),
            "window 5 should flag; deltas {:?} flagged {flagged:?}",
            a.deltas
        );
    }

    #[test]
    fn emergent_clusters_and_scoring() {
        let prev_pts: Vec<FeatureVector> = stable_window(0, 60);
        let mut cur_pts = stable_window(1, 60);
        for f in cur_pts.iter_mut().take(20) {
            for v in f.values.iter_mut().take(6) {
                *v += 8.0;
            }
        }
        let a = analyze_windows(&[prev_pts, cur_pts.clone()], 4, 3, None).unwrap();
        let em = emergent_clusters(&a.models[0], &a.models[1], 4.0);
        assert!(!em.is_empty(), "the shifted mass forms a new cluster");
        let theta_sum: f32 = em.iter().map(|c| c.theta).sum();
        assert!((theta_sum - 1.0).abs() < 1e-5);
        // anomalous vectors outscore background ones
        let scores = score_batch(&cur_pts, &em, None).unwrap();
        let anom_mean: f32 = scores[..20].iter().sum::<f32>() / 20.0;
        let bg_mean: f32 = scores[20..].iter().sum::<f32>() / 40.0;
        assert!(
            anom_mean > 10.0 * bg_mean.max(1e-9),
            "anom {anom_mean} vs bg {bg_mean}"
        );
    }

    #[test]
    fn empty_cluster_list_scores_zero() {
        let xs = stable_window(0, 3);
        assert_eq!(score_batch(&xs, &[], None).unwrap(), vec![0.0; 3]);
    }
}

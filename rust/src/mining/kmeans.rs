//! Lloyd's k-means — the clustering engine behind Angle (paper §7.1):
//! "For each window w_j, clusters are computed with centers a_{j,1},
//! a_{j,2}, ... a_{j,k}".
//!
//! The host implementation is the reference; `fit` optionally routes
//! each assignment/accumulation step through the PJRT `kmeans_step`
//! artifact (the L1 Pallas kernel), batching points through the fixed
//! 4096-row contract.

use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

/// Result of one clustering fit.
#[derive(Clone, Debug)]
pub struct KmeansModel {
    pub centers: Vec<f32>, // (k, d) row-major
    pub counts: Vec<f32>,
    pub inertia: f32,
    pub iterations: usize,
    pub k: usize,
    pub d: usize,
}

impl KmeansModel {
    /// Per-cluster variance estimate sigma_k^2 = inertia share / count
    /// (used by the emergent scoring function rho).
    pub fn sigma2(&self) -> Vec<f32> {
        let total: f32 = self.counts.iter().sum();
        self.counts
            .iter()
            .map(|&c| {
                if c > 0.0 {
                    (self.inertia / total.max(1.0)).max(1e-6)
                } else {
                    1.0
                }
            })
            .collect()
    }
}

/// k-means++ style seeding (deterministic): first center random, each
/// next proportional to squared distance.
pub fn seed_centers(points: &[f32], d: usize, k: usize, seed: u64) -> Vec<f32> {
    let n = points.len() / d;
    assert!(n >= k, "need at least k points");
    let mut rng = Pcg64::new(seed);
    let mut centers = Vec::with_capacity(k * d);
    let first = rng.gen_range(n as u64) as usize;
    centers.extend_from_slice(&points[first * d..(first + 1) * d]);
    let mut d2 = vec![f32::MAX; n];
    for c in 1..k {
        // update d2 against the newest center
        let newest = &centers[(c - 1) * d..c * d];
        let mut sum = 0.0f64;
        for i in 0..n {
            let mut dist = 0.0f32;
            for j in 0..d {
                let diff = points[i * d + j] - newest[j];
                dist += diff * diff;
            }
            d2[i] = d2[i].min(dist);
            sum += d2[i] as f64;
        }
        // sample proportional to d2
        let mut target = rng.next_f64() * sum;
        let mut pick = n - 1;
        for (i, &w) in d2.iter().enumerate() {
            target -= w as f64;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centers.extend_from_slice(&points[pick * d..(pick + 1) * d]);
    }
    centers
}

/// One host-side Lloyd's step: returns (sums, counts, inertia).
pub fn step_host(points: &[f32], centers: &[f32], d: usize, k: usize) -> (Vec<f32>, Vec<f32>, f32) {
    let n = points.len() / d;
    let mut sums = vec![0.0f32; k * d];
    let mut counts = vec![0.0f32; k];
    let mut inertia = 0.0f32;
    for i in 0..n {
        let p = &points[i * d..(i + 1) * d];
        let mut best = (f32::MAX, 0usize);
        for c in 0..k {
            let ctr = &centers[c * d..(c + 1) * d];
            let mut dist = 0.0f32;
            for j in 0..d {
                let diff = p[j] - ctr[j];
                dist += diff * diff;
            }
            if dist < best.0 {
                best = (dist, c);
            }
        }
        counts[best.1] += 1.0;
        inertia += best.0;
        for j in 0..d {
            sums[best.1 * d + j] += p[j];
        }
    }
    (sums, counts, inertia)
}

/// Fit k-means with at most `max_iters` Lloyd's iterations.  When
/// `runtime` is provided, the per-step accumulation runs on the PJRT
/// artifact (batched through the 4096-point contract); otherwise on the
/// host.  Both paths produce identical models (tested).
pub fn fit(
    points: &[f32],
    d: usize,
    k: usize,
    max_iters: usize,
    seed: u64,
    runtime: Option<&Runtime>,
) -> Result<KmeansModel, String> {
    let n = points.len() / d;
    if n * d != points.len() {
        return Err("ragged points".into());
    }
    if n < k {
        return Err(format!("n={n} < k={k}"));
    }
    let mut centers = seed_centers(points, d, k, seed);
    let mut counts = vec![0.0f32; k];
    let mut inertia = f32::MAX;
    let mut iterations = 0;
    for _ in 0..max_iters {
        iterations += 1;
        let (sums, new_counts, new_inertia) = match runtime {
            None => step_host(points, &centers, d, k),
            Some(rt) => {
                // Batch through the fixed-shape artifact.
                let batch = rt.shapes.n_points;
                let mut sums = vec![0.0f32; k * d];
                let mut cts = vec![0.0f32; k];
                let mut inert = 0.0f32;
                for chunk in points.chunks(batch * d) {
                    let (s, c, i) = rt
                        .kmeans_step(chunk, &centers, d, k)
                        .map_err(|e| format!("pjrt kmeans_step: {e}"))?;
                    for (acc, v) in sums.iter_mut().zip(&s) {
                        *acc += v;
                    }
                    for (acc, v) in cts.iter_mut().zip(&c) {
                        *acc += v;
                    }
                    inert += i;
                }
                (sums, cts, inert)
            }
        };
        // Update centers; empty clusters keep their position.
        let mut moved = 0.0f32;
        for c in 0..k {
            if new_counts[c] > 0.0 {
                for j in 0..d {
                    let new = sums[c * d + j] / new_counts[c];
                    moved += (new - centers[c * d + j]).abs();
                    centers[c * d + j] = new;
                }
            }
        }
        counts = new_counts;
        let converged = moved < 1e-6 || (inertia - new_inertia).abs() < 1e-4 * inertia.max(1.0);
        inertia = new_inertia;
        if converged {
            break;
        }
    }
    Ok(KmeansModel {
        centers,
        counts,
        inertia,
        iterations,
        k,
        d,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n_per: usize, seed: u64) -> Vec<f32> {
        // 3 well-separated 2-D blobs
        let mut rng = Pcg64::new(seed);
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let mut pts = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..n_per {
                pts.push(cx + rng.next_gaussian() as f32 * 0.5);
                pts.push(cy + rng.next_gaussian() as f32 * 0.5);
            }
        }
        pts
    }

    #[test]
    fn fits_separated_blobs() {
        let pts = blobs(50, 1);
        let m = fit(&pts, 2, 3, 50, 42, None).unwrap();
        assert_eq!(m.centers.len(), 6);
        // every blob got ~50 points
        let mut counts = m.counts.clone();
        counts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(counts, vec![50.0, 50.0, 50.0]);
        // centers near the true blob centers
        let mut found = [false; 3];
        for c in m.centers.chunks(2) {
            for (i, &(cx, cy)) in [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)].iter().enumerate() {
                if (c[0] - cx).abs() < 1.0 && (c[1] - cy).abs() < 1.0 {
                    found[i] = true;
                }
            }
        }
        assert!(found.iter().all(|&f| f), "centers {:?}", m.centers);
        assert!(m.inertia < 200.0);
    }

    #[test]
    fn host_step_conserves_mass() {
        let pts = blobs(20, 3);
        let ctr = seed_centers(&pts, 2, 3, 7);
        let (sums, counts, inertia) = step_host(&pts, &ctr, 2, 3);
        assert_eq!(counts.iter().sum::<f32>(), 60.0);
        assert!(inertia >= 0.0);
        // sum of sums == sum of points, coordinate-wise
        let mut total = [0.0f32; 2];
        for p in pts.chunks(2) {
            total[0] += p[0];
            total[1] += p[1];
        }
        let mut got = [0.0f32; 2];
        for s in sums.chunks(2) {
            got[0] += s[0];
            got[1] += s[1];
        }
        assert!((got[0] - total[0]).abs() < 1e-2);
        assert!((got[1] - total[1]).abs() < 1e-2);
    }

    #[test]
    fn seeding_is_deterministic_and_distinct() {
        let pts = blobs(30, 5);
        let a = seed_centers(&pts, 2, 3, 9);
        let b = seed_centers(&pts, 2, 3, 9);
        assert_eq!(a, b);
        // k-means++ seeds land in distinct blobs with high probability
        let dist = |i: usize, j: usize| -> f32 {
            let (ax, ay) = (a[i * 2], a[i * 2 + 1]);
            let (bx, by) = (a[j * 2], a[j * 2 + 1]);
            ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt()
        };
        assert!(dist(0, 1) > 3.0 && dist(1, 2) > 3.0 && dist(0, 2) > 3.0);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(fit(&[1.0, 2.0, 3.0], 2, 1, 5, 0, None).is_err()); // ragged
        assert!(fit(&[1.0, 2.0], 2, 3, 5, 0, None).is_err()); // n < k
    }
}

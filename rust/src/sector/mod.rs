//! Sector — the storage cloud (paper §4).
//!
//! Sector provides "long term archival storage and access for large
//! distributed datasets": files (not blocks) with companion record
//! indexes, located via the peer-to-peer routing layer, replicated to a
//! target count with random placement, writes gated by an IP ACL, data
//! movement over UDT with cached connections.

pub mod acl;
pub mod cloud;
pub mod index;
pub mod replica;
pub mod slave;
pub mod storage;

pub use acl::{Access, Acl};
pub use cloud::{CloudBuilder, SectorCloud};
pub use index::{RecordIndex, RecordPos};
pub use replica::{
    FileLoad, ReplicaBounds, ReplicaDirective, ReplicationManager, Scaler, StaticScaler,
    WatermarkScaler,
};
pub use slave::{FileMeta, Slave, SlaveId};
pub use storage::{DiskStorage, MemStorage, Storage};

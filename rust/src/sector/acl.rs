//! Sector's security layer (paper §4, Fig 3): "While data read is open
//! to the general public, write access to the Sector system is
//! controlled by ACL, as the client's IP address must appear in the
//! server's ACL in order to upload data to that particular server."

use std::net::Ipv4Addr;

/// One ACL rule: an IPv4 prefix (CIDR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cidr {
    pub addr: Ipv4Addr,
    pub prefix_len: u8,
}

impl Cidr {
    pub fn parse(s: &str) -> Result<Cidr, String> {
        let (ip, len) = match s.split_once('/') {
            Some((ip, len)) => (
                ip,
                len.parse::<u8>()
                    .map_err(|_| format!("bad prefix length in {s:?}"))?,
            ),
            None => (s, 32),
        };
        if len > 32 {
            return Err(format!("prefix length {len} > 32 in {s:?}"));
        }
        let addr: Ipv4Addr = ip.parse().map_err(|_| format!("bad IPv4 in {s:?}"))?;
        Ok(Cidr {
            addr,
            prefix_len: len,
        })
    }

    pub fn contains(&self, ip: Ipv4Addr) -> bool {
        if self.prefix_len == 0 {
            return true;
        }
        let mask = u32::MAX << (32 - self.prefix_len as u32);
        (u32::from(self.addr) & mask) == (u32::from(ip) & mask)
    }
}

/// Per-server access control list. Reads are open (paper); writes are
/// gated on membership. Deny rules take precedence over allows, letting
/// an admin carve exceptions out of a broad allow.
#[derive(Clone, Debug, Default)]
pub struct Acl {
    allows: Vec<Cidr>,
    denies: Vec<Cidr>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

impl Acl {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn allow(&mut self, cidr: &str) -> Result<&mut Self, String> {
        self.allows.push(Cidr::parse(cidr)?);
        Ok(self)
    }

    pub fn deny(&mut self, cidr: &str) -> Result<&mut Self, String> {
        self.denies.push(Cidr::parse(cidr)?);
        Ok(self)
    }

    /// The paper's policy: reads always permitted; writes require an
    /// allow match and no deny match.
    pub fn check(&self, ip: Ipv4Addr, access: Access) -> bool {
        match access {
            Access::Read => true,
            Access::Write => {
                !self.denies.iter().any(|c| c.contains(ip))
                    && self.allows.iter().any(|c| c.contains(ip))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ip(s: &str) -> Ipv4Addr {
        s.parse().unwrap()
    }

    #[test]
    fn cidr_parsing() {
        let c = Cidr::parse("10.0.0.0/8").unwrap();
        assert!(c.contains(ip("10.255.1.2")));
        assert!(!c.contains(ip("11.0.0.1")));
        let host = Cidr::parse("192.168.1.5").unwrap();
        assert_eq!(host.prefix_len, 32);
        assert!(host.contains(ip("192.168.1.5")));
        assert!(!host.contains(ip("192.168.1.6")));
        assert!(Cidr::parse("10.0.0.0/33").is_err());
        assert!(Cidr::parse("not-an-ip/8").is_err());
        assert!(Cidr::parse("10.0.0.0/x").is_err());
        assert!(Cidr::parse("0.0.0.0/0").unwrap().contains(ip("8.8.8.8")));
    }

    #[test]
    fn reads_open_writes_gated() {
        let mut acl = Acl::new();
        acl.allow("131.193.0.0/16").unwrap(); // UIC
        let outsider = ip("8.8.8.8");
        let member = ip("131.193.12.34");
        assert!(acl.check(outsider, Access::Read), "public read (paper §4)");
        assert!(!acl.check(outsider, Access::Write));
        assert!(acl.check(member, Access::Write));
    }

    #[test]
    fn deny_overrides_allow() {
        let mut acl = Acl::new();
        acl.allow("10.0.0.0/8").unwrap();
        acl.deny("10.9.0.0/16").unwrap();
        assert!(acl.check(ip("10.1.1.1"), Access::Write));
        assert!(!acl.check(ip("10.9.1.1"), Access::Write));
        assert!(acl.check(ip("10.9.1.1"), Access::Read));
    }

    #[test]
    fn empty_acl_denies_all_writes() {
        let acl = Acl::new();
        assert!(!acl.check(ip("127.0.0.1"), Access::Write));
        assert!(acl.check(ip("127.0.0.1"), Access::Read));
    }
}

//! Storage backends for Sector slaves.
//!
//! Sector "is not a file system per se, but rather provides services
//! that rely in part on the local native file systems" (paper §4).  The
//! slave's backing store is therefore a trait: `DiskStorage` uses the
//! real local filesystem (real-mode clusters, the e2e examples), and
//! `MemStorage` keeps bytes in memory (fast tests, simulation metadata).

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::Mutex;

pub trait Storage: Send + Sync {
    fn put(&self, name: &str, data: &[u8]) -> Result<(), String>;
    fn get(&self, name: &str) -> Result<Vec<u8>, String>;
    /// Read `len` bytes at `offset` (for record-granular segment reads).
    fn get_range(&self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>, String>;
    fn delete(&self, name: &str) -> Result<(), String>;
    fn exists(&self, name: &str) -> bool;
    fn len(&self, name: &str) -> Result<u64, String>;
    fn list(&self) -> Vec<String>;
}

/// In-memory backend.
#[derive(Default)]
pub struct MemStorage {
    files: Mutex<HashMap<String, Vec<u8>>>,
}

impl MemStorage {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Storage for MemStorage {
    fn put(&self, name: &str, data: &[u8]) -> Result<(), String> {
        self.files
            .lock()
            .unwrap()
            .insert(name.to_string(), data.to_vec());
        Ok(())
    }

    fn get(&self, name: &str) -> Result<Vec<u8>, String> {
        self.files
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| format!("no such file: {name}"))
    }

    fn get_range(&self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>, String> {
        let files = self.files.lock().unwrap();
        let data = files
            .get(name)
            .ok_or_else(|| format!("no such file: {name}"))?;
        let (o, l) = (offset as usize, len as usize);
        if o + l > data.len() {
            return Err(format!(
                "range [{o}, {}) out of bounds for {name} (len {})",
                o + l,
                data.len()
            ));
        }
        Ok(data[o..o + l].to_vec())
    }

    fn delete(&self, name: &str) -> Result<(), String> {
        self.files
            .lock()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| format!("no such file: {name}"))
    }

    fn exists(&self, name: &str) -> bool {
        self.files.lock().unwrap().contains_key(name)
    }

    fn len(&self, name: &str) -> Result<u64, String> {
        self.files
            .lock()
            .unwrap()
            .get(name)
            .map(|d| d.len() as u64)
            .ok_or_else(|| format!("no such file: {name}"))
    }

    fn list(&self) -> Vec<String> {
        let mut names: Vec<String> = self.files.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }
}

/// Real-filesystem backend rooted at a directory. File names may contain
/// `/` (subdirectories are created as needed); `..` is rejected.
pub struct DiskStorage {
    root: PathBuf,
}

impl DiskStorage {
    pub fn new(root: impl Into<PathBuf>) -> Result<Self, String> {
        let root = root.into();
        fs::create_dir_all(&root).map_err(|e| format!("create {root:?}: {e}"))?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &PathBuf {
        &self.root
    }

    fn path_of(&self, name: &str) -> Result<PathBuf, String> {
        if name.split('/').any(|part| part == ".." || part.is_empty()) {
            return Err(format!("illegal file name {name:?}"));
        }
        Ok(self.root.join(name))
    }
}

impl Storage for DiskStorage {
    fn put(&self, name: &str, data: &[u8]) -> Result<(), String> {
        let path = self.path_of(name)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent).map_err(|e| e.to_string())?;
        }
        fs::write(&path, data).map_err(|e| format!("write {path:?}: {e}"))
    }

    fn get(&self, name: &str) -> Result<Vec<u8>, String> {
        let path = self.path_of(name)?;
        fs::read(&path).map_err(|e| format!("read {path:?}: {e}"))
    }

    fn get_range(&self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>, String> {
        let path = self.path_of(name)?;
        let mut f = fs::File::open(&path).map_err(|e| format!("open {path:?}: {e}"))?;
        f.seek(SeekFrom::Start(offset)).map_err(|e| e.to_string())?;
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf)
            .map_err(|e| format!("read range {offset}+{len} of {path:?}: {e}"))?;
        Ok(buf)
    }

    fn delete(&self, name: &str) -> Result<(), String> {
        let path = self.path_of(name)?;
        fs::remove_file(&path).map_err(|e| format!("delete {path:?}: {e}"))
    }

    fn exists(&self, name: &str) -> bool {
        self.path_of(name).map(|p| p.exists()).unwrap_or(false)
    }

    fn len(&self, name: &str) -> Result<u64, String> {
        let path = self.path_of(name)?;
        fs::metadata(&path)
            .map(|m| m.len())
            .map_err(|e| format!("stat {path:?}: {e}"))
    }

    fn list(&self) -> Vec<String> {
        fn walk(dir: &PathBuf, prefix: String, out: &mut Vec<String>) {
            if let Ok(entries) = fs::read_dir(dir) {
                for e in entries.flatten() {
                    let name = e.file_name().to_string_lossy().to_string();
                    let rel = if prefix.is_empty() {
                        name.clone()
                    } else {
                        format!("{prefix}/{name}")
                    };
                    let p = e.path();
                    if p.is_dir() {
                        walk(&p, rel, out);
                    } else {
                        out.push(rel);
                    }
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.root, String::new(), &mut out);
        out.sort();
        out
    }
}

/// Append to a file (used by shuffle bucket writers). Disk-only helper.
impl DiskStorage {
    pub fn append(&self, name: &str, data: &[u8]) -> Result<(), String> {
        let path = self.path_of(name)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent).map_err(|e| e.to_string())?;
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("open-append {path:?}: {e}"))?;
        f.write_all(data).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "sector-test-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        p
    }

    fn exercise(s: &dyn Storage) {
        assert!(!s.exists("a.dat"));
        s.put("a.dat", b"hello records").unwrap();
        assert!(s.exists("a.dat"));
        assert_eq!(s.len("a.dat").unwrap(), 13);
        assert_eq!(s.get("a.dat").unwrap(), b"hello records");
        assert_eq!(s.get_range("a.dat", 6, 7).unwrap(), b"records");
        assert!(s.get_range("a.dat", 10, 10).is_err());
        s.put("dir/b.dat", b"xy").unwrap();
        assert_eq!(s.list(), vec!["a.dat".to_string(), "dir/b.dat".to_string()]);
        s.delete("a.dat").unwrap();
        assert!(!s.exists("a.dat"));
        assert!(s.delete("a.dat").is_err());
        assert!(s.get("missing").is_err());
    }

    #[test]
    fn mem_storage_contract() {
        exercise(&MemStorage::new());
    }

    #[test]
    fn disk_storage_contract() {
        let root = temp_root("contract");
        let s = DiskStorage::new(&root).unwrap();
        exercise(&s);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn disk_storage_rejects_traversal() {
        let root = temp_root("traversal");
        let s = DiskStorage::new(&root).unwrap();
        assert!(s.put("../evil", b"x").is_err());
        assert!(s.get("a/../../b").is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn disk_append_accumulates() {
        let root = temp_root("append");
        let s = DiskStorage::new(&root).unwrap();
        s.append("bucket-3.dat", b"aa").unwrap();
        s.append("bucket-3.dat", b"bb").unwrap();
        assert_eq!(s.get("bucket-3.dat").unwrap(), b"aabb");
        std::fs::remove_dir_all(&root).ok();
    }
}

//! A Sector slave (storage node): local storage managed through the
//! native file system, an ACL gating writes, and — because the evaluated
//! Sector is peer-to-peer (paper §2: "managed with a peer-to-peer
//! architecture", vs GFS/HDFS's "centralized master node") — a partition
//! of the file-metadata space, owned by Chord id.

use std::collections::HashMap;
use std::net::Ipv4Addr;
use std::sync::Mutex;

use super::acl::{Access, Acl};
use super::index::RecordIndex;
use super::storage::Storage;

/// Slave identifier (dense, 0-based).
pub type SlaveId = u32;

/// Metadata record for one Sector file.
#[derive(Clone, Debug, PartialEq)]
pub struct FileMeta {
    pub name: String,
    pub size_bytes: u64,
    pub n_records: u64,
    /// Slaves holding a replica (data + .idx co-located, paper §4).
    pub locations: Vec<SlaveId>,
    /// Sphere operator libraries are never replicated (paper §3.1).
    pub replicable: bool,
}

pub struct Slave {
    pub id: SlaveId,
    pub ip: Ipv4Addr,
    /// Chord ring id of this node.
    pub ring_id: u64,
    pub storage: Box<dyn Storage>,
    pub acl: Acl,
    /// The metadata partition this node owns (name -> meta).
    meta: Mutex<HashMap<String, FileMeta>>,
}

impl Slave {
    pub fn new(
        id: SlaveId,
        ip: Ipv4Addr,
        ring_id: u64,
        storage: Box<dyn Storage>,
        acl: Acl,
    ) -> Self {
        Self {
            id,
            ip,
            ring_id,
            storage,
            acl,
            meta: Mutex::new(HashMap::new()),
        }
    }

    /// Store a data file and its companion index, enforcing the ACL.
    pub fn put_file(
        &self,
        client_ip: Ipv4Addr,
        name: &str,
        data: &[u8],
        index: Option<&RecordIndex>,
    ) -> Result<(), String> {
        if !self.acl.check(client_ip, Access::Write) {
            return Err(format!(
                "ACL: {client_ip} may not write to slave {} ({})",
                self.id, self.ip
            ));
        }
        if let Some(idx) = index {
            idx.validate(data.len() as u64)?;
            self.storage
                .put(&RecordIndex::idx_name(name), &idx.to_bytes())?;
        }
        self.storage.put(name, data)
    }

    /// Read a whole file (reads are public, paper §4).
    pub fn get_file(&self, name: &str) -> Result<Vec<u8>, String> {
        self.storage.get(name)
    }

    /// Read a byte range (record-granular segment reads).
    pub fn get_range(&self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>, String> {
        self.storage.get_range(name, offset, len)
    }

    /// Load the companion record index, if one exists.
    pub fn get_index(&self, name: &str) -> Option<RecordIndex> {
        self.storage
            .get(&RecordIndex::idx_name(name))
            .ok()
            .and_then(|b| RecordIndex::from_bytes(&b).ok())
    }

    pub fn has_file(&self, name: &str) -> bool {
        self.storage.exists(name)
    }

    pub fn delete_file(&self, name: &str) -> Result<(), String> {
        let idx = RecordIndex::idx_name(name);
        if self.storage.exists(&idx) {
            self.storage.delete(&idx)?;
        }
        self.storage.delete(name)
    }

    // ---- metadata partition (this node is the Chord owner) ----

    pub fn meta_insert(&self, meta: FileMeta) {
        self.meta.lock().unwrap().insert(meta.name.clone(), meta);
    }

    pub fn meta_get(&self, name: &str) -> Option<FileMeta> {
        self.meta.lock().unwrap().get(name).cloned()
    }

    pub fn meta_update<F: FnOnce(&mut FileMeta)>(&self, name: &str, f: F) -> bool {
        let mut m = self.meta.lock().unwrap();
        match m.get_mut(name) {
            Some(meta) => {
                f(meta);
                true
            }
            None => false,
        }
    }

    pub fn meta_remove(&self, name: &str) -> Option<FileMeta> {
        self.meta.lock().unwrap().remove(name)
    }

    pub fn meta_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.meta.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::storage::MemStorage;

    fn slave_with_acl() -> Slave {
        let mut acl = Acl::new();
        acl.allow("10.0.0.0/8").unwrap();
        Slave::new(
            0,
            "10.0.0.1".parse().unwrap(),
            123,
            Box::new(MemStorage::new()),
            acl,
        )
    }

    #[test]
    fn put_get_respects_acl() {
        let s = slave_with_acl();
        let member = "10.1.2.3".parse().unwrap();
        let outsider = "8.8.8.8".parse().unwrap();
        let idx = RecordIndex::fixed(4, 12);
        s.put_file(member, "f.dat", b"abcdefghijkl", Some(&idx))
            .unwrap();
        assert!(s
            .put_file(outsider, "g.dat", b"x", None)
            .unwrap_err()
            .contains("ACL"));
        // reads are public
        assert_eq!(s.get_file("f.dat").unwrap(), b"abcdefghijkl");
        assert_eq!(s.get_range("f.dat", 4, 4).unwrap(), b"efgh");
        assert_eq!(s.get_index("f.dat").unwrap().len(), 3);
    }

    #[test]
    fn index_mismatch_rejected() {
        let s = slave_with_acl();
        let member = "10.1.2.3".parse().unwrap();
        let idx = RecordIndex::fixed(4, 8); // covers 8, data is 12
        assert!(s.put_file(member, "f.dat", b"abcdefghijkl", Some(&idx)).is_err());
    }

    #[test]
    fn delete_removes_idx_too() {
        let s = slave_with_acl();
        let member = "10.0.0.9".parse().unwrap();
        let idx = RecordIndex::fixed(1, 3);
        s.put_file(member, "f.dat", b"abc", Some(&idx)).unwrap();
        assert!(s.has_file("f.dat"));
        assert!(s.storage.exists("f.dat.idx"));
        s.delete_file("f.dat").unwrap();
        assert!(!s.has_file("f.dat"));
        assert!(!s.storage.exists("f.dat.idx"));
    }

    #[test]
    fn metadata_partition_crud() {
        let s = slave_with_acl();
        s.meta_insert(FileMeta {
            name: "f.dat".into(),
            size_bytes: 10,
            n_records: 2,
            locations: vec![0],
            replicable: true,
        });
        assert_eq!(s.meta_get("f.dat").unwrap().n_records, 2);
        assert!(s.meta_update("f.dat", |m| m.locations.push(3)));
        assert_eq!(s.meta_get("f.dat").unwrap().locations, vec![0, 3]);
        assert!(!s.meta_update("missing", |_| {}));
        assert_eq!(s.meta_names(), vec!["f.dat".to_string()]);
        assert!(s.meta_remove("f.dat").is_some());
        assert!(s.meta_get("f.dat").is_none());
    }
}

//! Replication policy (paper §4): "Sector uses replication in order to
//! safely archive data.  It monitors the number of replicas, and, when
//! necessary, creates additional replicas at a random location.  The
//! number of replicas of each file is checked once per day.  The choice
//! of random location leads to uniform distribution of data over the
//! whole system."

use super::cloud::SectorCloud;

/// Drives periodic replica checks against a virtual clock.
#[derive(Clone, Debug)]
pub struct ReplicationManager {
    /// Check period, seconds (paper: 86 400 — once per day).
    pub check_interval_secs: f64,
    next_check: f64,
    pub checks_run: u64,
    pub replicas_created: u64,
}

impl ReplicationManager {
    pub fn new(check_interval_secs: f64) -> Self {
        assert!(check_interval_secs > 0.0);
        Self {
            check_interval_secs,
            next_check: check_interval_secs,
            checks_run: 0,
            replicas_created: 0,
        }
    }

    /// Advance to time `now`, running any due checks. Returns the number
    /// of replicas created.
    pub fn tick(&mut self, now: f64, cloud: &SectorCloud) -> u64 {
        let mut created = 0;
        while now >= self.next_check {
            created += self.check_all(cloud);
            self.next_check += self.check_interval_secs;
        }
        created
    }

    /// One full pass: restore every under-replicated file up to the
    /// cloud's target. Returns replicas created.
    pub fn check_all(&mut self, cloud: &SectorCloud) -> u64 {
        self.checks_run += 1;
        let mut created = 0;
        for name in cloud.list() {
            loop {
                let meta = match cloud.stat(&name) {
                    Some(m) => m,
                    None => break,
                };
                if !meta.replicable || meta.locations.len() >= cloud.replica_target {
                    break;
                }
                match cloud.replicate_once(&name) {
                    Ok(Some(_)) => created += 1,
                    _ => break,
                }
            }
        }
        self.replicas_created += created;
        created
    }
}

// --------------------------------------------------------- elastic scaling
//
// The periodic daily check above keeps every file AT a fixed target.
// The elastic subsystem (DESIGN.md §16) instead asks, each scaler tick,
// which files should GROW a replica (hot — reads queue behind too few
// copies) and which should SHED one (cold — copies sit idle).  The
// policy lives behind the `Scaler` trait so the traffic engine can run
// different policies under identical demand traces and fault plans and
// compare the SLO-vs-replication-cost trade in one report.

/// Per-file demand observed over one scaler window, as the policy sees
/// it: how many live replicas serve the file and the read arrival rate
/// *per replica* (the quantity the watermarks are defined over — a file
/// with 4 replicas absorbing 40 reads/s is exactly as loaded as a file
/// with 1 replica absorbing 10 reads/s).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FileLoad {
    pub file: u32,
    /// Live (serving, non-draining) replicas right now.
    pub replicas: u32,
    /// Observed reads per second per live replica over the last window.
    pub reads_per_sec_per_replica: f64,
}

/// Replica-count bounds the policy must respect (from the
/// `[replication]` block).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaBounds {
    pub min: u32,
    pub max: u32,
}

/// One scaling decision.  The engine turns `Grow` into a real transfer
/// flow on the shared network (the new copy serves only once the bytes
/// land) and `Shed` into a drain: the replica leaves the read set
/// immediately but is only removed once its in-flight reads finish.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReplicaDirective {
    Grow { file: u32 },
    Shed { file: u32 },
}

/// An autoscaling policy: observe one window of per-file demand, emit
/// grow/shed directives.  Implementations must be deterministic —
/// `loads` arrives sorted by file id and any internal tie-breaking must
/// be value-based, never address- or hash-ordered.
pub trait Scaler {
    fn name(&self) -> &'static str;
    fn scale(&mut self, now: f64, loads: &[FileLoad], bounds: ReplicaBounds)
        -> Vec<ReplicaDirective>;
}

/// The do-nothing baseline: replica counts stay wherever the initial
/// placement put them.  Running the watermark policy against this under
/// the same trace is what gives `ElasticityReport` its SLO deltas.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticScaler;

impl Scaler for StaticScaler {
    fn name(&self) -> &'static str {
        "static"
    }

    fn scale(&mut self, _now: f64, _loads: &[FileLoad], _bounds: ReplicaBounds)
        -> Vec<ReplicaDirective> {
        Vec::new()
    }
}

/// Load-driven watermark policy: grow the hottest files whose
/// per-replica read rate exceeds `high`, shed the coldest whose rate
/// sits below `low`, at most `max_grows_per_tick` / `max_sheds_per_tick`
/// of each per window so one burst cannot flood the network with
/// re-replication traffic.
#[derive(Clone, Copy, Debug)]
pub struct WatermarkScaler {
    /// Grow when reads/sec/replica exceeds this.
    pub high: f64,
    /// Shed when reads/sec/replica falls below this.
    pub low: f64,
    pub max_grows_per_tick: u32,
    pub max_sheds_per_tick: u32,
}

impl Scaler for WatermarkScaler {
    fn name(&self) -> &'static str {
        "watermark"
    }

    fn scale(&mut self, _now: f64, loads: &[FileLoad], bounds: ReplicaBounds)
        -> Vec<ReplicaDirective> {
        // Hottest first for grows; coldest first for sheds.  f64 rates
        // come from deterministic counters so total_cmp is a stable
        // order; file id breaks exact ties.
        let mut hot: Vec<&FileLoad> = loads
            .iter()
            .filter(|l| l.reads_per_sec_per_replica > self.high && l.replicas < bounds.max)
            .collect();
        hot.sort_by(|a, b| {
            b.reads_per_sec_per_replica
                .total_cmp(&a.reads_per_sec_per_replica)
                .then(a.file.cmp(&b.file))
        });
        let mut cold: Vec<&FileLoad> = loads
            .iter()
            .filter(|l| l.reads_per_sec_per_replica < self.low && l.replicas > bounds.min)
            .collect();
        cold.sort_by(|a, b| {
            a.reads_per_sec_per_replica
                .total_cmp(&b.reads_per_sec_per_replica)
                .then(a.file.cmp(&b.file))
        });
        let mut out = Vec::new();
        for l in hot.into_iter().take(self.max_grows_per_tick as usize) {
            out.push(ReplicaDirective::Grow { file: l.file });
        }
        for l in cold.into_iter().take(self.max_sheds_per_tick as usize) {
            out.push(ReplicaDirective::Shed { file: l.file });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::cloud::SectorCloud;
    use std::net::Ipv4Addr;

    fn cloud_with_files(nodes: usize, files: usize, replicas: usize) -> SectorCloud {
        let c = SectorCloud::builder()
            .nodes(nodes)
            .replicas(replicas)
            .seed(11)
            .build()
            .unwrap();
        let ip: Ipv4Addr = "10.0.0.50".parse().unwrap();
        for i in 0..files {
            c.upload(ip, &format!("f{i:04}.dat"), &vec![7u8; 64], None, None)
                .unwrap();
        }
        c
    }

    #[test]
    fn restores_to_target() {
        let c = cloud_with_files(6, 10, 3);
        let mut mgr = ReplicationManager::new(86_400.0);
        let created = mgr.check_all(&c);
        assert_eq!(created, 20, "10 files x 2 missing replicas");
        for name in c.list() {
            assert_eq!(c.stat(&name).unwrap().locations.len(), 3);
        }
        // Second pass is a no-op.
        assert_eq!(mgr.check_all(&c), 0);
    }

    #[test]
    fn daily_schedule() {
        let c = cloud_with_files(4, 3, 2);
        let mut mgr = ReplicationManager::new(86_400.0);
        assert_eq!(mgr.tick(1000.0, &c), 0, "before the first day boundary");
        let created = mgr.tick(86_400.0, &c);
        assert_eq!(created, 3);
        assert_eq!(mgr.checks_run, 1);
        // Jumping three days runs the (now no-op) check three more times.
        mgr.tick(4.0 * 86_400.0, &c);
        assert_eq!(mgr.checks_run, 4);
    }

    #[test]
    fn recovers_after_slave_failure() {
        let c = cloud_with_files(5, 8, 2);
        let mut mgr = ReplicationManager::new(86_400.0);
        mgr.check_all(&c);
        c.fail_slave(2);
        let created = mgr.check_all(&c);
        assert!(created > 0, "files that lost a replica get a new one");
        for name in c.list() {
            assert_eq!(c.stat(&name).unwrap().locations.len(), 2);
            assert!(!c.stat(&name).unwrap().locations.contains(&2));
        }
    }

    #[test]
    fn placement_is_roughly_uniform() {
        // Paper: "The choice of random location leads to uniform
        // distribution of data over the whole system."
        let c = cloud_with_files(8, 200, 2);
        let mut mgr = ReplicationManager::new(86_400.0);
        mgr.check_all(&c);
        let mut per_slave = vec![0usize; 8];
        for name in c.list() {
            for loc in c.stat(&name).unwrap().locations {
                per_slave[loc as usize] += 1;
            }
        }
        let total: usize = per_slave.iter().sum();
        assert_eq!(total, 400);
        let mean = total as f64 / 8.0;
        for (i, &n) in per_slave.iter().enumerate() {
            assert!(
                (n as f64) > 0.5 * mean && (n as f64) < 1.6 * mean,
                "slave {i} holds {n} of {total} (mean {mean})"
            );
        }
    }

    fn load(file: u32, replicas: u32, rate: f64) -> FileLoad {
        FileLoad { file, replicas, reads_per_sec_per_replica: rate }
    }

    const BOUNDS: ReplicaBounds = ReplicaBounds { min: 2, max: 4 };

    #[test]
    fn static_scaler_never_acts() {
        let loads = vec![load(0, 2, 1e9), load(1, 4, 0.0)];
        assert!(StaticScaler.scale(0.0, &loads, BOUNDS).is_empty());
    }

    #[test]
    fn watermark_grows_hot_and_sheds_cold() {
        let mut s = WatermarkScaler {
            high: 10.0,
            low: 1.0,
            max_grows_per_tick: 8,
            max_sheds_per_tick: 8,
        };
        let loads = vec![
            load(0, 2, 50.0), // hot -> grow
            load(1, 3, 5.0),  // between the marks -> untouched
            load(2, 3, 0.2),  // cold -> shed
        ];
        assert_eq!(
            s.scale(0.0, &loads, BOUNDS),
            vec![
                ReplicaDirective::Grow { file: 0 },
                ReplicaDirective::Shed { file: 2 }
            ]
        );
    }

    #[test]
    fn watermark_respects_bounds() {
        let mut s = WatermarkScaler {
            high: 10.0,
            low: 1.0,
            max_grows_per_tick: 8,
            max_sheds_per_tick: 8,
        };
        // Hot but already at max; cold but already at min.
        let loads = vec![load(0, 4, 50.0), load(1, 2, 0.0)];
        assert!(s.scale(0.0, &loads, BOUNDS).is_empty());
    }

    #[test]
    fn watermark_budget_takes_hottest_and_coldest_first() {
        let mut s = WatermarkScaler {
            high: 10.0,
            low: 1.0,
            max_grows_per_tick: 1,
            max_sheds_per_tick: 1,
        };
        let loads = vec![
            load(0, 2, 20.0),
            load(1, 2, 90.0), // hottest wins the single grow slot
            load(2, 3, 0.5),
            load(3, 3, 0.1), // coldest wins the single shed slot
        ];
        assert_eq!(
            s.scale(0.0, &loads, BOUNDS),
            vec![
                ReplicaDirective::Grow { file: 1 },
                ReplicaDirective::Shed { file: 3 }
            ]
        );
    }

    #[test]
    fn watermark_breaks_rate_ties_by_file_id() {
        let mut s = WatermarkScaler {
            high: 10.0,
            low: 1.0,
            max_grows_per_tick: 1,
            max_sheds_per_tick: 0,
        };
        let loads = vec![load(7, 2, 20.0), load(3, 2, 20.0)];
        assert_eq!(
            s.scale(0.0, &loads, BOUNDS),
            vec![ReplicaDirective::Grow { file: 3 }]
        );
    }
}

//! Replication policy (paper §4): "Sector uses replication in order to
//! safely archive data.  It monitors the number of replicas, and, when
//! necessary, creates additional replicas at a random location.  The
//! number of replicas of each file is checked once per day.  The choice
//! of random location leads to uniform distribution of data over the
//! whole system."

use super::cloud::SectorCloud;

/// Drives periodic replica checks against a virtual clock.
#[derive(Clone, Debug)]
pub struct ReplicationManager {
    /// Check period, seconds (paper: 86 400 — once per day).
    pub check_interval_secs: f64,
    next_check: f64,
    pub checks_run: u64,
    pub replicas_created: u64,
}

impl ReplicationManager {
    pub fn new(check_interval_secs: f64) -> Self {
        assert!(check_interval_secs > 0.0);
        Self {
            check_interval_secs,
            next_check: check_interval_secs,
            checks_run: 0,
            replicas_created: 0,
        }
    }

    /// Advance to time `now`, running any due checks. Returns the number
    /// of replicas created.
    pub fn tick(&mut self, now: f64, cloud: &SectorCloud) -> u64 {
        let mut created = 0;
        while now >= self.next_check {
            created += self.check_all(cloud);
            self.next_check += self.check_interval_secs;
        }
        created
    }

    /// One full pass: restore every under-replicated file up to the
    /// cloud's target. Returns replicas created.
    pub fn check_all(&mut self, cloud: &SectorCloud) -> u64 {
        self.checks_run += 1;
        let mut created = 0;
        for name in cloud.list() {
            loop {
                let meta = match cloud.stat(&name) {
                    Some(m) => m,
                    None => break,
                };
                if !meta.replicable || meta.locations.len() >= cloud.replica_target {
                    break;
                }
                match cloud.replicate_once(&name) {
                    Ok(Some(_)) => created += 1,
                    _ => break,
                }
            }
        }
        self.replicas_created += created;
        created
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sector::cloud::SectorCloud;
    use std::net::Ipv4Addr;

    fn cloud_with_files(nodes: usize, files: usize, replicas: usize) -> SectorCloud {
        let c = SectorCloud::builder()
            .nodes(nodes)
            .replicas(replicas)
            .seed(11)
            .build()
            .unwrap();
        let ip: Ipv4Addr = "10.0.0.50".parse().unwrap();
        for i in 0..files {
            c.upload(ip, &format!("f{i:04}.dat"), &vec![7u8; 64], None, None)
                .unwrap();
        }
        c
    }

    #[test]
    fn restores_to_target() {
        let c = cloud_with_files(6, 10, 3);
        let mut mgr = ReplicationManager::new(86_400.0);
        let created = mgr.check_all(&c);
        assert_eq!(created, 20, "10 files x 2 missing replicas");
        for name in c.list() {
            assert_eq!(c.stat(&name).unwrap().locations.len(), 3);
        }
        // Second pass is a no-op.
        assert_eq!(mgr.check_all(&c), 0);
    }

    #[test]
    fn daily_schedule() {
        let c = cloud_with_files(4, 3, 2);
        let mut mgr = ReplicationManager::new(86_400.0);
        assert_eq!(mgr.tick(1000.0, &c), 0, "before the first day boundary");
        let created = mgr.tick(86_400.0, &c);
        assert_eq!(created, 3);
        assert_eq!(mgr.checks_run, 1);
        // Jumping three days runs the (now no-op) check three more times.
        mgr.tick(4.0 * 86_400.0, &c);
        assert_eq!(mgr.checks_run, 4);
    }

    #[test]
    fn recovers_after_slave_failure() {
        let c = cloud_with_files(5, 8, 2);
        let mut mgr = ReplicationManager::new(86_400.0);
        mgr.check_all(&c);
        c.fail_slave(2);
        let created = mgr.check_all(&c);
        assert!(created > 0, "files that lost a replica get a new one");
        for name in c.list() {
            assert_eq!(c.stat(&name).unwrap().locations.len(), 2);
            assert!(!c.stat(&name).unwrap().locations.contains(&2));
        }
    }

    #[test]
    fn placement_is_roughly_uniform() {
        // Paper: "The choice of random location leads to uniform
        // distribution of data over the whole system."
        let c = cloud_with_files(8, 200, 2);
        let mut mgr = ReplicationManager::new(86_400.0);
        mgr.check_all(&c);
        let mut per_slave = vec![0usize; 8];
        for name in c.list() {
            for loc in c.stat(&name).unwrap().locations {
                per_slave[loc as usize] += 1;
            }
        }
        let total: usize = per_slave.iter().sum();
        assert_eq!(total, 400);
        let mean = total as f64 / 8.0;
        for (i, &n) in per_slave.iter().enumerate() {
            assert!(
                (n as f64) > 0.5 * mean && (n as f64) < 1.6 * mean,
                "slave {i} holds {n} of {total} (mean {mean})"
            );
        }
    }
}

//! The Sector storage cloud: slaves + Chord routing + the client-visible
//! operations (upload / locate / download / delete), following the §4
//! access flow:
//!
//!   1. the client connects to a known server S and asks for an entity
//!      by name;
//!   2. S looks the name up through the routing layer (Chord) — the
//!      metadata lives on the name's ring owner;
//!   3. the client opens a (cached) data connection to a returned
//!      location via GMP;
//!   4. bulk bytes ride UDT on that connection.
//!
//! In-process, steps 3–4 are real storage reads; the GMP/UDT/cache cost
//! accounting feeds the metrics and the simulator.

use std::net::Ipv4Addr;
use std::sync::Mutex;

use crate::metrics::Metrics;
use crate::routing::chord::ChordRing;
use crate::routing::Router;
use crate::transport::ConnectionCache;
use crate::util::rng::Pcg64;

use super::acl::Acl;
use super::index::RecordIndex;
use super::slave::{FileMeta, Slave, SlaveId};
use super::storage::{MemStorage, Storage};

pub struct SectorCloud {
    slaves: Vec<Slave>,
    pub ring: ChordRing,
    /// Target replica count (paper: monitored, restored when below).
    pub replica_target: usize,
    /// slave id -> rack id; all zero when no topology was given
    /// (placement then degenerates to the paper's uniform-random rule).
    node_rack: Vec<usize>,
    pub conn_cache: Mutex<ConnectionCache>,
    pub metrics: Metrics,
    rng: Mutex<Pcg64>,
    /// Slaves currently considered failed (no reads, writes or replicas).
    dead: Mutex<std::collections::HashSet<SlaveId>>,
}

/// Builder for in-process clouds.
pub struct CloudBuilder {
    n: usize,
    replica_target: usize,
    seed: u64,
    acl_writers: Vec<String>,
    node_racks: Option<Vec<usize>>,
    make_storage: Box<dyn Fn(SlaveId) -> Box<dyn Storage>>,
}

impl Default for CloudBuilder {
    fn default() -> Self {
        Self {
            n: 4,
            replica_target: 2,
            seed: 1,
            acl_writers: vec!["10.0.0.0/8".to_string()],
            node_racks: None,
            make_storage: Box::new(|_| Box::new(MemStorage::new())),
        }
    }
}

impl CloudBuilder {
    pub fn nodes(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.n = n;
        self
    }

    pub fn replicas(mut self, r: usize) -> Self {
        assert!(r >= 1);
        self.replica_target = r;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn allow_writers(mut self, cidrs: &[&str]) -> Self {
        self.acl_writers = cidrs.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Describe the physical layout: `racks[i]` is slave i's rack id.
    /// When given, replica placement prefers a rack no existing replica
    /// occupies, so a whole-rack failure cannot take out every copy
    /// (the scale-out testbeds of DESIGN.md §4; the paper's two
    /// testbeds are single-rack-per-site so its uniform-random rule is
    /// unchanged there).
    pub fn racks(mut self, racks: &[usize]) -> Self {
        self.node_racks = Some(racks.to_vec());
        self
    }

    pub fn storage_factory(
        mut self,
        f: impl Fn(SlaveId) -> Box<dyn Storage> + 'static,
    ) -> Self {
        self.make_storage = Box::new(f);
        self
    }

    pub fn build(self) -> Result<SectorCloud, String> {
        let node_rack = match self.node_racks {
            Some(r) => {
                if r.len() != self.n {
                    return Err(format!(
                        "racks() got {} entries for {} slaves",
                        r.len(),
                        self.n
                    ));
                }
                r
            }
            None => vec![0; self.n],
        };
        let mut rng = Pcg64::new(self.seed);
        let mut slaves = Vec::with_capacity(self.n);
        let mut ring_ids = Vec::with_capacity(self.n);
        for id in 0..self.n as SlaveId {
            let ip: Ipv4Addr = format!("10.0.{}.{}", id / 250, (id % 250) + 1)
                .parse()
                .unwrap();
            let ring_id = rng.next_u64();
            ring_ids.push(ring_id);
            let mut acl = Acl::new();
            for cidr in &self.acl_writers {
                acl.allow(cidr)?;
            }
            slaves.push(Slave::new(
                id,
                ip,
                ring_id,
                (self.make_storage)(id),
                acl,
            ));
        }
        Ok(SectorCloud {
            slaves,
            ring: ChordRing::build(&ring_ids),
            replica_target: self.replica_target,
            node_rack,
            conn_cache: Mutex::new(ConnectionCache::new(1024, 600.0)),
            metrics: Metrics::new(),
            rng: Mutex::new(rng),
            dead: Mutex::new(std::collections::HashSet::new()),
        })
    }
}

impl SectorCloud {
    pub fn builder() -> CloudBuilder {
        CloudBuilder::default()
    }

    pub fn n_slaves(&self) -> usize {
        self.slaves.len()
    }

    pub fn slave(&self, id: SlaveId) -> &Slave {
        &self.slaves[id as usize]
    }

    pub fn slaves(&self) -> &[Slave] {
        &self.slaves
    }

    /// The slave owning a name's metadata (Chord successor of its hash).
    pub fn meta_owner(&self, name: &str) -> SlaveId {
        let ring_id = self.ring.locate(name).expect("non-empty ring");
        self.slaves
            .iter()
            .position(|s| s.ring_id == ring_id)
            .expect("ring id belongs to a slave") as SlaveId
    }

    /// Routing hops for a lookup starting at `from` (latency accounting).
    pub fn lookup_hops(&self, from: SlaveId, name: &str) -> u32 {
        self.ring.hops(self.slaves[from as usize].ring_id, name)
    }

    /// Upload a file into the cloud.  The initial replica lands on
    /// `target` (or a deterministic-random slave); metadata registers at
    /// the name's ring owner.  ACL checked at the target slave.
    pub fn upload(
        &self,
        client_ip: Ipv4Addr,
        name: &str,
        data: &[u8],
        index: Option<&RecordIndex>,
        target: Option<SlaveId>,
    ) -> Result<SlaveId, String> {
        if self.stat(name).is_some() {
            return Err(format!("file exists: {name}"));
        }
        let target = target.unwrap_or_else(|| {
            self.rng.lock().unwrap().gen_range(self.slaves.len() as u64) as SlaveId
        });
        let slave = &self.slaves[target as usize];
        slave.put_file(client_ip, name, data, index)?;
        let owner = self.meta_owner(name);
        // Sphere operator libraries are excluded from replication (§3.1).
        let replicable = !name.ends_with(".so");
        self.slaves[owner as usize].meta_insert(FileMeta {
            name: name.to_string(),
            size_bytes: data.len() as u64,
            n_records: index.map(|i| i.len() as u64).unwrap_or(0),
            locations: vec![target],
            replicable,
        });
        self.metrics.incr("sector.uploads");
        self.metrics.add("sector.bytes_uploaded", data.len() as u64);
        Ok(target)
    }

    /// Metadata lookup by name.
    pub fn stat(&self, name: &str) -> Option<FileMeta> {
        let owner = self.meta_owner(name);
        self.slaves[owner as usize].meta_get(name)
    }

    /// Locations of a file's replicas (paper step 2). Returns (locations,
    /// lookup hops from the asking slave).
    pub fn locate(&self, from: SlaveId, name: &str) -> (Vec<SlaveId>, u32) {
        let hops = self.lookup_hops(from, name);
        self.metrics.incr("sector.lookups");
        (
            self.stat(name).map(|m| m.locations).unwrap_or_default(),
            hops,
        )
    }

    /// Download a whole file, preferring a replica co-located with
    /// `near`, then one in `near`'s rack (the routing layer "can use
    /// information involving network bandwidth and latency", §4).
    /// Slaves in the `dead` set are never read, even if a stale
    /// location list still names them.
    pub fn download(&self, near: SlaveId, name: &str) -> Result<Vec<u8>, String> {
        let meta = self
            .stat(name)
            .ok_or_else(|| format!("no such file: {name}"))?;
        let dead = self.dead.lock().unwrap();
        let live: Vec<SlaveId> = meta
            .locations
            .iter()
            .copied()
            .filter(|l| !dead.contains(l))
            .collect();
        drop(dead);
        let near_rack = self.node_rack[near as usize];
        let &src = live
            .iter()
            .find(|&&l| l == near)
            .or_else(|| {
                live.iter()
                    .min_by_key(|&&l| (self.node_rack[l as usize] != near_rack, l))
            })
            .ok_or_else(|| format!("file {name} has no live replicas"))?;
        self.conn_cache
            .lock()
            .unwrap()
            .acquire(0.0, u32::MAX, src);
        self.metrics.incr("sector.downloads");
        self.metrics.add("sector.bytes_downloaded", meta.size_bytes);
        self.slaves[src as usize].get_file(name)
    }

    /// Load a file's record index from any replica.
    pub fn load_index(&self, name: &str) -> Option<RecordIndex> {
        let meta = self.stat(name)?;
        meta.locations
            .iter()
            .find_map(|&l| self.slaves[l as usize].get_index(name))
    }

    /// Delete a file everywhere.
    pub fn delete(&self, name: &str) -> Result<(), String> {
        let owner = self.meta_owner(name);
        let meta = self.slaves[owner as usize]
            .meta_remove(name)
            .ok_or_else(|| format!("no such file: {name}"))?;
        for loc in meta.locations {
            self.slaves[loc as usize].delete_file(name).ok();
        }
        Ok(())
    }

    /// All file names known to the cloud (union of metadata partitions).
    pub fn list(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .slaves
            .iter()
            .flat_map(|s| s.meta_names())
            .collect();
        names.sort();
        names
    }

    /// Slave id -> rack id (all zero without a configured layout).
    pub fn rack_of(&self, id: SlaveId) -> usize {
        self.node_rack[id as usize]
    }

    /// Copy one replica of `name` to a random slave not yet holding it
    /// (the replication primitive; policy lives in `replica.rs`).
    /// With a configured rack layout the random choice is restricted to
    /// racks holding no replica yet, falling back to any candidate when
    /// every rack is covered.  Returns the chosen slave or None if
    /// fully replicated already.
    pub fn replicate_once(&self, name: &str) -> Result<Option<SlaveId>, String> {
        let meta = self
            .stat(name)
            .ok_or_else(|| format!("no such file: {name}"))?;
        if !meta.replicable {
            return Ok(None);
        }
        let dead = self.dead.lock().unwrap();
        let candidates: Vec<SlaveId> = (0..self.slaves.len() as SlaveId)
            .filter(|id| !meta.locations.contains(id) && !dead.contains(id))
            .collect();
        drop(dead);
        if candidates.is_empty() {
            return Ok(None);
        }
        let used_racks: Vec<usize> = meta
            .locations
            .iter()
            .map(|&l| self.node_rack[l as usize])
            .collect();
        let diverse: Vec<SlaveId> = candidates
            .iter()
            .copied()
            .filter(|&id| !used_racks.contains(&self.node_rack[id as usize]))
            .collect();
        let pool = if diverse.is_empty() { &candidates } else { &diverse };
        let pick = {
            let mut rng = self.rng.lock().unwrap();
            pool[rng.gen_range(pool.len() as u64) as usize]
        };
        let src = meta.locations[0];
        let data = self.slaves[src as usize].get_file(name)?;
        let dst_slave = &self.slaves[pick as usize];
        // Replication is a system action: bypass client ACL, write direct.
        dst_slave.storage.put(name, &data)?;
        // Index files are co-replicated (paper §4).
        if let Some(idx) = self.slaves[src as usize].get_index(name) {
            dst_slave
                .storage
                .put(&RecordIndex::idx_name(name), &idx.to_bytes())?;
        }
        let owner = self.meta_owner(name);
        self.slaves[owner as usize].meta_update(name, |m| m.locations.push(pick));
        self.metrics.incr("sector.replications");
        Ok(Some(pick))
    }

    /// System-level write: used by Sphere's shuffle/local writers and the
    /// replication service. Bypasses the client ACL (it is the system
    /// moving its own data), writes data + optional index to `target`,
    /// and registers metadata. Overwrites any existing file of the name.
    pub fn system_put(
        &self,
        name: &str,
        data: &[u8],
        index: Option<&RecordIndex>,
        target: SlaveId,
    ) -> Result<(), String> {
        let slave = &self.slaves[target as usize];
        if let Some(idx) = index {
            idx.validate(data.len() as u64)?;
            slave
                .storage
                .put(&RecordIndex::idx_name(name), &idx.to_bytes())?;
        }
        slave.storage.put(name, data)?;
        let owner = self.meta_owner(name);
        self.slaves[owner as usize].meta_insert(FileMeta {
            name: name.to_string(),
            size_bytes: data.len() as u64,
            n_records: index.map(|i| i.len() as u64).unwrap_or(0),
            locations: vec![target],
            replicable: !name.ends_with(".so"),
        });
        Ok(())
    }

    /// Handle a slave failure: mark it dead (excluded from replica
    /// placement and reads) and drop it from all location lists.
    /// Returns the number of files that lost a replica.
    pub fn fail_slave(&self, dead: SlaveId) -> usize {
        self.dead.lock().unwrap().insert(dead);
        let mut lost = 0;
        for s in &self.slaves {
            for name in s.meta_names() {
                s.meta_update(&name, |m| {
                    if let Some(pos) = m.locations.iter().position(|&l| l == dead) {
                        m.locations.remove(pos);
                        lost += 1;
                    }
                });
            }
        }
        self.metrics.incr("sector.slave_failures");
        lost
    }

    /// Bring a failed slave back (it rejoins empty of metadata; its old
    /// on-disk bytes may still exist but are unregistered).
    pub fn revive_slave(&self, id: SlaveId) {
        self.dead.lock().unwrap().remove(&id);
    }

    pub fn is_dead(&self, id: SlaveId) -> bool {
        self.dead.lock().unwrap().contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(n: usize) -> SectorCloud {
        SectorCloud::builder().nodes(n).seed(7).build().unwrap()
    }

    const CLIENT: &str = "10.0.0.99";

    #[test]
    fn upload_locate_download_roundtrip() {
        let c = cloud(4);
        let idx = RecordIndex::fixed(10, 100);
        let data: Vec<u8> = (0..100u8).collect();
        let loc = c
            .upload(CLIENT.parse().unwrap(), "f01.dat", &data, Some(&idx), None)
            .unwrap();
        let (locs, hops) = c.locate(0, "f01.dat");
        assert_eq!(locs, vec![loc]);
        assert!(hops >= 1);
        assert_eq!(c.download(0, "f01.dat").unwrap(), data);
        let meta = c.stat("f01.dat").unwrap();
        assert_eq!(meta.n_records, 10);
        assert_eq!(c.load_index("f01.dat").unwrap().len(), 10);
        assert_eq!(c.list(), vec!["f01.dat".to_string()]);
    }

    #[test]
    fn duplicate_upload_rejected() {
        let c = cloud(3);
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "f.dat", b"abc", None, None).unwrap();
        assert!(c.upload(ip, "f.dat", b"abc", None, None).is_err());
    }

    #[test]
    fn acl_blocks_outsider_upload() {
        let c = cloud(3);
        let err = c
            .upload("8.8.8.8".parse().unwrap(), "f.dat", b"abc", None, Some(0))
            .unwrap_err();
        assert!(err.contains("ACL"), "{err}");
        assert!(c.stat("f.dat").is_none(), "no metadata for failed upload");
    }

    #[test]
    fn replicate_once_copies_data_and_index() {
        let c = cloud(4);
        let ip = CLIENT.parse().unwrap();
        let idx = RecordIndex::fixed(5, 25);
        c.upload(ip, "r.dat", b"aaaaabbbbbcccccdddddeeeee", Some(&idx), Some(1))
            .unwrap();
        let added = c.replicate_once("r.dat").unwrap().unwrap();
        assert_ne!(added, 1);
        assert!(c.slave(added).has_file("r.dat"));
        assert_eq!(c.slave(added).get_index("r.dat").unwrap().len(), 5);
        assert_eq!(c.stat("r.dat").unwrap().locations.len(), 2);
    }

    #[test]
    fn replica_placement_prefers_unused_racks() {
        // Slaves 0-1 rack 0, slaves 2-3 rack 1: a file born in rack 0
        // must get its first replica in rack 1, whatever the seed says.
        for seed in 0..10 {
            let c = SectorCloud::builder()
                .nodes(4)
                .seed(seed)
                .racks(&[0, 0, 1, 1])
                .build()
                .unwrap();
            let ip = CLIENT.parse().unwrap();
            c.upload(ip, "r.dat", b"payload", None, Some(0)).unwrap();
            let added = c.replicate_once("r.dat").unwrap().unwrap();
            assert!(
                c.rack_of(added) == 1,
                "seed {seed}: replica landed on slave {added} (rack {})",
                c.rack_of(added)
            );
        }
    }

    #[test]
    fn replica_chain_covers_distinct_racks() {
        // Three racks: growing a file to three replicas must land each
        // copy on its own rack before any rack is reused.
        for seed in 0..10 {
            let c = SectorCloud::builder()
                .nodes(6)
                .seed(seed)
                .racks(&[0, 0, 1, 1, 2, 2])
                .build()
                .unwrap();
            let ip = CLIENT.parse().unwrap();
            c.upload(ip, "r.dat", b"payload", None, Some(0)).unwrap();
            c.replicate_once("r.dat").unwrap().unwrap();
            c.replicate_once("r.dat").unwrap().unwrap();
            let mut racks: Vec<usize> = c
                .stat("r.dat")
                .unwrap()
                .locations
                .iter()
                .map(|&l| c.rack_of(l))
                .collect();
            racks.sort_unstable();
            assert_eq!(racks, vec![0, 1, 2], "seed {seed}: racks reused early");
        }
    }

    #[test]
    fn reads_route_around_dead_slaves() {
        let c = SectorCloud::builder()
            .nodes(4)
            .seed(3)
            .racks(&[0, 0, 1, 1])
            .build()
            .unwrap();
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "f.dat", b"abc", None, Some(0)).unwrap();
        let added = c.replicate_once("f.dat").unwrap().unwrap();
        assert_eq!(c.rack_of(added), 1, "replica is rack-diverse");
        // Kill the original holder: a read from its rack-mate must be
        // served by the surviving replica, not the dead slave.
        c.fail_slave(0);
        assert!(c.is_dead(0));
        assert_eq!(c.download(1, "f.dat").unwrap(), b"abc");
        // The dead-set filter proper: a location registered while its
        // slave is in the dead set (a write through a stale target)
        // must never be read, even though the metadata names it.
        c.fail_slave(added);
        c.upload(ip, "g.dat", b"stale", None, Some(added)).unwrap();
        assert_eq!(c.stat("g.dat").unwrap().locations, vec![added]);
        let err = c.download(1, "g.dat").unwrap_err();
        assert!(err.contains("no live replicas"), "{err}");
        // Revival brings the copy back into rotation.
        c.revive_slave(added);
        assert_eq!(c.download(1, "g.dat").unwrap(), b"stale");
    }

    #[test]
    fn download_prefers_rack_local_replica() {
        let c = SectorCloud::builder()
            .nodes(4)
            .seed(5)
            .racks(&[0, 0, 1, 1])
            .build()
            .unwrap();
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "f.dat", b"xyz", None, Some(3)).unwrap();
        let added = c.replicate_once("f.dat").unwrap().unwrap();
        assert_eq!(c.rack_of(added), 0);
        // Reader in rack 0 (not holding a copy): the rack-0 replica
        // wins over the rack-1 original.  Which slave served is
        // observable through the connection cache: download records
        // the (client, src) pair it opened.
        let reader = if added == 0 { 1 } else { 0 };
        assert_eq!(c.download(reader, "f.dat").unwrap(), b"xyz");
        {
            let mut cache = c.conn_cache.lock().unwrap();
            assert!(
                cache.acquire(0.0, u32::MAX, added),
                "the rack-local replica must have served the read"
            );
            assert!(
                !cache.acquire(0.0, u32::MAX, 3),
                "the cross-rack original must not have been touched"
            );
        }
        // And killing the rack-local copy still leaves the read
        // serveable from the original.
        c.fail_slave(added);
        assert_eq!(c.download(reader, "f.dat").unwrap(), b"xyz");
    }

    #[test]
    fn rack_layout_must_cover_every_slave() {
        assert!(SectorCloud::builder()
            .nodes(4)
            .racks(&[0, 1])
            .build()
            .is_err());
    }

    #[test]
    fn so_files_not_replicated() {
        let c = cloud(4);
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "op_sort.so", b"\x7fELF...", None, Some(0)).unwrap();
        assert_eq!(c.replicate_once("op_sort.so").unwrap(), None);
        assert_eq!(c.stat("op_sort.so").unwrap().locations.len(), 1);
    }

    #[test]
    fn fully_replicated_file_stops() {
        let c = cloud(2);
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "f.dat", b"xy", None, Some(0)).unwrap();
        assert!(c.replicate_once("f.dat").unwrap().is_some());
        assert_eq!(c.replicate_once("f.dat").unwrap(), None, "all slaves hold it");
    }

    #[test]
    fn failure_drops_locations() {
        let c = cloud(3);
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "f.dat", b"abc", None, Some(1)).unwrap();
        c.replicate_once("f.dat").unwrap();
        let before = c.stat("f.dat").unwrap().locations.len();
        assert_eq!(before, 2);
        let dead = c.stat("f.dat").unwrap().locations[0];
        let lost = c.fail_slave(dead);
        assert_eq!(lost, 1);
        assert_eq!(c.stat("f.dat").unwrap().locations.len(), 1);
        // download still works from the surviving replica
        assert_eq!(c.download(0, "f.dat").unwrap(), b"abc");
    }

    #[test]
    fn delete_removes_all_replicas() {
        let c = cloud(3);
        let ip = CLIENT.parse().unwrap();
        c.upload(ip, "f.dat", b"abc", None, Some(0)).unwrap();
        c.replicate_once("f.dat").unwrap();
        c.delete("f.dat").unwrap();
        assert!(c.stat("f.dat").is_none());
        for s in c.slaves() {
            assert!(!s.has_file("f.dat"));
        }
        assert!(c.delete("f.dat").is_err());
    }

    #[test]
    fn meta_spreads_across_owners() {
        // With many files, the chord partition should use >1 owner.
        let c = cloud(8);
        let ip = CLIENT.parse().unwrap();
        for i in 0..64 {
            c.upload(ip, &format!("f{i:03}.dat"), b"x", None, None).unwrap();
        }
        let owners_used = c
            .slaves()
            .iter()
            .filter(|s| !s.meta_names().is_empty())
            .count();
        assert!(owners_used >= 4, "metadata clumped on {owners_used} owners");
    }
}

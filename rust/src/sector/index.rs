//! Record indexes (paper §4): "each data file in Sector has a companion
//! index file, with a post-fix of .idx ... The index contains the start
//! and end positions (i.e., the offset and size) of each record in the
//! data file."
//!
//! The on-disk format is a flat little-endian array of (offset: u64,
//! size: u64) pairs.  Files without an index can only be processed at
//! file granularity (§4), which `sphere::segment` honours.

/// One record's position in its data file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecordPos {
    pub offset: u64,
    pub size: u64,
}

/// An in-memory record index.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecordIndex {
    entries: Vec<RecordPos>,
}

impl RecordIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build an index for fixed-size records covering `total` bytes.
    /// (Terasort's 100-byte records use this.)
    pub fn fixed(record_size: u64, total_bytes: u64) -> Self {
        assert!(record_size > 0);
        assert_eq!(
            total_bytes % record_size,
            0,
            "file is not a whole number of records"
        );
        let n = total_bytes / record_size;
        Self {
            entries: (0..n)
                .map(|i| RecordPos {
                    offset: i * record_size,
                    size: record_size,
                })
                .collect(),
        }
    }

    /// Build from explicit record byte lengths (variable-size records,
    /// e.g. Angle pcap-derived feature lines).
    pub fn from_lengths(lengths: &[u64]) -> Self {
        let mut entries = Vec::with_capacity(lengths.len());
        let mut offset = 0;
        for &len in lengths {
            entries.push(RecordPos { offset, size: len });
            offset += len;
        }
        Self { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> Option<RecordPos> {
        self.entries.get(i).copied()
    }

    /// Total bytes covered by records [first, first+count).
    pub fn span_bytes(&self, first: usize, count: usize) -> u64 {
        self.entries[first..first + count]
            .iter()
            .map(|r| r.size)
            .sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.entries
            .last()
            .map(|r| r.offset + r.size)
            .unwrap_or(0)
    }

    /// Serialize to the .idx wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.entries.len() * 16);
        for e in &self.entries {
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.size.to_le_bytes());
        }
        out
    }

    /// Parse the .idx wire format, validating monotonicity/contiguity.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() % 16 != 0 {
            return Err(format!(".idx length {} not a multiple of 16", bytes.len()));
        }
        let mut entries = Vec::with_capacity(bytes.len() / 16);
        let mut expected_offset = 0u64;
        for chunk in bytes.chunks_exact(16) {
            let offset = u64::from_le_bytes(chunk[0..8].try_into().unwrap());
            let size = u64::from_le_bytes(chunk[8..16].try_into().unwrap());
            if offset != expected_offset {
                return Err(format!(
                    ".idx gap: record at offset {offset}, expected {expected_offset}"
                ));
            }
            if size == 0 {
                return Err("zero-size record in .idx".into());
            }
            entries.push(RecordPos { offset, size });
            expected_offset = offset + size;
        }
        Ok(Self { entries })
    }

    /// Validate against the data file length.
    pub fn validate(&self, data_len: u64) -> Result<(), String> {
        if self.total_bytes() != data_len {
            return Err(format!(
                ".idx covers {} bytes but data file has {}",
                self.total_bytes(),
                data_len
            ));
        }
        Ok(())
    }

    /// Companion index-file name for a data file (paper: "file01.dat" ->
    /// "file01.dat.idx").
    pub fn idx_name(data_name: &str) -> String {
        format!("{data_name}.idx")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_index_layout() {
        let idx = RecordIndex::fixed(100, 1000);
        assert_eq!(idx.len(), 10);
        assert_eq!(idx.get(3), Some(RecordPos { offset: 300, size: 100 }));
        assert_eq!(idx.total_bytes(), 1000);
        assert_eq!(idx.span_bytes(2, 4), 400);
        assert!(idx.validate(1000).is_ok());
        assert!(idx.validate(999).is_err());
    }

    #[test]
    #[should_panic]
    fn fixed_rejects_ragged() {
        RecordIndex::fixed(100, 950);
    }

    #[test]
    fn variable_records_roundtrip() {
        let idx = RecordIndex::from_lengths(&[5, 17, 3, 100]);
        assert_eq!(idx.len(), 4);
        assert_eq!(idx.get(2), Some(RecordPos { offset: 22, size: 3 }));
        let parsed = RecordIndex::from_bytes(&idx.to_bytes()).unwrap();
        assert_eq!(parsed, idx);
    }

    #[test]
    fn from_bytes_rejects_corruption() {
        assert!(RecordIndex::from_bytes(&[0u8; 15]).is_err());
        // gap: second record starts at 10 but first ends at 5
        let mut bad = Vec::new();
        bad.extend_from_slice(&0u64.to_le_bytes());
        bad.extend_from_slice(&5u64.to_le_bytes());
        bad.extend_from_slice(&10u64.to_le_bytes());
        bad.extend_from_slice(&5u64.to_le_bytes());
        assert!(RecordIndex::from_bytes(&bad).is_err());
        // zero-size record
        let mut zero = Vec::new();
        zero.extend_from_slice(&0u64.to_le_bytes());
        zero.extend_from_slice(&0u64.to_le_bytes());
        assert!(RecordIndex::from_bytes(&zero).is_err());
    }

    #[test]
    fn idx_naming_matches_paper() {
        assert_eq!(RecordIndex::idx_name("sdss1.dat"), "sdss1.dat.idx");
    }

    #[test]
    fn empty_index() {
        let idx = RecordIndex::new();
        assert!(idx.is_empty());
        assert_eq!(idx.total_bytes(), 0);
        assert!(RecordIndex::from_bytes(&[]).unwrap().is_empty());
    }
}

//! Byte/bit-rate quantities with human formatting and parsing.
//!
//! The paper mixes units freely (10 Gb/s links, 128 MB blocks, 10 GB per
//! node, 440 Mb/s throughput); keeping them typed here prevents the
//! classic factor-of-8 bugs in the simulator.

pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// Bits per second (link and protocol rates).
#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub struct BitRate(pub f64);

impl BitRate {
    pub fn gbps(v: f64) -> Self {
        BitRate(v * 1e9)
    }

    pub fn mbps(v: f64) -> Self {
        BitRate(v * 1e6)
    }

    pub fn as_gbps(&self) -> f64 {
        self.0 / 1e9
    }

    pub fn as_mbps(&self) -> f64 {
        self.0 / 1e6
    }

    /// Bytes per second carried at this bit rate.
    pub fn bytes_per_sec(&self) -> f64 {
        self.0 / 8.0
    }

    /// Seconds to move `bytes` at this rate.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        if self.0 <= 0.0 {
            return f64::INFINITY;
        }
        bytes as f64 / self.bytes_per_sec()
    }
}

/// Format a byte count for reports ("1.30 TB", "128 MB", "512 B").
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if b >= TB {
        format!("{:.2} TB", bf / TB as f64)
    } else if b >= GB {
        format!("{:.2} GB", bf / GB as f64)
    } else if b >= MB {
        format!("{:.2} MB", bf / MB as f64)
    } else if b >= KB {
        format!("{:.2} KB", bf / KB as f64)
    } else {
        format!("{b} B")
    }
}

/// Format a byte-per-second throughput as a bit rate ("1.10 Gb/s").
pub fn fmt_rate_bytes_per_sec(bps: f64) -> String {
    let bits = bps * 8.0;
    if bits >= 1e9 {
        format!("{:.2} Gb/s", bits / 1e9)
    } else if bits >= 1e6 {
        format!("{:.1} Mb/s", bits / 1e6)
    } else {
        format!("{:.0} Kb/s", bits / 1e3)
    }
}

/// Parse "10GB", "128MB", "64kb", "512" (bytes). Decimal units.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let v: f64 = num
        .parse()
        .map_err(|_| format!("bad byte quantity: {s:?}"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" => KB as f64,
        "m" | "mb" => MB as f64,
        "g" | "gb" => GB as f64,
        "t" | "tb" => TB as f64,
        "kib" => KIB as f64,
        "mib" => MIB as f64,
        "gib" => GIB as f64,
        u => return Err(format!("unknown byte unit {u:?} in {s:?}")),
    };
    Ok((v * mult) as u64)
}

/// Format seconds for the tables ("905 s", "85 min", "178 h").
pub fn fmt_duration_secs(secs: f64) -> String {
    if secs < 0.1 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.1} s")
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.1} h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitrate_roundtrips() {
        let r = BitRate::gbps(10.0);
        assert!((r.as_gbps() - 10.0).abs() < 1e-12);
        assert!((r.bytes_per_sec() - 1.25e9).abs() < 1.0);
        // 10 GB at 10 Gb/s = 8 seconds
        assert!((r.transfer_secs(10 * GB) - 8.0).abs() < 1e-9);
        assert_eq!(BitRate(0.0).transfer_secs(1), f64::INFINITY);
    }

    #[test]
    fn parse_bytes_units() {
        assert_eq!(parse_bytes("512").unwrap(), 512);
        assert_eq!(parse_bytes("128MB").unwrap(), 128 * MB);
        assert_eq!(parse_bytes("10 GB").unwrap(), 10 * GB);
        assert_eq!(parse_bytes("1.5gb").unwrap(), 1_500_000_000);
        assert_eq!(parse_bytes("64MiB").unwrap(), 64 * MIB);
        assert!(parse_bytes("10 parsecs").is_err());
        assert!(parse_bytes("x").is_err());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(1_300_000_000_000), "1.30 TB");
        assert_eq!(fmt_bytes(128 * MB), "128.00 MB");
        assert_eq!(fmt_bytes(17), "17 B");
        assert_eq!(fmt_rate_bytes_per_sec(137_500_000.0), "1.10 Gb/s");
        assert_eq!(fmt_duration_secs(905.0), "15.1 min");
        assert_eq!(fmt_duration_secs(12.0), "12.0 s");
        assert_eq!(fmt_duration_secs(640_800.0), "178.0 h");
    }
}

//! Deterministic PRNGs for the simulator, workload generators and tests.
//!
//! The environment is offline (no `rand` crate), and determinism is a hard
//! requirement anyway — the discrete-event simulator must replay the same
//! timeline for the same seed — so we carry our own small, well-known
//! generators: SplitMix64 for seeding and Pcg64 (XSL-RR 128/64) for bulk
//! generation.

/// SplitMix64: tiny, passes BigCrush, ideal for turning one seed into many.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64: the default bulk generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed via SplitMix64 so nearby integer seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let i = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let mut rng = Self {
            state: 0,
            inc: (i << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child stream (used per-node / per-flow).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Marsaglia polar (cached spare discarded for
    /// simplicity; this is not a hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Pareto (heavy-tailed) with scale `xm > 0` and shape `alpha > 0` —
    /// used for flow-size distributions in the Angle trace generator.
    pub fn next_pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm / (1.0 - self.next_f64()).powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fill a byte slice (used by the gensort record generator).
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64 code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let xs: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let ys: Vec<u64> = {
            let mut r = Pcg64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let zs: Vec<u64> = {
            let mut r = Pcg64::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn uniform_f64_in_unit_interval_with_sane_mean() {
        let mut r = Pcg64::new(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.next_gaussian();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(13);
        let n = 50_000;
        let lambda = 2.5;
        let mean: f64 = (0..n).map(|_| r.next_exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn pareto_lower_bound_respected() {
        let mut r = Pcg64::new(17);
        for _ in 0..1000 {
            assert!(r.next_pareto(3.0, 1.5) >= 3.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>(), "astronomically unlikely");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(23);
        let s = r.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Pcg64::new(31);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Pcg64::new(37);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}

//! Small statistics helpers shared by the benches, metrics and the
//! emergent-cluster detector (which thresholds on a z-score of δ_j).

/// Online mean/variance (Welford). Numerically stable for long streams.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample vector: mean, std, min, max, percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Some(Summary {
            n: xs.len(),
            mean: w.mean(),
            std_dev: w.std_dev(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Shannon entropy (bits) of a count histogram. Zero bins are skipped.
pub fn entropy_bits(counts: &[f64]) -> f64 {
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    -counts
        .iter()
        .filter(|&&c| c > 0.0)
        .map(|&c| {
            let p = c / total;
            p * p.log2()
        })
        .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of that classic vector is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn welford_degenerate() {
        let mut w = Welford::new();
        assert_eq!(w.variance(), 0.0);
        w.push(3.0);
        assert_eq!(w.mean(), 3.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
        assert!((percentile_sorted(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_of_constant_vector() {
        let s = Summary::of(&[5.0; 10]).unwrap();
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(s.p99, 5.0);
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn entropy_uniform_and_pure() {
        assert!((entropy_bits(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(entropy_bits(&[10.0, 0.0]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
        assert!((entropy_bits(&[1.0; 8]) - 3.0).abs() < 1e-12);
    }
}

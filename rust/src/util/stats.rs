//! Small statistics helpers shared by the benches, metrics and the
//! emergent-cluster detector (which thresholds on a z-score of δ_j).

/// Online mean/variance (Welford). Numerically stable for long streams.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample vector: mean, std, min, max, percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Some(Summary {
            n: xs.len(),
            mean: w.mean(),
            std_dev: w.std_dev(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Nearest-rank median of an already-sorted slice: the upper-median
/// element `sorted[len / 2]`, 0.0 for an empty slice.  Kept distinct
/// from `percentile_sorted(_, 0.5)` on purpose — speculation
/// thresholds compare against a duration that actually occurred, not
/// an interpolated midpoint between two samples.
pub fn median_nearest_rank(sorted: &[f64]) -> f64 {
    if sorted.is_empty() {
        0.0
    } else {
        sorted[sorted.len() / 2]
    }
}

/// Sub-buckets per octave: resolution of [`LogHist`] (relative
/// quantile error is bounded by `2^(1/8) - 1`, about 9%).
const SUB_BUCKETS: usize = 8;
/// Octaves covered above [`LOG_HIST_MIN`]: 64 doublings from 1ns
/// reaches ~1.8e10 seconds, far past any duration we record.
const OCTAVES: usize = 64;
const N_BUCKETS: usize = SUB_BUCKETS * OCTAVES;
/// Values at or below this floor share bucket 0.
const LOG_HIST_MIN: f64 = 1e-9;

/// Fixed-footprint log-bucketed histogram for duration samples: the
/// bucket array never grows, so memory is O(1) in the observation
/// count (a `Vec<f64>` per timer grows without bound on a long run).
/// n, sum, min and max are exact; quantiles interpolate linearly
/// inside the owning geometric bucket and are clamped to the observed
/// range, so `quantile(0.0)`/`quantile(1.0)` are exact too.
#[derive(Clone, Debug)]
pub struct LogHist {
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
    counts: Vec<u64>,
}

impl Default for LogHist {
    fn default() -> Self {
        LogHist {
            n: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            counts: vec![0; N_BUCKETS],
        }
    }
}

impl LogHist {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(x: f64) -> usize {
        if x <= LOG_HIST_MIN {
            return 0;
        }
        let idx = ((x / LOG_HIST_MIN).log2() * SUB_BUCKETS as f64) as usize;
        idx.min(N_BUCKETS - 1)
    }

    /// Lower edge of bucket `i` (the upper edge is `edge(i + 1)`).
    fn edge(i: usize) -> f64 {
        LOG_HIST_MIN * (i as f64 / SUB_BUCKETS as f64).exp2()
    }

    /// Record one sample.  NaN is dropped; negatives clamp to zero
    /// (durations cannot be negative, but clock math can wobble).
    pub fn observe(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        let x = x.max(0.0);
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
        self.counts[Self::bucket(x)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate, q in [0, 1]; 0.0 when empty.  Follows
    /// `percentile_sorted`'s rank convention (`q * (n - 1)`), so the
    /// two agree exactly at the edges and to within bucket resolution
    /// in the interior.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let rank = q * (self.n - 1) as f64;
        let mut below = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let last = (below + c - 1) as f64;
            if rank <= last {
                let lo = Self::edge(i);
                let hi = Self::edge(i + 1);
                let within = if c > 1 {
                    (rank - below as f64) / (c - 1) as f64
                } else {
                    0.5
                };
                let v = lo + (hi - lo) * within;
                return v.clamp(self.min, self.max);
            }
            below += c;
        }
        self.max
    }

    /// Total footprint in bytes — constant regardless of `count()`.
    pub fn footprint_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.counts.capacity() * std::mem::size_of::<u64>()
    }
}

/// Shannon entropy (bits) of a count histogram. Zero bins are skipped.
pub fn entropy_bits(counts: &[f64]) -> f64 {
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    -counts
        .iter()
        .filter(|&&c| c > 0.0)
        .map(|&c| {
            let p = c / total;
            p * p.log2()
        })
        .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of that classic vector is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn welford_degenerate() {
        let mut w = Welford::new();
        assert_eq!(w.variance(), 0.0);
        w.push(3.0);
        assert_eq!(w.mean(), 3.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
        assert!((percentile_sorted(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_small_n_pins_interpolation() {
        // N=2: the only two samples bracket every interior quantile.
        let xs = [10.0, 20.0];
        assert!((percentile_sorted(&xs, 0.5) - 15.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.95) - 19.5).abs() < 1e-12);
        // N=3: p50 is the middle element exactly; p25 interpolates.
        let ys = [1.0, 5.0, 9.0];
        assert_eq!(percentile_sorted(&ys, 0.5), 5.0);
        assert!((percentile_sorted(&ys, 0.25) - 3.0).abs() < 1e-12);
        // N=1: every quantile is the sample.
        assert_eq!(percentile_sorted(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn median_nearest_rank_picks_real_samples() {
        assert_eq!(median_nearest_rank(&[]), 0.0);
        assert_eq!(median_nearest_rank(&[3.0]), 3.0);
        // Even N picks the upper-median ELEMENT, never an interpolated
        // midpoint — the speculation cutoff must be a real duration.
        assert_eq!(median_nearest_rank(&[1.0, 9.0]), 9.0);
        assert_eq!(median_nearest_rank(&[1.0, 2.0, 3.0, 4.0]), 3.0);
        assert_eq!(median_nearest_rank(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn log_hist_tracks_quantiles_within_bucket_error() {
        let mut h = LogHist::new();
        assert_eq!(h.quantile(0.5), 0.0);
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-3); // 1ms..1s uniform
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
        // Edges are exact; interior quantiles within bucket error.
        assert_eq!(h.quantile(0.0), 1e-3);
        assert_eq!(h.quantile(1.0), 1.0);
        let p50 = h.quantile(0.5);
        assert!((p50 - 0.5).abs() / 0.5 < 0.10, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((p99 - 0.99).abs() / 0.99 < 0.10, "p99={p99}");
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
    }

    #[test]
    fn log_hist_footprint_is_constant() {
        let mut h = LogHist::new();
        h.observe(0.25);
        let before = h.footprint_bytes();
        for i in 0..1_000_000u32 {
            h.observe((i % 997) as f64 * 1e-4);
        }
        assert_eq!(h.count(), 1_000_001);
        assert_eq!(h.footprint_bytes(), before, "bucket array never grows");
        assert!(before < 16 * 1024, "footprint stays a few KB: {before}");
    }

    #[test]
    fn summary_of_constant_vector() {
        let s = Summary::of(&[5.0; 10]).unwrap();
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(s.p99, 5.0);
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn entropy_uniform_and_pure() {
        assert!((entropy_bits(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(entropy_bits(&[10.0, 0.0]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
        assert!((entropy_bits(&[1.0; 8]) - 3.0).abs() < 1e-12);
    }
}

//! Fixed-bin histogram + ASCII series plotting used by the figure benches
//! (Figs 5 and 6 are rendered as terminal plots of the δ_j series).

/// A simple fixed-width-bin histogram over [lo, hi).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = (((x - self.lo) / w) as usize).min(n - 1);
            self.bins[i] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn out_of_range(&self) -> (u64, u64) {
        (self.underflow, self.overflow)
    }
}

/// Render a series as a compact ASCII line plot (rows = height).
/// Used to print Figs 5/6 in the bench output.
pub fn ascii_plot(series: &[f64], width: usize, height: usize) -> String {
    if series.is_empty() || width == 0 || height == 0 {
        return String::new();
    }
    // Downsample (mean-pool) to `width` columns.
    let cols: Vec<f64> = (0..width.min(series.len()))
        .map(|c| {
            let n = series.len();
            let w = width.min(n);
            let lo = c * n / w;
            let hi = ((c + 1) * n / w).max(lo + 1);
            series[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect();
    let max = cols.iter().cloned().fold(f64::MIN, f64::max);
    let min = cols.iter().cloned().fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-30);
    let mut rows = vec![vec![b' '; cols.len()]; height];
    for (c, &v) in cols.iter().enumerate() {
        let h = (((v - min) / span) * (height - 1) as f64).round() as usize;
        for (r, row) in rows.iter_mut().enumerate() {
            let level = height - 1 - r;
            row[c] = match level.cmp(&h) {
                std::cmp::Ordering::Equal => b'*',
                std::cmp::Ordering::Less => b'.',
                std::cmp::Ordering::Greater => b' ',
            };
        }
    }
    let mut out = String::new();
    out.push_str(&format!("max={max:.4}\n"));
    for row in rows {
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("min={min:.4}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_and_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert!(h.bins().iter().all(|&b| b == 1));
        h.push(-1.0);
        h.push(10.0); // hi edge is exclusive -> overflow
        assert_eq!(h.out_of_range(), (1, 1));
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn ascii_plot_shapes() {
        let series: Vec<f64> = (0..100).map(|i| (i as f64 / 10.0).sin()).collect();
        let plot = ascii_plot(&series, 40, 8);
        assert!(plot.lines().count() == 10); // 8 rows + max + min labels
        assert!(plot.contains('*'));
        assert!(ascii_plot(&[], 40, 8).is_empty());
    }

    #[test]
    fn ascii_plot_constant_series() {
        let plot = ascii_plot(&[2.0; 10], 10, 4);
        assert!(plot.contains('*')); // degenerate span must not panic
    }
}

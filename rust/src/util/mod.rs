//! From-scratch substrate utilities (the offline environment has no access
//! to the usual ecosystem crates, and the simulator needs determinism
//! anyway): PRNGs, statistics, byte/rate quantities, histograms.

pub mod bytes;
pub mod hist;
pub mod rng;
pub mod stats;

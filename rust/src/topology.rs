//! Testbed topology descriptions + netsim wiring (substitution for the
//! paper's physical testbeds; DESIGN.md §2).
//!
//! Two layers:
//!
//! * `TopologySpec` — a parameterized generator: WAN sites × racks per
//!   site × nodes per rack, with three link tiers (node NIC, rack
//!   uplink, site/WAN uplink) and either a uniform or an explicit
//!   site-to-site RTT matrix.  The paper's two physical layouts are
//!   named presets (`paper_wan`, `paper_lan`), and `scale_out` builds
//!   the arbitrary large configurations the scenario engine runs
//!   (DESIGN.md §4).  Specs parse from the `[topology]` section of a
//!   scenario TOML via `from_table`.
//! * `Testbed` — a concrete (not yet instantiated) layout.
//!   `wan_testbed()` is the §6.1 wide-area testbed: 6 servers in 3
//!   sites (2× Chicago, 2× Pasadena, 2× Greenbelt), 10 Gb/s
//!   everywhere, RTTs 16 ms (CHI–GRB), 55 ms (CHI–PAS), 71 ms
//!   (GRB–PAS, routed through Chicago).  `lan_testbed(n)` is the §6.1
//!   rack: n ≤ 8 servers on one switch.
//!
//! `build_network` instantiates per-node NIC links, per-rack uplinks
//! and per-site WAN uplinks in a `NetSim`; `path`/`rtt_secs` answer the
//! per-pair questions job simulators ask.  Sites with a single rack
//! collapse the rack tier into the site switch (no extra hop), which
//! keeps the paper presets byte-identical to their original models.

use crate::config::Table;
use crate::sim::netsim::{LinkId, NetSim};

pub const SITE_CHICAGO: usize = 0;
pub const SITE_PASADENA: usize = 1;
pub const SITE_GREENBELT: usize = 2;

const MS: f64 = 1e-3;
const TEN_GBPS: f64 = 10.0e9 / 8.0;

/// One site in a `TopologySpec`: `racks` racks of `nodes_per_rack`
/// nodes each.
#[derive(Clone, Debug, PartialEq)]
pub struct SiteSpec {
    pub name: String,
    pub racks: usize,
    pub nodes_per_rack: usize,
}

/// Parameterized testbed generator.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologySpec {
    pub name: String,
    pub sites: Vec<SiteSpec>,
    /// Explicit site × site RTT matrix in seconds (diagonal = cross-rack
    /// intra-site RTT).  None derives a uniform matrix from
    /// `wan_rtt_secs` / `intra_site_rtt_secs`.
    pub site_rtt: Option<Vec<Vec<f64>>>,
    /// Uniform inter-site RTT, seconds (ignored with explicit matrix).
    pub wan_rtt_secs: f64,
    /// Cross-rack, same-site RTT, seconds (matrix diagonal when derived).
    pub intra_site_rtt_secs: f64,
    /// Same-rack RTT, seconds.
    pub intra_rack_rtt_secs: f64,
    /// Per-node NIC rate, bytes/s.
    pub nic_bps: f64,
    /// Per-rack uplink rate, bytes/s.
    pub rack_bps: f64,
    /// Per-site WAN uplink rate, bytes/s.
    pub wan_bps: f64,
    /// Per-site WAN uplink multipliers (heterogeneous sites).  Empty
    /// means uniform 1.0; otherwise one entry per site, applied to
    /// `wan_bps` when the site uplinks are instantiated.
    pub site_link_mult: Vec<f64>,
    /// Per-site disk throughput multipliers (heterogeneous sites).
    /// Empty means uniform 1.0; otherwise one entry per site, folded
    /// into every member node's effective disk rate.
    pub site_disk_mult: Vec<f64>,
}

impl TopologySpec {
    /// The paper's 6-node, 3-site wide-area layout (§6.1) as a spec.
    pub fn paper_wan() -> TopologySpec {
        let site = |name: &str| SiteSpec {
            name: name.into(),
            racks: 1,
            nodes_per_rack: 2,
        };
        TopologySpec {
            name: "wan-6node".into(),
            sites: vec![site("chicago"), site("pasadena"), site("greenbelt")],
            site_rtt: Some(vec![
                vec![0.1 * MS, 55.0 * MS, 16.0 * MS],
                vec![55.0 * MS, 0.1 * MS, 71.0 * MS],
                vec![16.0 * MS, 71.0 * MS, 0.1 * MS],
            ]),
            wan_rtt_secs: 71.0 * MS,
            intra_site_rtt_secs: 0.1 * MS,
            intra_rack_rtt_secs: 0.1 * MS,
            nic_bps: TEN_GBPS,
            rack_bps: TEN_GBPS,
            wan_bps: TEN_GBPS,
            site_link_mult: Vec::new(),
            site_disk_mult: Vec::new(),
        }
    }

    /// The Table 1 sweep prefix of the WAN layout: `nodes` ∈ 1..=6,
    /// filling Chicago, then Pasadena, then Greenbelt two nodes at a
    /// time.  Unused sites are dropped (with their RTT matrix rows) so
    /// the spec describes exactly the machines in play.
    pub fn paper_wan_prefix(nodes: usize) -> Result<TopologySpec, String> {
        if !(1..=6).contains(&nodes) {
            return Err(format!("paper_wan supports 1..=6 nodes, got {nodes}"));
        }
        let mut spec = TopologySpec::paper_wan();
        let counts = [
            nodes.min(2),
            nodes.saturating_sub(2).min(2),
            nodes.saturating_sub(4).min(2),
        ];
        let used = counts.iter().filter(|&&c| c > 0).count();
        spec.sites.truncate(used);
        for (i, site) in spec.sites.iter_mut().enumerate() {
            site.nodes_per_rack = counts[i];
        }
        if let Some(m) = &mut spec.site_rtt {
            m.truncate(used);
            for row in m.iter_mut() {
                row.truncate(used);
            }
        }
        spec.site_link_mult.truncate(used);
        spec.site_disk_mult.truncate(used);
        spec.name = format!("wan-{nodes}node");
        Ok(spec)
    }

    /// The paper's single-rack layout (§6.1) as a spec: `nodes` ≤ 8
    /// servers on one switch.
    pub fn paper_lan(nodes: usize) -> TopologySpec {
        TopologySpec {
            name: format!("lan-{nodes}node"),
            sites: vec![SiteSpec {
                name: "rack".into(),
                racks: 1,
                nodes_per_rack: nodes,
            }],
            site_rtt: Some(vec![vec![0.0001]]),
            wan_rtt_secs: 0.0001,
            intra_site_rtt_secs: 0.0001,
            intra_rack_rtt_secs: 0.0001,
            nic_bps: TEN_GBPS,
            rack_bps: TEN_GBPS,
            wan_bps: TEN_GBPS,
            site_link_mult: Vec::new(),
            site_disk_mult: Vec::new(),
        }
    }

    /// A uniform scale-out layout: `sites` WAN sites, each with
    /// `racks_per_site` racks of `nodes_per_rack` nodes.  Defaults model
    /// a 2008-era multi-site testbed: 10 Gb/s NICs, 40 Gb/s rack
    /// uplinks, 10 Gb/s WAN uplinks, 40 ms WAN RTT.
    pub fn scale_out(sites: usize, racks_per_site: usize, nodes_per_rack: usize) -> TopologySpec {
        let nodes = sites * racks_per_site * nodes_per_rack;
        TopologySpec {
            name: format!("scale-{nodes}node"),
            sites: (0..sites)
                .map(|i| SiteSpec {
                    name: format!("site{i:02}"),
                    racks: racks_per_site,
                    nodes_per_rack,
                })
                .collect(),
            site_rtt: None,
            wan_rtt_secs: 40.0 * MS,
            intra_site_rtt_secs: 0.5 * MS,
            intra_rack_rtt_secs: 0.1 * MS,
            nic_bps: TEN_GBPS,
            rack_bps: 4.0 * TEN_GBPS,
            wan_bps: TEN_GBPS,
            site_link_mult: Vec::new(),
            site_disk_mult: Vec::new(),
        }
    }

    /// Parse the `[topology]` section of a scenario config.  Either a
    /// preset (`preset = "paper_wan" | "paper_lan"`, optionally trimmed
    /// with `nodes = n`) or a generated layout:
    ///
    /// sites / racks_per_site / nodes_per_rack (integers),
    /// wan_rtt_ms / intra_site_rtt_ms / intra_rack_rtt_ms,
    /// nic_gbps / rack_gbps / wan_gbps, name (string).
    pub fn from_table(t: &Table) -> Result<TopologySpec, String> {
        if let Some(v) = t.get("topology.preset") {
            let preset = v.as_str().ok_or("topology.preset must be a string")?;
            let nodes = t.int_or("topology.nodes", 0) as usize;
            return match preset {
                "paper_wan" => TopologySpec::paper_wan_prefix(if nodes == 0 { 6 } else { nodes }),
                "paper_lan" => {
                    let nodes = if nodes == 0 { 8 } else { nodes };
                    if !(1..=8).contains(&nodes) {
                        return Err(format!("paper_lan supports 1..=8 nodes, got {nodes}"));
                    }
                    Ok(TopologySpec::paper_lan(nodes))
                }
                other => Err(format!("unknown topology preset {other:?}")),
            };
        }
        let sites = t.int_or("topology.sites", 1).max(1) as usize;
        let racks = t.int_or("topology.racks_per_site", 1).max(1) as usize;
        let npr = t.int_or("topology.nodes_per_rack", 1).max(1) as usize;
        let mut spec = TopologySpec::scale_out(sites, racks, npr);
        spec.wan_rtt_secs = t.float_or("topology.wan_rtt_ms", spec.wan_rtt_secs / MS) * MS;
        spec.intra_site_rtt_secs =
            t.float_or("topology.intra_site_rtt_ms", spec.intra_site_rtt_secs / MS) * MS;
        spec.intra_rack_rtt_secs =
            t.float_or("topology.intra_rack_rtt_ms", spec.intra_rack_rtt_secs / MS) * MS;
        let gbps = 1.0e9 / 8.0;
        spec.nic_bps = t.float_or("topology.nic_gbps", spec.nic_bps / gbps) * gbps;
        spec.rack_bps = t.float_or("topology.rack_gbps", spec.rack_bps / gbps) * gbps;
        spec.wan_bps = t.float_or("topology.wan_gbps", spec.wan_bps / gbps) * gbps;
        for (key, out) in [
            ("site_link_mult", &mut spec.site_link_mult),
            ("site_disk_mult", &mut spec.site_disk_mult),
        ] {
            if let Some(v) = t.get(&format!("topology.{key}")) {
                let arr = v
                    .as_array()
                    .ok_or_else(|| format!("topology.{key} must be an array of numbers"))?;
                *out = arr
                    .iter()
                    .map(|x| {
                        x.as_float()
                            .ok_or_else(|| format!("topology.{key} entries must be numbers"))
                    })
                    .collect::<Result<_, _>>()?;
            }
        }
        spec.name = t.str_or("topology.name", &spec.name).to_string();
        Ok(spec)
    }

    pub fn nodes(&self) -> usize {
        self.sites.iter().map(|s| s.racks * s.nodes_per_rack).sum()
    }

    /// Materialize the spec into a concrete `Testbed`.
    pub fn generate(&self) -> Result<Testbed, String> {
        if self.sites.is_empty() {
            return Err("topology needs at least one site".into());
        }
        let ns = self.sites.len();
        if let Some(m) = &self.site_rtt {
            if m.len() != ns || m.iter().any(|row| row.len() != ns) {
                return Err(format!("site_rtt must be {ns}x{ns}"));
            }
        }
        if self.nic_bps <= 0.0 || self.rack_bps <= 0.0 || self.wan_bps <= 0.0 {
            return Err("link rates must be positive".into());
        }
        for (key, mult) in [
            ("site_link_mult", &self.site_link_mult),
            ("site_disk_mult", &self.site_disk_mult),
        ] {
            if !mult.is_empty() && mult.len() != ns {
                return Err(format!(
                    "{key} must have one entry per site ({ns}), got {}",
                    mult.len()
                ));
            }
            if mult.iter().any(|m| !m.is_finite() || *m <= 0.0) {
                return Err(format!("{key} entries must be positive and finite"));
            }
        }
        let mut site_names = Vec::with_capacity(ns);
        let mut node_site = Vec::new();
        let mut node_rack = Vec::new();
        let mut rack_site = Vec::new();
        for (si, site) in self.sites.iter().enumerate() {
            if site.racks == 0 || site.nodes_per_rack == 0 {
                return Err(format!("site {:?} has no nodes", site.name));
            }
            site_names.push(site.name.clone());
            for _ in 0..site.racks {
                let rack_id = rack_site.len();
                rack_site.push(si);
                for _ in 0..site.nodes_per_rack {
                    node_site.push(si);
                    node_rack.push(rack_id);
                }
            }
        }
        let rtt = match &self.site_rtt {
            Some(m) => m.clone(),
            None => (0..ns)
                .map(|a| {
                    (0..ns)
                        .map(|b| {
                            if a == b {
                                self.intra_site_rtt_secs
                            } else {
                                self.wan_rtt_secs
                            }
                        })
                        .collect()
                })
                .collect(),
        };
        Ok(Testbed {
            name: self.name.clone(),
            site_names,
            node_site,
            rtt,
            nic_bps: self.nic_bps,
            wan_bps: self.wan_bps,
            node_rack,
            rack_site,
            rack_bps: self.rack_bps,
            intra_rack_rtt_secs: self.intra_rack_rtt_secs,
            site_link_mult: self.site_link_mult.clone(),
            site_disk_mult: self.site_disk_mult.clone(),
        })
    }
}

/// A described (not yet instantiated) testbed.
#[derive(Clone, Debug)]
pub struct Testbed {
    pub name: String,
    pub site_names: Vec<String>,
    /// node index -> site index.
    pub node_site: Vec<usize>,
    /// site × site RTT in seconds (diagonal = intra-site, cross-rack RTT).
    pub rtt: Vec<Vec<f64>>,
    /// Per-node NIC rate, bytes/s.
    pub nic_bps: f64,
    /// Per-site WAN uplink rate, bytes/s (ignored for 1-site testbeds).
    pub wan_bps: f64,
    /// node index -> global rack index.
    pub node_rack: Vec<usize>,
    /// rack index -> site index.
    pub rack_site: Vec<usize>,
    /// Per-rack uplink rate, bytes/s (only crossed in multi-rack sites).
    pub rack_bps: f64,
    /// RTT between two nodes in the same rack, seconds.
    pub intra_rack_rtt_secs: f64,
    /// Per-site WAN uplink multipliers (empty = uniform 1.0).
    pub site_link_mult: Vec<f64>,
    /// Per-site disk throughput multipliers (empty = uniform 1.0).
    pub site_disk_mult: Vec<f64>,
}

/// Network distance classes between two nodes, nearest first.  The
/// derive order makes `Ord` sort by preference, which is what replica
/// selection in the service layer keys on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Proximity {
    Local,
    SameRack,
    SameSite,
    Wan,
}

/// Rack-diverse replica partner: the same-offset node in the next rack
/// (wrapping over the global rack list), falling back to the next node
/// when the testbed has a single rack.  Shared by the scenario engine's
/// data placement and the service layer's catalog.
pub fn rack_diverse_replica(testbed: &Testbed, node: usize) -> usize {
    let n = testbed.nodes();
    if testbed.racks() <= 1 {
        return (node + 1) % n;
    }
    let rack = testbed.node_rack[node];
    let members: Vec<usize> = (0..n).filter(|&x| testbed.node_rack[x] == rack).collect();
    let offset = members.iter().position(|&x| x == node).unwrap_or(0);
    let next_rack = (rack + 1) % testbed.racks();
    let next_members: Vec<usize> = (0..n)
        .filter(|&x| testbed.node_rack[x] == next_rack)
        .collect();
    if next_members.is_empty() {
        (node + 1) % n
    } else {
        next_members[offset % next_members.len()]
    }
}

/// Link handles produced by `build_network`.
#[derive(Clone, Debug)]
pub struct NetLinks {
    pub node_up: Vec<LinkId>,
    pub node_down: Vec<LinkId>,
    pub rack_up: Vec<LinkId>,
    pub rack_down: Vec<LinkId>,
    pub site_up: Vec<LinkId>,
    pub site_down: Vec<LinkId>,
}

impl Testbed {
    /// The paper's 6-node, 3-site wide-area testbed (§6.1). `nodes`
    /// trims to the Table 1 sweep prefix (1..=6): nodes 1-2 Chicago,
    /// 3-4 Pasadena, 5-6 Greenbelt.
    pub fn wan_testbed(nodes: usize) -> Testbed {
        assert!((1..=6).contains(&nodes));
        let mut t = TopologySpec::paper_wan()
            .generate()
            .expect("paper preset is valid");
        t.node_site.truncate(nodes);
        t.node_rack.truncate(nodes);
        t.name = format!("wan-{nodes}node");
        t
    }

    /// The paper's single-rack testbed (§6.1): up to 8 nodes, one site.
    pub fn lan_testbed(nodes: usize) -> Testbed {
        assert!((1..=8).contains(&nodes));
        TopologySpec::paper_lan(nodes)
            .generate()
            .expect("paper preset is valid")
    }

    pub fn nodes(&self) -> usize {
        self.node_site.len()
    }

    pub fn racks(&self) -> usize {
        self.rack_site.len()
    }

    pub fn sites_used(&self) -> usize {
        let mut seen = vec![false; self.site_names.len()];
        for &s in &self.node_site {
            seen[s] = true;
        }
        seen.iter().filter(|&&b| b).count()
    }

    /// Number of racks belonging to `site`.
    pub fn racks_in_site(&self, site: usize) -> usize {
        self.rack_site.iter().filter(|&&s| s == site).count()
    }

    /// Nominal WAN uplink rate of `site` with its heterogeneity
    /// multiplier applied (network weather composes on top of this
    /// in the scenario engine, it is not folded in here).
    pub fn site_wan_bps(&self, site: usize) -> f64 {
        self.wan_bps * self.site_link_mult.get(site).copied().unwrap_or(1.0)
    }

    /// Disk throughput multiplier for `node` (its site's entry; 1.0 on
    /// homogeneous testbeds).  > 1 is a faster-than-baseline site.
    pub fn disk_mult(&self, node: usize) -> f64 {
        self.site_disk_mult
            .get(self.node_site[node])
            .copied()
            .unwrap_or(1.0)
    }

    /// Network distance class between two nodes.
    pub fn proximity(&self, a: usize, b: usize) -> Proximity {
        if a == b {
            Proximity::Local
        } else if self.node_rack[a] == self.node_rack[b] {
            Proximity::SameRack
        } else if self.node_site[a] == self.node_site[b] {
            Proximity::SameSite
        } else {
            Proximity::Wan
        }
    }

    /// RTT between two nodes, seconds.
    pub fn rtt_secs(&self, a: usize, b: usize) -> f64 {
        if self.node_rack[a] == self.node_rack[b] {
            self.intra_rack_rtt_secs
        } else {
            self.rtt[self.node_site[a]][self.node_site[b]]
        }
    }

    /// The maximum RTT any pair in the testbed sees (for reporting).
    pub fn max_rtt_secs(&self) -> f64 {
        let n = self.nodes();
        let mut max = 0.0f64;
        for a in 0..n {
            for b in 0..n {
                max = max.max(self.rtt_secs(a, b));
            }
        }
        max
    }

    /// Instantiate links in `net`: a full-duplex NIC per node, a
    /// full-duplex uplink per rack and a full-duplex WAN uplink per
    /// site.
    pub fn build_network(&self, net: &mut NetSim) -> NetLinks {
        let node_up = (0..self.nodes())
            .map(|_| net.add_link(self.nic_bps))
            .collect();
        let node_down = (0..self.nodes())
            .map(|_| net.add_link(self.nic_bps))
            .collect();
        let rack_up = (0..self.racks())
            .map(|_| net.add_link(self.rack_bps))
            .collect();
        let rack_down = (0..self.racks())
            .map(|_| net.add_link(self.rack_bps))
            .collect();
        let site_up = (0..self.site_names.len())
            .map(|s| net.add_link(self.site_wan_bps(s)))
            .collect();
        let site_down = (0..self.site_names.len())
            .map(|s| net.add_link(self.site_wan_bps(s)))
            .collect();
        NetLinks {
            node_up,
            node_down,
            rack_up,
            rack_down,
            site_up,
            site_down,
        }
    }

    /// Link path for a src -> dst transfer.  Same node: empty (local
    /// copy, disk-bound only).  Same rack: NIC up + NIC down.  Same
    /// site, different rack: additionally the two rack uplinks.
    /// Cross-site: the rack tier is crossed only where the site actually
    /// has more than one rack (single-rack sites collapse the rack
    /// switch into the site switch), then the two site uplinks.
    pub fn path(&self, links: &NetLinks, src: usize, dst: usize) -> Vec<LinkId> {
        if src == dst {
            return vec![];
        }
        let (sr, dr) = (self.node_rack[src], self.node_rack[dst]);
        if sr == dr {
            return vec![links.node_up[src], links.node_down[dst]];
        }
        let (ss, ds) = (self.node_site[src], self.node_site[dst]);
        let mut p = vec![links.node_up[src]];
        if ss == ds {
            p.push(links.rack_up[sr]);
            p.push(links.rack_down[dr]);
        } else {
            if self.racks_in_site(ss) > 1 {
                p.push(links.rack_up[sr]);
            }
            p.push(links.site_up[ss]);
            p.push(links.site_down[ds]);
            if self.racks_in_site(ds) > 1 {
                p.push(links.rack_down[dr]);
            }
        }
        p.push(links.node_down[dst]);
        p
    }

    /// Bottleneck capacity along a path, bytes/s.
    pub fn bottleneck_bps(&self, net: &NetSim, path: &[LinkId]) -> f64 {
        path.iter()
            .map(|&l| net.link_capacity(l))
            .fold(f64::INFINITY, f64::min)
            .min(self.nic_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wan_testbed_matches_paper_layout() {
        let t = Testbed::wan_testbed(6);
        assert_eq!(t.nodes(), 6);
        assert_eq!(t.sites_used(), 3);
        // Table 1 note: nodes 1-2 Chicago, 3-4 Pasadena, 5-6 Greenbelt.
        assert_eq!(t.node_site, vec![0, 0, 1, 1, 2, 2]);
        assert!((t.rtt_secs(0, 4) - 0.016).abs() < 1e-9); // CHI-GRB
        assert!((t.rtt_secs(0, 2) - 0.055).abs() < 1e-9); // CHI-PAS
        assert!((t.rtt_secs(2, 4) - 0.071).abs() < 1e-9); // PAS-GRB
        assert!((t.max_rtt_secs() - 0.071).abs() < 1e-9);
    }

    #[test]
    fn sweep_prefixes_use_sites_like_the_table() {
        // Table 1: 1-4 nodes span 2 locations only at >= 3 nodes, 3 at >= 5.
        assert_eq!(Testbed::wan_testbed(2).sites_used(), 1);
        assert_eq!(Testbed::wan_testbed(3).sites_used(), 2);
        assert_eq!(Testbed::wan_testbed(4).sites_used(), 2);
        assert_eq!(Testbed::wan_testbed(5).sites_used(), 3);
    }

    #[test]
    fn lan_testbed_is_one_site() {
        let t = Testbed::lan_testbed(8);
        assert_eq!(t.nodes(), 8);
        assert_eq!(t.sites_used(), 1);
        assert!(t.rtt_secs(0, 7) < 0.001);
    }

    #[test]
    fn paths_route_through_expected_links() {
        let t = Testbed::wan_testbed(6);
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        assert!(t.path(&links, 2, 2).is_empty());
        let same_site = t.path(&links, 0, 1);
        assert_eq!(same_site.len(), 2);
        // Single-rack sites: no rack hop, exactly the four-link WAN path.
        let cross = t.path(&links, 0, 2);
        assert_eq!(cross.len(), 4);
        assert_eq!(cross[1], links.site_up[SITE_CHICAGO]);
        assert_eq!(cross[2], links.site_down[SITE_PASADENA]);
        let b = t.bottleneck_bps(&net, &cross);
        assert!((b - t.nic_bps).abs() < 1.0);
    }

    #[test]
    fn cross_site_flows_contend_on_the_uplink() {
        let t = Testbed::wan_testbed(6);
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        // Both Chicago nodes send to Pasadena: they share Chicago's uplink.
        let p1 = t.path(&links, 0, 2);
        let p2 = t.path(&links, 1, 3);
        let f1 = net.start_flow(&p1, 1e12, 1e12);
        let f2 = net.start_flow(&p2, 1e12, 1e12);
        let half = t.wan_bps / 2.0;
        assert!((net.flow_rate(f1) - half).abs() < 1.0);
        assert!((net.flow_rate(f2) - half).abs() < 1.0);
    }

    // ------------------------------------------------ generator layer

    #[test]
    fn generator_reproduces_paper_presets_exactly() {
        // The §6.1 WAN layout, regenerated from its spec.
        let t = TopologySpec::paper_wan().generate().unwrap();
        assert_eq!(t.node_site, vec![0, 0, 1, 1, 2, 2]);
        assert_eq!(t.node_rack, vec![0, 0, 1, 1, 2, 2]);
        assert_eq!(
            t.site_names,
            vec!["chicago".to_string(), "pasadena".into(), "greenbelt".into()]
        );
        assert!((t.rtt_secs(0, 2) - 0.055).abs() < 1e-12);
        assert!((t.rtt_secs(0, 4) - 0.016).abs() < 1e-12);
        assert!((t.rtt_secs(2, 4) - 0.071).abs() < 1e-12);
        assert!((t.nic_bps - 10.0e9 / 8.0).abs() < 1.0);
        assert!((t.wan_bps - 10.0e9 / 8.0).abs() < 1.0);
        // The §6.1 rack.
        let l = TopologySpec::paper_lan(8).generate().unwrap();
        assert_eq!(l.nodes(), 8);
        assert_eq!(l.racks(), 1);
        assert_eq!(l.sites_used(), 1);
        assert!((l.rtt_secs(0, 7) - 0.0001).abs() < 1e-12);
        assert_eq!(l.name, "lan-8node");
    }

    #[test]
    fn scale_out_generates_racks_and_sites() {
        let spec = TopologySpec::scale_out(4, 4, 8);
        assert_eq!(spec.nodes(), 128);
        let t = spec.generate().unwrap();
        assert_eq!(t.nodes(), 128);
        assert_eq!(t.racks(), 16);
        assert_eq!(t.sites_used(), 4);
        assert_eq!(t.racks_in_site(0), 4);
        // node 0 and node 8 share a site but not a rack.
        assert_eq!(t.node_site[0], t.node_site[8]);
        assert_ne!(t.node_rack[0], t.node_rack[8]);
        assert!((t.rtt_secs(0, 1) - 0.1e-3).abs() < 1e-12, "same rack");
        assert!((t.rtt_secs(0, 8) - 0.5e-3).abs() < 1e-12, "cross rack");
        assert!((t.rtt_secs(0, 127) - 40.0e-3).abs() < 1e-12, "cross site");
    }

    #[test]
    fn multi_rack_paths_cross_rack_uplinks() {
        let t = TopologySpec::scale_out(2, 2, 2).generate().unwrap();
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        // nodes 0,1 rack 0; nodes 2,3 rack 1 (site 0); nodes 4.. site 1.
        assert_eq!(t.path(&links, 0, 1).len(), 2, "same rack: NICs only");
        let cross_rack = t.path(&links, 0, 2);
        assert_eq!(cross_rack.len(), 4);
        assert_eq!(cross_rack[1], links.rack_up[0]);
        assert_eq!(cross_rack[2], links.rack_down[1]);
        let cross_site = t.path(&links, 0, 4);
        assert_eq!(cross_site.len(), 6);
        assert_eq!(cross_site[1], links.rack_up[0]);
        assert_eq!(cross_site[2], links.site_up[0]);
        assert_eq!(cross_site[3], links.site_down[1]);
        assert_eq!(cross_site[4], links.rack_down[2]);
    }

    #[test]
    fn rack_uplink_is_a_real_bottleneck() {
        let mut spec = TopologySpec::scale_out(1, 2, 2);
        spec.rack_bps = spec.nic_bps / 2.0; // oversubscribed rack uplink
        let t = spec.generate().unwrap();
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        let p = t.path(&links, 0, 2);
        let b = t.bottleneck_bps(&net, &p);
        assert!((b - spec.rack_bps).abs() < 1.0);
    }

    #[test]
    fn spec_parses_from_table() {
        let t = Table::parse(
            r#"
            [topology]
            sites = 2
            racks_per_site = 3
            nodes_per_rack = 4
            wan_rtt_ms = 25.0
            nic_gbps = 1.0
            "#,
        )
        .unwrap();
        let spec = TopologySpec::from_table(&t).unwrap();
        assert_eq!(spec.nodes(), 24);
        assert!((spec.wan_rtt_secs - 0.025).abs() < 1e-12);
        assert!((spec.nic_bps - 1.0e9 / 8.0).abs() < 1.0);
        let preset = Table::parse("[topology]\npreset = \"paper_wan\"").unwrap();
        assert_eq!(TopologySpec::from_table(&preset).unwrap(), TopologySpec::paper_wan());
        let bad = Table::parse("[topology]\npreset = \"mesh\"").unwrap();
        assert!(TopologySpec::from_table(&bad).is_err());
    }

    #[test]
    fn preset_nodes_trim_is_honored() {
        // `nodes = 4` on the WAN preset gives the Table 1 4-node row:
        // 2x Chicago + 2x Pasadena, Greenbelt dropped entirely.
        let t = Table::parse("[topology]\npreset = \"paper_wan\"\nnodes = 4").unwrap();
        let spec = TopologySpec::from_table(&t).unwrap();
        assert_eq!(spec.nodes(), 4);
        let tb = spec.generate().unwrap();
        assert_eq!(tb.node_site, vec![0, 0, 1, 1]);
        assert_eq!(tb.site_names.len(), 2);
        assert!((tb.rtt_secs(0, 2) - 0.055).abs() < 1e-12, "CHI-PAS RTT survives the trim");
        // Out-of-range trims are rejected for both presets.
        let t = Table::parse("[topology]\npreset = \"paper_wan\"\nnodes = 9").unwrap();
        assert!(TopologySpec::from_table(&t).is_err());
        let t = Table::parse("[topology]\npreset = \"paper_lan\"\nnodes = 9").unwrap();
        assert!(TopologySpec::from_table(&t).is_err());
    }

    #[test]
    fn proximity_classes_and_ordering() {
        let t = TopologySpec::scale_out(2, 2, 2).generate().unwrap();
        assert_eq!(t.proximity(0, 0), Proximity::Local);
        assert_eq!(t.proximity(0, 1), Proximity::SameRack);
        assert_eq!(t.proximity(0, 2), Proximity::SameSite);
        assert_eq!(t.proximity(0, 4), Proximity::Wan);
        assert!(Proximity::Local < Proximity::SameRack);
        assert!(Proximity::SameRack < Proximity::SameSite);
        assert!(Proximity::SameSite < Proximity::Wan);
    }

    #[test]
    fn rack_diverse_replica_crosses_racks() {
        let t = TopologySpec::scale_out(2, 2, 4).generate().unwrap();
        for node in 0..t.nodes() {
            let r = rack_diverse_replica(&t, node);
            assert_ne!(t.node_rack[node], t.node_rack[r], "node {node} -> {r}");
        }
        let single = TopologySpec::paper_lan(4).generate().unwrap();
        assert_eq!(
            rack_diverse_replica(&single, 3),
            0,
            "single rack wraps to next node"
        );
    }

    #[test]
    fn heterogeneous_site_multipliers() {
        let mut spec = TopologySpec::scale_out(2, 1, 2);
        spec.site_link_mult = vec![1.0, 0.5];
        spec.site_disk_mult = vec![2.0, 1.0];
        let t = spec.generate().unwrap();
        assert!((t.site_wan_bps(0) - t.wan_bps).abs() < 1.0);
        assert!((t.site_wan_bps(1) - t.wan_bps * 0.5).abs() < 1.0);
        assert!((t.disk_mult(0) - 2.0).abs() < 1e-12, "node 0 sits in site 0");
        assert!((t.disk_mult(3) - 1.0).abs() < 1e-12, "node 3 sits in site 1");
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        assert!((net.link_capacity(links.site_up[1]) - t.wan_bps * 0.5).abs() < 1.0);
        assert!((net.link_capacity(links.site_down[0]) - t.wan_bps).abs() < 1.0);
        // Empty vectors mean uniform 1.0 everywhere.
        let uniform = TopologySpec::scale_out(2, 1, 2).generate().unwrap();
        assert!((uniform.site_wan_bps(1) - uniform.wan_bps).abs() < 1.0);
        assert!((uniform.disk_mult(3) - 1.0).abs() < 1e-12);
        // Wrong lengths and non-positive entries are rejected.
        spec.site_link_mult = vec![1.0];
        assert!(spec.generate().is_err());
        spec.site_link_mult = vec![1.0, -1.0];
        assert!(spec.generate().is_err());
        spec.site_link_mult = vec![1.0, 0.5];
        spec.site_disk_mult = vec![0.0, 1.0];
        assert!(spec.generate().is_err());
        // And they parse from `[topology]` arrays.
        let t2 = Table::parse(
            "[topology]\nsites = 2\nnodes_per_rack = 2\n\
             site_link_mult = [1.0, 0.5]\nsite_disk_mult = [2.0, 1.0]",
        )
        .unwrap();
        let spec2 = TopologySpec::from_table(&t2).unwrap();
        assert_eq!(spec2.site_link_mult, vec![1.0, 0.5]);
        assert_eq!(spec2.site_disk_mult, vec![2.0, 1.0]);
        let bad = Table::parse("[topology]\nsites = 2\nsite_link_mult = 2.0").unwrap();
        assert!(TopologySpec::from_table(&bad).is_err());
    }

    #[test]
    fn generate_rejects_bad_specs() {
        let mut spec = TopologySpec::scale_out(1, 1, 1);
        spec.sites.clear();
        assert!(spec.generate().is_err());
        let mut spec = TopologySpec::paper_wan();
        spec.site_rtt = Some(vec![vec![0.0]]); // wrong shape for 3 sites
        assert!(spec.generate().is_err());
        let mut spec = TopologySpec::scale_out(1, 1, 2);
        spec.nic_bps = 0.0;
        assert!(spec.generate().is_err());
    }
}

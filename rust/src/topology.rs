//! Testbed topology descriptions + netsim wiring (substitution for the
//! paper's physical testbeds; DESIGN.md §2).
//!
//! * `wan_testbed()` — the §6.1 wide-area testbed: 6 servers in 3 sites
//!   (2× Chicago, 2× Pasadena, 2× Greenbelt), 10 Gb/s everywhere, RTTs
//!   16 ms (CHI–GRB), 55 ms (CHI–PAS), 71 ms (GRB–PAS, routed through
//!   Chicago).
//! * `lan_testbed(n)` — the §6.1 rack: n ≤ 8 servers on one switch.
//!
//! `build_network` instantiates per-node NIC links and per-site WAN
//! uplinks in a `NetSim`; `path`/`rtt_secs` answer the per-pair questions
//! job simulators ask.

use crate::sim::netsim::{LinkId, NetSim};

pub const SITE_CHICAGO: usize = 0;
pub const SITE_PASADENA: usize = 1;
pub const SITE_GREENBELT: usize = 2;

/// A described (not yet instantiated) testbed.
#[derive(Clone, Debug)]
pub struct Testbed {
    pub name: String,
    pub site_names: Vec<String>,
    /// node index -> site index.
    pub node_site: Vec<usize>,
    /// site × site RTT in seconds (diagonal = intra-site RTT).
    pub rtt: Vec<Vec<f64>>,
    /// Per-node NIC rate, bytes/s.
    pub nic_bps: f64,
    /// Per-site WAN uplink rate, bytes/s (ignored for 1-site testbeds).
    pub wan_bps: f64,
}

/// Link handles produced by `build_network`.
#[derive(Clone, Debug)]
pub struct NetLinks {
    pub node_up: Vec<LinkId>,
    pub node_down: Vec<LinkId>,
    pub site_up: Vec<LinkId>,
    pub site_down: Vec<LinkId>,
}

impl Testbed {
    /// The paper's 6-node, 3-site wide-area testbed (§6.1). `nodes`
    /// trims to the Table 1 sweep prefix (1..=6): nodes 1-2 Chicago,
    /// 3-4 Pasadena, 5-6 Greenbelt.
    pub fn wan_testbed(nodes: usize) -> Testbed {
        assert!((1..=6).contains(&nodes));
        let ms = 1e-3;
        let node_site_full = [
            SITE_CHICAGO,
            SITE_CHICAGO,
            SITE_PASADENA,
            SITE_PASADENA,
            SITE_GREENBELT,
            SITE_GREENBELT,
        ];
        Testbed {
            name: format!("wan-{nodes}node"),
            site_names: vec![
                "chicago".into(),
                "pasadena".into(),
                "greenbelt".into(),
            ],
            node_site: node_site_full[..nodes].to_vec(),
            rtt: vec![
                vec![0.1 * ms, 55.0 * ms, 16.0 * ms],
                vec![55.0 * ms, 0.1 * ms, 71.0 * ms],
                vec![16.0 * ms, 71.0 * ms, 0.1 * ms],
            ],
            nic_bps: 10.0e9 / 8.0,
            wan_bps: 10.0e9 / 8.0,
        }
    }

    /// The paper's single-rack testbed (§6.1): up to 8 nodes, one site.
    pub fn lan_testbed(nodes: usize) -> Testbed {
        assert!((1..=8).contains(&nodes));
        Testbed {
            name: format!("lan-{nodes}node"),
            site_names: vec!["rack".into()],
            node_site: vec![0; nodes],
            rtt: vec![vec![0.0001]],
            nic_bps: 10.0e9 / 8.0,
            wan_bps: 10.0e9 / 8.0,
        }
    }

    pub fn nodes(&self) -> usize {
        self.node_site.len()
    }

    pub fn sites_used(&self) -> usize {
        let mut seen = vec![false; self.site_names.len()];
        for &s in &self.node_site {
            seen[s] = true;
        }
        seen.iter().filter(|&&b| b).count()
    }

    /// RTT between two nodes, seconds.
    pub fn rtt_secs(&self, a: usize, b: usize) -> f64 {
        self.rtt[self.node_site[a]][self.node_site[b]]
    }

    /// The maximum RTT any pair in the testbed sees (for reporting).
    pub fn max_rtt_secs(&self) -> f64 {
        let n = self.nodes();
        let mut max = 0.0f64;
        for a in 0..n {
            for b in 0..n {
                max = max.max(self.rtt_secs(a, b));
            }
        }
        max
    }

    /// Instantiate links in `net`: a full-duplex NIC per node and a
    /// full-duplex WAN uplink per site.
    pub fn build_network(&self, net: &mut NetSim) -> NetLinks {
        let node_up = (0..self.nodes())
            .map(|_| net.add_link(self.nic_bps))
            .collect();
        let node_down = (0..self.nodes())
            .map(|_| net.add_link(self.nic_bps))
            .collect();
        let site_up = (0..self.site_names.len())
            .map(|_| net.add_link(self.wan_bps))
            .collect();
        let site_down = (0..self.site_names.len())
            .map(|_| net.add_link(self.wan_bps))
            .collect();
        NetLinks {
            node_up,
            node_down,
            site_up,
            site_down,
        }
    }

    /// Link path for a src -> dst transfer. Same node: empty (local copy,
    /// disk-bound only). Same site: NIC up + NIC down. Cross-site: NIC up,
    /// site uplink, site downlink, NIC down.
    pub fn path(&self, links: &NetLinks, src: usize, dst: usize) -> Vec<LinkId> {
        if src == dst {
            return vec![];
        }
        let (ss, ds) = (self.node_site[src], self.node_site[dst]);
        if ss == ds {
            vec![links.node_up[src], links.node_down[dst]]
        } else {
            vec![
                links.node_up[src],
                links.site_up[ss],
                links.site_down[ds],
                links.node_down[dst],
            ]
        }
    }

    /// Bottleneck capacity along a path, bytes/s.
    pub fn bottleneck_bps(&self, net: &NetSim, path: &[LinkId]) -> f64 {
        path.iter()
            .map(|&l| net.link_capacity(l))
            .fold(f64::INFINITY, f64::min)
            .min(self.nic_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wan_testbed_matches_paper_layout() {
        let t = Testbed::wan_testbed(6);
        assert_eq!(t.nodes(), 6);
        assert_eq!(t.sites_used(), 3);
        // Table 1 note: nodes 1-2 Chicago, 3-4 Pasadena, 5-6 Greenbelt.
        assert_eq!(t.node_site, vec![0, 0, 1, 1, 2, 2]);
        assert!((t.rtt_secs(0, 4) - 0.016).abs() < 1e-9); // CHI-GRB
        assert!((t.rtt_secs(0, 2) - 0.055).abs() < 1e-9); // CHI-PAS
        assert!((t.rtt_secs(2, 4) - 0.071).abs() < 1e-9); // PAS-GRB
        assert!((t.max_rtt_secs() - 0.071).abs() < 1e-9);
    }

    #[test]
    fn sweep_prefixes_use_sites_like_the_table() {
        // Table 1: 1-4 nodes span 2 locations only at >= 3 nodes, 3 at >= 5.
        assert_eq!(Testbed::wan_testbed(2).sites_used(), 1);
        assert_eq!(Testbed::wan_testbed(3).sites_used(), 2);
        assert_eq!(Testbed::wan_testbed(4).sites_used(), 2);
        assert_eq!(Testbed::wan_testbed(5).sites_used(), 3);
    }

    #[test]
    fn lan_testbed_is_one_site() {
        let t = Testbed::lan_testbed(8);
        assert_eq!(t.nodes(), 8);
        assert_eq!(t.sites_used(), 1);
        assert!(t.rtt_secs(0, 7) < 0.001);
    }

    #[test]
    fn paths_route_through_expected_links() {
        let t = Testbed::wan_testbed(6);
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        assert!(t.path(&links, 2, 2).is_empty());
        let same_site = t.path(&links, 0, 1);
        assert_eq!(same_site.len(), 2);
        let cross = t.path(&links, 0, 2);
        assert_eq!(cross.len(), 4);
        assert_eq!(cross[1], links.site_up[SITE_CHICAGO]);
        assert_eq!(cross[2], links.site_down[SITE_PASADENA]);
        let b = t.bottleneck_bps(&net, &cross);
        assert!((b - t.nic_bps).abs() < 1.0);
    }

    #[test]
    fn cross_site_flows_contend_on_the_uplink() {
        let t = Testbed::wan_testbed(6);
        let mut net = NetSim::new();
        let links = t.build_network(&mut net);
        // Both Chicago nodes send to Pasadena: they share Chicago's uplink.
        let p1 = t.path(&links, 0, 2);
        let p2 = t.path(&links, 1, 3);
        let f1 = net.start_flow(&p1, 1e12, 1e12);
        let f2 = net.start_flow(&p2, 1e12, 1e12);
        let half = t.wan_bps / 2.0;
        assert!((net.flow_rate(f1) - half).abs() < 1.0);
        assert!((net.flow_rate(f2) - half).abs() < 1.0);
    }
}

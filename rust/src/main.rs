//! sector-sphere — CLI for the Sector/Sphere reproduction.
//!
//! Subcommands mirror the paper's workflows: bring up an in-process
//! cloud and run Terasort/Terasplit/Angle for real, or simulate the
//! paper-scale testbeds (Tables 1–2 rows) from the command line.

use sector_sphere::cli::{usage, Args, FlagSpec};
use sector_sphere::cluster::Cluster;
use sector_sphere::config::SimConfig;
use sector_sphere::hadoop::simulate_hadoop_row;
use sector_sphere::mining::{run_pipeline, AngleScenario};
use sector_sphere::sphere::simjob::simulate_sphere_row;
use sector_sphere::topology::Testbed;
use sector_sphere::util::bytes::{fmt_duration_secs, parse_bytes};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("sort", "run real Terasort+Terasplit on an in-process cluster"),
    ("angle", "run the Angle pipeline (in-process; --preset/--file: staged scenario)"),
    ("sim", "simulate a paper-scale Table 1/2 row (WAN or LAN)"),
    ("scenario", "run a TOML-described scenario (topology+workload+faults)"),
    ("traffic", "serve multi-tenant client traffic (SLO report)"),
    ("compare", "run the same job through Sphere AND Hadoop (head-to-head)"),
    ("sweep", "expand a [sweep] grid and run every point (SweepReport JSON)"),
    ("quickstart", "upload files and run a grep UDF"),
];

fn flag_spec() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "nodes", help: "cluster node count", takes_value: true },
        FlagSpec { name: "records", help: "records per node (sort)", takes_value: true },
        FlagSpec { name: "testbed", help: "sim testbed: wan|lan", takes_value: true },
        FlagSpec { name: "bytes-per-node", help: "sim data size, e.g. 10GB", takes_value: true },
        FlagSpec { name: "windows", help: "angle time windows", takes_value: true },
        FlagSpec { name: "seed", help: "deterministic seed", takes_value: true },
        FlagSpec { name: "file", help: "scenario TOML (see config/scenarios/)", takes_value: true },
        FlagSpec { name: "preset", help: "scenario preset: paper_wan6|paper_lan8|scale128|traffic_scale128|traffic_elastic512|colocate_scale128|compare_wan4|compare_scale128|angle_wan4|angle_scale128|churn_wan32|weather_compare16; sweep: sweep_fig5_scaling|sweep_speedup_wan", takes_value: true },
        FlagSpec { name: "requests", help: "traffic: total requests to drive", takes_value: true },
        FlagSpec { name: "clients", help: "traffic: simulated client population", takes_value: true },
        FlagSpec { name: "rps", help: "traffic: open-loop arrival rate", takes_value: true },
        FlagSpec { name: "metrics", help: "traffic: also print the metrics registry", takes_value: false },
        FlagSpec { name: "trace", help: "write trace artifacts (Chrome JSON + JSONL) to this path", takes_value: true },
        FlagSpec { name: "out", help: "sweep: SweepReport JSON path (default <sweep-name>.json)", takes_value: true },
        FlagSpec { name: "workers", help: "sweep: worker threads for the point fan-out", takes_value: true },
        FlagSpec { name: "disk", help: "back slaves with real files", takes_value: false },
        FlagSpec { name: "pjrt", help: "load AOT artifacts (needs `make artifacts`)", takes_value: false },
        FlagSpec { name: "help", help: "show usage", takes_value: false },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, true, &flag_spec()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", usage("sector-sphere", SUBCOMMANDS, &flag_spec()));
            std::process::exit(2);
        }
    };
    if args.has("help") || args.subcommand.is_none() {
        println!("{}", usage("sector-sphere", SUBCOMMANDS, &flag_spec()));
        return;
    }
    let result = match args.subcommand.as_deref().unwrap() {
        "sort" => cmd_sort(&args),
        "angle" => cmd_angle(&args),
        "sim" => cmd_sim(&args),
        "scenario" => cmd_scenario(&args),
        "traffic" => cmd_traffic(&args),
        "compare" => cmd_compare(&args),
        "sweep" => cmd_sweep(&args),
        "quickstart" => cmd_quickstart(&args),
        other => Err(format!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn build_cluster(args: &Args) -> Result<Cluster, String> {
    Cluster::builder()
        .nodes(args.usize_or("nodes", 4)?)
        .seed(args.u64_or("seed", 20080824)?)
        .on_disk(args.has("disk"))
        .with_runtime(args.has("pjrt"))
        .build()
}

fn cmd_sort(args: &Args) -> Result<(), String> {
    let records = args.usize_or("records", 2000)?;
    let cluster = build_cluster(args)?;
    println!(
        "terasort: {} nodes x {} records ({} bytes/node){}",
        cluster.nodes(),
        records,
        records * 100,
        if cluster.runtime.is_some() { " [pjrt]" } else { "" }
    );
    let r = cluster.terasort_e2e(records)?;
    println!("  records sorted     {}", r.records);
    println!("  bucket files       {}", r.bucket_files);
    println!("  globally sorted    {}", r.globally_sorted);
    println!("  split gain         {:.4} bits @ record {}", r.split_gain_bits, r.split_index);
    println!("  partition locality {:.0}%", r.partition_locality * 100.0);
    println!("  wall time          {}", fmt_duration_secs(r.wall_secs));
    if !r.globally_sorted {
        return Err("output not globally sorted".into());
    }
    Ok(())
}

fn cmd_angle(args: &Args) -> Result<(), String> {
    // With a scenario file or preset, run the staged five-stage Angle
    // pipeline on the scenario substrate (DESIGN.md §13)...
    if args.get("file").is_some() || args.get("preset").is_some() {
        use sector_sphere::scenario::run_scenario;
        let mut spec = load_scenario_spec(args, "angle_wan4")?;
        // The user asked for Angle: a terasort/compare TOML slipping
        // through here would silently run the wrong pipeline.
        match spec.workload.as_ref().map(|w| w.kind.name()) {
            Some("angle") => {}
            other => {
                return Err(format!(
                    "angle: the selected scenario runs {:?}, not the Angle \
                     pipeline (use the `scenario` subcommand for it)",
                    other.unwrap_or("no workload")
                ))
            }
        }
        if spec.traffic.is_some() {
            // angle + [traffic] is the legacy colocated model, not the
            // staged pipeline — run it via `scenario`, not `angle`.
            return Err(
                "angle: the selected scenario colocates with [traffic] and \
                 would run the legacy extract+clustering-tail model (use the \
                 `scenario` subcommand for it)"
                    .into(),
            );
        }
        if let Some(v) = args.get("windows") {
            let windows: usize = v
                .parse()
                .map_err(|_| format!("--windows expects an integer, got {v:?}"))?;
            spec.angle.get_or_insert_with(Default::default).windows = windows;
        }
        if let Some(seed) = args.get("seed") {
            spec.cfg.seed = seed
                .parse()
                .map_err(|_| format!("--seed expects an integer, got {seed:?}"))?;
        }
        apply_trace_flag(args, &mut spec);
        let r = run_scenario(&spec)?;
        print_scenario_report(&r);
        print_trace_paths(&spec);
        return Ok(());
    }
    // ...otherwise the in-process real-mode pipeline on actual bytes.
    let cluster = build_cluster(args)?;
    let scenario = AngleScenario {
        windows: args.u64_or("windows", 8)?,
        ..AngleScenario::default()
    };
    let report = run_pipeline(&cluster.cloud, &scenario, cluster.runtime.as_ref())?;
    println!("angle: {} feature files, {} vectors", report.feature_files, report.features_total);
    println!("  delta series  {:?}", report.analysis.deltas.iter().map(|d| (d * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("  emergent at   {:?}", report.emergent_window_ids);
    for (src, w, score) in &report.top_scores {
        println!("  rho={score:.4}  src={src:016x} window={w}");
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<(), String> {
    let nodes = args.usize_or("nodes", 6)?;
    let bytes = parse_bytes(args.str_or("bytes-per-node", "10GB"))? as f64;
    let (testbed, cfg) = match args.str_or("testbed", "wan") {
        "wan" => (Testbed::wan_testbed(nodes), SimConfig::wan_default()),
        "lan" => (Testbed::lan_testbed(nodes), SimConfig::lan_default()),
        other => return Err(format!("unknown testbed {other:?} (wan|lan)")),
    };
    let sphere = simulate_sphere_row(&testbed, &cfg, bytes);
    let hadoop = simulate_hadoop_row(&testbed, &cfg, bytes);
    println!("{} / {} per node:", testbed.name, args.str_or("bytes-per-node", "10GB"));
    println!("  {:<20} {:>10} {:>10}", "", "Sphere", "Hadoop");
    println!("  {:<20} {:>10.0} {:>10.0}", "Terasort (s)", sphere.terasort_secs, hadoop.terasort_secs);
    println!("  {:<20} {:>10.0} {:>10.0}", "Terasplit (s)", sphere.terasplit_secs, hadoop.terasplit_secs);
    println!(
        "  {:<20} {:>10.0} {:>10.0}",
        "Total (s)",
        sphere.terasort_secs + sphere.terasplit_secs,
        hadoop.terasort_secs + hadoop.terasplit_secs
    );
    println!(
        "  speedup: sort {:.1}x, split {:.1}x, total {:.1}x",
        hadoop.terasort_secs / sphere.terasort_secs,
        hadoop.terasplit_secs / sphere.terasplit_secs,
        (hadoop.terasort_secs + hadoop.terasplit_secs)
            / (sphere.terasort_secs + sphere.terasplit_secs)
    );
    Ok(())
}

fn load_scenario_spec(
    args: &Args,
    default_preset: &str,
) -> Result<sector_sphere::scenario::ScenarioSpec, String> {
    use sector_sphere::scenario::ScenarioSpec;
    match args.get("file") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("read scenario {path}: {e}"))?;
            ScenarioSpec::from_toml(&text)
        }
        None => match args.str_or("preset", default_preset) {
            "paper_wan6" => Ok(ScenarioSpec::paper_wan6()),
            "paper_lan8" => Ok(ScenarioSpec::paper_lan8()),
            "scale128" => Ok(ScenarioSpec::scale128()),
            "traffic_scale128" => Ok(ScenarioSpec::traffic_scale128()),
            "traffic_elastic512" => Ok(ScenarioSpec::traffic_elastic512()),
            "colocate_scale128" => Ok(ScenarioSpec::colocate_scale128()),
            "compare_wan4" => Ok(ScenarioSpec::compare_wan4()),
            "compare_scale128" => Ok(ScenarioSpec::compare_scale128()),
            "angle_wan4" => Ok(ScenarioSpec::angle_wan4()),
            "angle_scale128" => Ok(ScenarioSpec::angle_scale128()),
            "churn_wan32" => Ok(ScenarioSpec::churn_wan32()),
            "weather_compare16" => Ok(ScenarioSpec::weather_compare16()),
            other => Err(format!(
                "unknown preset {other:?} \
                 (paper_wan6|paper_lan8|scale128|traffic_scale128|traffic_elastic512|\
                 colocate_scale128|compare_wan4|compare_scale128|angle_wan4|\
                 angle_scale128|churn_wan32|weather_compare16) — or pass --file"
            )),
        },
    }
}

/// Apply `--trace <path>` to a scenario spec: switches the always-on
/// recorder from digest-only to artifact-writing mode.
fn apply_trace_flag(args: &Args, spec: &mut sector_sphere::scenario::ScenarioSpec) {
    if let Some(path) = args.get("trace") {
        spec.trace.get_or_insert_with(Default::default).path = Some(path.to_string());
    }
}

/// After a traced run: tell the user where the artifacts went.
fn print_trace_paths(spec: &sector_sphere::scenario::ScenarioSpec) {
    if let Some(path) = spec.trace.as_ref().and_then(|t| t.path.as_deref()) {
        let (chrome, jsonl) = sector_sphere::scenario::trace::artifact_paths(path);
        println!("  trace          {chrome} (load in Perfetto / chrome://tracing)");
        println!("  trace log      {jsonl}");
    }
}

fn print_scenario_report(r: &sector_sphere::scenario::ScenarioReport) {
    println!(
        "scenario {}: {} on {} nodes ({} racks, {} sites)",
        r.name, r.workload, r.nodes, r.racks, r.sites
    );
    println!("  makespan       {}", fmt_duration_secs(r.makespan_secs));
    println!("  events         {}", r.events);
    if let Some(t) = &r.traffic {
        println!(
            "  requests       {} issued: {} completed, {} rejected, {} unavailable",
            t.requests, t.completed, t.rejected, t.unavailable
        );
        println!(
            "  caches         metadata {:.1}% hit, connections {:.1}% hit",
            t.meta_hit_rate * 100.0,
            t.conn_hit_rate * 100.0
        );
        println!(
            "  placement      {:.0}% served same-node/rack, peak queue {}, {:.2} GB replicated",
            t.near_fraction * 100.0,
            t.peak_queue,
            t.replica_gbytes
        );
        println!(
            "  {:<14} {:>8} {:>8} {:>6} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8}",
            "tenant", "reqs", "done", "rej", "unavail", "p50 ms", "p95 ms", "p99 ms", "rps", "GB"
        );
        for s in &t.tenants {
            println!(
                "  {:<14} {:>8} {:>8} {:>6} {:>8} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>8.2}",
                s.name,
                s.requests,
                s.completed,
                s.rejected,
                s.unavailable,
                s.p50_ms,
                s.p95_ms,
                s.p99_ms,
                s.throughput_rps,
                s.gbytes
            );
        }
    } else {
        println!("  segments       {}", r.segments);
        println!("  locality       {:.0}%", r.locality_fraction * 100.0);
        println!("  shuffled       {:.2} GB", r.shuffle_gbytes);
    }
    if let Some(e) = &r.elasticity {
        println!(
            "  elasticity     {} policy: {} grows, {} sheds ({} drained), \
             peak {} replicas, final {}",
            e.policy, e.grows, e.sheds, e.drained_sheds, e.peak_replicas, e.final_replicas
        );
        println!(
            "  re-replication {:.2} GB moved (nic {:.2} / rack {:.2} / wan {:.2}), \
             {} invariant violations",
            e.rereplication.total() / 1e9,
            e.rereplication.nic / 1e9,
            e.rereplication.rack / 1e9,
            e.rereplication.wan / 1e9,
            e.invariant_violations
        );
        for d in &e.tenant_deltas {
            println!(
                "  elastic gain   {:<12} p50 {:+8.1} ms  p95 {:+8.1} ms  p99 {:+8.1} ms \
                 (vs static baseline)",
                d.name, d.p50_delta_ms, d.p95_delta_ms, d.p99_delta_ms
            );
        }
    }
    if let Some(co) = &r.colocation {
        println!(
            "  job            {} done in {} ({} segments, {:.0}% local, {:.2} GB shuffled)",
            r.workload,
            fmt_duration_secs(co.job_makespan_secs),
            r.segments,
            r.locality_fraction * 100.0,
            r.shuffle_gbytes
        );
        for (name, end) in &co.stage_ends {
            println!("    stage {:<18} ended {}", name, fmt_duration_secs(*end));
        }
        println!(
            "  speculation    {} backups launched, {} won",
            r.speculative_launched, r.speculative_won
        );
        for d in &co.tenant_deltas {
            println!(
                "  colo cost      {:<12} p50 {:+8.1} ms  p95 {:+8.1} ms  p99 {:+8.1} ms \
                 (vs uncolocated)",
                d.name, d.p50_delta_ms, d.p95_delta_ms, d.p99_delta_ms
            );
        }
    }
    if let Some(cmp) = &r.comparison {
        println!(
            "  {:<8} {:>12} {:>7} {:>7} {:>9} {:>9} {:>9} {:>9} {:>6}",
            "system", "makespan(s)", "tasks", "local%", "nic GB", "rack GB", "wan GB", "reassign", "spec"
        );
        for s in [&cmp.sphere, &cmp.hadoop] {
            println!(
                "  {:<8} {:>12.1} {:>7} {:>6.0}% {:>9.2} {:>9.2} {:>9.2} {:>9} {:>3}/{}",
                s.system,
                s.makespan_secs,
                s.tasks,
                s.locality_fraction * 100.0,
                s.tier.nic / 1e9,
                s.tier.rack / 1e9,
                s.tier.wan / 1e9,
                s.reassignments,
                s.speculative_won,
                s.speculative_launched,
            );
            for (name, end) in &s.stage_ends {
                println!("    `- stage {:<18} ended {}", name, fmt_duration_secs(*end));
            }
        }
        println!(
            "  speedup        {:.2}x (Hadoop / Sphere makespan; paper §7: 2.4-2.6x WAN sort)",
            cmp.speedup
        );
    }
    if let Some(an) = &r.angle {
        println!(
            "  angle          {} temporal windows over {} Sector files",
            an.windows, an.files
        );
        let rounded: Vec<f64> = an
            .deltas
            .iter()
            .map(|d| (d * 100.0).round() / 100.0)
            .collect();
        println!("  delta_j        {rounded:?}");
        println!(
            "  emergent       found {:?} vs planted {:?} -> recall {:.2}",
            an.emergent_found, an.emergent_planted, an.recall
        );
        println!(
            "  features       {:.3} GB shuffled into windows; models {:.1} KB \
             (nic {:.1} / rack {:.1} / wan {:.1})",
            an.feature_gbytes,
            an.model_tier.total() / 1e3,
            an.model_tier.nic / 1e3,
            an.model_tier.rack / 1e3,
            an.model_tier.wan / 1e3
        );
        println!(
            "  calibration    staged mining work {:.0} s vs Table 3 oracle {:.0} s \
             ({:.2}x)",
            an.staged_work_secs,
            an.oracle_secs,
            an.staged_work_secs / an.oracle_secs.max(1e-9)
        );
        if r.speculative_launched > 0 {
            println!(
                "  speculation    {} cluster backups launched, {} won",
                r.speculative_launched, r.speculative_won
            );
        }
    }
    println!(
        "  faults         {} injected, {} nodes crashed, {} reassignments",
        r.faults_injected, r.nodes_crashed, r.reassignments
    );
    println!("  trace digest   {}", r.trace_digest);
}

fn cmd_scenario(args: &Args) -> Result<(), String> {
    use sector_sphere::scenario::run_scenario;
    let mut spec = load_scenario_spec(args, "scale128")?;
    apply_trace_flag(args, &mut spec);
    let r = run_scenario(&spec)?;
    print_scenario_report(&r);
    print_trace_paths(&spec);
    Ok(())
}

fn cmd_traffic(args: &Args) -> Result<(), String> {
    use sector_sphere::metrics::Metrics;
    use sector_sphere::scenario::run_scenario;
    let mut spec = load_scenario_spec(args, "traffic_scale128")?;
    let traffic = spec
        .traffic
        .as_mut()
        .ok_or("the selected scenario has no [traffic] block")?;
    if let Some(v) = args.get("requests") {
        traffic.requests = v
            .parse()
            .map_err(|_| format!("--requests expects an integer, got {v:?}"))?;
    }
    if let Some(v) = args.get("clients") {
        traffic.clients = v
            .parse()
            .map_err(|_| format!("--clients expects an integer, got {v:?}"))?;
    }
    if let Some(v) = args.get("rps") {
        let rps: f64 = v
            .parse()
            .map_err(|_| format!("--rps expects a number, got {v:?}"))?;
        traffic.arrival = sector_sphere::service::ArrivalProcess::Open { rps };
    }
    if let Some(seed) = args.get("seed") {
        spec.cfg.seed = seed
            .parse()
            .map_err(|_| format!("--seed expects an integer, got {seed:?}"))?;
    }
    apply_trace_flag(args, &mut spec);
    let r = run_scenario(&spec)?;
    print_scenario_report(&r);
    print_trace_paths(&spec);
    if args.has("metrics") {
        let m = Metrics::new();
        r.traffic
            .as_ref()
            .expect("traffic scenario produces a traffic report")
            .record_into(&m);
        print!("{}", m.report());
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    use sector_sphere::scenario::{run_scenario, CompareSpec};
    let mut spec = load_scenario_spec(args, "compare_wan4")?;
    // Any batch scenario can be compared: `compare --preset scale128`
    // promotes a Sphere-only preset into a head-to-head.
    if spec.compare.is_none() {
        spec.compare = Some(CompareSpec::default());
    }
    apply_trace_flag(args, &mut spec);
    let r = run_scenario(&spec)?;
    print_scenario_report(&r);
    print_trace_paths(&spec);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    use sector_sphere::scenario::{run_sweep, SweepSpec};
    let mut spec = match args.get("file") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("read sweep {path}: {e}"))?;
            SweepSpec::from_toml(&text)?
        }
        None => match args.str_or("preset", "sweep_fig5_scaling") {
            "sweep_fig5_scaling" => SweepSpec::fig5_scaling(),
            "sweep_speedup_wan" => SweepSpec::speedup_wan(),
            other => {
                return Err(format!(
                    "unknown sweep preset {other:?} \
                     (sweep_fig5_scaling|sweep_speedup_wan) — or pass --file"
                ))
            }
        },
    };
    if let Some(v) = args.get("workers") {
        spec.workers = v
            .parse::<usize>()
            .ok()
            .filter(|w| *w >= 1)
            .ok_or_else(|| format!("--workers expects a positive integer, got {v:?}"))?;
    }
    let r = run_sweep(&spec)?;
    let axes: Vec<String> = r.axes.iter().map(|(k, v)| format!("{k}[{}]", v.len())).collect();
    println!(
        "sweep {}: {} points over {} ({} workers)",
        r.name,
        r.records.len(),
        axes.join(" x "),
        r.workers
    );
    for rec in &r.records {
        let assignment: Vec<String> =
            rec.axes.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let mut extras = String::new();
        if let Some(s) = rec.speedup {
            extras.push_str(&format!("  speedup {s:.2}x"));
        }
        if let Some(rc) = rec.recall {
            extras.push_str(&format!("  recall {rc:.2}"));
        }
        if let Some(p99) = rec.worst_p99_ms {
            extras.push_str(&format!("  worst p99 {p99:.1} ms"));
        }
        println!(
            "  #{:<3} {:<44} makespan {:>10}{extras}  [{}]",
            rec.index,
            assignment.join(","),
            fmt_duration_secs(rec.makespan_secs),
            rec.fingerprint
        );
    }
    println!("  grid fingerprint {}", r.grid_fingerprint);
    let default_out = format!("{}.json", r.name);
    let out = args.str_or("out", &default_out);
    r.write(out).map_err(|e| format!("write {out}: {e}"))?;
    println!("  report           {out}");
    Ok(())
}

fn cmd_quickstart(args: &Args) -> Result<(), String> {
    use sector_sphere::sphere::{run_job, FaultPlan, GrepOp, JobSpec, Stream};
    let cluster = build_cluster(args)?;
    let ip = "10.0.0.20".parse().unwrap();
    let cloud = &cluster.cloud;
    for (i, text) in [
        "a brown dwarf candidate\nnothing here\n",
        "another brown dwarf\nblue giant\n",
    ]
    .iter()
    .enumerate()
    {
        let lines: Vec<&str> = text.split_inclusive('\n').collect();
        let lengths: Vec<u64> = lines.iter().map(|l| l.len() as u64).collect();
        let idx = sector_sphere::sector::RecordIndex::from_lengths(&lengths);
        cloud.upload(ip, &format!("sky{i}.dat"), text.as_bytes(), Some(&idx), None)?;
    }
    let stream = Stream::from_cloud(cloud, &["sky0.dat".into(), "sky1.dat".into()])?;
    let res = run_job(
        cloud,
        &GrepOp,
        &stream,
        &JobSpec {
            params: b"brown dwarf".to_vec(),
            seg_min_bytes: 1,
            seg_max_bytes: 1024,
            ..JobSpec::default()
        },
        &FaultPlan::default(),
    )?;
    println!("quickstart: sphere.run(sky, \"grep brown dwarf\") matched:");
    for (_, rec) in res.to_client {
        print!("  {}", String::from_utf8_lossy(&rec));
    }
    Ok(())
}

//! Routing services (paper §5).  Sector interfaces with routing through
//! a narrow API so protocols can be swapped; the evaluated version used
//! Chord ([`chord`]), and the paper's "next version" sketches
//! location-aware routing for uniform/non-uniform clouds — implemented
//! here as [`LocationAware`], used by the ablation benches.

pub mod chord;

pub use chord::{hash_name, ChordRing, Id};

/// The routing-layer API Sector consumes (paper §4 step 2: "the Sector
/// Server runs a look-up inside the server network using the services
/// from the routing layer").
pub trait Router {
    /// Node responsible for a named entity's metadata.
    fn locate(&self, name: &str) -> Option<Id>;
    /// Route cost in overlay hops from `from` (for latency accounting).
    fn hops(&self, from: Id, name: &str) -> u32;
    fn node_count(&self) -> usize;
}

impl Router for ChordRing {
    fn locate(&self, name: &str) -> Option<Id> {
        self.owner_of(name)
    }

    fn hops(&self, from: Id, name: &str) -> u32 {
        self.lookup(from, hash_name(name)).map(|(_, h)| h).unwrap_or(0)
    }

    fn node_count(&self) -> usize {
        self.len()
    }
}

/// The paper's §5 "next version": specialized routing for clouds where
/// bandwidth/RTT between clusters is known — a one-hop directory that
/// prefers replicas in the requester's own site.  (Used in ablations to
/// quantify what Chord's multi-hop lookups cost.)
#[derive(Clone, Debug, Default)]
pub struct LocationAware {
    /// node id -> site index
    pub node_site: Vec<usize>,
    /// name ownership: a simple deterministic map (hash mod n).
    pub nodes: Vec<Id>,
}

impl LocationAware {
    pub fn new(nodes: Vec<Id>, node_site: Vec<usize>) -> Self {
        assert_eq!(nodes.len(), node_site.len());
        Self { node_site, nodes }
    }
}

impl Router for LocationAware {
    fn locate(&self, name: &str) -> Option<Id> {
        if self.nodes.is_empty() {
            return None;
        }
        let idx = (hash_name(name) % self.nodes.len() as u64) as usize;
        Some(self.nodes[idx])
    }

    fn hops(&self, _from: Id, _name: &str) -> u32 {
        1 // directory lookup
    }

    fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chord_implements_router() {
        let ring = ChordRing::build(&[10, 20, 30]);
        let owner = ring.locate("angle-0001.pcap").unwrap();
        assert!(ring.contains(owner));
        assert!(ring.hops(10, "angle-0001.pcap") >= 1);
        assert_eq!(ring.node_count(), 3);
    }

    #[test]
    fn location_aware_is_single_hop() {
        let r = LocationAware::new(vec![1, 2, 3], vec![0, 0, 1]);
        assert!(r.locate("x").is_some());
        assert_eq!(r.hops(1, "x"), 1);
        let empty = LocationAware::default();
        assert!(empty.locate("x").is_none());
    }
}

//! Chord peer-to-peer routing (Stoica et al., SIGCOMM'01) — the routing
//! layer used by the version of Sector evaluated in the paper (§5):
//! "a peer-to-peer routing protocol (the Chord protocol) is used so that
//! nodes can be easily added and removed from the system."
//!
//! Identifiers live in a 64-bit ring; a key is owned by its *successor*
//! (first node clockwise at or after the key).  Lookups walk finger
//! tables greedily and take O(log n) hops; `lookup` returns the hop
//! count so the benches can report routing cost.

use std::collections::BTreeMap;

/// 64-bit ring id.
pub type Id = u64;

pub const M: usize = 64; // bits in the identifier space

/// FNV-1a 64-bit — the name → ring-id hash (no crypto needed here; we
/// only require uniformity and determinism).
pub fn hash_name(name: &str) -> Id {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Is `x` in the half-open ring interval (a, b]?
#[inline]
pub fn in_interval_oc(x: Id, a: Id, b: Id) -> bool {
    if a < b {
        x > a && x <= b
    } else if a > b {
        x > a || x <= b
    } else {
        true // full circle: single-node ring owns everything
    }
}

/// Is `x` in the open ring interval (a, b)?
#[inline]
pub fn in_interval_oo(x: Id, a: Id, b: Id) -> bool {
    if a < b {
        x > a && x < b
    } else if a > b {
        x > a || x < b
    } else {
        x != a
    }
}

#[derive(Clone, Debug)]
struct Node {
    /// finger[i] = successor(id + 2^i); entry 0 is the immediate successor.
    finger: Vec<Id>,
    predecessor: Id,
}

/// The ring: a registry of live nodes with per-node finger state.
/// (In the deployed system each node holds only its own row; the ring
/// struct is the omniscient test/sim container, with per-node state kept
/// faithfully separate so lookups only use node-local information.)
#[derive(Clone, Debug, Default)]
pub struct ChordRing {
    nodes: BTreeMap<Id, Node>,
}

impl ChordRing {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a ring from node ids, fully stabilized.
    pub fn build(ids: &[Id]) -> Self {
        let mut ring = Self::new();
        for &id in ids {
            ring.nodes.insert(
                id,
                Node {
                    finger: vec![id; M],
                    predecessor: id,
                },
            );
        }
        ring.rebuild_all_fingers();
        ring
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node_ids(&self) -> impl Iterator<Item = Id> + '_ {
        self.nodes.keys().copied()
    }

    pub fn contains(&self, id: Id) -> bool {
        self.nodes.contains_key(&id)
    }

    /// Ground truth: the first live node at or after `key` on the ring.
    pub fn naive_successor(&self, key: Id) -> Option<Id> {
        if self.nodes.is_empty() {
            return None;
        }
        self.nodes
            .range(key..)
            .next()
            .map(|(id, _)| *id)
            .or_else(|| self.nodes.keys().next().copied())
    }

    fn rebuild_all_fingers(&mut self) {
        let ids: Vec<Id> = self.nodes.keys().copied().collect();
        for &id in &ids {
            let mut finger = Vec::with_capacity(M);
            for i in 0..M {
                let start = id.wrapping_add(1u64.wrapping_shl(i as u32));
                finger.push(self.naive_successor(start).unwrap());
            }
            let pred = self.naive_predecessor(id);
            let n = self.nodes.get_mut(&id).unwrap();
            n.finger = finger;
            n.predecessor = pred;
        }
    }

    fn naive_predecessor(&self, id: Id) -> Id {
        self.nodes
            .range(..id)
            .next_back()
            .map(|(i, _)| *i)
            .or_else(|| self.nodes.keys().next_back().copied())
            .unwrap()
    }

    /// Join a node and re-stabilize. (The deployed protocol stabilizes
    /// lazily; the model stabilizes eagerly, which is the fixed point the
    /// lazy protocol converges to.)
    pub fn join(&mut self, id: Id) {
        self.nodes.insert(
            id,
            Node {
                finger: vec![id; M],
                predecessor: id,
            },
        );
        self.rebuild_all_fingers();
    }

    /// Remove a node (leave or failure) and re-stabilize.
    pub fn leave(&mut self, id: Id) -> bool {
        let removed = self.nodes.remove(&id).is_some();
        if removed && !self.nodes.is_empty() {
            self.rebuild_all_fingers();
        }
        removed
    }

    /// Finger-table lookup from `start_node`: returns (owner, hops).
    /// Each hop uses only the current node's own finger table, exactly
    /// as the distributed protocol would.
    pub fn lookup(&self, start_node: Id, key: Id) -> Option<(Id, u32)> {
        if self.nodes.is_empty() {
            return None;
        }
        assert!(self.contains(start_node), "lookup from unknown node");
        let mut current = start_node;
        let mut hops = 0u32;
        loop {
            let node = &self.nodes[&current];
            let successor = node.finger[0];
            if in_interval_oc(key, current, successor) {
                return Some((successor, hops + 1));
            }
            // closest preceding finger
            let mut next = current;
            for i in (0..M).rev() {
                let f = node.finger[i];
                if in_interval_oo(f, current, key) {
                    next = f;
                    break;
                }
            }
            if next == current {
                // fingers degenerate (e.g. 1-node ring): successor owns it
                return Some((successor, hops + 1));
            }
            current = next;
            hops += 1;
            debug_assert!(hops as usize <= 2 * M, "lookup did not converge");
            if hops as usize > 2 * M {
                return None;
            }
        }
    }

    /// Owner of a named entity (hash + successor).
    pub fn owner_of(&self, name: &str) -> Option<Id> {
        self.naive_successor(hash_name(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn ring_of(n: usize, seed: u64) -> (ChordRing, Vec<Id>) {
        let mut rng = Pcg64::new(seed);
        let mut ids: Vec<Id> = (0..n).map(|_| rng.next_u64()).collect();
        ids.sort_unstable();
        ids.dedup();
        (ChordRing::build(&ids), ids)
    }

    #[test]
    fn hash_is_stable_and_spread() {
        assert_eq!(hash_name("file01.dat"), hash_name("file01.dat"));
        assert_ne!(hash_name("file01.dat"), hash_name("file02.dat"));
    }

    #[test]
    fn intervals_wraparound() {
        assert!(in_interval_oc(5, 3, 7));
        assert!(!in_interval_oc(3, 3, 7));
        assert!(in_interval_oc(7, 3, 7));
        // wrapped: (u64::MAX-1, 2]
        assert!(in_interval_oc(0, u64::MAX - 1, 2));
        assert!(in_interval_oc(u64::MAX, u64::MAX - 1, 2));
        assert!(!in_interval_oo(2, u64::MAX - 1, 2));
    }

    #[test]
    fn lookup_matches_naive_successor() {
        let (ring, ids) = ring_of(50, 1);
        let mut rng = Pcg64::new(2);
        for _ in 0..500 {
            let key = rng.next_u64();
            let start = ids[rng.gen_range(ids.len() as u64) as usize];
            let (owner, _) = ring.lookup(start, key).unwrap();
            assert_eq!(owner, ring.naive_successor(key).unwrap());
        }
    }

    #[test]
    fn hop_count_is_logarithmic() {
        let (ring, ids) = ring_of(256, 3);
        let mut rng = Pcg64::new(4);
        let mut max_hops = 0;
        for _ in 0..300 {
            let key = rng.next_u64();
            let start = ids[rng.gen_range(ids.len() as u64) as usize];
            let (_, hops) = ring.lookup(start, key).unwrap();
            max_hops = max_hops.max(hops);
        }
        // log2(256) = 8; allow slack for the greedy walk.
        assert!(max_hops <= 16, "max hops {max_hops}");
    }

    #[test]
    fn single_node_ring_owns_everything() {
        let ring = ChordRing::build(&[42]);
        assert_eq!(ring.lookup(42, 0).unwrap().0, 42);
        assert_eq!(ring.lookup(42, u64::MAX).unwrap().0, 42);
    }

    #[test]
    fn join_and_leave_preserve_correctness() {
        let (mut ring, _) = ring_of(16, 5);
        ring.join(12345);
        assert!(ring.contains(12345));
        let mut rng = Pcg64::new(6);
        for _ in 0..100 {
            let key = rng.next_u64();
            let (owner, _) = ring.lookup(12345, key).unwrap();
            assert_eq!(owner, ring.naive_successor(key).unwrap());
        }
        assert!(ring.leave(12345));
        assert!(!ring.leave(12345), "double-leave is a no-op");
        let start = ring.node_ids().next().unwrap();
        for _ in 0..100 {
            let key = rng.next_u64();
            let (owner, _) = ring.lookup(start, key).unwrap();
            assert_eq!(owner, ring.naive_successor(key).unwrap());
        }
    }

    #[test]
    fn join_repairs_successor_and_fingers() {
        // Every row of per-node state must equal the stabilized fixed
        // point after a join: finger[i] = successor(id + 2^i) and the
        // predecessor link closes the ring around the newcomer.
        let (mut ring, ids) = ring_of(16, 8);
        let newcomer = 0x5eed_0000_dead_beef;
        assert!(!ids.contains(&newcomer));
        ring.join(newcomer);
        for id in ring.node_ids().collect::<Vec<_>>() {
            let node = &ring.nodes[&id];
            for (i, &f) in node.finger.iter().enumerate() {
                let start = id.wrapping_add(1u64.wrapping_shl(i as u32));
                assert_eq!(
                    f,
                    ring.naive_successor(start).unwrap(),
                    "node {id:#x} finger {i} stale after join"
                );
            }
            assert_eq!(node.predecessor, ring.naive_predecessor(id));
        }
        // The key just below the newcomer now belongs to it.
        assert_eq!(
            ring.naive_successor(newcomer.wrapping_sub(1)).unwrap(),
            newcomer
        );
    }

    #[test]
    fn concurrent_leave_and_join_converge() {
        // One maintenance round sees a departure AND an arrival (the
        // churn expansion schedules both at the same instant when
        // rejoin_secs lines up).  Whatever the order, the ring must
        // stabilize to the membership set — and match a ring built
        // from scratch with that membership.
        let (ring0, ids) = ring_of(12, 9);
        let gone = ids[5];
        let newcomer = 0x0c0f_fee0_0c0f_fee0;
        let mut a = ring0.clone();
        a.leave(gone);
        a.join(newcomer);
        let mut b = ring0.clone();
        b.join(newcomer);
        b.leave(gone);
        let want: Vec<Id> = a.node_ids().collect();
        assert_eq!(want, b.node_ids().collect::<Vec<_>>());
        let fresh = ChordRing::build(&want);
        let mut rng = Pcg64::new(10);
        for _ in 0..200 {
            let key = rng.next_u64();
            let start = want[rng.gen_range(want.len() as u64) as usize];
            let (oa, _) = a.lookup(start, key).unwrap();
            let (ob, _) = b.lookup(start, key).unwrap();
            assert_eq!(oa, ob, "leave/join order changed ownership");
            assert_eq!(oa, fresh.naive_successor(key).unwrap());
        }
    }

    #[test]
    fn rejoin_of_departed_id_restores_the_ring() {
        // A churned node comes back under its SAME ring id (the churn
        // plan re-joins the same slave name): the ring must be
        // indistinguishable from one that never saw the departure.
        let (mut ring, ids) = ring_of(10, 11);
        let before = format!("{ring:?}");
        let victim = ids[4];
        assert!(ring.leave(victim));
        assert!(!ring.contains(victim));
        ring.join(victim);
        assert_eq!(ring.len(), ids.len());
        assert_eq!(format!("{ring:?}"), before, "rejoin must restore all state");
    }

    #[test]
    fn keys_redistribute_on_leave() {
        let (mut ring, ids) = ring_of(8, 7);
        let victim = ids[3];
        let key = victim.wrapping_sub(1); // owned by victim
        assert_eq!(ring.naive_successor(key).unwrap(), victim);
        ring.leave(victim);
        let new_owner = ring.naive_successor(key).unwrap();
        assert_ne!(new_owner, victim);
        assert!(ring.contains(new_owner));
    }
}

//! The networking layer (paper §5): UDT for bulk data, TCP as the
//! baseline's transport, GMP for control messages, and the connection
//! cache.  Sector keeps routing and transport behind narrow APIs so
//! either can be swapped — mirrored here by `TransportKind` +
//! `rate_cap_for` which the simulator calls for every flow.

pub mod cache;
pub mod gmp;
pub mod tcp;
pub mod udt;

pub use cache::ConnectionCache;
pub use gmp::{Datagram, DatagramKind, GmpEndpoint};
pub use tcp::TcpModel;
pub use udt::{UdtCc, UdtModel};

use crate::config::TransportKind;

/// Flow-level transport parameters for a simulated data channel.
#[derive(Clone, Copy, Debug)]
pub struct TransportModels {
    pub udt: UdtModel,
    pub tcp: TcpModel,
}

impl Default for TransportModels {
    fn default() -> Self {
        Self {
            udt: UdtModel::default(),
            tcp: TcpModel::default(),
        }
    }
}

impl TransportModels {
    /// Rate cap (bytes/s) a bulk flow of `kind` sustains on a path whose
    /// bottleneck link has `bottleneck_bps` and round-trip time `rtt`.
    pub fn rate_cap_for(&self, kind: TransportKind, bottleneck_bps: f64, rtt_secs: f64) -> f64 {
        match kind {
            TransportKind::Udt => self.udt.rate_cap(bottleneck_bps),
            TransportKind::Tcp => self.tcp.rate_cap(bottleneck_bps, rtt_secs),
        }
    }

    /// Setup transient for a new logical transfer.
    pub fn setup_secs_for(&self, kind: TransportKind, rtt_secs: f64, cached: bool) -> f64 {
        match kind {
            TransportKind::Udt => self.udt.setup_secs(rtt_secs, cached),
            TransportKind::Tcp => self.tcp.setup_secs(rtt_secs, cached),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn udt_beats_tcp_on_wan_paths() {
        let m = TransportModels::default();
        let link = 1.25e9;
        for rtt in [0.016, 0.055, 0.071] {
            let udt = m.rate_cap_for(TransportKind::Udt, link, rtt);
            let tcp = m.rate_cap_for(TransportKind::Tcp, link, rtt);
            assert!(udt > 10.0 * tcp, "rtt={rtt}: udt={udt} tcp={tcp}");
        }
    }

    #[test]
    fn both_fill_lan_paths() {
        let m = TransportModels::default();
        let link = 1.25e9;
        let udt = m.rate_cap_for(TransportKind::Udt, link, 0.0001);
        let tcp = m.rate_cap_for(TransportKind::Tcp, link, 0.0001);
        assert!(udt > 0.8 * link);
        assert!(tcp > 0.8 * link);
    }
}

//! UDT: UDP-based Data Transfer protocol (Gu & Grossman, Computer
//! Networks 51(7), 2007) — Sector's data-channel transport.
//!
//! Two views of the protocol live here:
//!
//! 1. `UdtCc` — a faithful packet-level model of UDT's DAIMD rate
//!    control (the published increase formula and the 1/9 multiplicative
//!    decrease), stepped per SYN interval (10 ms).  Unit tests use it to
//!    establish the property the paper relies on: UDT converges to near
//!    link capacity *independent of RTT*, unlike TCP.
//! 2. `UdtModel` — the flow-level abstraction the simulator consumes: an
//!    effective rate cap for a bulk flow plus a startup transient, both
//!    derived from `UdtCc`'s behaviour.

/// UDT constants from the reference implementation.
pub const SYN_SECS: f64 = 0.01;
/// Packet size used for rate accounting (1500-byte MTU minus headers).
pub const PACKET_BYTES: f64 = 1456.0;

/// Packet-level DAIMD rate controller (one sender).
#[derive(Clone, Debug)]
pub struct UdtCc {
    /// Estimated link capacity, packets/s (UDT probes this with packet
    /// pairs; the model takes it as given).
    pub link_pps: f64,
    /// Current sending rate, packets/s.
    pub rate_pps: f64,
}

impl UdtCc {
    pub fn new(link_bps: f64) -> Self {
        Self {
            link_pps: link_bps / 8.0 / PACKET_BYTES * 8.0, // bytes/s -> pkt/s
            rate_pps: 1.0 / SYN_SECS,                      // slow start floor
        }
    }

    /// The UDT increase step per SYN when no loss was observed:
    ///   inc = max( 10^(ceil(log10((L - C) * PS * 8))) * beta / PS, 1/PS )
    /// packets per SYN, with beta = 1.5e-6, L the link capacity and C the
    /// current rate (both in packets/s converted to bits/s via PS*8).
    pub fn on_syn_no_loss(&mut self) {
        let l_bps = self.link_pps * PACKET_BYTES * 8.0;
        let c_bps = self.rate_pps * PACKET_BYTES * 8.0;
        let spare = (l_bps - c_bps).max(1.0);
        let beta = 1.5e-6;
        let inc_pkts = ((10f64.powf(spare.log10().ceil()) * beta) / PACKET_BYTES)
            .max(1.0 / PACKET_BYTES);
        self.rate_pps += inc_pkts / SYN_SECS;
        self.rate_pps = self.rate_pps.min(self.link_pps);
    }

    /// Multiplicative decrease on a loss event (NAK): rate *= 8/9.
    pub fn on_loss(&mut self) {
        self.rate_pps *= 1.0 - 1.0 / 9.0;
    }

    pub fn rate_bps(&self) -> f64 {
        self.rate_pps * PACKET_BYTES // bytes/s
    }

    /// Step the controller for `secs` of simulated time with a Bernoulli
    /// loss probability per SYN interval; returns mean achieved rate in
    /// bytes/s. RTT intentionally does NOT appear: UDT's control loop is
    /// clocked by SYN, not by RTT — this is the crux of its WAN advantage.
    pub fn run(&mut self, secs: f64, loss_per_syn: f64, rng: &mut crate::util::rng::Pcg64) -> f64 {
        let steps = (secs / SYN_SECS).ceil() as usize;
        let mut acc = 0.0;
        for _ in 0..steps {
            if rng.next_f64() < loss_per_syn {
                self.on_loss();
            } else {
                self.on_syn_no_loss();
            }
            acc += self.rate_bps() * SYN_SECS;
        }
        acc / secs
    }
}

/// Flow-level UDT parameters consumed by the simulator.
#[derive(Clone, Copy, Debug)]
pub struct UdtModel {
    /// Fraction of bottleneck capacity a bulk UDT flow sustains
    /// (protocol efficiency; the paper measured ~8.1 Gb/s of 10 Gb/s
    /// moving SDSS data => ~0.81, with 6 parallel servers; a single
    /// tuned flow reaches ~0.9 — we default between the two).
    pub efficiency: f64,
    /// Connection handshake round trips (UDT uses one).
    pub handshake_rtts: f64,
    /// Effective seconds lost to rate ramp-up (SYN-clocked, so
    /// RTT-independent; UdtCc reaches 90% of a 10 Gb/s link in ~7.5 s,
    /// which costs a long bulk flow roughly half that in lost bytes).
    pub startup_secs: f64,
}

impl Default for UdtModel {
    fn default() -> Self {
        Self {
            efficiency: 0.87,
            handshake_rtts: 1.0,
            startup_secs: 3.5,
        }
    }
}

impl UdtModel {
    /// Effective rate cap (bytes/s) for a bulk flow whose narrowest link
    /// has `bottleneck_bps` capacity. RTT-independent by design.
    pub fn rate_cap(&self, bottleneck_bps: f64) -> f64 {
        self.efficiency * bottleneck_bps
    }

    /// One-time cost before the flow reaches steady state: handshake
    /// (skipped on a cached connection) + rate ramp.
    pub fn setup_secs(&self, rtt_secs: f64, cached_connection: bool) -> f64 {
        let hs = if cached_connection {
            0.0
        } else {
            self.handshake_rtts * rtt_secs
        };
        hs + self.startup_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn converges_near_capacity_lossless() {
        let link = 1.25e9; // 10 Gb/s in bytes/s
        let mut cc = UdtCc::new(link);
        let mut rng = Pcg64::new(1);
        cc.run(20.0, 0.0, &mut rng);
        assert!(
            cc.rate_bps() > 0.9 * link,
            "rate {} of {link}",
            cc.rate_bps()
        );
    }

    #[test]
    fn rtt_does_not_appear_in_control_loop() {
        // The API has no RTT parameter at all (the DAIMD loop is clocked
        // by the fixed 10 ms SYN); this test documents the convergence
        // time on a 10 Gb/s link, ~7-8 s to 90% with the published
        // increase formula — regardless of path RTT.
        let link = 1.25e9;
        let mut cc = UdtCc::new(link);
        let mut rng = Pcg64::new(2);
        let mut t = 0.0;
        while cc.rate_bps() < 0.9 * link && t < 30.0 {
            cc.run(0.1, 0.0, &mut rng);
            t += 0.1;
        }
        assert!((5.0..12.0).contains(&t), "took {t} s to reach 90% of 10 Gb/s");
    }

    #[test]
    fn loss_reduces_but_does_not_collapse_throughput() {
        let link = 1.25e9;
        let mut rng = Pcg64::new(3);
        let mut cc = UdtCc::new(link);
        cc.run(5.0, 0.0, &mut rng); // warm
        let clean = cc.run(10.0, 0.0, &mut rng);
        let mut cc2 = UdtCc::new(link);
        cc2.run(5.0, 0.0, &mut rng);
        let lossy = cc2.run(10.0, 0.02, &mut rng); // 2 losses/s
        assert!(lossy < clean);
        assert!(
            lossy > 0.4 * clean,
            "UDT should degrade gracefully: {lossy} vs {clean}"
        );
    }

    #[test]
    fn decrease_factor_is_one_ninth() {
        let mut cc = UdtCc::new(1.25e9);
        cc.rate_pps = 900.0;
        cc.on_loss();
        assert!((cc.rate_pps - 800.0).abs() < 1e-9);
    }

    #[test]
    fn model_caps_and_setup() {
        let m = UdtModel::default();
        let cap = m.rate_cap(1.25e9);
        assert!(cap > 1.0e9 && cap < 1.25e9);
        let fresh = m.setup_secs(0.055, false);
        let cached = m.setup_secs(0.055, true);
        assert!(fresh > cached);
        assert!((fresh - cached - 0.055).abs() < 1e-12);
    }
}

//! GMP — the Group Messaging Protocol (paper §5): Sector's control-plane
//! messaging layer, "a specialized network transport protocol we
//! developed for this purpose".  Sector uses GMP for lookups, job
//! control and SPE progress acknowledgments; bulk data rides UDT.
//!
//! This is a real, runnable implementation over an in-memory datagram
//! fabric (the same trait the real-mode cluster threads use): reliable
//! delivery via sequence numbers + retransmission, duplicate
//! suppression, and per-peer FIFO ordering.  The simulator uses the
//! message-count/latency accounting; real mode uses the actual codec.

use std::collections::{HashMap, VecDeque};

/// Wire header: (src, dst, seq, kind). Payload is opaque bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Datagram {
    pub src: u32,
    pub dst: u32,
    pub seq: u64,
    pub kind: DatagramKind,
    pub payload: Vec<u8>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatagramKind {
    Msg,
    Ack,
}

/// Encode to bytes (fixed 21-byte header + payload). Hand-rolled: the
/// offline environment has no serde, and GMP's framing is tiny.
pub fn encode(d: &Datagram) -> Vec<u8> {
    let mut out = Vec::with_capacity(21 + d.payload.len());
    out.extend_from_slice(&d.src.to_le_bytes());
    out.extend_from_slice(&d.dst.to_le_bytes());
    out.extend_from_slice(&d.seq.to_le_bytes());
    out.push(match d.kind {
        DatagramKind::Msg => 0,
        DatagramKind::Ack => 1,
    });
    out.extend_from_slice(&(d.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&d.payload);
    out
}

pub fn decode(bytes: &[u8]) -> Result<Datagram, String> {
    if bytes.len() < 21 {
        return Err(format!("datagram too short: {} bytes", bytes.len()));
    }
    let src = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    let dst = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let seq = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let kind = match bytes[16] {
        0 => DatagramKind::Msg,
        1 => DatagramKind::Ack,
        k => return Err(format!("bad datagram kind {k}")),
    };
    let len = u32::from_le_bytes(bytes[17..21].try_into().unwrap()) as usize;
    if bytes.len() != 21 + len {
        return Err(format!("length mismatch: header {len}, actual {}", bytes.len() - 21));
    }
    Ok(Datagram {
        src,
        dst,
        seq,
        kind,
        payload: bytes[21..].to_vec(),
    })
}

/// One GMP endpoint. Drive it with `send`/`on_datagram`/`tick`; it emits
/// outbound datagrams through the queue returned by each call.
pub struct GmpEndpoint {
    pub node: u32,
    next_seq: HashMap<u32, u64>,
    /// Per-peer next expected sequence for delivery.
    expected: HashMap<u32, u64>,
    /// Out-of-order stash per peer: seq -> payload.
    stash: HashMap<u32, HashMap<u64, Vec<u8>>>,
    /// Unacked outbound messages: (dst, seq) -> (payload, last_send_time).
    unacked: HashMap<(u32, u64), (Vec<u8>, f64)>,
    /// Retransmission timeout, seconds.
    pub rto: f64,
    /// Messages ready for the application, in order.
    pub delivered: VecDeque<(u32, Vec<u8>)>,
    /// Counters.
    pub sent_msgs: u64,
    pub retransmits: u64,
    pub dup_drops: u64,
}

impl GmpEndpoint {
    pub fn new(node: u32, rto: f64) -> Self {
        Self {
            node,
            next_seq: HashMap::new(),
            expected: HashMap::new(),
            stash: HashMap::new(),
            unacked: HashMap::new(),
            rto,
            delivered: VecDeque::new(),
            sent_msgs: 0,
            retransmits: 0,
            dup_drops: 0,
        }
    }

    /// Queue a reliable message to `dst`; returns the datagram to put on
    /// the wire.
    pub fn send(&mut self, now: f64, dst: u32, payload: Vec<u8>) -> Datagram {
        let seq = self.next_seq.entry(dst).or_insert(0);
        let d = Datagram {
            src: self.node,
            dst,
            seq: *seq,
            kind: DatagramKind::Msg,
            payload: payload.clone(),
        };
        self.unacked.insert((dst, *seq), (payload, now));
        *seq += 1;
        self.sent_msgs += 1;
        d
    }

    /// Process an inbound datagram; returns any datagrams to send back
    /// (acks), delivering application messages into `self.delivered`.
    pub fn on_datagram(&mut self, d: Datagram) -> Vec<Datagram> {
        debug_assert_eq!(d.dst, self.node, "datagram routed to wrong node");
        match d.kind {
            DatagramKind::Ack => {
                self.unacked.remove(&(d.src, d.seq));
                vec![]
            }
            DatagramKind::Msg => {
                let ack = Datagram {
                    src: self.node,
                    dst: d.src,
                    seq: d.seq,
                    kind: DatagramKind::Ack,
                    payload: vec![],
                };
                let expected = self.expected.entry(d.src).or_insert(0);
                if d.seq < *expected {
                    self.dup_drops += 1; // retransmitted duplicate
                    return vec![ack];
                }
                let stash = self.stash.entry(d.src).or_default();
                stash.insert(d.seq, d.payload);
                // Deliver any now-contiguous run.
                while let Some(p) = stash.remove(expected) {
                    self.delivered.push_back((d.src, p));
                    *expected += 1;
                }
                vec![ack]
            }
        }
    }

    /// Retransmit anything unacked past the RTO. Returns datagrams.
    pub fn tick(&mut self, now: f64) -> Vec<Datagram> {
        let mut out = Vec::new();
        let mut keys: Vec<(u32, u64)> = self.unacked.keys().copied().collect();
        keys.sort_unstable();
        for key in keys {
            let (payload, last) = self.unacked.get_mut(&key).unwrap();
            if now - *last >= self.rto {
                *last = now;
                self.retransmits += 1;
                out.push(Datagram {
                    src: self.node,
                    dst: key.0,
                    seq: key.1,
                    kind: DatagramKind::Msg,
                    payload: payload.clone(),
                });
            }
        }
        out
    }

    pub fn unacked_count(&self) -> usize {
        self.unacked.len()
    }

    /// Pop the next in-order application message, if any.
    pub fn recv(&mut self) -> Option<(u32, Vec<u8>)> {
        self.delivered.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_roundtrip() {
        let d = Datagram {
            src: 3,
            dst: 9,
            seq: 42,
            kind: DatagramKind::Msg,
            payload: b"locate sdss23.dat".to_vec(),
        };
        assert_eq!(decode(&encode(&d)).unwrap(), d);
        assert!(decode(&[1, 2, 3]).is_err());
        let mut bad = encode(&d);
        bad[16] = 7;
        assert!(decode(&bad).is_err());
        let mut truncated = encode(&d);
        truncated.pop();
        assert!(decode(&truncated).is_err());
    }

    #[test]
    fn in_order_delivery() {
        let mut a = GmpEndpoint::new(1, 1.0);
        let mut b = GmpEndpoint::new(2, 1.0);
        let d1 = a.send(0.0, 2, b"m1".to_vec());
        let d2 = a.send(0.0, 2, b"m2".to_vec());
        let acks = b.on_datagram(d1);
        b.on_datagram(d2);
        assert_eq!(b.recv(), Some((1, b"m1".to_vec())));
        assert_eq!(b.recv(), Some((1, b"m2".to_vec())));
        assert_eq!(b.recv(), None);
        for ack in acks {
            a.on_datagram(ack);
        }
        assert_eq!(a.unacked_count(), 1); // m2's ack wasn't delivered
    }

    #[test]
    fn reordering_is_repaired() {
        let mut a = GmpEndpoint::new(1, 1.0);
        let mut b = GmpEndpoint::new(2, 1.0);
        let d1 = a.send(0.0, 2, b"first".to_vec());
        let d2 = a.send(0.0, 2, b"second".to_vec());
        b.on_datagram(d2); // arrives out of order
        assert_eq!(b.recv(), None, "cannot deliver 'second' before 'first'");
        b.on_datagram(d1);
        assert_eq!(b.recv(), Some((1, b"first".to_vec())));
        assert_eq!(b.recv(), Some((1, b"second".to_vec())));
    }

    #[test]
    fn lost_message_retransmits_and_dedups() {
        let mut a = GmpEndpoint::new(1, 0.5);
        let mut b = GmpEndpoint::new(2, 0.5);
        let d = a.send(0.0, 2, b"ping".to_vec());
        // First copy is "lost". RTO passes; tick retransmits.
        assert!(a.tick(0.2).is_empty(), "before RTO nothing resends");
        let re = a.tick(0.6);
        assert_eq!(re.len(), 1);
        assert_eq!(a.retransmits, 1);
        // Both the original (late) and the retransmit arrive.
        let ack1 = b.on_datagram(d);
        let ack2 = b.on_datagram(re[0].clone());
        assert_eq!(b.recv(), Some((1, b"ping".to_vec())));
        assert_eq!(b.recv(), None, "duplicate suppressed");
        assert_eq!(b.dup_drops, 1);
        a.on_datagram(ack1[0].clone());
        a.on_datagram(ack2[0].clone());
        assert_eq!(a.unacked_count(), 0);
        assert!(a.tick(5.0).is_empty(), "acked messages never resend");
    }

    #[test]
    fn independent_peers_do_not_block_each_other() {
        let mut a = GmpEndpoint::new(1, 1.0);
        let mut b = GmpEndpoint::new(2, 1.0);
        let mut c = GmpEndpoint::new(3, 1.0);
        let to_b = a.send(0.0, 2, b"to-b".to_vec());
        let _to_c_lost = a.send(0.0, 3, b"to-c".to_vec());
        b.on_datagram(to_b);
        assert_eq!(b.recv(), Some((1, b"to-b".to_vec())));
        assert_eq!(c.recv(), None);
    }
}

//! TCP throughput model — the transport under Hadoop's shuffle and the
//! contrast case for UDT (paper §5: "TCP flows ... use the bandwidth
//! they require", but window growth limits them on long fat pipes).
//!
//! Per-stream steady-state throughput is the minimum of:
//!   * the Mathis model  MSS/RTT * C/sqrt(p)   (loss-limited),
//!   * the window limit  wnd_max/RTT           (buffer-limited; 2008-era
//!     stacks shipped 64–256 KB default buffers, and Hadoop 0.16 did not
//!     tune them),
//!   * the link capacity.
//!
//! Aggregate transfers open several parallel streams (Hadoop's
//! `parallel.copies`), which the flow model accounts for.

/// Mathis constant for Reno-style AIMD: sqrt(3/2) ≈ 1.22.
pub const MATHIS_C: f64 = 1.22;

#[derive(Clone, Copy, Debug)]
pub struct TcpModel {
    /// Maximum segment size, bytes.
    pub mss: f64,
    /// Socket buffer / max congestion window, bytes.
    pub wnd_max: f64,
    /// Stationary loss probability on the path.
    pub loss: f64,
    /// Parallel streams per logical transfer.
    pub parallel_streams: usize,
    /// Handshake round trips (SYN/SYNACK).
    pub handshake_rtts: f64,
    /// Slow-start ramp, in RTTs, before steady state.
    pub slowstart_rtts: f64,
}

impl Default for TcpModel {
    fn default() -> Self {
        Self {
            mss: 1460.0,
            wnd_max: 256.0 * 1024.0,
            loss: 1.0e-6,
            parallel_streams: 1,
            handshake_rtts: 1.5,
            slowstart_rtts: 12.0,
        }
    }
}

impl TcpModel {
    /// Hadoop 0.16 shuffle fetcher defaults (mapred.reduce.parallel.copies
    /// = 5; untuned 2008 socket buffers).
    pub fn hadoop_shuffle() -> Self {
        Self {
            parallel_streams: 5,
            ..Self::default()
        }
    }

    /// Steady-state throughput of ONE stream in bytes/s.
    pub fn stream_rate(&self, bottleneck_bps: f64, rtt_secs: f64) -> f64 {
        if rtt_secs <= 0.0 {
            return bottleneck_bps;
        }
        let mathis = self.mss / rtt_secs * MATHIS_C / self.loss.sqrt();
        let window = self.wnd_max / rtt_secs;
        mathis.min(window).min(bottleneck_bps)
    }

    /// Effective rate cap of a logical transfer using the configured
    /// parallel streams (bytes/s).
    pub fn rate_cap(&self, bottleneck_bps: f64, rtt_secs: f64) -> f64 {
        (self.stream_rate(bottleneck_bps, rtt_secs) * self.parallel_streams as f64)
            .min(bottleneck_bps)
    }

    /// Connection setup + slow-start transient, seconds.
    pub fn setup_secs(&self, rtt_secs: f64, cached_connection: bool) -> f64 {
        let hs = if cached_connection {
            0.0
        } else {
            self.handshake_rtts * rtt_secs
        };
        hs + self.slowstart_rtts * rtt_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GBPS10: f64 = 1.25e9; // bytes/s

    #[test]
    fn lan_tcp_fills_the_pipe() {
        let m = TcpModel::default();
        // 0.1 ms rack RTT: window limit = 256 KiB / 1e-4 = 2.6 GB/s >> link
        let r = m.rate_cap(GBPS10, 0.0001);
        assert!(r > 0.9 * GBPS10, "rate {r}");
    }

    #[test]
    fn wan_tcp_is_window_limited() {
        let m = TcpModel::default();
        // 55 ms RTT: window limit = 256 KiB / 0.055 ≈ 4.8 MB/s per stream.
        let r = m.stream_rate(GBPS10, 0.055);
        assert!(r < 5.0e6, "rate {r}");
        assert!(r > 1.0e6);
        // This is the paper's structural asymmetry: UDT ~0.87 * link vs
        // TCP orders of magnitude below it on the same 10 Gb/s WAN path.
        let udt = super::super::udt::UdtModel::default().rate_cap(GBPS10);
        assert!(udt / r > 100.0);
    }

    #[test]
    fn parallel_streams_multiply_until_link() {
        let m = TcpModel {
            parallel_streams: 8,
            ..TcpModel::default()
        };
        let one = m.stream_rate(GBPS10, 0.016);
        let agg = m.rate_cap(GBPS10, 0.016);
        assert!((agg - (one * 8.0).min(GBPS10)).abs() < 1.0);
        // On a LAN the aggregate saturates at the link, not 8x the link.
        assert!(m.rate_cap(GBPS10, 0.00005) <= GBPS10);
    }

    #[test]
    fn loss_limits_kick_in_when_loss_is_high() {
        let lossy = TcpModel {
            loss: 1e-2,
            ..TcpModel::default()
        };
        let clean = TcpModel::default();
        let r_lossy = lossy.stream_rate(GBPS10, 0.016);
        let r_clean = clean.stream_rate(GBPS10, 0.016);
        assert!(r_lossy < r_clean / 10.0);
    }

    #[test]
    fn setup_scales_with_rtt_and_caching() {
        let m = TcpModel::default();
        assert!(m.setup_secs(0.055, false) > m.setup_secs(0.055, true));
        assert!(m.setup_secs(0.071, false) > m.setup_secs(0.016, false));
        assert_eq!(m.setup_secs(0.0, true), 0.0);
    }

    #[test]
    fn zero_rtt_degenerates_to_link() {
        let m = TcpModel::default();
        assert_eq!(m.stream_rate(GBPS10, 0.0), GBPS10);
    }
}

//! Connection cache (paper §4): "Sector also caches data connections.
//! Therefore, frequent data transfers between the same pair of nodes do
//! not need to set up a data connection every time."
//!
//! The cache tracks live connections per (src, dst) pair with an LRU
//! eviction bound and an idle timeout; `acquire` reports whether the
//! caller pays connection-setup cost.  Both the simulator (time
//! accounting) and the real-mode cluster (actual channel reuse) consult
//! it.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PairKey {
    pub src: u32,
    pub dst: u32,
}

#[derive(Clone, Debug)]
struct Entry {
    last_used: f64,
    uses: u64,
}

#[derive(Clone, Debug)]
pub struct ConnectionCache {
    entries: HashMap<PairKey, Entry>,
    /// Maximum live connections (Sector bounds per-node FDs).
    pub capacity: usize,
    /// Idle timeout, seconds.
    pub idle_timeout: f64,
    /// Disable switch (ablation lever).
    pub enabled: bool,
    pub hits: u64,
    pub misses: u64,
}

impl ConnectionCache {
    pub fn new(capacity: usize, idle_timeout: f64) -> Self {
        assert!(capacity > 0);
        Self {
            entries: HashMap::new(),
            capacity,
            idle_timeout,
            enabled: true,
            hits: 0,
            misses: 0,
        }
    }

    /// Acquire a connection src->dst at time `now`. Returns true when an
    /// existing (cached, un-expired) connection is reused — i.e. the
    /// caller does NOT pay setup.
    pub fn acquire(&mut self, now: f64, src: u32, dst: u32) -> bool {
        if !self.enabled {
            self.misses += 1;
            return false;
        }
        let key = PairKey { src, dst };
        let hit = match self.entries.get(&key) {
            Some(e) => now - e.last_used <= self.idle_timeout,
            None => false,
        };
        if hit {
            let e = self.entries.get_mut(&key).unwrap();
            e.last_used = now;
            e.uses += 1;
            self.hits += 1;
        } else {
            self.misses += 1;
            self.evict_if_full(now);
            self.entries.insert(
                key,
                Entry {
                    last_used: now,
                    uses: 1,
                },
            );
        }
        hit
    }

    /// Drop every entry idle past the timeout as of `now`.
    pub fn purge_expired(&mut self, now: f64) {
        self.entries
            .retain(|_, e| now - e.last_used <= self.idle_timeout);
    }

    fn evict_if_full(&mut self, now: f64) {
        // Drop expired entries first, then LRU if still at capacity.
        self.purge_expired(now);
        while self.entries.len() >= self.capacity {
            let lru = self
                .entries
                .iter()
                .min_by(|a, b| {
                    a.1.last_used
                        .partial_cmp(&b.1.last_used)
                        .unwrap()
                        .then(a.0.cmp(b.0))
                })
                .map(|(k, _)| *k)
                .unwrap();
            self.entries.remove(&lru);
        }
    }

    /// Connections still live at time `now`.  Expired entries are
    /// purged first: they used to linger until the next miss-path
    /// eviction, so this over-reported between misses (a node's FD
    /// budget looked consumed by connections that were already gone).
    pub fn live_connections(&mut self, now: f64) -> usize {
        self.purge_expired(now);
        self.entries.len()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_acquire_hits() {
        let mut c = ConnectionCache::new(8, 60.0);
        assert!(!c.acquire(0.0, 1, 2));
        assert!(c.acquire(1.0, 1, 2));
        assert!(!c.acquire(1.0, 2, 1), "direction matters");
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 2);
        assert!((c.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn idle_timeout_expires() {
        let mut c = ConnectionCache::new(8, 10.0);
        c.acquire(0.0, 1, 2);
        assert!(c.acquire(9.9, 1, 2));
        assert!(!c.acquire(30.0, 1, 2), "expired after idle timeout");
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let mut c = ConnectionCache::new(2, 1e9);
        c.acquire(0.0, 1, 10);
        c.acquire(1.0, 1, 11);
        c.acquire(2.0, 1, 12); // evicts (1,10)
        assert!(c.live_connections(2.0) <= 2);
        assert!(!c.acquire(3.0, 1, 10), "evicted pair must reconnect");
        assert!(c.acquire(4.0, 1, 12));
    }

    #[test]
    fn live_connections_purges_expired_entries() {
        // Regression: expired entries were only purged on the miss
        // path, so live_connections over-reported between misses.
        let mut c = ConnectionCache::new(8, 10.0);
        c.acquire(0.0, 1, 2);
        c.acquire(1.0, 3, 4);
        assert_eq!(c.live_connections(1.0), 2);
        assert_eq!(c.live_connections(50.0), 0, "both idled out");
        assert!(!c.acquire(51.0, 1, 2), "expired pair must reconnect");
        assert_eq!(c.live_connections(51.0), 1);
    }

    #[test]
    fn disabled_cache_never_hits() {
        let mut c = ConnectionCache::new(8, 60.0);
        c.enabled = false;
        assert!(!c.acquire(0.0, 1, 2));
        assert!(!c.acquire(1.0, 1, 2));
        assert_eq!(c.hits, 0);
    }
}

//! Typed configuration for clusters, systems and benchmarks.
//!
//! A TOML-subset file (`config::toml`) can override any field; defaults
//! are the calibrated constants described in DESIGN.md §3.
//! Calibration rule: hardware constants are fitted ONLY to the paper's
//! single-node, single-site table cells; all scaling behaviour must
//! emerge from the simulation.

pub mod toml;

use crate::util::bytes::{parse_bytes, GB, MB};
pub use toml::{Table, Value};

/// Per-node hardware description (one entry per testbed generation).
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareSpec {
    /// Physical cores per node.
    pub cores: usize,
    /// Sequential disk read bandwidth, bytes/s.
    pub disk_read_bps: f64,
    /// Sequential disk write bandwidth, bytes/s.
    pub disk_write_bps: f64,
    /// Per-op seek cost, seconds.
    pub disk_seek_secs: f64,
    /// NIC line rate, bytes/s.
    pub nic_bps: f64,
    /// Memory per node, bytes (bounds in-memory sort buffers).
    pub mem_bytes: u64,
}

impl HardwareSpec {
    /// The 2008 WAN servers: double dual-core 2.4 GHz Opteron, 4 GB RAM,
    /// 10GE NIC, 2 TB disk array.  Disk rates fitted to the Table 1
    /// single-node column (905 s Sphere Terasort, 110 s Terasplit).
    pub fn wan_opteron() -> Self {
        Self {
            cores: 4,
            disk_read_bps: 90.0e6,
            disk_write_bps: 72.0e6,
            disk_seek_secs: 0.008,
            nic_bps: 10.0e9 / 8.0,
            mem_bytes: 4 * GB,
        }
    }

    /// The newer LAN rack servers: dual quad-core 2.4 GHz Xeon, 16 GB
    /// RAM, 10GE NIC, 5.5 TB disk.  Write rate fitted to the §6.3
    /// file-generation measurement (10 GB in 68 s ≈ 147 MB/s ≈ 1.1 Gb/s).
    pub fn lan_xeon() -> Self {
        Self {
            cores: 8,
            disk_read_bps: 180.0e6,
            disk_write_bps: 147.0e6,
            disk_seek_secs: 0.006,
            nic_bps: 10.0e9 / 8.0,
            mem_bytes: 16 * GB,
        }
    }

    pub fn from_table(t: &Table, section: &str, default: HardwareSpec) -> Self {
        let k = |name: &str| format!("{section}.{name}");
        Self {
            cores: t.int_or(&k("cores"), default.cores as i64) as usize,
            disk_read_bps: t.float_or(&k("disk_read_bps"), default.disk_read_bps),
            disk_write_bps: t.float_or(&k("disk_write_bps"), default.disk_write_bps),
            disk_seek_secs: t.float_or(&k("disk_seek_secs"), default.disk_seek_secs),
            nic_bps: t.float_or(&k("nic_bps"), default.nic_bps),
            mem_bytes: t.int_or(&k("mem_bytes"), default.mem_bytes as i64) as u64,
        }
    }
}

/// Per-core software processing rates (bytes/s) — the CPU side of the
/// calibration (DESIGN.md §3).  Fitted to the paper's
/// single-node cells only.
#[derive(Clone, Debug, PartialEq)]
pub struct CpuRates {
    /// Bucket-partitioning a record stream (hash + emit), per core.
    pub partition_bps: f64,
    /// In-memory record sort (Sphere's stage-B UDF), per core.
    pub sort_bps: f64,
    /// Terasplit entropy scan at the client, per core.
    pub scan_bps: f64,
    /// Hadoop map-side record handling (Java stream stack), per core.
    pub hadoop_map_bps: f64,
    /// Hadoop sort/merge, per core.
    pub hadoop_sort_bps: f64,
}

impl CpuRates {
    /// 2.4 GHz Opteron (WAN testbed generation).
    pub fn wan_opteron() -> Self {
        Self {
            partition_bps: 250.0e6,
            sort_bps: 48.0e6,
            scan_bps: 120.0e6,
            hadoop_map_bps: 55.0e6,
            hadoop_sort_bps: 28.0e6,
        }
    }

    /// 2.4 GHz Xeon (LAN rack generation; same clock, better memory).
    pub fn lan_xeon() -> Self {
        Self {
            partition_bps: 300.0e6,
            sort_bps: 47.0e6,
            scan_bps: 105.0e6,
            hadoop_map_bps: 70.0e6,
            hadoop_sort_bps: 35.0e6,
        }
    }
}

/// Sector storage-cloud parameters (paper §4).
#[derive(Clone, Debug, PartialEq)]
pub struct SectorParams {
    /// Target replica count per file.
    pub replicas: usize,
    /// Replica-count check period (paper: once per day).
    pub check_interval_secs: f64,
    /// Cache data connections between node pairs (paper §4).
    pub connection_cache: bool,
}

impl Default for SectorParams {
    fn default() -> Self {
        Self {
            replicas: 2,
            check_interval_secs: 86_400.0,
            connection_cache: true,
        }
    }
}

/// Service-layer parameters: how a slave admits and serves client
/// traffic (DESIGN.md §10).
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceParams {
    /// Concurrent transfers one slave serves; beyond this, requests
    /// queue (they share the disk link while active).
    pub slots_per_slave: usize,
    /// Bounded per-slave admission queue, all tenants combined.  A
    /// request finding every live replica's queue full is rejected —
    /// overload sheds instead of queueing without limit.
    pub queue_capacity: usize,
    /// Client-side metadata cache TTL, seconds (§4 step 2 short-cut).
    pub meta_ttl_secs: f64,
    /// Client-side metadata cache capacity, entries per session.
    pub meta_cache_entries: usize,
    /// Node-pair data-connection cache size and idle timeout (§4).
    pub conn_cache_entries: usize,
    pub conn_idle_secs: f64,
}

impl Default for ServiceParams {
    fn default() -> Self {
        Self {
            slots_per_slave: 4,
            queue_capacity: 64,
            meta_ttl_secs: 60.0,
            meta_cache_entries: 8,
            conn_cache_entries: 4096,
            conn_idle_secs: 600.0,
        }
    }
}

/// Sphere compute-cloud parameters (paper §3.2).
#[derive(Clone, Debug, PartialEq)]
pub struct SphereParams {
    /// Minimum data-segment size handed to one SPE.
    pub seg_min_bytes: u64,
    /// Maximum data-segment size handed to one SPE.
    pub seg_max_bytes: u64,
    /// SPEs started per node (paper's Terasort used 1 of 4 cores).
    pub spes_per_node: usize,
    /// Fraction of disk I/O overlapped with computation in the UDF loop
    /// (double-buffered read/process/write pipeline).
    pub io_overlap: f64,
    /// Effective fraction of raw disk bandwidth the Sphere data path
    /// achieves (indexing + record framing overhead).
    pub io_efficiency: f64,
    /// Enable locality-aware segment assignment (ablation lever).
    pub locality_scheduling: bool,
    /// Segment retry budget (assignments + speculative backups); a
    /// segment exhausting it is an explicit job failure (§3.2 fault
    /// handling).
    pub max_attempts: u32,
}

impl Default for SphereParams {
    fn default() -> Self {
        Self {
            seg_min_bytes: 8 * MB,
            seg_max_bytes: 256 * MB,
            spes_per_node: 1,
            io_overlap: 0.55,
            io_efficiency: 0.92,
            locality_scheduling: true,
            max_attempts: 4,
        }
    }
}

/// Hadoop 0.16 baseline parameters (paper §2, §6).
#[derive(Clone, Debug, PartialEq)]
pub struct HadoopParams {
    /// HDFS block size (paper used 128 MB, up from the 64 MB default).
    pub block_bytes: u64,
    /// Input-data replication for the baseline engine's block map.
    /// Stock HDFS defaults to 3; the head-to-head keeps 2 so both
    /// systems carry the same redundancy as the Sector deployment and
    /// survive the same crash plans (DESIGN.md §12).
    pub replication_in: usize,
    /// Output replication during job writes (dfs.replication).
    pub replication_out: usize,
    /// Concurrent map tasks per TaskTracker
    /// (mapred.tasktracker.map.tasks.maximum; 0.16 default 2).
    pub map_slots: usize,
    /// Concurrent reduce tasks per TaskTracker (0.16 default 2).
    pub reduce_slots: usize,
    /// Per-task JVM startup + scheduling latency, seconds.
    pub task_startup_secs: f64,
    /// Effective fraction of raw disk bandwidth through the Java stream
    /// stack (checksumming, serialization, JVM) for local-FS I/O
    /// (map spills, merges).
    pub io_efficiency: f64,
    /// Effective fraction for writes through the HDFS client pipeline
    /// (chunked checksums + pipelined acks; §6.3 measured 440 Mb/s vs
    /// the disk's ~1.2 Gb/s).
    pub hdfs_write_efficiency: f64,
    /// Extra merge passes over map output before reduce.
    pub merge_passes: f64,
    /// Cores used per node (paper: Hadoop used all 4).
    pub cores_used: usize,
    /// Fraction of map-output bytes crossing the network in the shuffle
    /// (1 - locality of reducers; 1.0 - 1/n for uniform partitioning).
    pub shuffle_http_overhead: f64,
}

impl Default for HadoopParams {
    fn default() -> Self {
        Self {
            block_bytes: 128 * MB,
            replication_in: 2,
            replication_out: 1,
            map_slots: 2,
            reduce_slots: 2,
            task_startup_secs: 1.2,
            io_efficiency: 0.48,
            hdfs_write_efficiency: 0.32,
            merge_passes: 1.0,
            cores_used: 4,
            shuffle_http_overhead: 1.15,
        }
    }
}

/// Transport protocol selection for data channels (ablation lever; the
/// paper's Sector uses UDT, Hadoop uses TCP).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    Udt,
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "udt" => Ok(TransportKind::Udt),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(format!("unknown transport {other:?} (udt|tcp)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Udt => "udt",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Everything a simulated run needs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub hardware: HardwareSpec,
    pub cpu: CpuRates,
    pub sector: SectorParams,
    pub sphere: SphereParams,
    pub hadoop: HadoopParams,
    pub service: ServiceParams,
    pub sphere_transport: TransportKind,
    pub seed: u64,
}

impl SimConfig {
    pub fn wan_default() -> Self {
        Self {
            hardware: HardwareSpec::wan_opteron(),
            cpu: CpuRates::wan_opteron(),
            sector: SectorParams::default(),
            sphere: SphereParams::default(),
            hadoop: HadoopParams::default(),
            service: ServiceParams::default(),
            sphere_transport: TransportKind::Udt,
            seed: 20080824, // KDD'08 began Aug 24 2008; any fixed seed works
        }
    }

    pub fn lan_default() -> Self {
        Self {
            hardware: HardwareSpec::lan_xeon(),
            cpu: CpuRates::lan_xeon(),
            ..Self::wan_default()
        }
    }

    /// Look up a hardware generation by name — the `[hardware] profile`
    /// key of scenario configs ("wan" = 2008 Opterons, "lan" = the newer
    /// Xeon rack).
    pub fn profile(name: &str) -> Result<Self, String> {
        match name.to_ascii_lowercase().as_str() {
            "wan" => Ok(Self::wan_default()),
            "lan" => Ok(Self::lan_default()),
            other => Err(format!("unknown hardware profile {other:?} (wan|lan)")),
        }
    }

    /// Apply overrides from a parsed config file.
    pub fn apply_table(mut self, t: &Table) -> Result<Self, String> {
        self.hardware = HardwareSpec::from_table(t, "hardware", self.hardware);
        self.sector.replicas = t.int_or("sector.replicas", self.sector.replicas as i64) as usize;
        self.sector.check_interval_secs =
            t.float_or("sector.check_interval_secs", self.sector.check_interval_secs);
        self.sector.connection_cache =
            t.bool_or("sector.connection_cache", self.sector.connection_cache);
        if let Some(v) = t.get("sphere.seg_min") {
            self.sphere.seg_min_bytes =
                parse_bytes(v.as_str().ok_or("sphere.seg_min must be a string")?)?;
        }
        if let Some(v) = t.get("sphere.seg_max") {
            self.sphere.seg_max_bytes =
                parse_bytes(v.as_str().ok_or("sphere.seg_max must be a string")?)?;
        }
        self.sphere.spes_per_node =
            t.int_or("sphere.spes_per_node", self.sphere.spes_per_node as i64) as usize;
        self.sphere.locality_scheduling =
            t.bool_or("sphere.locality_scheduling", self.sphere.locality_scheduling);
        self.sphere.max_attempts =
            t.int_or("sphere.max_attempts", self.sphere.max_attempts as i64).max(1) as u32;
        if let Some(v) = t.get("hadoop.block") {
            self.hadoop.block_bytes =
                parse_bytes(v.as_str().ok_or("hadoop.block must be a string")?)?;
        }
        self.hadoop.replication_in =
            t.int_or("hadoop.replication_in", self.hadoop.replication_in as i64).max(1) as usize;
        self.hadoop.replication_out =
            t.int_or("hadoop.replication_out", self.hadoop.replication_out as i64) as usize;
        self.hadoop.map_slots =
            t.int_or("hadoop.map_slots", self.hadoop.map_slots as i64).max(1) as usize;
        self.hadoop.reduce_slots =
            t.int_or("hadoop.reduce_slots", self.hadoop.reduce_slots as i64).max(1) as usize;
        self.hadoop.cores_used =
            t.int_or("hadoop.cores_used", self.hadoop.cores_used as i64) as usize;
        self.service.slots_per_slave =
            t.int_or("service.slots_per_slave", self.service.slots_per_slave as i64) as usize;
        self.service.queue_capacity =
            t.int_or("service.queue_capacity", self.service.queue_capacity as i64) as usize;
        self.service.meta_ttl_secs =
            t.float_or("service.meta_ttl_secs", self.service.meta_ttl_secs);
        self.service.meta_cache_entries = t.int_or(
            "service.meta_cache_entries",
            self.service.meta_cache_entries as i64,
        ) as usize;
        self.service.conn_cache_entries = t.int_or(
            "service.conn_cache_entries",
            self.service.conn_cache_entries as i64,
        ) as usize;
        self.service.conn_idle_secs =
            t.float_or("service.conn_idle_secs", self.service.conn_idle_secs);
        if let Some(v) = t.get("sphere.transport") {
            self.sphere_transport =
                TransportKind::parse(v.as_str().ok_or("sphere.transport must be a string")?)?;
        }
        self.seed = t.int_or("seed", self.seed as i64) as u64;
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = SimConfig::wan_default();
        assert_eq!(c.hardware.cores, 4);
        assert!(c.sphere.seg_min_bytes < c.sphere.seg_max_bytes);
        assert_eq!(c.hadoop.block_bytes, 128 * MB);
        assert_eq!(c.hadoop.map_slots, 2, "0.16 TaskTracker defaults");
        assert_eq!(c.hadoop.reduce_slots, 2);
        assert_eq!(c.hadoop.replication_in, 2, "matched to Sector's replica count");
        assert_eq!(c.sphere_transport, TransportKind::Udt);
        let l = SimConfig::lan_default();
        assert_eq!(l.hardware.cores, 8);
        assert!(l.hardware.disk_write_bps > c.hardware.disk_write_bps);
    }

    #[test]
    fn table_overrides() {
        let t = Table::parse(
            r#"
            seed = 7
            [hardware]
            cores = 16
            [sector]
            replicas = 3
            [sphere]
            seg_min = "16MB"
            transport = "tcp"
            [hadoop]
            block = "64MB"
            map_slots = 4
            replication_in = 3
            "#,
        )
        .unwrap();
        let c = SimConfig::wan_default().apply_table(&t).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.hardware.cores, 16);
        assert_eq!(c.sector.replicas, 3);
        assert_eq!(c.sphere.seg_min_bytes, 16 * MB);
        assert_eq!(c.sphere_transport, TransportKind::Tcp);
        assert_eq!(c.hadoop.block_bytes, 64 * MB);
        assert_eq!(c.hadoop.map_slots, 4);
        assert_eq!(c.hadoop.replication_in, 3);
    }

    #[test]
    fn service_overrides_apply() {
        let c = SimConfig::lan_default();
        assert_eq!(c.service.slots_per_slave, 4);
        assert_eq!(c.service.queue_capacity, 64);
        let t = Table::parse(
            "[service]\nslots_per_slave = 8\nqueue_capacity = 16\nmeta_ttl_secs = 5.0",
        )
        .unwrap();
        let c = c.apply_table(&t).unwrap();
        assert_eq!(c.service.slots_per_slave, 8);
        assert_eq!(c.service.queue_capacity, 16);
        assert_eq!(c.service.meta_ttl_secs, 5.0);
        assert_eq!(c.service.meta_cache_entries, 8, "untouched fields keep defaults");
    }

    #[test]
    fn max_attempts_overrides_and_clamps() {
        assert_eq!(SimConfig::lan_default().sphere.max_attempts, 4);
        let t = Table::parse("[sphere]\nmax_attempts = 2").unwrap();
        let c = SimConfig::lan_default().apply_table(&t).unwrap();
        assert_eq!(c.sphere.max_attempts, 2);
        // Zero would make every segment an instant job failure.
        let t = Table::parse("[sphere]\nmax_attempts = 0").unwrap();
        let c = SimConfig::lan_default().apply_table(&t).unwrap();
        assert_eq!(c.sphere.max_attempts, 1, "clamped to >= 1");
    }

    #[test]
    fn profiles_resolve_by_name() {
        assert_eq!(SimConfig::profile("wan").unwrap().hardware.cores, 4);
        assert_eq!(SimConfig::profile("LAN").unwrap().hardware.cores, 8);
        assert!(SimConfig::profile("cloud9").is_err());
    }

    #[test]
    fn bad_transport_rejected() {
        let t = Table::parse("[sphere]\ntransport = \"carrier-pigeon\"").unwrap();
        assert!(SimConfig::wan_default().apply_table(&t).is_err());
        assert!(TransportKind::parse("UDT").is_ok());
    }
}

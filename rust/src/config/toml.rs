//! Minimal TOML-subset parser (offline environment: no serde/toml crates).
//!
//! Supports what the cluster/job config files need:
//!   * `[section]` and `[section.sub]` headers
//!   * `key = "string" | 123 | 4.5 | true | [1, 2, 3] | ["a", "b"]`
//!   * `#` comments, blank lines
//!
//! Values are kept as a small dynamic enum; typed accessors live on
//! `Table`.  Errors carry the line number.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed config: dotted-path -> value ("section.key").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Table {
    pub fn parse(text: &str) -> Result<Table, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ParseError {
                        line: lineno,
                        msg: "empty section name".into(),
                    });
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ParseError {
                    line: lineno,
                    msg: "empty key".into(),
                });
            }
            let val = parse_value(line[eq + 1..].trim(), lineno)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(path.clone(), val).is_some() {
                return Err(ParseError {
                    line: lineno,
                    msg: format!("duplicate key {path:?}"),
                });
            }
        }
        Ok(Table { entries })
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// All keys under a section prefix ("sector." ...).
    pub fn section_keys<'a>(&'a self, section: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&prefix))
            .map(String::as_str)
    }

    /// Reject typo'd config: every key directly under `section` must
    /// be in `allowed`, and every nested subsection must be named in
    /// `allowed_subsections` (whose own keys are NOT checked here —
    /// call again per subsection).  A misspelled key must error, not
    /// silently become a default.
    pub fn check_known_keys(
        &self,
        section: &str,
        allowed: &[&str],
        allowed_subsections: &[&str],
    ) -> Result<(), String> {
        let prefix = format!("{section}.");
        for key in self.section_keys(section) {
            let rest = key.strip_prefix(&prefix).unwrap_or(key);
            if let Some((sub, _)) = rest.split_once('.') {
                if allowed_subsections.contains(&sub) {
                    continue;
                }
                return Err(format!("[{section}]: unknown subsection {sub:?}"));
            }
            if !allowed.contains(&rest) {
                return Err(format!(
                    "[{section}]: unknown field {rest:?} (expected one of {allowed:?})"
                ));
            }
        }
        Ok(())
    }

    /// Immediate child section names under `section`, sorted and
    /// deduplicated.  `[faults.crash1]` / `[faults.slow2]` headers give
    /// `subsections("faults") == ["crash1", "slow2"]` — how scenario
    /// configs enumerate their fault plan (scenario::ScenarioSpec).
    pub fn subsections(&self, section: &str) -> Vec<String> {
        let prefix = format!("{section}.");
        let mut out: Vec<String> = Vec::new();
        for k in self.entries.keys() {
            if let Some(rest) = k.strip_prefix(&prefix) {
                if let Some((child, _)) = rest.split_once('.') {
                    out.push(child.to_string());
                }
            }
        }
        // BTreeMap keys are sorted, so duplicates are adjacent.
        out.dedup();
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let err = |msg: String| ParseError { line, msg };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(format!("unterminated string {s:?}")))?;
        if inner.contains('"') {
            return Err(err("embedded quote in string".into()));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(format!("unterminated array {s:?}")))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim(), line))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("unrecognized value {s:?}")))
}

/// Split an array body on commas not inside quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let t = Table::parse(
            r#"
            # cluster file
            name = "wan"
            [sector]
            replicas = 2
            check_interval_secs = 86400.0
            p2p = true
            [sphere]
            smin = "8MB"   # parsed by util::bytes later
            "#,
        )
        .unwrap();
        assert_eq!(t.str_or("name", "?"), "wan");
        assert_eq!(t.int_or("sector.replicas", 0), 2);
        assert_eq!(t.float_or("sector.check_interval_secs", 0.0), 86400.0);
        assert!(t.bool_or("sector.p2p", false));
        assert_eq!(t.str_or("sphere.smin", "?"), "8MB");
        assert_eq!(t.int_or("missing", 7), 7);
    }

    #[test]
    fn parses_arrays() {
        let t = Table::parse(r#"rtt = [16.0, 55.0, 71.0]
names = ["chicago", "pasadena"]"#)
            .unwrap();
        let rtt = t.get("rtt").unwrap().as_array().unwrap();
        assert_eq!(rtt.len(), 3);
        assert_eq!(rtt[1].as_float(), Some(55.0));
        let names = t.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[0].as_str(), Some("chicago"));
    }

    #[test]
    fn int_vs_float() {
        let t = Table::parse("a = 3\nb = 3.5\nc = 1_000_000").unwrap();
        assert_eq!(t.get("a").unwrap().as_int(), Some(3));
        assert_eq!(t.get("a").unwrap().as_float(), Some(3.0));
        assert_eq!(t.get("b").unwrap().as_int(), None);
        assert_eq!(t.get("c").unwrap().as_int(), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let t = Table::parse(r##"s = "a#b" # trailing"##).unwrap();
        assert_eq!(t.str_or("s", "?"), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Table::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Table::parse("[unclosed").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Table::parse("x = \"abc").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Table::parse("x = 1\nx = 2").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn section_keys_enumerate() {
        let t = Table::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = t.section_keys("a").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }

    #[test]
    fn check_known_keys_catches_typos() {
        let t = Table::parse("[s]\ngood = 1\n[s.sub]\nx = 2").unwrap();
        assert!(t.check_known_keys("s", &["good"], &["sub"]).is_ok());
        let e = t.check_known_keys("s", &["other"], &["sub"]).unwrap_err();
        assert!(e.contains("good"), "{e}");
        let e = t.check_known_keys("s", &["good"], &[]).unwrap_err();
        assert!(e.contains("sub"), "{e}");
        assert!(t.check_known_keys("missing", &[], &[]).is_ok());
    }

    #[test]
    fn subsections_enumerate_children() {
        let t = Table::parse(
            "[faults.crash1]\nkind = \"crash\"\nnode = 3\n\
             [faults.slow2]\nkind = \"straggler\"\n\
             [faults]\ncount = 2\n[other.x]\ny = 1",
        )
        .unwrap();
        assert_eq!(t.subsections("faults"), vec!["crash1", "slow2"]);
        assert_eq!(t.subsections("other"), vec!["x"]);
        assert!(t.subsections("missing").is_empty());
    }
}

//! From-scratch property-based testing substrate (no `proptest` offline).
//!
//! A `Gen` produces random values from a `Pcg64`; `forall` runs a
//! property over N generated cases and, on failure, greedily shrinks the
//! failing input via the value's `Shrink` implementation before
//! panicking with the minimal counterexample and the reproducing seed.
//!
//! Usage:
//! ```ignore
//! testkit::forall("segment covers stream", 200, gen, |case| { ...; Ok(()) });
//! ```

use crate::util::rng::Pcg64;

/// A generator of random test cases.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;
}

impl<T, F: Fn(&mut Pcg64) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Pcg64) -> T {
        self(rng)
    }
}

/// Types that can propose strictly "smaller" variants of themselves.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|v| v as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() > 1 {
            for i in 0..self.len().min(8) {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        }
        for i in 0..self.len().min(4) {
            for s in self[i].shrink() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone, C: Shrink + Clone> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` inputs drawn from `gen`. On failure, shrink
/// (up to 200 steps) and panic with the minimal counterexample.
pub fn forall<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: Gen<T>,
    P: Fn(&T) -> PropResult,
{
    forall_seeded(name, cases, env_seed(), gen, prop)
}

/// Default seed; override to reproduce failures with TESTKIT_SEED=<n>.
const DEFAULT_SEED: u64 = 0x5EC7_0354_1CEB_EEF1;

fn env_seed() -> u64 {
    std::env::var("TESTKIT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

pub fn forall_seeded<T, G, P>(name: &str, cases: usize, seed: u64, gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: Gen<T>,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Pcg64::new(seed);
    for case_idx in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property {name:?} failed (case {case_idx}, seed {seed}; rerun with \
                 TESTKIT_SEED={seed}):\n  error: {min_msg}\n  minimal input: {min_input:#?}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut msg: String, prop: &P) -> (T, String)
where
    T: Shrink + Clone + std::fmt::Debug,
    P: Fn(&T) -> PropResult,
{
    let mut budget = 200;
    'outer: while budget > 0 {
        for cand in input.shrink() {
            budget -= 1;
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    (input, msg)
}

// ------------------------------------------------------ stock generators

/// Uniform u64 in [lo, hi).
pub fn range_u64(lo: u64, hi: u64) -> impl Gen<u64> {
    assert!(hi > lo);
    move |rng: &mut Pcg64| lo + rng.gen_range(hi - lo)
}

/// Uniform usize in [lo, hi).
pub fn range_usize(lo: usize, hi: usize) -> impl Gen<usize> {
    assert!(hi > lo);
    move |rng: &mut Pcg64| lo + rng.gen_range((hi - lo) as u64) as usize
}

/// Uniform f64 in [lo, hi).
pub fn range_f64(lo: f64, hi: f64) -> impl Gen<f64> {
    move |rng: &mut Pcg64| rng.gen_range_f64(lo, hi)
}

/// Vec of `inner` with length in [min_len, max_len].
pub fn vec_of<T>(
    inner: impl Gen<T>,
    min_len: usize,
    max_len: usize,
) -> impl Gen<Vec<T>> {
    assert!(max_len >= min_len);
    move |rng: &mut Pcg64| {
        let n = min_len + rng.gen_range((max_len - min_len + 1) as u64) as usize;
        (0..n).map(|_| inner.generate(rng)).collect()
    }
}

/// Pair of two generators.
pub fn pair<A, B>(ga: impl Gen<A>, gb: impl Gen<B>) -> impl Gen<(A, B)> {
    move |rng: &mut Pcg64| (ga.generate(rng), gb.generate(rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        forall_seeded("u64 halves are smaller", 50, 1, range_u64(1, 1000), |&x| {
            **counter.borrow_mut() += 1;
            if x / 2 <= x {
                Ok(())
            } else {
                Err("half bigger".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            forall_seeded(
                "all values below 100",
                100,
                2,
                range_u64(0, 1_000_000),
                |&x| {
                    if x < 100 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 100"))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // shrinker should walk the failure down to exactly 100
        assert!(msg.contains("minimal input: 100"), "got: {msg}");
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let result = std::panic::catch_unwind(|| {
            forall_seeded(
                "vectors stay short",
                100,
                3,
                vec_of(range_u64(0, 10), 0, 50),
                |v: &Vec<u64>| {
                    if v.len() < 5 {
                        Ok(())
                    } else {
                        Err(format!("len {}", v.len()))
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("len 5"), "minimal failing vec has len 5: {msg}");
    }

    #[test]
    fn pair_generator_and_shrink() {
        let g = pair(range_u64(0, 10), range_f64(0.0, 1.0));
        let mut rng = Pcg64::new(4);
        let (a, b) = g.generate(&mut rng);
        assert!(a < 10 && (0.0..1.0).contains(&b));
        let shrunk = (6u64, 0.5f64).shrink();
        assert!(!shrunk.is_empty());
    }
}

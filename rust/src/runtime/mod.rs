//! PJRT runtime — the bridge from the Rust coordinator to the
//! AOT-compiled JAX/Pallas artifacts.  Python never runs here: `make
//! artifacts` lowered the L2 graphs (with the L1 Pallas kernels inside)
//! to HLO *text*, and this module loads, compiles and executes them on
//! the PJRT CPU client from the request path.
//!
//! Shapes are the AOT contract from `python/compile/model.py`; inputs
//! are padded (weight-0 / valid-0 rows) to fit.

pub mod artifact;

pub use artifact::{ArtifactShapes, Runtime};

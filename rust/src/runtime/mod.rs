//! PJRT runtime — the bridge from the Rust coordinator to the
//! AOT-compiled JAX/Pallas artifacts.  Python never runs here: `make
//! artifacts` lowered the L2 graphs (with the L1 Pallas kernels inside)
//! to HLO *text*, and this module loads, compiles and executes them on
//! the PJRT CPU client from the request path.
//!
//! Shapes are the AOT contract from `python/compile/model.py`; inputs
//! are padded (weight-0 / valid-0 rows) to fit.
//!
//! The xla-backed implementation (`artifact`) needs the vendored `xla`
//! crate and is gated behind the `pjrt` cargo feature; the default
//! offline build compiles the API-compatible `stub` whose `load` always
//! fails, so every caller falls back to its host oracle (DESIGN.md §8).

#[cfg(feature = "pjrt")]
pub mod artifact;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use artifact::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// The AOT shape contract — keep in sync with python/compile/model.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactShapes {
    pub n_points: usize,
    pub n_dim: usize,
    pub n_clusters: usize,
    pub n_labels: usize,
    pub n_classes: usize,
    pub score_batch: usize,
}

pub const SHAPES: ArtifactShapes = ArtifactShapes {
    n_points: 4096,
    n_dim: 16,
    n_clusters: 32,
    n_labels: 32768,
    n_classes: 8,
    score_batch: 256,
};

/// Locate the artifacts directory: explicit arg, `$SECTOR_ARTIFACTS`,
/// or `./artifacts` relative to the workspace root.
pub(crate) fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("SECTOR_ARTIFACTS") {
        return std::path::PathBuf::from(d);
    }
    // CARGO_MANIFEST_DIR works for tests/benches; fall back to cwd.
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_contract_matches_python() {
        assert_eq!(SHAPES.n_points, 4096);
        assert_eq!(SHAPES.n_dim, 16);
        assert_eq!(SHAPES.n_clusters, 32);
        assert_eq!(SHAPES.n_labels, 32768);
        assert_eq!(SHAPES.n_classes, 8);
        assert_eq!(SHAPES.score_batch, 256);
    }

    #[test]
    fn default_dir_resolves() {
        let d = Runtime::default_dir();
        assert!(d.ends_with("artifacts"));
    }
}

//! Artifact loading + typed execution wrappers.
//!
//! Interchange is HLO text (NOT serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::{ArtifactShapes, SHAPES};

const ARTIFACT_NAMES: [&str; 4] = ["kmeans_step", "split_gain", "delta_stat", "score"];

/// A loaded PJRT runtime holding one compiled executable per artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    pub shapes: ArtifactShapes,
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Locate the artifacts directory: explicit arg, `$SECTOR_ARTIFACTS`,
    /// or `./artifacts` relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    /// Load + compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut execs = HashMap::new();
        for name in ARTIFACT_NAMES {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parse HLO text {path:?} (run `make artifacts`?)"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            execs.insert(name.to_string(), exe);
        }
        Ok(Runtime {
            client,
            execs,
            shapes: SHAPES,
            artifact_dir: dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn lit2(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let out = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// One k-means assignment/accumulation step over up to `n_points`
    /// weighted points of dimension <= n_dim, against k <= n_clusters
    /// centers.  Inputs are padded to the contract shapes; outputs are
    /// truncated back to (k, d).  Returns (sums, counts, inertia).
    pub fn kmeans_step(
        &self,
        points: &[f32], // row-major (n, d)
        centers: &[f32], // row-major (k, d)
        d: usize,
        k: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let s = self.shapes;
        if d > s.n_dim || k > s.n_clusters {
            bail!("kmeans_step: d={d} k={k} exceed artifact contract {s:?}");
        }
        let n = points.len() / d;
        if n * d != points.len() || centers.len() != k * d {
            bail!("kmeans_step: ragged input");
        }
        if n > s.n_points {
            bail!("kmeans_step: n={n} > {} (batch the call)", s.n_points);
        }
        // Pad points -> (N_POINTS, N_DIM) with weight-0 rows; pad centers
        // -> (N_CLUSTERS, N_DIM) placing dead centers far away so no live
        // point selects them.
        let mut p = vec![0.0f32; s.n_points * s.n_dim];
        for i in 0..n {
            p[i * s.n_dim..i * s.n_dim + d].copy_from_slice(&points[i * d..(i + 1) * d]);
        }
        let mut c = vec![0.0f32; s.n_clusters * s.n_dim];
        for j in 0..s.n_clusters {
            if j < k {
                c[j * s.n_dim..j * s.n_dim + d].copy_from_slice(&centers[j * d..(j + 1) * d]);
            } else {
                c[j * s.n_dim] = 3.0e18; // unreachable sentinel center
            }
        }
        let mut w = vec![0.0f32; s.n_points];
        for wi in w.iter_mut().take(n) {
            *wi = 1.0;
        }
        let out = self.run(
            "kmeans_step",
            &[
                Self::lit2(&p, s.n_points, s.n_dim)?,
                Self::lit2(&c, s.n_clusters, s.n_dim)?,
                xla::Literal::vec1(&w),
            ],
        )?;
        let sums_full = out[0].to_vec::<f32>()?;
        let counts_full = out[1].to_vec::<f32>()?;
        let inertia = out[2].to_vec::<f32>()?[0];
        let mut sums = vec![0.0f32; k * d];
        for j in 0..k {
            sums[j * d..(j + 1) * d]
                .copy_from_slice(&sums_full[j * s.n_dim..j * s.n_dim + d]);
        }
        Ok((sums, counts_full[..k].to_vec(), inertia))
    }

    /// Best entropy split of a key-sorted class-label sequence
    /// (Terasplit's inner computation). Labels in [0, n_classes).
    /// Returns (best_gain_bits, split_index).
    pub fn split_gain(&self, class_ids: &[u8]) -> Result<(f32, usize)> {
        let s = self.shapes;
        if class_ids.len() > s.n_labels {
            bail!(
                "split_gain: {} labels > contract {} (pre-aggregate)",
                class_ids.len(),
                s.n_labels
            );
        }
        if let Some(&bad) = class_ids.iter().find(|&&c| c as usize >= s.n_classes) {
            bail!("split_gain: class id {bad} >= {}", s.n_classes);
        }
        let mut ids = vec![0.0f32; s.n_labels];
        let mut valid = vec![0.0f32; s.n_labels];
        for (i, &c) in class_ids.iter().enumerate() {
            ids[i] = c as f32;
            valid[i] = 1.0;
        }
        let out = self.run(
            "split_gain",
            &[xla::Literal::vec1(&ids), xla::Literal::vec1(&valid)],
        )?;
        let gain = out[0].to_vec::<f32>()?[0];
        let idx = out[1].to_vec::<f32>()?[0] as usize;
        Ok((gain, idx))
    }

    /// delta_j between two center sets (k <= n_clusters each).
    pub fn delta_stat(&self, a: &[f32], b: &[f32], d: usize, ka: usize, kb: usize) -> Result<f32> {
        let s = self.shapes;
        if d > s.n_dim || ka > s.n_clusters || kb > s.n_clusters {
            bail!("delta_stat: shapes exceed contract");
        }
        let pad = |src: &[f32], k: usize| {
            let mut full = vec![0.0f32; s.n_clusters * s.n_dim];
            for j in 0..k {
                full[j * s.n_dim..j * s.n_dim + d].copy_from_slice(&src[j * d..(j + 1) * d]);
            }
            let mut live = vec![0.0f32; s.n_clusters];
            for l in live.iter_mut().take(k) {
                *l = 1.0;
            }
            (full, live)
        };
        let (fa, la) = pad(a, ka);
        let (fb, lb) = pad(b, kb);
        let out = self.run(
            "delta_stat",
            &[
                Self::lit2(&fa, s.n_clusters, s.n_dim)?,
                Self::lit2(&fb, s.n_clusters, s.n_dim)?,
                xla::Literal::vec1(&la),
                xla::Literal::vec1(&lb),
            ],
        )?;
        Ok(out[0].to_vec::<f32>()?[0])
    }

    /// Emergent-behaviour scores rho(x) for up to `score_batch` feature
    /// vectors against k emergent clusters with per-cluster (sigma^2,
    /// theta, lambda).
    #[allow(clippy::too_many_arguments)]
    pub fn score(
        &self,
        x: &[f32], // (n, d)
        centers: &[f32],
        sigma2: &[f32],
        theta: &[f32],
        lam: &[f32],
        d: usize,
        k: usize,
    ) -> Result<Vec<f32>> {
        let s = self.shapes;
        let n = x.len() / d;
        if n > s.score_batch || d > s.n_dim || k > s.n_clusters {
            bail!("score: shapes exceed contract");
        }
        if sigma2.len() != k || theta.len() != k || lam.len() != k || centers.len() != k * d {
            bail!("score: ragged cluster parameters");
        }
        let mut xf = vec![0.0f32; s.score_batch * s.n_dim];
        for i in 0..n {
            xf[i * s.n_dim..i * s.n_dim + d].copy_from_slice(&x[i * d..(i + 1) * d]);
        }
        let mut cf = vec![0.0f32; s.n_clusters * s.n_dim];
        let mut s2 = vec![1.0f32; s.n_clusters];
        let mut th = vec![0.0f32; s.n_clusters];
        let mut lm = vec![0.0f32; s.n_clusters];
        let mut live = vec![0.0f32; s.n_clusters];
        for j in 0..k {
            cf[j * s.n_dim..j * s.n_dim + d].copy_from_slice(&centers[j * d..(j + 1) * d]);
            s2[j] = sigma2[j];
            th[j] = theta[j];
            lm[j] = lam[j];
            live[j] = 1.0;
        }
        let out = self.run(
            "score",
            &[
                Self::lit2(&xf, s.score_batch, s.n_dim)?,
                Self::lit2(&cf, s.n_clusters, s.n_dim)?,
                xla::Literal::vec1(&s2),
                xla::Literal::vec1(&th),
                xla::Literal::vec1(&lm),
                xla::Literal::vec1(&live),
            ],
        )?;
        Ok(out[0].to_vec::<f32>()?[..n].to_vec())
    }
}

// Runtime tests live in rust/tests/runtime_artifacts.rs (they need
// `make artifacts` to have run); contract-level checks live in the
// parent module so they run in both backend configurations.

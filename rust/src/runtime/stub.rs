//! API-compatible stand-in for the PJRT runtime, compiled when the
//! `pjrt` feature is off (the default in the offline environment —
//! the real backend needs the vendored `xla` crate; DESIGN.md §8).
//!
//! `load` always fails, so a `Runtime` value is never constructed and
//! every caller (cluster, kmeans, emergent, terasplit) takes its host
//! oracle path.  The methods still exist so the call sites typecheck
//! identically under both configurations.

use std::fmt;
use std::path::{Path, PathBuf};

use super::ArtifactShapes;

/// Error type mirroring the Display surface callers rely on
/// (`format!("{e}")` / `format!("{e:#}")`).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Stub runtime: same shape contract, no executables.
pub struct Runtime {
    pub shapes: ArtifactShapes,
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Locate the artifacts directory: explicit arg, `$SECTOR_ARTIFACTS`,
    /// or `./artifacts` relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    /// Always fails: this build carries no PJRT backend.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Err(RuntimeError(format!(
            "built without the `pjrt` feature: cannot load PJRT artifacts \
             from {dir:?}; enabling it needs vendored `xla`/`anyhow` path \
             dependencies in Cargo.toml plus `make artifacts` (DESIGN.md \
             §8) — or run without --pjrt to use the host oracles"
        )))
    }

    fn unavailable(&self, what: &str) -> RuntimeError {
        RuntimeError(format!("{what}: PJRT backend not compiled in"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn kmeans_step(
        &self,
        _points: &[f32],
        _centers: &[f32],
        _d: usize,
        _k: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        Err(self.unavailable("kmeans_step"))
    }

    pub fn split_gain(&self, _class_ids: &[u8]) -> Result<(f32, usize)> {
        Err(self.unavailable("split_gain"))
    }

    pub fn delta_stat(
        &self,
        _a: &[f32],
        _b: &[f32],
        _d: usize,
        _ka: usize,
        _kb: usize,
    ) -> Result<f32> {
        Err(self.unavailable("delta_stat"))
    }

    #[allow(clippy::too_many_arguments)]
    pub fn score(
        &self,
        _x: &[f32],
        _centers: &[f32],
        _sigma2: &[f32],
        _theta: &[f32],
        _lam: &[f32],
        _d: usize,
        _k: usize,
    ) -> Result<Vec<f32>> {
        Err(self.unavailable("score"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Runtime::load(&Runtime::default_dir()).err().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn stub_shapes_match_contract() {
        // The shape contract is shared with the real backend so code
        // written against `rt.shapes` behaves the same either way.
        assert_eq!(crate::runtime::SHAPES.n_points, 4096);
    }
}

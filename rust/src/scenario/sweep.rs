//! Deterministic parameter-sweep orchestration (DESIGN.md §17).
//!
//! The paper's headline results are curves and surfaces, not points:
//! Figs 5–6 scale node count and data size, and Table 1's
//! Sphere-vs-Hadoop comparison moves with WAN capacity.  A [`SweepSpec`]
//! takes a base scenario plus a grid of axes (the `[sweep]` TOML
//! block), expands the cartesian product into a deterministic shard
//! plan — every point carries a config fingerprint and a fixed worker
//! shard — fans the points out across worker threads (each point runs
//! the existing batch/traffic/compare/angle engine on its own
//! substrate), and aggregates one machine-readable [`SweepReport`].
//!
//! Determinism contract: the report is assembled in grid order, never
//! completion order, so the same grid always renders byte-identical
//! JSON regardless of thread scheduling.  Axes expand row-major with
//! the *last* axis fastest, in the canonical axis order `nodes`,
//! `wan_gbps`, `bytes_per_node`, `total_bytes`, `fault_intensity`,
//! `tenant_mix`, `replication_policy`, `replication_max`,
//! `churn_rate`, `weather_trace`, `transport` — the order the axes are
//! applied to the base spec (so `total_bytes` divides by the
//! already-rescaled node count).
//!
//! ```
//! use sector_sphere::scenario::sweep::SweepSpec;
//!
//! let spec = SweepSpec::from_toml(
//!     r#"
//!     name = "minimal-grid"
//!     [topology]
//!     sites = 2
//!     racks_per_site = 1
//!     nodes_per_rack = 4
//!     [workload]
//!     kind = "terasort"
//!     bytes_per_node = "1GB"
//!     [sweep]
//!     nodes = [4, 8]
//!     total_bytes = ["8GB"]
//!     "#,
//! )
//! .unwrap();
//! assert_eq!(spec.points(), 2);
//! assert_eq!(spec.plan().unwrap()[1].axes[0], ("nodes", "8".to_string()));
//! ```

use crate::config::{Table, TransportKind, Value};
use crate::routing::hash_name;
use crate::service::ScalerPolicy;
use crate::util::bytes::parse_bytes;

use super::{run_scenario, FaultSpec, ScenarioReport, ScenarioSpec};

/// Hard cap on the grid's point count: past this a "sweep" is really a
/// batch queue and should be split (also the guard that makes an
/// accidentally huge product an explicit error, not an hour of CI).
pub const MAX_POINTS: usize = 4096;

/// Worker threads used when the `[sweep]` block does not set
/// `workers`.  A fixed constant — NOT the machine's core count — so the
/// shard ids in the report are machine-independent.
pub const DEFAULT_WORKERS: usize = 4;

const GBPS: f64 = 1.0e9 / 8.0;

/// A byte quantity that remembers its spelling ("32GB"), so axis
/// labels in the report read like the TOML that produced them.
#[derive(Clone, Debug, PartialEq)]
pub struct ByteSize {
    pub bytes: f64,
    pub label: String,
}

impl ByteSize {
    pub fn parse(label: &str) -> Result<ByteSize, String> {
        Ok(ByteSize {
            bytes: parse_bytes(label)? as f64,
            label: label.to_string(),
        })
    }
}

/// One swept parameter: which knob of the base scenario varies, and
/// the values it takes.  Enum order IS the canonical application and
/// expansion order.
#[derive(Clone, Debug, PartialEq)]
pub enum Axis {
    /// Total node count; the base topology rescales uniformly (every
    /// rack gets `n / racks` nodes), so each value must divide evenly.
    Nodes(Vec<usize>),
    /// WAN uplink capacity in Gbit/s (`topology.wan_bps` override).
    WanGbps(Vec<f64>),
    /// Per-node workload size — total data grows with the node count.
    BytesPerNode(Vec<ByteSize>),
    /// Fixed total workload size — per-node data is `total / nodes`,
    /// the Fig 5–6 strong-scaling shape.  Mutually exclusive with
    /// [`Axis::BytesPerNode`].
    TotalBytes(Vec<ByteSize>),
    /// Fault-plan severity: `0` drops every fault; `k > 0` keeps
    /// crashes and raises straggler/degrade factors to the power `k`
    /// (factors live in `(0, 1]`, so larger `k` means slower).
    FaultIntensity(Vec<f64>),
    /// Tenant weight mix as colon-separated weights ("70:25:5"),
    /// applied positionally to the base `[traffic]` tenants.
    TenantMix(Vec<String>),
    /// Replica-scaler policy (`static` | `watermark`).
    ReplicationPolicy(Vec<ScalerPolicy>),
    /// Replica-count ceiling (`replication.max_replicas`).
    ReplicationMax(Vec<u32>),
    /// Churn severity: departures per 100 s (`churn.rate_per_100s`
    /// override; 0 disables the episode).  Requires a base `[churn]`
    /// block.
    ChurnRate(Vec<f64>),
    /// Weather-trace identity: the seed of the generated part of the
    /// `[weather]` trace.  Requires a base `[weather]` block.
    WeatherTrace(Vec<u64>),
    /// WAN flow-throughput model (`udt` | `tcp`) — the paper's
    /// Sector-uses-UDT / Hadoop-uses-TCP contrast as a swept axis.
    Transport(Vec<TransportKind>),
}

impl Axis {
    /// The `[sweep]` key this axis parses from (also its report label).
    pub fn key(&self) -> &'static str {
        match self {
            Axis::Nodes(_) => "nodes",
            Axis::WanGbps(_) => "wan_gbps",
            Axis::BytesPerNode(_) => "bytes_per_node",
            Axis::TotalBytes(_) => "total_bytes",
            Axis::FaultIntensity(_) => "fault_intensity",
            Axis::TenantMix(_) => "tenant_mix",
            Axis::ReplicationPolicy(_) => "replication_policy",
            Axis::ReplicationMax(_) => "replication_max",
            Axis::ChurnRate(_) => "churn_rate",
            Axis::WeatherTrace(_) => "weather_trace",
            Axis::Transport(_) => "transport",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Axis::Nodes(v) => v.len(),
            Axis::WanGbps(v) => v.len(),
            Axis::BytesPerNode(v) | Axis::TotalBytes(v) => v.len(),
            Axis::FaultIntensity(v) => v.len(),
            Axis::TenantMix(v) => v.len(),
            Axis::ReplicationPolicy(v) => v.len(),
            Axis::ReplicationMax(v) => v.len(),
            Axis::ChurnRate(v) => v.len(),
            Axis::WeatherTrace(v) => v.len(),
            Axis::Transport(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Human/JSON label of value `i` (the TOML spelling where one
    /// exists — "32GB", not "34359738368").
    pub fn label(&self, i: usize) -> String {
        match self {
            Axis::Nodes(v) => v[i].to_string(),
            Axis::WanGbps(v) => format!("{}", v[i]),
            Axis::BytesPerNode(v) | Axis::TotalBytes(v) => v[i].label.clone(),
            Axis::FaultIntensity(v) => format!("{}", v[i]),
            Axis::TenantMix(v) => v[i].clone(),
            Axis::ReplicationPolicy(v) => v[i].name().to_string(),
            Axis::ReplicationMax(v) => v[i].to_string(),
            Axis::ChurnRate(v) => format!("{}", v[i]),
            Axis::WeatherTrace(v) => v[i].to_string(),
            Axis::Transport(v) => v[i].name().to_string(),
        }
    }

    /// All value labels, in grid order.
    pub fn labels(&self) -> Vec<String> {
        (0..self.len()).map(|i| self.label(i)).collect()
    }

    /// Mutate `spec` to value `i` of this axis.
    fn apply(&self, i: usize, spec: &mut ScenarioSpec) -> Result<(), String> {
        match self {
            Axis::Nodes(v) => {
                let n = v[i];
                let racks: usize = spec.topology.sites.iter().map(|s| s.racks).sum();
                if racks == 0 || n % racks != 0 {
                    return Err(format!(
                        "sweep.nodes: {n} nodes does not divide evenly over the \
                         base topology's {racks} racks"
                    ));
                }
                let per_rack = n / racks;
                for site in &mut spec.topology.sites {
                    site.nodes_per_rack = per_rack;
                }
            }
            Axis::WanGbps(v) => spec.topology.wan_bps = v[i] * GBPS,
            Axis::BytesPerNode(v) => {
                workload_mut(spec, "sweep.bytes_per_node")?.bytes_per_node = v[i].bytes;
            }
            Axis::TotalBytes(v) => {
                // Canonical order applies `nodes` first, so this sees
                // the point's final node count.
                let nodes = spec.topology.nodes().max(1) as f64;
                workload_mut(spec, "sweep.total_bytes")?.bytes_per_node = v[i].bytes / nodes;
            }
            Axis::FaultIntensity(v) => {
                let k = v[i];
                if k == 0.0 {
                    spec.faults.clear();
                    spec.churn = None;
                    spec.weather = None;
                } else {
                    for f in &mut spec.faults {
                        match f {
                            FaultSpec::Straggler { factor, .. }
                            | FaultSpec::LinkDegrade { factor, .. }
                            | FaultSpec::WeatherSet { factor, .. } => {
                                *factor = factor.powf(k).clamp(1e-6, 1.0);
                            }
                            FaultSpec::SlaveCrash { .. }
                            | FaultSpec::NodeLeave { .. }
                            | FaultSpec::NodeJoin { .. }
                            | FaultSpec::MasterCrash { .. } => {}
                        }
                    }
                }
            }
            Axis::TenantMix(v) => {
                let weights = parse_mix(&v[i])?;
                let traffic = spec
                    .traffic
                    .as_mut()
                    .ok_or("sweep.tenant_mix: the base scenario has no [traffic] block")?;
                if weights.len() != traffic.tenants.len() {
                    return Err(format!(
                        "sweep.tenant_mix: mix {:?} has {} weights but the base \
                         scenario declares {} tenants",
                        v[i],
                        weights.len(),
                        traffic.tenants.len()
                    ));
                }
                for (tenant, w) in traffic.tenants.iter_mut().zip(&weights) {
                    tenant.weight = *w;
                }
            }
            Axis::ReplicationPolicy(v) => {
                replication_mut(spec, "sweep.replication_policy")?.policy = v[i];
            }
            Axis::ReplicationMax(v) => {
                replication_mut(spec, "sweep.replication_max")?.max_replicas = v[i];
            }
            Axis::ChurnRate(v) => {
                spec.churn
                    .as_mut()
                    .ok_or("sweep.churn_rate: the base scenario has no [churn] block")?
                    .rate_per_100s = v[i];
            }
            Axis::WeatherTrace(v) => {
                spec.weather
                    .as_mut()
                    .ok_or("sweep.weather_trace: the base scenario has no [weather] block")?
                    .seed = v[i];
            }
            Axis::Transport(v) => spec.cfg.sphere_transport = v[i],
        }
        Ok(())
    }
}

fn workload_mut<'a>(
    spec: &'a mut ScenarioSpec,
    key: &str,
) -> Result<&'a mut super::WorkloadSpec, String> {
    spec.workload
        .as_mut()
        .ok_or_else(|| format!("{key}: the base scenario has no [workload] block"))
}

fn replication_mut<'a>(
    spec: &'a mut ScenarioSpec,
    key: &str,
) -> Result<&'a mut crate::service::ReplicationSpec, String> {
    spec.replication
        .as_mut()
        .ok_or_else(|| format!("{key}: the base scenario has no [replication] block"))
}

/// Parse a "70:25:5"-style tenant weight mix.
fn parse_mix(s: &str) -> Result<Vec<f64>, String> {
    let mut out = Vec::new();
    for part in s.split(':') {
        let w: f64 = part.trim().parse().map_err(|_| {
            format!(
                "sweep.tenant_mix: {s:?} is not a colon-separated weight list \
                 (e.g. \"70:25:5\")"
            )
        })?;
        if !(w.is_finite() && w > 0.0) {
            return Err(format!("sweep.tenant_mix: weight {part:?} in {s:?} must be > 0"));
        }
        out.push(w);
    }
    Ok(out)
}

/// A base scenario plus the grid of axes swept over it (the `[sweep]`
/// TOML block).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Sweep name (`sweep.name`, defaulting to the base scenario's).
    pub name: String,
    /// The scenario every point derives from.  Its own `[trace]` block
    /// is ignored per point — hundreds of runs must not race on one
    /// artifact path (digests are still computed).
    pub base: ScenarioSpec,
    /// Worker threads for the fan-out.  Part of the spec (not probed
    /// from the machine) so the report's shard ids are reproducible.
    pub workers: usize,
    /// Axes in canonical order; the cartesian product is the grid.
    pub axes: Vec<Axis>,
}

/// One expanded grid point of the shard plan.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Row-major grid index (last axis fastest).
    pub index: usize,
    /// Worker shard this point runs on (`index % workers`).
    pub shard: usize,
    /// `(axis key, value label)` assignment, in canonical axis order.
    pub axes: Vec<(&'static str, String)>,
    /// FNV-1a over the fully materialized spec — the config
    /// fingerprint that names this point across runs and machines.
    pub fingerprint: String,
    /// The derived, validated scenario this point runs.
    pub spec: ScenarioSpec,
}

impl SweepSpec {
    /// Parse a sweep document: a normal scenario TOML plus a `[sweep]`
    /// block with at least one axis.  Validates the whole grid (every
    /// derived point included) before returning.
    pub fn from_toml(text: &str) -> Result<SweepSpec, String> {
        let t = Table::parse(text).map_err(|e| e.to_string())?;
        Self::from_table(&t)
    }

    pub fn from_table(t: &Table) -> Result<SweepSpec, String> {
        if t.section_keys("sweep").next().is_none() {
            return Err(
                "[sweep]: missing — a sweep document needs at least one axis \
                 (nodes, wan_gbps, bytes_per_node, total_bytes, fault_intensity, \
                 tenant_mix, replication_policy, replication_max, churn_rate, \
                 weather_trace, transport)"
                    .into(),
            );
        }
        t.check_known_keys(
            "sweep",
            &[
                "name",
                "workers",
                "nodes",
                "wan_gbps",
                "bytes_per_node",
                "total_bytes",
                "fault_intensity",
                "tenant_mix",
                "replication_policy",
                "replication_max",
                "churn_rate",
                "weather_trace",
                "transport",
            ],
            &[],
        )?;
        let base = ScenarioSpec::from_table_base(t)?;
        let workers = t.int_or("sweep.workers", DEFAULT_WORKERS as i64);
        if workers < 1 {
            return Err(format!("sweep.workers: must be >= 1, got {workers}"));
        }
        let mut axes = Vec::new();
        if let Some(vals) = axis_array(t, "nodes")? {
            let mut out = Vec::new();
            for v in vals {
                match v.as_int() {
                    Some(n) if n > 0 => out.push(n as usize),
                    _ => return Err("sweep.nodes: values must be positive integers".into()),
                }
            }
            axes.push(Axis::Nodes(out));
        }
        if let Some(vals) = axis_array(t, "wan_gbps")? {
            axes.push(Axis::WanGbps(positive_floats(vals, "sweep.wan_gbps")?));
        }
        for (key, total) in [("bytes_per_node", false), ("total_bytes", true)] {
            if let Some(vals) = axis_array(t, key)? {
                let mut out = Vec::new();
                for v in vals {
                    let label = v.as_str().ok_or_else(|| {
                        format!("sweep.{key}: values must be byte-size strings (e.g. \"32GB\")")
                    })?;
                    out.push(ByteSize::parse(label).map_err(|e| format!("sweep.{key}: {e}"))?);
                }
                axes.push(if total {
                    Axis::TotalBytes(out)
                } else {
                    Axis::BytesPerNode(out)
                });
            }
        }
        if let Some(vals) = axis_array(t, "fault_intensity")? {
            let mut out = Vec::new();
            for v in vals {
                match v.as_float() {
                    Some(k) if k.is_finite() && k >= 0.0 => out.push(k),
                    _ => {
                        return Err(
                            "sweep.fault_intensity: values must be numbers >= 0 \
                             (0 disables the fault plan)"
                                .into(),
                        )
                    }
                }
            }
            axes.push(Axis::FaultIntensity(out));
        }
        if let Some(vals) = axis_array(t, "tenant_mix")? {
            let mut out = Vec::new();
            for v in vals {
                let mix = v
                    .as_str()
                    .ok_or("sweep.tenant_mix: values must be strings like \"70:25:5\"")?;
                parse_mix(mix)?; // fail at parse time, not per point
                out.push(mix.to_string());
            }
            axes.push(Axis::TenantMix(out));
        }
        if let Some(vals) = axis_array(t, "replication_policy")? {
            let mut out = Vec::new();
            for v in vals {
                out.push(match v.as_str() {
                    Some("static") => ScalerPolicy::Static,
                    Some("watermark") => ScalerPolicy::Watermark,
                    other => {
                        return Err(format!(
                            "sweep.replication_policy: unknown policy {other:?} \
                             (static|watermark)"
                        ))
                    }
                });
            }
            axes.push(Axis::ReplicationPolicy(out));
        }
        if let Some(vals) = axis_array(t, "replication_max")? {
            let mut out = Vec::new();
            for v in vals {
                match v.as_int() {
                    Some(n) if n >= 1 => out.push(n as u32),
                    _ => {
                        return Err("sweep.replication_max: values must be integers >= 1".into())
                    }
                }
            }
            axes.push(Axis::ReplicationMax(out));
        }
        if let Some(vals) = axis_array(t, "churn_rate")? {
            let mut out = Vec::new();
            for v in vals {
                match v.as_float() {
                    Some(r) if r.is_finite() && r >= 0.0 => out.push(r),
                    _ => {
                        return Err(
                            "sweep.churn_rate: values must be numbers >= 0 \
                             (departures per 100 s; 0 disables the episode)"
                                .into(),
                        )
                    }
                }
            }
            axes.push(Axis::ChurnRate(out));
        }
        if let Some(vals) = axis_array(t, "weather_trace")? {
            let mut out = Vec::new();
            for v in vals {
                match v.as_int() {
                    Some(s) if s >= 0 => out.push(s as u64),
                    _ => {
                        return Err(
                            "sweep.weather_trace: values must be non-negative \
                             integer seeds"
                                .into(),
                        )
                    }
                }
            }
            axes.push(Axis::WeatherTrace(out));
        }
        if let Some(vals) = axis_array(t, "transport")? {
            let mut out = Vec::new();
            for v in vals {
                let s = v
                    .as_str()
                    .ok_or("sweep.transport: values must be strings (udt|tcp)")?;
                out.push(TransportKind::parse(s).map_err(|e| format!("sweep.transport: {e}"))?);
            }
            axes.push(Axis::Transport(out));
        }
        let spec = SweepSpec {
            name: t.str_or("sweep.name", &base.name).to_string(),
            base,
            workers: workers as usize,
            axes,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Grid size (product of axis lengths; saturating).
    pub fn points(&self) -> usize {
        self.axes.iter().map(Axis::len).fold(1usize, |a, b| a.saturating_mul(b))
    }

    fn effective_workers(&self, total: usize) -> usize {
        self.workers.max(1).min(total.max(1))
    }

    /// Structural grid checks — every error names the offending
    /// `sweep.<key>`.  Returns the point count.
    fn validate_grid(&self) -> Result<usize, String> {
        if self.workers == 0 {
            return Err("sweep.workers: must be >= 1".into());
        }
        if self.axes.is_empty() {
            return Err(
                "[sweep]: declares no axes (nodes, wan_gbps, bytes_per_node, \
                 total_bytes, fault_intensity, tenant_mix, replication_policy, \
                 replication_max, churn_rate, weather_trace, transport)"
                    .into(),
            );
        }
        let mut total: usize = 1;
        for (i, axis) in self.axes.iter().enumerate() {
            let key = axis.key();
            if self.axes[..i].iter().any(|a| a.key() == key) {
                return Err(format!("sweep.{key}: duplicate axis"));
            }
            if axis.is_empty() {
                return Err(format!("sweep.{key}: axis is empty"));
            }
            let labels = axis.labels();
            for (j, label) in labels.iter().enumerate() {
                if labels[..j].contains(label) {
                    return Err(format!("sweep.{key}: duplicate value {label}"));
                }
            }
            total = total
                .checked_mul(axis.len())
                .ok_or_else(|| "sweep: the grid's point count overflows".to_string())?;
        }
        if total > MAX_POINTS {
            return Err(format!(
                "sweep: {total} points exceeds the {MAX_POINTS}-point cap (split the grid)"
            ));
        }
        let has = |k: &str| self.axes.iter().any(|a| a.key() == k);
        if has("bytes_per_node") && has("total_bytes") {
            return Err(
                "sweep.bytes_per_node and sweep.total_bytes are mutually exclusive \
                 (per-node vs fixed-total sizing)"
                    .into(),
            );
        }
        if (has("bytes_per_node") || has("total_bytes")) && self.base.workload.is_none() {
            return Err(
                "sweep.bytes_per_node/total_bytes: the base scenario has no [workload] block"
                    .into(),
            );
        }
        if has("tenant_mix") && self.base.traffic.is_none() {
            return Err("sweep.tenant_mix: the base scenario has no [traffic] block".into());
        }
        if (has("replication_policy") || has("replication_max")) && self.base.replication.is_none()
        {
            return Err(
                "sweep.replication_policy/replication_max: the base scenario has no \
                 [replication] block"
                    .into(),
            );
        }
        if has("churn_rate") && self.base.churn.is_none() {
            return Err("sweep.churn_rate: the base scenario has no [churn] block".into());
        }
        if has("weather_trace") && self.base.weather.is_none() {
            return Err("sweep.weather_trace: the base scenario has no [weather] block".into());
        }
        Ok(total)
    }

    /// Validate the grid AND every derived point (each materialized
    /// spec must pass [`ScenarioSpec::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        self.plan().map(|_| ())
    }

    /// Expand the grid into the deterministic shard plan: every point
    /// gets its derived spec, its `(axis, value)` assignment, its
    /// config fingerprint and its worker shard.  Pure function of the
    /// spec — no clocks, no machine probes.
    pub fn plan(&self) -> Result<Vec<SweepPoint>, String> {
        let total = self.validate_grid()?;
        let workers = self.effective_workers(total);
        let mut points = Vec::with_capacity(total);
        for index in 0..total {
            let mut spec = self.base.clone();
            // Points never write trace artifacts (they would race on
            // one path); the timeline digest is still computed and
            // becomes the point's determinism hash.
            spec.trace = None;
            let mut axes = Vec::with_capacity(self.axes.len());
            let mut rem = index;
            let mut stride = total;
            for axis in &self.axes {
                stride /= axis.len();
                let vi = rem / stride;
                rem %= stride;
                axis.apply(vi, &mut spec)
                    .map_err(|e| format!("sweep point #{index}: {e}"))?;
                axes.push((axis.key(), axis.label(vi)));
            }
            let label: Vec<String> = axes.iter().map(|(k, v)| format!("{k}={v}")).collect();
            let label = label.join(",");
            spec.name = format!("{}/{label}", self.name);
            spec.validate()
                .map_err(|e| format!("sweep point #{index} ({label}): {e}"))?;
            let fingerprint = format!("{:016x}", hash_name(&format!("{spec:?}")));
            points.push(SweepPoint {
                index,
                shard: index % workers,
                axes,
                fingerprint,
                spec,
            });
        }
        Ok(points)
    }

    // ---------------------------------------------------- presets

    /// Fig 5–6-style strong-scaling curve: the scale-out Terasort
    /// topology (4 sites x 4 racks) swept over node count at two fixed
    /// TOTAL data sizes, fault-free.  Per-node data is `total / nodes`,
    /// so makespans must fall (or hold) as nodes grow — the acceptance
    /// gate `benches/bench_sweep.rs` enforces monotonicity per size.
    /// Mirrors config/scenarios/sweep_fig5_scaling.toml.
    pub fn fig5_scaling() -> SweepSpec {
        let mut base = ScenarioSpec::scale128();
        base.name = "sweep-fig5-scaling".into();
        // The paper's scaling figures are fault-free runs; the scale128
        // fault plan would also pin node ids past the smallest point.
        base.faults.clear();
        SweepSpec {
            name: "sweep-fig5-scaling".into(),
            base,
            workers: DEFAULT_WORKERS,
            axes: vec![
                Axis::Nodes(vec![32, 64, 128]),
                Axis::TotalBytes(vec![
                    ByteSize::parse("32GB").expect("static byte size"),
                    ByteSize::parse("64GB").expect("static byte size"),
                ]),
            ],
        }
    }

    /// Sphere-over-Hadoop speedup surface: the §7 head-to-head swept
    /// over WAN capacity and node count on a two-site wide-area
    /// testbed.  Each point runs BOTH engines; `records[].speedup`
    /// is the surface.  Mirrors config/scenarios/sweep_speedup_wan.toml.
    pub fn speedup_wan() -> SweepSpec {
        use super::{CompareSpec, WorkloadKind, WorkloadSpec};
        use crate::config::SimConfig;
        use crate::topology::TopologySpec;
        let base = ScenarioSpec {
            name: "sweep-speedup-wan".into(),
            topology: TopologySpec::scale_out(2, 2, 4),
            cfg: SimConfig::wan_default(),
            workload: Some(WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 2.0 * crate::util::bytes::GB as f64,
                iterations: 10,
            }),
            faults: Vec::new(),
            churn: None,
            weather: None,
            traffic: None,
            replication: None,
            colocation: super::ColocationSpec::default(),
            compare: Some(CompareSpec::default()),
            angle: None,
            trace: None,
        };
        SweepSpec {
            name: "sweep-speedup-wan".into(),
            base,
            workers: DEFAULT_WORKERS,
            axes: vec![
                Axis::Nodes(vec![8, 16, 32]),
                Axis::WanGbps(vec![1.0, 2.5, 5.0, 10.0]),
            ],
        }
    }
}

fn axis_array<'a>(t: &'a Table, key: &str) -> Result<Option<&'a [Value]>, String> {
    match t.get(&format!("sweep.{key}")) {
        None => Ok(None),
        Some(v) => match v.as_array() {
            Some(a) => Ok(Some(a)),
            None => Err(format!(
                "sweep.{key}: expected an array of values (e.g. {key} = [...])"
            )),
        },
    }
}

fn positive_floats(vals: &[Value], key: &str) -> Result<Vec<f64>, String> {
    let mut out = Vec::new();
    for v in vals {
        match v.as_float() {
            Some(f) if f.is_finite() && f > 0.0 => out.push(f),
            _ => return Err(format!("{key}: values must be positive numbers")),
        }
    }
    Ok(out)
}

/// One executed grid point's extracted metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct PointRecord {
    pub index: usize,
    pub shard: usize,
    /// The derived scenario name (`<sweep>/<axis=value,...>`).
    pub name: String,
    /// `(axis key, value label)` assignment for this point.
    pub axes: Vec<(&'static str, String)>,
    /// FNV-1a config fingerprint of the materialized spec.
    pub fingerprint: String,
    /// FNV-1a digest of the run's full event timeline — the per-point
    /// determinism hash (DESIGN.md §15).
    pub determinism: String,
    pub nodes: usize,
    pub makespan_secs: f64,
    pub events: u64,
    pub segments: usize,
    pub shuffle_gbytes: f64,
    /// Hadoop/Sphere makespan ratio when the point ran `[compare]`.
    pub speedup: Option<f64>,
    /// Emergent-window recall when the point ran the Angle pipeline.
    pub recall: Option<f64>,
    /// Worst per-tenant p99 latency when the point served `[traffic]`.
    pub worst_p99_ms: Option<f64>,
    pub completed: Option<u64>,
    pub rejected: Option<u64>,
}

impl PointRecord {
    fn from_report(p: &SweepPoint, r: &ScenarioReport) -> PointRecord {
        PointRecord {
            index: p.index,
            shard: p.shard,
            name: r.name.clone(),
            axes: p.axes.clone(),
            fingerprint: p.fingerprint.clone(),
            determinism: r.trace_digest.clone(),
            nodes: r.nodes,
            makespan_secs: r.makespan_secs,
            events: r.events,
            segments: r.segments,
            shuffle_gbytes: r.shuffle_gbytes,
            speedup: r.comparison.as_ref().map(|c| c.speedup),
            recall: r.angle.as_ref().map(|a| a.recall),
            worst_p99_ms: r
                .traffic
                .as_ref()
                .map(|t| t.tenants.iter().map(|s| s.p99_ms).fold(0.0, f64::max)),
            completed: r.traffic.as_ref().map(|t| t.completed),
            rejected: r.traffic.as_ref().map(|t| t.rejected),
        }
    }

    /// Single-line JSON object (stable key order, no wall clock).
    pub fn to_json(&self) -> String {
        let axes: Vec<String> = self
            .axes
            .iter()
            .map(|(k, v)| format!("{}: {}", jstr(k), jstr(v)))
            .collect();
        format!(
            "{{\"index\": {}, \"shard\": {}, \"name\": {}, \"axes\": {{{}}}, \
             \"fingerprint\": {}, \"determinism\": {}, \"nodes\": {}, \
             \"makespan_secs\": {}, \"events\": {}, \"segments\": {}, \
             \"shuffle_gbytes\": {}, \"speedup\": {}, \"recall\": {}, \
             \"worst_p99_ms\": {}, \"completed\": {}, \"rejected\": {}}}",
            self.index,
            self.shard,
            jstr(&self.name),
            axes.join(", "),
            jstr(&self.fingerprint),
            jstr(&self.determinism),
            self.nodes,
            jnum(self.makespan_secs),
            self.events,
            self.segments,
            jnum(self.shuffle_gbytes),
            jopt(self.speedup),
            jopt(self.recall),
            jopt(self.worst_p99_ms),
            jopt_u64(self.completed),
            jopt_u64(self.rejected),
        )
    }
}

/// The aggregated machine-readable result of one sweep run.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepReport {
    pub name: String,
    pub base_scenario: String,
    pub workers: usize,
    /// `(axis key, value labels)` — the grid axes, canonical order.
    pub axes: Vec<(&'static str, Vec<String>)>,
    /// FNV-1a over every point fingerprint in grid order — one hash
    /// naming the whole materialized grid.
    pub grid_fingerprint: String,
    /// Per-point records, always in grid order (never completion
    /// order) — the byte-identical-JSON determinism contract.
    pub records: Vec<PointRecord>,
}

impl SweepReport {
    /// Render the full report as JSON.  Deterministic: same grid, same
    /// bytes — no wall clock, no machine probes, records in grid order.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"sweep\": {},\n", jstr(&self.name)));
        s.push_str(&format!("  \"base_scenario\": {},\n", jstr(&self.base_scenario)));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"points\": {},\n", self.records.len()));
        s.push_str(&format!("  \"grid_fingerprint\": {},\n", jstr(&self.grid_fingerprint)));
        s.push_str("  \"axes\": [\n");
        for (i, (key, values)) in self.axes.iter().enumerate() {
            let vals: Vec<String> = values.iter().map(|v| jstr(v)).collect();
            let comma = if i + 1 < self.axes.len() { "," } else { "" };
            s.push_str(&format!(
                "    {{\"key\": {}, \"values\": [{}]}}{comma}\n",
                jstr(key),
                vals.join(", ")
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"records\": [\n");
        for (i, rec) in self.records.iter().enumerate() {
            let comma = if i + 1 < self.records.len() { "," } else { "" };
            s.push_str(&format!("    {}{comma}\n", rec.to_json()));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// The records array alone, single-line — what `bench_sweep` folds
    /// into the flat `BENCH_sweep.json` trajectory file.
    pub fn records_json(&self) -> String {
        let recs: Vec<String> = self.records.iter().map(PointRecord::to_json).collect();
        format!("[{}]", recs.join(", "))
    }

    /// Write the JSON to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn jstr(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn jopt(v: Option<f64>) -> String {
    match v {
        Some(v) => jnum(v),
        None => "null".to_string(),
    }
}

fn jopt_u64(v: Option<u64>) -> String {
    match v {
        Some(v) => format!("{v}"),
        None => "null".to_string(),
    }
}

/// Expand the grid and run every point across the spec's worker
/// threads.  Each worker owns the shard `index % workers` and runs its
/// points in index order; results are slotted back by grid index, so
/// the aggregated report (and its JSON) is independent of thread
/// completion order.  The first failing point (by grid index) fails
/// the sweep.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepReport, String> {
    let points = spec.plan()?;
    let workers = spec.effective_workers(points.len());
    let shard_results: Vec<Vec<Result<(usize, PointRecord), (usize, String)>>> =
        std::thread::scope(|scope| {
            let points = &points;
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        points
                            .iter()
                            .skip(w)
                            .step_by(workers)
                            .map(|p| match run_scenario(&p.spec) {
                                Ok(r) => Ok((p.index, PointRecord::from_report(p, &r))),
                                Err(e) => Err((p.index, e)),
                            })
                            .collect()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("sweep worker panicked"))
                .collect()
        });
    let mut records: Vec<(usize, PointRecord)> = Vec::with_capacity(points.len());
    let mut errors: Vec<(usize, String)> = Vec::new();
    for shard in shard_results {
        for result in shard {
            match result {
                Ok(r) => records.push(r),
                Err(e) => errors.push(e),
            }
        }
    }
    if !errors.is_empty() {
        errors.sort_by_key(|(i, _)| *i);
        let (index, e) = &errors[0];
        return Err(format!(
            "sweep point #{index} failed: {e}{}",
            if errors.len() > 1 {
                format!(" (+{} more points failed)", errors.len() - 1)
            } else {
                String::new()
            }
        ));
    }
    records.sort_by_key(|(i, _)| *i);
    let concat: String = points.iter().map(|p| p.fingerprint.as_str()).collect();
    Ok(SweepReport {
        name: spec.name.clone(),
        base_scenario: spec.base.name.clone(),
        workers,
        axes: spec.axes.iter().map(|a| (a.key(), a.labels())).collect(),
        grid_fingerprint: format!("{:016x}", hash_name(&concat)),
        records: records.into_iter().map(|(_, r)| r).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologySpec;

    fn tiny_base() -> ScenarioSpec {
        let mut base = ScenarioSpec::scale128();
        base.name = "tiny".into();
        base.faults.clear();
        base.topology = TopologySpec::scale_out(2, 2, 2);
        base.workload.as_mut().unwrap().bytes_per_node = 64.0 * 1024.0 * 1024.0;
        base
    }

    fn tiny_sweep() -> SweepSpec {
        SweepSpec {
            name: "tiny-sweep".into(),
            base: tiny_base(),
            workers: 3,
            axes: vec![
                Axis::Nodes(vec![4, 8]),
                Axis::TotalBytes(vec![ByteSize::parse("512MB").unwrap()]),
            ],
        }
    }

    #[test]
    fn parses_a_sweep_document() {
        let spec = SweepSpec::from_toml(
            r#"
            name = "doc"
            [topology]
            sites = 2
            racks_per_site = 1
            nodes_per_rack = 4
            [workload]
            kind = "terasort"
            bytes_per_node = "1GB"
            [sweep]
            workers = 2
            nodes = [4, 8]
            total_bytes = ["4GB", "8GB"]
            fault_intensity = [0.0, 1.0]
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "doc");
        assert_eq!(spec.workers, 2);
        assert_eq!(spec.points(), 8);
        // Canonical axis order regardless of TOML order.
        let keys: Vec<&str> = spec.axes.iter().map(|a| a.key()).collect();
        assert_eq!(keys, vec!["nodes", "total_bytes", "fault_intensity"]);
    }

    #[test]
    fn expansion_is_row_major_with_the_last_axis_fastest() {
        let plan = tiny_sweep().plan().unwrap();
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].axes[0], ("nodes", "4".to_string()));
        assert_eq!(plan[1].axes[0], ("nodes", "8".to_string()));
        // total_bytes divides by the point's final node count
        // (parse_bytes is decimal: 512MB = 512e6, exact under /4 and /8).
        assert_eq!(plan[0].spec.workload.as_ref().unwrap().bytes_per_node, 512.0e6 / 4.0);
        assert_eq!(plan[1].spec.workload.as_ref().unwrap().bytes_per_node, 512.0e6 / 8.0);
        // Shards follow index % workers; names carry the assignment.
        assert_eq!(plan[0].shard, 0);
        assert_eq!(plan[1].shard, 1);
        assert_eq!(plan[1].spec.name, "tiny-sweep/nodes=8,total_bytes=512MB");
    }

    #[test]
    fn fingerprints_are_deterministic_and_distinct() {
        let a = tiny_sweep().plan().unwrap();
        let b = tiny_sweep().plan().unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.fingerprint, y.fingerprint);
        }
        assert_ne!(a[0].fingerprint, a[1].fingerprint);
    }

    #[test]
    fn empty_axis_error_names_the_key() {
        let mut spec = tiny_sweep();
        spec.axes = vec![Axis::WanGbps(vec![])];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("sweep.wan_gbps") && e.contains("empty"), "{e}");
    }

    #[test]
    fn duplicate_value_error_names_the_key() {
        let mut spec = tiny_sweep();
        spec.axes = vec![Axis::Nodes(vec![4, 8, 4])];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("sweep.nodes") && e.contains("duplicate value 4"), "{e}");
    }

    #[test]
    fn duplicate_axis_is_rejected() {
        let mut spec = tiny_sweep();
        spec.axes = vec![Axis::Nodes(vec![4]), Axis::Nodes(vec![8])];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("sweep.nodes") && e.contains("duplicate axis"), "{e}");
    }

    #[test]
    fn overflowing_product_is_capped() {
        let mut spec = tiny_sweep();
        spec.axes = vec![
            Axis::Nodes((1..=80).map(|i| i * 4).collect()),
            Axis::FaultIntensity((0..80).map(|i| i as f64).collect()),
        ];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("6400 points exceeds the 4096-point cap"), "{e}");
    }

    #[test]
    fn sizing_axes_are_mutually_exclusive() {
        let mut spec = tiny_sweep();
        spec.axes = vec![
            Axis::BytesPerNode(vec![ByteSize::parse("1GB").unwrap()]),
            Axis::TotalBytes(vec![ByteSize::parse("8GB").unwrap()]),
        ];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("mutually exclusive"), "{e}");
    }

    #[test]
    fn axis_applicability_is_checked_against_the_base() {
        let mut spec = tiny_sweep();
        spec.axes = vec![Axis::TenantMix(vec!["70:30".into()])];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("sweep.tenant_mix") && e.contains("[traffic]"), "{e}");
        let mut spec = tiny_sweep();
        spec.axes = vec![Axis::ReplicationMax(vec![4])];
        let e = spec.validate().unwrap_err();
        assert!(e.contains("sweep.replication_max") && e.contains("[replication]"), "{e}");
    }

    #[test]
    fn indivisible_node_count_is_rejected_per_point() {
        let mut spec = tiny_sweep();
        spec.axes = vec![Axis::Nodes(vec![6])]; // 4 racks
        let e = spec.validate().unwrap_err();
        assert!(
            e.contains("sweep.nodes") && e.contains("does not divide"),
            "{e}"
        );
    }

    #[test]
    fn fault_intensity_scales_the_plan() {
        let mut spec = tiny_sweep();
        spec.base.faults = vec![
            FaultSpec::Straggler { node: 1, factor: 0.5 },
            FaultSpec::SlaveCrash { at_secs: 1.0, node: 2 },
        ];
        spec.axes = vec![Axis::FaultIntensity(vec![0.0, 1.0, 2.0])];
        let plan = spec.plan().unwrap();
        assert!(plan[0].spec.faults.is_empty(), "intensity 0 clears the plan");
        assert_eq!(plan[1].spec.faults, spec.base.faults, "intensity 1 is as written");
        assert!(
            matches!(
                plan[2].spec.faults[0],
                FaultSpec::Straggler { node: 1, factor } if (factor - 0.25).abs() < 1e-12
            ),
            "intensity 2 squares the straggler factor: {:?}",
            plan[2].spec.faults[0]
        );
        assert_eq!(plan[2].spec.faults[1], spec.base.faults[1], "crashes are unscaled");
    }

    #[test]
    fn scenario_from_toml_rejects_sweep_documents() {
        let e = ScenarioSpec::from_toml(
            "[workload]\nkind = \"terasort\"\n[sweep]\nnodes = [2, 4]\n",
        )
        .unwrap_err();
        assert!(e.contains("`sweep` subcommand"), "{e}");
    }

    #[test]
    fn sweep_from_toml_requires_the_block() {
        let e = SweepSpec::from_toml("[workload]\nkind = \"terasort\"\n").unwrap_err();
        assert!(e.contains("[sweep]"), "{e}");
    }

    #[test]
    fn unknown_sweep_key_is_rejected() {
        let e = SweepSpec::from_toml(
            "[workload]\nkind = \"terasort\"\n[sweep]\nnode = [2]\n",
        )
        .unwrap_err();
        assert!(e.contains("unknown field \"node\""), "{e}");
    }

    #[test]
    fn presets_expand_to_their_documented_grids() {
        let fig5 = SweepSpec::fig5_scaling();
        assert_eq!(fig5.points(), 6);
        fig5.validate().unwrap();
        let wan = SweepSpec::speedup_wan();
        assert_eq!(wan.points(), 12);
        wan.validate().unwrap();
        // Every compare point keeps its [compare] block.
        assert!(wan.plan().unwrap().iter().all(|p| p.spec.compare.is_some()));
    }

    #[test]
    fn run_sweep_is_deterministic_and_worker_invariant() {
        let spec = tiny_sweep();
        let a = run_sweep(&spec).unwrap();
        let b = run_sweep(&spec).unwrap();
        assert_eq!(a.to_json(), b.to_json(), "same grid twice -> byte-identical JSON");
        let mut serial = spec.clone();
        serial.workers = 1;
        let c = run_sweep(&serial).unwrap();
        for (x, y) in a.records.iter().zip(&c.records) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.fingerprint, y.fingerprint);
            assert_eq!(x.determinism, y.determinism, "worker count must not leak into results");
            assert_eq!(x.makespan_secs, y.makespan_secs);
        }
        assert_eq!(a.grid_fingerprint, c.grid_fingerprint);
    }

    #[test]
    fn report_json_has_the_documented_shape() {
        let r = run_sweep(&tiny_sweep()).unwrap();
        let json = r.to_json();
        for needle in [
            "\"sweep\": \"tiny-sweep\"",
            "\"points\": 2",
            "\"grid_fingerprint\": \"",
            "{\"key\": \"nodes\", \"values\": [\"4\", \"8\"]}",
            "\"makespan_secs\": ",
            "\"determinism\": \"",
            "\"speedup\": null",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        assert!(r.records_json().starts_with("[{\"index\": 0"));
        // A failing point names its grid index.
        let mut bad = tiny_sweep();
        bad.base.faults = vec![FaultSpec::SlaveCrash { at_secs: 1.0, node: 6 }];
        bad.axes = vec![Axis::Nodes(vec![8, 4])];
        let e = bad.plan().unwrap_err();
        assert!(e.contains("sweep point #1"), "{e}");
    }

    #[test]
    fn wide_area_axes_parse_apply_and_gate_on_the_base() {
        let spec = SweepSpec::from_toml(
            r#"
            [topology]
            sites = 2
            racks_per_site = 1
            nodes_per_rack = 4
            [workload]
            kind = "terasort"
            bytes_per_node = "256MB"
            [churn]
            rate_per_100s = 4.0
            duration_secs = 200.0
            [weather]
            amplitude = 0.3
            steps = 2
            [sweep]
            churn_rate = [0.0, 4.0]
            weather_trace = [7, 8]
            transport = ["udt", "tcp"]
            "#,
        )
        .unwrap();
        let keys: Vec<&str> = spec.axes.iter().map(|a| a.key()).collect();
        assert_eq!(keys, vec!["churn_rate", "weather_trace", "transport"]);
        let plan = spec.plan().unwrap();
        assert_eq!(plan.len(), 8);
        // Last axis fastest: point 0 is udt, point 1 tcp.
        assert_eq!(
            plan[0].spec.cfg.sphere_transport,
            crate::config::TransportKind::Udt
        );
        assert_eq!(
            plan[1].spec.cfg.sphere_transport,
            crate::config::TransportKind::Tcp
        );
        // churn_rate 0 points expand to weather faults only.
        let p0 = &plan[0].spec;
        assert_eq!(p0.churn.unwrap().rate_per_100s, 0.0);
        assert!(p0
            .effective_faults()
            .iter()
            .all(|f| matches!(f, FaultSpec::WeatherSet { .. })));
        // Rate 4 points carry churn faults; seeds move the instants.
        let p4 = &plan[4].spec;
        assert!((p4.churn.unwrap().rate_per_100s - 4.0).abs() < 1e-12);
        assert!(p4
            .effective_faults()
            .iter()
            .any(|f| matches!(f, FaultSpec::NodeLeave { .. })));
        assert_ne!(plan[4].fingerprint, plan[6].fingerprint, "weather seed axis");
        // Missing base blocks are named.
        let mut bad = tiny_sweep();
        bad.axes = vec![Axis::ChurnRate(vec![1.0])];
        let e = bad.validate().unwrap_err();
        assert!(e.contains("sweep.churn_rate") && e.contains("[churn]"), "{e}");
        let mut bad = tiny_sweep();
        bad.axes = vec![Axis::WeatherTrace(vec![1])];
        let e = bad.validate().unwrap_err();
        assert!(e.contains("sweep.weather_trace") && e.contains("[weather]"), "{e}");
        // Bad transport values are rejected at parse time.
        let e = SweepSpec::from_toml(
            "[workload]\nkind = \"terasort\"\n[sweep]\ntransport = [\"ipx\"]\n",
        )
        .unwrap_err();
        assert!(e.contains("sweep.transport"), "{e}");
    }
}

//! Deterministic scenario execution (DESIGN.md §4).
//!
//! The engine runs a `ScenarioSpec` at *segment* granularity against
//! the discrete-event substrate: an `EventQueue` carries segment
//! completions and fault injections, a `NetSim` carries shuffle
//! transfers, and the real `sphere::Scheduler` makes every placement
//! decision (locality preference, rule-3 anti-affinity, re-assignment
//! after failure) so scenario behaviour exercises the production
//! coordination code.
//!
//! Modelling notes (the calibrated Table 1/2 generators remain
//! `sphere::simjob` / `hadoop::simjob`; this engine trades their
//! closed-form disk contention terms for event-level fault dynamics):
//!
//! * one flow per completed segment carries its remote fraction to a
//!   deterministic partner, capped by the transport model;
//! * a crashed node's queued and running segments re-enter the
//!   scheduler; transfers toward it re-route to a live partner;
//!   transfers already leaving it are assumed salvageable from the
//!   replica without re-transfer (optimistic);
//! * link degradation scales the site's WAN uplink capacity in place —
//!   max-min fair sharing redistributes the loss immediately;
//! * terasplit and kmeans have no shuffle stage: they run on the
//!   analytic path with the same fault state (stragglers slow their
//!   node, crashed nodes are served by their replica).
//!
//! Scale: queues and link tables are pre-sized from the topology, event
//! waves are drained in batches (`EventQueue::pop_simultaneous`), and
//! the flow table iterates in id order without hashing, which keeps a
//! 128-node faulted Terasort scenario in the low milliseconds of wall
//! time (benches/bench_scale.rs prints events/sec).
//!
//! The event loop itself lives in `scenario::core` (DESIGN.md §14):
//! this engine is a [`core::Harness`] — it owns stage semantics
//! (segment service times, the shuffle, SPE pumping) while the core
//! owns dispatch, fault application and event counting.

use std::collections::BTreeMap;

use crate::config::{SimConfig, TransportKind};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::sphere::scheduler::Scheduler;
use crate::sphere::segment::Segment;
use crate::sphere::simjob::udt_efficiency;
use crate::topology::{NetLinks, Proximity, Testbed, rack_diverse_replica};
use crate::transport::TransportModels;

use super::core::{self, CoreEv, FaultEv, Harness};
use super::trace::{HarnessGauges, TraceRecorder, Tracer};
use super::{ScenarioSpec, WorkloadKind};

// Fault-plan machinery moved to the shared engine core; re-exported so
// the service/colocate/hadoop/angle engines keep their import paths.
pub(crate) use super::core::FaultState;

/// What a scenario run produced. Byte-identical across repeat runs of
/// the same spec (the determinism contract the suite asserts).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioReport {
    pub name: String,
    pub workload: &'static str,
    pub nodes: usize,
    pub racks: usize,
    pub sites: usize,
    pub makespan_secs: f64,
    /// Discrete events processed (segment completions, flow
    /// completions, fault injections).
    pub events: u64,
    pub segments: usize,
    /// Segment re-assignments + transfer re-routes caused by faults.
    pub reassignments: u64,
    pub locality_fraction: f64,
    pub shuffle_gbytes: f64,
    pub faults_injected: usize,
    pub nodes_crashed: usize,
    /// Speculative backup attempts launched / won (colocated runs with
    /// `colocation.speculative`; zero elsewhere).  DESIGN.md §11.
    pub speculative_launched: u64,
    pub speculative_won: u64,
    /// SLO report when the scenario ran the service-layer traffic
    /// engine (`[traffic]` block), alone or colocated.
    pub traffic: Option<crate::service::TrafficReport>,
    /// Joint view of a colocated run: job makespan/stage breakdown plus
    /// per-tenant SLO deltas versus the uncolocated baseline.
    pub colocation: Option<super::colocate::ColocationReport>,
    /// Sphere-vs-Hadoop head-to-head when the scenario carried a
    /// `[compare]` block (DESIGN.md §12).
    pub comparison: Option<super::compare::ComparisonReport>,
    /// Mining-side view of a staged Angle run: delta series, emergent
    /// windows vs planted ground truth, model-distribution bytes per
    /// link tier (DESIGN.md §13).
    pub angle: Option<super::angle::AngleReport>,
    /// Elastic-replication summary when the traffic engine ran with a
    /// `[replication]` block: scaler activity, re-replication bytes per
    /// link tier and SLO deltas vs the static baseline (DESIGN.md §16).
    pub elasticity: Option<crate::service::ElasticityReport>,
    /// FNV-1a digest of the run's full trace timeline (DESIGN.md §15).
    /// Always computed — with or without `--trace` — so the golden
    /// fixtures pin the event-by-event timeline, not just the summary.
    pub trace_digest: String,
}

/// Bytes moved between nodes, bucketed by the deepest link tier the
/// transfer crossed (the `Proximity` of its endpoints).  The compare
/// mode reports this per system so "Hadoop shuffled 3x the WAN bytes"
/// is a read-off, not an inference.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TierBytes {
    /// Same-node moves: disk links only, no NIC crossed.
    pub local: f64,
    /// Same-rack transfers: the two node NICs.
    pub nic: f64,
    /// Cross-rack, same-site transfers: the rack uplinks.
    pub rack: f64,
    /// Cross-site transfers: the WAN uplinks.
    pub wan: f64,
}

impl TierBytes {
    pub(crate) fn add(&mut self, testbed: &Testbed, src: usize, dst: usize, bytes: f64) {
        match testbed.proximity(src, dst) {
            Proximity::Local => self.local += bytes,
            Proximity::SameRack => self.nic += bytes,
            Proximity::SameSite => self.rack += bytes,
            Proximity::Wan => self.wan += bytes,
        }
    }

    pub fn total(&self) -> f64 {
        self.local + self.nic + self.rack + self.wan
    }
}

/// Run one scenario to completion. Deterministic: no wall clock, no
/// ambient randomness — the spec is the only input.
///
/// One [`TraceRecorder`] observes the whole run (DESIGN.md §15): every
/// sub-engine feeds it through `core::drive`, the report carries its
/// timeline digest, and — when `[trace] path` / `--trace` is set — the
/// JSONL + Chrome `trace_event` artifacts are written at the end.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<ScenarioReport, String> {
    spec.validate()?;
    let testbed = spec.topology.generate()?;
    let rec = TraceRecorder::for_spec(spec.trace.as_ref());
    let mut report = if spec.compare.is_some() {
        // Head-to-head scenario: the same workload through the Sphere
        // engine AND the Hadoop baseline engine (DESIGN.md §12).
        super::compare::run_compare(spec, &testbed, &rec)?
    } else {
        match (&spec.workload, &spec.traffic) {
            // Colocated scenario: batch job + client traffic share one
            // substrate (DESIGN.md §11).
            (Some(_), Some(_)) => super::colocate::run_colocated(spec, &testbed, &rec)?,
            // Service-only scenario: the traffic engine replaces the
            // batch workload, composing with the same fault plan.
            (None, Some(_)) => crate::service::run_traffic(spec, &testbed, &rec)?,
            (None, None) => return Err("scenario has neither workload nor traffic".into()),
            (Some(_), None) => run_batch(spec, &testbed, &rec)?.into_report(spec, &testbed),
        }
    };
    report.trace_digest = rec.digest_hex();
    if let Some(path) = spec.trace.as_ref().and_then(|t| t.path.as_deref()) {
        rec.write_artifacts(&spec.name, path, &testbed)?;
    }
    Ok(report)
}

/// Raw outcome of the Sphere batch half of the engine — what the
/// compare driver consumes directly (it builds one joint report from
/// two system runs instead of two `ScenarioReport`s).
pub(crate) struct BatchOutcome {
    pub(crate) makespan: f64,
    pub(crate) agg: Aggregate,
    pub(crate) state: FaultState,
    /// Mining-side report when the workload was the staged Angle
    /// pipeline (DESIGN.md §13); `None` for every other workload.
    pub(crate) angle: Option<super::angle::AngleReport>,
}

impl BatchOutcome {
    pub(crate) fn into_report(self, spec: &ScenarioSpec, testbed: &Testbed) -> ScenarioReport {
        let workload = spec.workload.as_ref().expect("batch outcome has a workload");
        ScenarioReport {
            name: spec.name.clone(),
            workload: workload.kind.name(),
            nodes: testbed.nodes(),
            racks: testbed.racks(),
            sites: testbed.site_names.len(),
            makespan_secs: self.makespan,
            events: self.agg.events,
            segments: self.agg.segments,
            reassignments: self.agg.reassignments,
            locality_fraction: self.agg.locality_fraction(),
            shuffle_gbytes: self.agg.shuffle_bytes / 1e9,
            faults_injected: self.state.injected,
            nodes_crashed: self.state.crashes,
            speculative_launched: self.agg.speculative_launched,
            speculative_won: self.agg.speculative_won,
            traffic: None,
            colocation: None,
            comparison: None,
            angle: self.angle,
            elasticity: None,
            trace_digest: String::new(),
        }
    }
}

/// Run the `[workload]` block to completion on a fresh substrate built
/// from `testbed`.  Shared by the plain batch path of [`run_scenario`]
/// and the Sphere side of the compare driver (DESIGN.md §12).
pub(crate) fn run_batch(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<BatchOutcome, String> {
    let workload = spec
        .workload
        .as_ref()
        .ok_or("batch run requires a [workload] block")?;
    let mut state = FaultState::for_run(spec, testbed);
    let b = workload.bytes_per_node;
    let mut agg = Aggregate::default();
    let tracer = rec.tracer("sphere");

    let makespan = match workload.kind {
        WorkloadKind::Terasort => {
            let (run, net, q) =
                StageRun::new(testbed, &spec.cfg, StageKind::TerasortA, b, 0.0, &state)?;
            let end_a = run.execute(net, q, &mut state, &mut agg, &tracer)?;
            let (run, net, q) =
                StageRun::new(testbed, &spec.cfg, StageKind::TerasortB, b, end_a, &state)?;
            run.execute(net, q, &mut state, &mut agg, &tracer)?
        }
        WorkloadKind::Filegen => {
            let (run, net, q) =
                StageRun::new(testbed, &spec.cfg, StageKind::Filegen, b, 0.0, &state)?;
            run.execute(net, q, &mut state, &mut agg, &tracer)?
        }
        // The staged Angle pipeline owns its whole substrate — ingest,
        // extract, aggregate, cluster and score all run event-driven
        // (DESIGN.md §13; the old off-substrate clustering scalar
        // survives only as its calibration oracle).
        WorkloadKind::Angle => return super::angle::run_angle(spec, testbed, rec),
        WorkloadKind::Terasplit => run_terasplit(testbed, &spec.cfg, b, &mut state, &mut agg)?,
        WorkloadKind::Kmeans => run_kmeans(
            testbed,
            &spec.cfg,
            b,
            workload.iterations,
            &mut state,
            &mut agg,
        )?,
    };

    Ok(BatchOutcome {
        makespan,
        agg,
        state,
        angle: None,
    })
}

// ------------------------------------------------------------ aggregates

#[derive(Default)]
pub(crate) struct Aggregate {
    pub(crate) events: u64,
    pub(crate) segments: usize,
    pub(crate) reassignments: u64,
    pub(crate) local_assignments: u64,
    pub(crate) remote_assignments: u64,
    pub(crate) shuffle_bytes: f64,
    /// Bytes moved between nodes, by link tier crossed.
    pub(crate) tier: TierBytes,
    /// (stage name, end time) in execution order.
    pub(crate) stage_ends: Vec<(String, f64)>,
    /// Speculative backup attempts launched / won (the staged Angle
    /// pipeline's cluster stage; zero for the other batch workloads).
    pub(crate) speculative_launched: u64,
    pub(crate) speculative_won: u64,
}

impl Aggregate {
    pub(crate) fn locality_fraction(&self) -> f64 {
        let assignments = self.local_assignments + self.remote_assignments;
        if assignments == 0 {
            0.0
        } else {
            self.local_assignments as f64 / assignments as f64
        }
    }
}

// ------------------------------------------------------------ staged engine

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum StageKind {
    /// Read + partition + write the incoming partition; shuffles.
    TerasortA,
    /// Local sort of the received partition (read/sort/write pipeline).
    TerasortB,
    /// Synthetic record generation to local disk.
    Filegen,
    /// Packet-trace scan + feature emission.
    AngleExtract,
}

impl StageKind {
    pub(crate) fn shuffles(self) -> bool {
        self == StageKind::TerasortA
    }

    /// Stage names for the colocation report's per-stage breakdown.
    pub(crate) fn name(self) -> &'static str {
        match self {
            StageKind::TerasortA => "partition+shuffle",
            StageKind::TerasortB => "local sort",
            StageKind::Filegen => "filegen",
            StageKind::AngleExtract => "angle extract",
        }
    }

    /// The stage sequence of an event-driven workload (the analytic
    /// workloads — terasplit, kmeans — have none).
    pub(crate) fn stages_of(kind: WorkloadKind) -> Option<&'static [StageKind]> {
        match kind {
            WorkloadKind::Terasort => Some(&[StageKind::TerasortA, StageKind::TerasortB]),
            WorkloadKind::Filegen => Some(&[StageKind::Filegen]),
            WorkloadKind::Angle => Some(&[StageKind::AngleExtract]),
            WorkloadKind::Terasplit | WorkloadKind::Kmeans => None,
        }
    }

    /// Whether the stage reads from / writes to the local spindle —
    /// which disk links a colocated segment flow crosses.
    pub(crate) fn touches_disk(self) -> (bool, bool) {
        match self {
            StageKind::TerasortA | StageKind::TerasortB => (true, true),
            StageKind::Filegen => (false, true),
            StageKind::AngleExtract => (true, false),
        }
    }

    /// Nominal per-segment service time on one SPE (no straggler
    /// factor, no coordination cost).
    pub(crate) fn service_secs(self, cfg: &SimConfig, bytes: f64) -> f64 {
        let eff = cfg.sphere.io_efficiency;
        let read = cfg.hardware.disk_read_bps * eff;
        let write = cfg.hardware.disk_write_bps * eff;
        match self {
            StageKind::TerasortA => bytes / read.min(cfg.cpu.partition_bps) + bytes / write,
            StageKind::TerasortB => {
                let io = bytes / read + bytes / write;
                let cpu = bytes / cfg.cpu.sort_bps;
                let o = cfg.sphere.io_overlap;
                io.max(cpu) + (1.0 - o) * io.min(cpu)
            }
            StageKind::Filegen => bytes / write.min(cfg.cpu.partition_bps),
            StageKind::AngleExtract => bytes / read.min(cfg.cpu.scan_bps),
        }
    }
}

/// Events in a staged run: segment completions plus the shared fault
/// vocabulary the core intercepts.
enum Ev {
    /// A segment finished on its SPE (stale if the generation is gone).
    Seg { gen: u64 },
    /// Crash / brown-out events owned by `scenario::core`.
    Fault(FaultEv),
}

impl CoreEv for Ev {
    fn from_fault(f: FaultEv) -> Ev {
        Ev::Fault(f)
    }

    fn to_fault(&self) -> Option<FaultEv> {
        match self {
            Ev::Fault(f) => Some(*f),
            Ev::Seg { .. } => None,
        }
    }

    fn trace_name(&self) -> &'static str {
        match self {
            Ev::Seg { .. } => "seg",
            Ev::Fault(_) => "fault",
        }
    }
}

struct FlowOut {
    src: usize,
    dst: usize,
}

/// One event-driven stage over every node's `bytes_per_node`.  The
/// substrate (NetSim, queue, fault state) lives outside and is threaded
/// through `core::drive`; this struct owns only stage semantics.
struct StageRun<'a> {
    testbed: &'a Testbed,
    cfg: &'a SimConfig,
    kind: StageKind,
    start: f64,
    models: TransportModels,
    sched: Scheduler,
    links: NetLinks,
    /// generation -> (node, segment) for in-flight work.
    inflight: BTreeMap<u64, (usize, Segment)>,
    next_gen: u64,
    running: Vec<usize>,
    flows: BTreeMap<FlowId, FlowOut>,
    coord_secs: f64,
    /// Link capacities at build time, indexed by LinkId. Transport rate
    /// caps are computed against these NOMINAL rates so a degradation
    /// window slows flows via link sharing (and lifts when the window
    /// ends) instead of freezing a degraded cap into every flow that
    /// happened to start inside it.
    nominal_caps: Vec<f64>,
}

impl<'a> StageRun<'a> {
    fn new(
        testbed: &'a Testbed,
        cfg: &'a SimConfig,
        kind: StageKind,
        bytes_per_node: f64,
        start: f64,
        state: &FaultState,
    ) -> Result<(StageRun<'a>, NetSim, EventQueue<Ev>), String> {
        let n = testbed.nodes();
        let spes = cfg.sphere.spes_per_node.max(1);
        let n_links = 2 * n + 2 * testbed.racks() + 2 * testbed.site_names.len();
        let mut net = NetSim::with_capacity(n_links);
        let links = testbed.build_network(&mut net);
        let nominal_caps = (0..n_links)
            .map(|i| net.link_capacity(crate::sim::netsim::LinkId(i)))
            .collect();
        net.advance_to(start);
        let q = EventQueue::with_capacity(n * spes + 2 * state.faults.len() + 8);
        let coord_secs = coordination_secs(testbed);
        let segments = build_stage_segments(testbed, cfg, state, bytes_per_node, spes)?;
        let mut sched = Scheduler::new(segments, cfg.sphere.locality_scheduling);
        sched.max_attempts = cfg.sphere.max_attempts;
        let run = StageRun {
            testbed,
            cfg,
            kind,
            start,
            models: TransportModels::default(),
            sched,
            links,
            inflight: BTreeMap::new(),
            next_gen: 0,
            running: vec![0; n],
            flows: BTreeMap::new(),
            coord_secs,
            nominal_caps,
        };
        Ok((run, net, q))
    }

    /// Hand pending segments to every idle SPE slot.  While the master
    /// is down no NEW segment can be scheduled (assignment goes through
    /// it); in-flight work keeps running and the drained-wave pump
    /// resumes dispatch after `MasterUp` (DESIGN.md §18).
    fn pump(&mut self, now: f64, q: &mut EventQueue<Ev>, state: &FaultState) {
        if state.master_down {
            return;
        }
        let spes = self.cfg.sphere.spes_per_node.max(1);
        for node in 0..self.testbed.nodes() {
            if state.dead[node] {
                continue;
            }
            while self.running[node] < spes {
                let Some(seg) = self.sched.assign(node as u32) else {
                    break;
                };
                self.next_gen += 1;
                let secs = self.kind.service_secs(self.cfg, seg.bytes as f64)
                    / state.factor[node]
                    + self.coord_secs;
                q.push_at(now + secs, Ev::Seg { gen: self.next_gen });
                self.inflight.insert(self.next_gen, (node, seg));
                self.running[node] += 1;
            }
        }
    }

    fn start_shuffle_flow(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        net: &mut NetSim,
        state: &FaultState,
    ) {
        let path = self.testbed.path(&self.links, src, dst);
        let cap = shuffle_rate_cap(
            self.cfg,
            &self.models,
            &self.nominal_caps,
            &path,
            self.testbed.nic_bps,
            self.testbed.rtt_secs(src, dst),
            state.factor[src],
        );
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, FlowOut { src, dst });
    }

    /// Run the stage to completion on the core loop; returns its end
    /// time.
    fn execute(
        mut self,
        mut net: NetSim,
        mut q: EventQueue<Ev>,
        state: &mut FaultState,
        agg: &mut Aggregate,
        tracer: &Tracer,
    ) -> Result<f64, String> {
        core::schedule_faults(state, &mut q, self.start);
        self.pump(self.start, &mut q, state);
        let links = self.links.clone();
        let testbed = self.testbed;
        let out = {
            let mut h = StageHarness {
                run: &mut self,
                agg,
                tracer,
            };
            core::drive(&mut h, &mut net, &mut q, state, &links, testbed, tracer)?
        };
        tracer.stage_mark(out.end, self.kind.name());
        agg.events += out.events;
        agg.local_assignments += self.sched.local_assignments;
        agg.remote_assignments += self.sched.remote_assignments;
        agg.stage_ends.push((self.kind.name().to_string(), out.end));
        Ok(out.end)
    }
}

/// The batch stage plugged into the core loop: stage state plus the
/// cross-stage aggregate it reports into.
struct StageHarness<'r, 'a> {
    run: &'r mut StageRun<'a>,
    agg: &'r mut Aggregate,
    tracer: &'r Tracer,
}

impl<'r, 'a> Harness for StageHarness<'r, 'a> {
    type Ev = Ev;

    fn finished(&self, net: &NetSim) -> bool {
        self.run.sched.is_drained() && self.run.inflight.is_empty() && net.active_flows() == 0
    }

    fn flow_done(
        &mut self,
        fid: FlowId,
        _now: f64,
        _net: &mut NetSim,
        _q: &mut EventQueue<Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        self.run.flows.remove(&fid);
        Ok(())
    }

    fn handle(
        &mut self,
        ev: Ev,
        now: f64,
        net: &mut NetSim,
        _q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        let Ev::Seg { gen } = ev else {
            return Ok(()); // fault events never reach the harness
        };
        let run = &mut *self.run;
        let Some((node, seg)) = run.inflight.remove(&gen) else {
            return Ok(()); // pre-empted by a crash
        };
        run.running[node] -= 1;
        run.sched.complete(&seg);
        self.tracer.task_mark(now, "seg done", node, run.kind.name());
        self.agg.segments += 1;
        if run.kind.shuffles() {
            // Scoped: `alive` borrows the fault state,
            // start_shuffle_flow needs the run mutably.
            let (n_alive, dst) = {
                let alive = state.alive();
                (alive.len(), pick_dst_in(alive, node, seg.id))
            };
            if let Some(dst) = dst {
                let frac = (n_alive - 1) as f64 / n_alive as f64;
                let bytes = seg.bytes as f64 * frac;
                run.start_shuffle_flow(node, dst, bytes, net, state);
                self.agg.shuffle_bytes += bytes;
                self.agg.tier.add(run.testbed, node, dst, bytes);
            }
        }
        Ok(())
    }

    fn on_crash(
        &mut self,
        node: usize,
        _now: f64,
        net: &mut NetSim,
        _q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        let run = &mut *self.run;
        // Re-queue the dead node's running segments.
        let stale: Vec<u64> = run
            .inflight
            .iter()
            .filter(|(_, (nd, _))| *nd == node)
            .map(|(&g, _)| g)
            .collect();
        for g in stale {
            let (_, seg) = run.inflight.remove(&g).expect("stale gen exists");
            let id = seg.id;
            if !run.sched.fail(seg) {
                // Explicit job failure — never a silent drop from
                // pending (the exhausted id is also recorded in the
                // scheduler for the property suite).
                return Err(format!(
                    "job failed: segment {id} exhausted its {} attempts \
                     after node {node} crashed",
                    run.sched.max_attempts
                ));
            }
            self.agg.reassignments += 1;
        }
        run.running[node] = 0;
        // Re-route transfers headed for the dead node: pick the new
        // destinations under a scoped alive-list borrow, then act.
        let redirect: Vec<(FlowId, usize, Option<usize>)> = {
            let alive = state.alive();
            run.flows
                .iter()
                .filter(|(_, fo)| fo.dst == node)
                .map(|(&f, fo)| (f, fo.src, pick_dst_in(alive, fo.src, fo.dst + 1)))
                .collect()
        };
        // The rerouted remainder is not re-counted in tier/shuffle
        // byte totals — those count each payload once, at first send.
        for (fid, src, new_dst) in redirect {
            run.flows.remove(&fid);
            let left = net.cancel_flow(fid);
            if let Some(new_dst) = new_dst {
                run.start_shuffle_flow(src, new_dst, left, net, state);
            }
            self.agg.reassignments += 1;
        }
        Ok(())
    }

    fn after_wave(
        &mut self,
        now: f64,
        drained: bool,
        _net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        if drained {
            self.run.pump(now, q, state);
        }
        Ok(())
    }

    fn gauges(&self) -> HarnessGauges {
        HarnessGauges {
            occupancy: self.run.running.iter().map(|&r| r as u64).sum(),
            queued: self.run.sched.pending_count() as u64,
            spec_inflight: 0,
            replicas: 0,
        }
    }
}

/// Build a stage's segment list: every node's data, owned by the node
/// itself or (when it is already dead) its rack-diverse replica, split
/// into S_min/S_max-clamped pieces.  Errors when a home's whole
/// replica chain is dead — the data is gone, and a run that lost data
/// must not report a normal makespan (matching `run_terasplit`'s
/// behaviour).  Shared by the staged batch engine and the colocation
/// engine (DESIGN.md §11).
pub(crate) fn build_stage_segments(
    testbed: &Testbed,
    cfg: &SimConfig,
    state: &FaultState,
    bytes_per_node: f64,
    spes: usize,
) -> Result<Vec<Segment>, String> {
    let n = testbed.nodes();
    let target = (bytes_per_node / spes as f64).clamp(
        cfg.sphere.seg_min_bytes as f64,
        cfg.sphere.seg_max_bytes as f64,
    );
    let mut segments = Vec::new();
    for home in 0..n {
        let owner = live_owner(testbed, state, home)?;
        let replica = replica_of(testbed, owner);
        let mut locations: Vec<u32> = [owner, replica]
            .into_iter()
            .filter(|&x| !state.dead[x])
            .map(|x| x as u32)
            .collect();
        locations.dedup();
        if locations.is_empty() {
            locations.push(owner as u32);
        }
        let pieces = (bytes_per_node / target).ceil().max(1.0) as usize;
        let piece_bytes = (bytes_per_node / pieces as f64) as u64;
        for p in 0..pieces {
            segments.push(Segment {
                id: segments.len(),
                file: format!("scenario/node{home:04}.dat"),
                first_record: p as u64,
                n_records: 1,
                bytes: piece_bytes,
                locations: locations.clone(),
                whole_file: false,
            });
        }
    }
    Ok(segments)
}

/// Deterministic shuffle partner: the `salt`-th live node after `src`
/// in id order.  Takes the alive list by reference so hot-loop callers
/// build it once per event, not per lookup.
pub(crate) fn pick_dst_in(alive: &[usize], src: usize, salt: usize) -> Option<usize> {
    if alive.len() < 2 {
        return None;
    }
    let pos = alive.iter().position(|&x| x == src).unwrap_or(0);
    Some(alive[(pos + 1 + salt % (alive.len() - 1)) % alive.len()])
}

/// Per-segment coordination cost: Chord lookup hops + GMP handshake +
/// completion ack over the mean RTT (same shape as simjob).
pub(crate) fn coordination_secs(testbed: &Testbed) -> f64 {
    let n = testbed.nodes();
    let hops = (n as f64).log2().ceil().max(1.0);
    let mut acc = 0.0;
    for a in 0..n {
        for b in 0..n {
            acc += testbed.rtt_secs(a, b);
        }
    }
    let mean_rtt = acc / (n * n).max(1) as f64;
    hops * mean_rtt + 2.0 * mean_rtt
}

/// Rack-diverse replica partner — shared with the service layer's
/// catalog placement (`crate::topology::rack_diverse_replica`).
pub(crate) fn replica_of(testbed: &Testbed, node: usize) -> usize {
    rack_diverse_replica(testbed, node)
}

/// Walk `home`'s replica chain to a live owner; error when the whole
/// chain is dead — the data is gone, and a run that lost data must not
/// report a normal makespan.  Shared by the staged batch engine's
/// segment builder and the Angle pipeline's flow routing.
pub(crate) fn live_owner(
    testbed: &Testbed,
    state: &FaultState,
    home: usize,
) -> Result<usize, String> {
    let mut owner = home;
    for _ in 0..testbed.nodes() {
        if !state.dead[owner] {
            return Ok(owner);
        }
        owner = replica_of(testbed, owner);
    }
    Err(format!(
        "node {home}'s data lost: its whole replica chain crashed"
    ))
}

/// Transport-model rate cap for a shuffle transfer along `path`,
/// against NOMINAL link rates (degradation constrains the shared link
/// capacity instead, so a brown-out's slowdown lifts when the window
/// ends), bounded by the source disk at its straggler factor.  Shared
/// by the batch and colocation engines so a calibration change lands
/// in both.
pub(crate) fn shuffle_rate_cap(
    cfg: &SimConfig,
    models: &TransportModels,
    nominal_caps: &[f64],
    path: &[LinkId],
    nic_bps: f64,
    rtt: f64,
    src_factor: f64,
) -> f64 {
    let bottleneck = path
        .iter()
        .map(|l| nominal_caps[l.0])
        .fold(f64::INFINITY, f64::min)
        .min(nic_bps);
    let read = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
    match cfg.sphere_transport {
        TransportKind::Udt => udt_efficiency(models.udt.efficiency, rtt) * bottleneck,
        TransportKind::Tcp => models.tcp.rate_cap(bottleneck, rtt),
    }
    .min(read * src_factor)
}

// ------------------------------------------------------------ analytic paths

/// Terasplit: one client streams every node's sorted data sequentially
/// through the entropy scan (paper §6.2's "read ... into a single
/// client").  Crashed sources are served by their replica; a transfer
/// starting inside a degradation window pays its factor.
fn run_terasplit(
    testbed: &Testbed,
    cfg: &SimConfig,
    bytes_per_node: f64,
    state: &mut FaultState,
    agg: &mut Aggregate,
) -> Result<f64, String> {
    state.apply_crashes_due(0.0);
    let models = TransportModels::default();
    let read = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
    let mut client = *state
        .alive()
        .first()
        .ok_or("no live node to host the client")?;
    let mut now = 0.0f64;
    for home in 0..testbed.nodes() {
        state.apply_crashes_due(now);
        // The client itself can crash mid-run: the split job restarts
        // on the next live node (the gathered scan resumes from there).
        if state.dead[client] {
            client = *state
                .alive()
                .first()
                .ok_or("no live node to host the client")?;
            agg.reassignments += 1;
        }
        let src = if state.dead[home] {
            let r = replica_of(testbed, home);
            if state.dead[r] {
                return Err(format!("node {home} and its replica {r} both crashed"));
            }
            agg.reassignments += 1;
            r
        } else {
            home
        };
        let scan = cfg.cpu.scan_bps * state.factor[client];
        let rate = if src == client {
            (read * state.factor[client]).min(scan)
        } else {
            let rtt = testbed.rtt_secs(client, src);
            // WAN degradation only affects transfers that actually
            // cross a site uplink (cf. Testbed::path); within a site
            // the bottleneck of the two uplinks is what caps the flow.
            let (ss, cs) = (testbed.node_site[src], testbed.node_site[client]);
            let degrade = if ss == cs {
                1.0
            } else {
                state
                    .degrade_factor_counting(ss, now)
                    .min(state.degrade_factor_counting(cs, now))
            };
            let net_cap = match cfg.sphere_transport {
                TransportKind::Udt => {
                    udt_efficiency(models.udt.efficiency, rtt) * testbed.nic_bps * degrade
                }
                TransportKind::Tcp => models.tcp.rate_cap(testbed.nic_bps * degrade, rtt),
            };
            (read * state.factor[src]).min(net_cap).min(scan)
        };
        let setup = models.setup_secs_for(
            cfg.sphere_transport,
            testbed.rtt_secs(client, src),
            cfg.sector.connection_cache,
        );
        now += bytes_per_node / rate + setup;
        agg.events += 1;
        agg.segments += 1;
        agg.tier.add(testbed, src, client, bytes_per_node);
    }
    agg.stage_ends.push(("gather scan".to_string(), now));
    Ok(now)
}

/// Iterative distributed k-means: each round scans every live node's
/// share (the slowest node gates the round) then synchronizes centers
/// over Chord-hop RTTs.  Crashed nodes hand their share to survivors.
fn run_kmeans(
    testbed: &Testbed,
    cfg: &SimConfig,
    bytes_per_node: f64,
    iterations: usize,
    state: &mut FaultState,
    agg: &mut Aggregate,
) -> Result<f64, String> {
    let total = bytes_per_node * testbed.nodes() as f64;
    let read = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
    let scan = read.min(cfg.cpu.scan_bps);
    let sync = 2.0 * coordination_secs(testbed);
    let mut now = 0.0f64;
    for _round in 0..iterations {
        state.apply_crashes_due(now);
        let alive = state.alive();
        if alive.is_empty() {
            return Err("every node crashed".into());
        }
        let share = total / alive.len() as f64;
        let slowest = alive
            .iter()
            .map(|&nd| share / (scan * state.factor[nd]))
            .fold(0.0f64, f64::max);
        now += slowest + sync;
        agg.events += alive.len() as u64 + 1;
        agg.segments += alive.len();
    }
    agg.stage_ends.push(("kmeans rounds".to_string(), now));
    Ok(now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{FaultSpec, ScenarioSpec};
    use crate::topology::TopologySpec;
    use crate::util::bytes::GB;

    fn lan_spec(nodes: usize, kind: WorkloadKind) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::paper_lan(nodes);
        let w = spec.workload.as_mut().unwrap();
        w.kind = kind;
        w.bytes_per_node = 1.0 * GB as f64;
        spec.name = format!("test-{}-{nodes}", kind.name());
        spec
    }

    #[test]
    fn terasort_runs_and_is_deterministic() {
        let spec = lan_spec(4, WorkloadKind::Terasort);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "same spec, same report");
        assert!(a.makespan_secs > 0.0);
        assert!(a.segments > 0);
        assert!(a.shuffle_gbytes > 0.0);
        assert_eq!(a.faults_injected, 0);
        assert!(
            a.locality_fraction > 0.9,
            "fault-free run stays local (got {})",
            a.locality_fraction
        );
    }

    #[test]
    fn all_workloads_complete() {
        for kind in [
            WorkloadKind::Terasort,
            WorkloadKind::Terasplit,
            WorkloadKind::Filegen,
            WorkloadKind::Angle,
            WorkloadKind::Kmeans,
        ] {
            let r = run_scenario(&lan_spec(4, kind)).unwrap();
            assert!(r.makespan_secs > 0.0, "{}: empty makespan", kind.name());
            assert!(r.events > 0, "{}: no events", kind.name());
        }
    }

    #[test]
    fn crash_reassigns_and_still_finishes() {
        let mut spec = lan_spec(4, WorkloadKind::Terasort);
        let baseline = run_scenario(&spec).unwrap();
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 2.0,
            node: 1,
        });
        let r = run_scenario(&spec).unwrap();
        assert_eq!(r.nodes_crashed, 1);
        assert!(r.faults_injected >= 1);
        assert!(r.reassignments > 0, "crash mid-run must reassign work");
        assert!(
            r.makespan_secs > baseline.makespan_secs,
            "3 survivors absorb the 4th node's work: {} vs {}",
            r.makespan_secs,
            baseline.makespan_secs
        );
        assert_eq!(r.segments, baseline.segments, "no segment is lost");
    }

    #[test]
    fn exhausted_retries_surface_as_explicit_job_failure() {
        // Regression: with a 1-attempt budget, a crash that kills a
        // running segment must FAIL the run naming the segment — never
        // complete with the segment silently dropped from pending.
        let mut spec = lan_spec(4, WorkloadKind::Terasort);
        spec.cfg.sphere.max_attempts = 1;
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 2.0,
            node: 1,
        });
        let err = run_scenario(&spec).unwrap_err();
        assert!(err.contains("exhausted"), "{err}");
        assert!(err.contains("segment"), "{err}");
        // With the default budget the same crash recovers.
        spec.cfg.sphere.max_attempts = 4;
        run_scenario(&spec).unwrap();
    }

    #[test]
    fn straggler_slows_the_run() {
        let mut spec = lan_spec(4, WorkloadKind::Terasort);
        let baseline = run_scenario(&spec).unwrap();
        spec.faults.push(FaultSpec::Straggler {
            node: 2,
            factor: 0.25,
        });
        let r = run_scenario(&spec).unwrap();
        assert!(r.makespan_secs > baseline.makespan_secs);
    }

    #[test]
    fn wan_degradation_slows_the_shuffle() {
        let mut spec = ScenarioSpec::paper_wan6();
        spec.workload.as_mut().unwrap().bytes_per_node = 1.0 * GB as f64;
        let baseline = run_scenario(&spec).unwrap();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.05,
        });
        let r = run_scenario(&spec).unwrap();
        assert!(
            r.makespan_secs > baseline.makespan_secs,
            "choked Chicago uplink: {} vs {}",
            r.makespan_secs,
            baseline.makespan_secs
        );
    }

    #[test]
    fn losing_a_node_and_its_replica_fails_the_run() {
        // scale_out(1,2,2): replica pairs are 0<->2 and 1<->3. Killing
        // both ends of a pair destroys that data; the run must error
        // like run_terasplit does, not report a normal makespan.
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(1, 2, 2);
        spec.workload.as_mut().unwrap().bytes_per_node = 1.0 * GB as f64;
        spec.faults.push(FaultSpec::SlaveCrash { at_secs: 0.5, node: 0 });
        spec.faults.push(FaultSpec::SlaveCrash { at_secs: 1.0, node: 2 });
        let err = run_scenario(&spec).unwrap_err();
        assert!(err.contains("data lost"), "{err}");
    }

    #[test]
    fn degradation_lifts_when_the_window_ends() {
        // Flows started inside the window must speed back up when it
        // closes (their caps are nominal; the shared link capacity is
        // what degrades), so a brief brown-out beats a permanent one.
        let mut spec = ScenarioSpec::paper_wan6();
        spec.workload.as_mut().unwrap().bytes_per_node = 1.0 * GB as f64;
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: 10.0,
            site: 0,
            factor: 0.05,
        });
        let brief = run_scenario(&spec).unwrap();
        spec.faults[0] = FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.05,
        };
        let forever = run_scenario(&spec).unwrap();
        assert!(
            brief.makespan_secs < forever.makespan_secs,
            "brief window must recover: {} vs {}",
            brief.makespan_secs,
            forever.makespan_secs
        );
    }

    #[test]
    fn overlapping_degrade_windows_compound() {
        let mut spec = ScenarioSpec::paper_wan6();
        spec.workload.as_mut().unwrap().bytes_per_node = 1.0 * GB as f64;
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.2,
        });
        let single = run_scenario(&spec).unwrap();
        assert_eq!(single.faults_injected, 1, "one window counts once across stages");
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.2,
        });
        let double = run_scenario(&spec).unwrap();
        assert!(
            double.makespan_secs > single.makespan_secs,
            "stacked windows compound (0.04x): {} vs {}",
            double.makespan_secs,
            single.makespan_secs
        );
    }

    #[test]
    fn replica_partner_is_rack_diverse() {
        let t = TopologySpec::scale_out(2, 2, 4).generate().unwrap();
        for node in 0..t.nodes() {
            let r = replica_of(&t, node);
            assert_ne!(t.node_rack[node], t.node_rack[r], "node {node} -> {r}");
        }
        let single = TopologySpec::paper_lan(4).generate().unwrap();
        assert_eq!(replica_of(&single, 3), 0, "single rack wraps to next node");
    }

    #[test]
    fn scale128_preset_runs_deterministically() {
        let spec = ScenarioSpec::scale128();
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.nodes, 128);
        assert_eq!(a.nodes_crashed, 1);
        assert!(a.faults_injected >= 2);
        assert!(a.events > 1000, "segment waves at scale ({})", a.events);
    }
}

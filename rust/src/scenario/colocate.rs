//! Colocated compute + serving engine (DESIGN.md §11).
//!
//! The paper's thesis is ONE cloud that simultaneously archives,
//! analyzes and mines large data sets (§1); the companion papers
//! (arXiv:0809.1181, arXiv:0907.4810) describe exactly this shared
//! deployment: Sphere jobs contending with wide-area client traffic on
//! the same disks and links.  This engine makes that scenario class
//! expressible: a `ScenarioSpec` carrying BOTH a `[workload]` and a
//! `[traffic]` block runs here, on ONE shared substrate —
//!
//! * one `NetSim` holds the topology links AND the per-node disk
//!   links, so batch segment I/O, shuffle transfers, client reads and
//!   background replication all share spindles and WAN tiers through
//!   max-min fairness;
//! * one `EventQueue<CoEv>` interleaves both sides' events (the
//!   service engine is generic over any event type convertible from
//!   its own, so it pushes into the joint queue unchanged);
//! * one `FaultState` applies the fault plan to both sides: a crash
//!   re-queues segments AND re-dispatches requests, a WAN brown-out
//!   squeezes shuffles AND cross-site reads.
//!
//! The event loop itself is the shared engine core (`scenario::core`,
//! DESIGN.md §14): both sides plug in as one [`core::Harness`], so
//! fault application and dispatch order are the core's, not copies.
//!
//! The job side models a segment as a flow through its node's disk
//! links whose rate cap is the stage's nominal pipeline rate (so an
//! uncontended run reproduces the staged batch engine's shape, and
//! tenant I/O on the same spindle slows it) — throttled to
//! `colocation.job_share` of the disk when a reservation for tenant
//! I/O is configured.
//!
//! **Speculative re-execution** (§3.2's slow-node handling, the
//! mechanism behind Hadoop-style speculation): when a running
//! attempt's elapsed time exceeds `colocation.threshold` × the running
//! median segment duration, a backup attempt is dispatched to another
//! live replica holder with a free SPE.  First finisher wins
//! (`Scheduler::complete` is first-finisher-wins per segment id), the
//! loser's flow is cancelled, and the `speculative_launched` /
//! `speculative_won` counters surface in the report.
//!
//! The report is a joint view: job makespan + per-stage breakdown,
//! the full per-tenant SLO table, and per-tenant percentile *deltas*
//! against an uncolocated baseline (the same traffic run alone on an
//! identical substrate — computed here, deterministically, as part of
//! the run).

use std::collections::BTreeMap;

use crate::config::SimConfig;
use crate::mining::angle::simulate_angle_clustering;
use crate::mining::pcap::PACKET_BYTES;
use crate::service::engine::{Engine as TrafficEngine, Ev as SvcEv};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::sphere::scheduler::Scheduler;
use crate::sphere::segment::Segment;
use crate::topology::{NetLinks, Testbed};
use crate::transport::TransportModels;

use super::core::{self, CoreEv, FaultEv, Harness, SpecCand, Speculation};
use super::engine::{
    FaultState, ScenarioReport, StageKind, build_stage_segments, coordination_secs, pick_dst_in,
    shuffle_rate_cap,
};
use super::trace::{HarnessGauges, TraceRecorder, Tracer};
use super::{ScenarioSpec, WorkloadKind, WorkloadSpec};

/// Minimum completed segments before the running median is trusted.
const SPEC_MIN_SAMPLES: usize = 5;

/// Per-tenant SLO damage of colocation: colocated minus uncolocated
/// percentile latency, in milliseconds (positive = colocation hurt).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSloDelta {
    pub name: String,
    pub p50_delta_ms: f64,
    pub p95_delta_ms: f64,
    pub p99_delta_ms: f64,
}

/// The joint view a colocated run adds to [`ScenarioReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct ColocationReport {
    /// When the batch job finished (client traffic may run longer).
    pub job_makespan_secs: f64,
    /// (stage name, end time) in execution order.
    pub stage_ends: Vec<(String, f64)>,
    /// Colocated-vs-baseline percentile deltas, one entry per tenant.
    pub tenant_deltas: Vec<TenantSloDelta>,
}

// ------------------------------------------------------------ events

/// Joint event type: either side's events ride one queue.
enum CoEv {
    Job(JobEv),
    Svc(SvcEv),
}

enum JobEv {
    /// Coordination delay elapsed: start the attempt's disk flow.
    SegStart { gen: u64 },
    /// Re-scan in-flight attempts for speculation candidates.
    SpecCheck,
}

impl From<SvcEv> for CoEv {
    fn from(e: SvcEv) -> CoEv {
        CoEv::Svc(e)
    }
}

impl From<JobEv> for CoEv {
    fn from(e: JobEv) -> CoEv {
        CoEv::Job(e)
    }
}

impl CoreEv for CoEv {
    fn from_fault(f: FaultEv) -> CoEv {
        CoEv::Svc(SvcEv::from_fault(f))
    }

    fn to_fault(&self) -> Option<FaultEv> {
        match self {
            CoEv::Svc(e) => e.to_fault(),
            CoEv::Job(_) => None,
        }
    }
}

// ------------------------------------------------------------ job side

/// One running (or coordinating) attempt of a segment.
struct Attempt {
    node: usize,
    seg: Segment,
    started: f64,
    /// None while the coordination handshake is in flight.
    fid: Option<FlowId>,
    speculative: bool,
}

enum JobFlow {
    /// A segment's disk I/O pipeline on its executing node.
    Service { gen: u64 },
    /// Stage-A shuffle transfer between nodes.
    Shuffle { src: usize, dst: usize },
}

/// The batch job half of a colocated run: the staged segment engine
/// re-expressed over the shared substrate, plus speculation.
struct JobSide<'a> {
    testbed: &'a Testbed,
    cfg: &'a SimConfig,
    kinds: &'static [StageKind],
    stage: usize,
    bytes_per_node: f64,
    links: NetLinks,
    disk_read: Vec<LinkId>,
    disk_write: Vec<LinkId>,
    nominal_caps: Vec<f64>,
    models: TransportModels,
    sched: Scheduler,
    inflight: BTreeMap<u64, Attempt>,
    /// Sibling-attempt bookkeeping (core-owned; engine keeps policy).
    spec: Speculation,
    /// Completed attempt durations this stage, sorted ascending.
    durations: Vec<f64>,
    next_gen: u64,
    running: Vec<usize>,
    flows: BTreeMap<FlowId, JobFlow>,
    coord_secs: f64,
    // colocation knobs
    speculative: bool,
    threshold: f64,
    job_share: f64,
    // counters
    segments: usize,
    reassignments: u64,
    shuffle_bytes: f64,
    local_assignments: u64,
    remote_assignments: u64,
    spec_launched: u64,
    spec_won: u64,
    stage_ends: Vec<(String, f64)>,
    done: bool,
    makespan: f64,
    /// Observability feed for task spans, speculation marks and
    /// cancelled flows.
    tracer: Tracer,
}

impl<'a> JobSide<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        spec: &'a ScenarioSpec,
        workload: &WorkloadSpec,
        testbed: &'a Testbed,
        links: NetLinks,
        disk_read: Vec<LinkId>,
        disk_write: Vec<LinkId>,
        nominal_caps: Vec<f64>,
        state: &FaultState,
        tracer: Tracer,
    ) -> Result<JobSide<'a>, String> {
        let kinds = StageKind::stages_of(workload.kind)
            .ok_or("colocation: analytic workloads have no event stream to colocate")?;
        let cfg = &spec.cfg;
        let spes = cfg.sphere.spes_per_node.max(1);
        let segments = build_stage_segments(testbed, cfg, state, workload.bytes_per_node, spes)?;
        let mut sched = Scheduler::new(segments, cfg.sphere.locality_scheduling);
        sched.max_attempts = cfg.sphere.max_attempts;
        Ok(JobSide {
            testbed,
            cfg,
            kinds,
            stage: 0,
            bytes_per_node: workload.bytes_per_node,
            links,
            disk_read,
            disk_write,
            nominal_caps,
            models: TransportModels::default(),
            sched,
            inflight: BTreeMap::new(),
            spec: Speculation::new(),
            durations: Vec::new(),
            next_gen: 0,
            running: vec![0; testbed.nodes()],
            flows: BTreeMap::new(),
            coord_secs: coordination_secs(testbed),
            speculative: spec.colocation.speculative,
            threshold: spec.colocation.threshold,
            job_share: spec.colocation.job_share,
            segments: 0,
            reassignments: 0,
            shuffle_bytes: 0.0,
            local_assignments: 0,
            remote_assignments: 0,
            spec_launched: 0,
            spec_won: 0,
            stage_ends: Vec::new(),
            done: false,
            makespan: 0.0,
            tracer,
        })
    }

    fn spes(&self) -> usize {
        self.cfg.sphere.spes_per_node.max(1)
    }

    /// Hand pending segments to every idle SPE slot.  While the master
    /// is down no NEW segment can be scheduled (assignment goes through
    /// it); in-flight work keeps running and the drained-wave pump
    /// resumes dispatch after `MasterUp` (DESIGN.md §18).
    fn pump(&mut self, now: f64, q: &mut EventQueue<CoEv>, state: &FaultState) {
        if state.master_down {
            return;
        }
        let spes = self.spes();
        for node in 0..self.testbed.nodes() {
            if state.dead[node] {
                continue;
            }
            while self.running[node] < spes {
                let Some(seg) = self.sched.assign(node as u32) else {
                    break;
                };
                self.next_gen += 1;
                let gen = self.next_gen;
                self.spec.register(seg.id, gen);
                self.inflight.insert(
                    gen,
                    Attempt {
                        node,
                        seg,
                        started: now,
                        fid: None,
                        speculative: false,
                    },
                );
                self.running[node] += 1;
                q.push_at(now + self.coord_secs, JobEv::SegStart { gen }.into());
            }
        }
    }

    /// Start the attempt's disk-I/O flow: the stage's pipeline as one
    /// flow through the node's (shared) disk links, rate-capped at the
    /// nominal pipeline rate × the straggler factor, and at
    /// `job_share` of the disk when tenant I/O has a reservation.
    fn start_segment_flow(&mut self, gen: u64, net: &mut NetSim, state: &FaultState) {
        let Some(att) = self.inflight.get_mut(&gen) else {
            return; // pre-empted by a crash or a speculation win
        };
        let kind = self.kinds[self.stage];
        let bytes = att.seg.bytes as f64;
        let nominal_secs = kind.service_secs(self.cfg, bytes).max(1e-9);
        let mut cap = (bytes / nominal_secs) * state.factor[att.node];
        let (reads, writes) = kind.touches_disk();
        let mut path = Vec::with_capacity(2);
        let mut disk_cap = f64::INFINITY;
        if reads {
            let l = self.disk_read[att.node];
            path.push(l);
            disk_cap = disk_cap.min(self.nominal_caps[l.0]);
        }
        if writes {
            let l = self.disk_write[att.node];
            path.push(l);
            disk_cap = disk_cap.min(self.nominal_caps[l.0]);
        }
        if self.job_share < 1.0 && disk_cap.is_finite() {
            cap = cap.min(self.job_share * disk_cap);
        }
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        att.fid = Some(fid);
        self.flows.insert(fid, JobFlow::Service { gen });
    }

    fn start_shuffle_flow(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        net: &mut NetSim,
        state: &FaultState,
    ) {
        let path = self.testbed.path(&self.links, src, dst);
        let cap = shuffle_rate_cap(
            self.cfg,
            &self.models,
            &self.nominal_caps,
            &path,
            self.testbed.nic_bps,
            self.testbed.rtt_secs(src, dst),
            state.factor[src],
        );
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, JobFlow::Shuffle { src, dst });
    }

    /// A network flow landed.  Returns `true` when it was job-side.
    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &FaultState,
    ) -> bool {
        let Some(flow) = self.flows.remove(&fid) else {
            return false;
        };
        let JobFlow::Service { gen } = flow else {
            return true; // shuffle landed; nothing to bookkeep
        };
        let Some(att) = self.inflight.remove(&gen) else {
            return true;
        };
        self.running[att.node] -= 1;
        let first = self.sched.complete(&att.seg);
        // First-finisher-wins: cancel every sibling attempt (the
        // speculation loser, or the original when the backup won).
        for g in self.spec.take_losers(att.seg.id, gen) {
            if let Some(loser) = self.inflight.remove(&g) {
                self.running[loser.node] -= 1;
                if let Some(lfid) = loser.fid {
                    self.flows.remove(&lfid);
                    net.try_cancel_flow(lfid);
                    self.tracer.flow_cancel(lfid, now);
                }
                self.sched.cancel_attempt(&loser.seg);
            }
        }
        if first {
            let stage_name = self.kinds[self.stage].name();
            self.tracer
                .task(att.started, now, "segment", att.node, stage_name);
            if att.speculative {
                self.sched.record_speculative_win();
                self.tracer.task_mark(now, "spec won", att.node, stage_name);
            }
            self.segments += 1;
            let d = (now - att.started).max(0.0);
            let pos = self.durations.partition_point(|&x| x <= d);
            self.durations.insert(pos, d);
            if self.kinds[self.stage].shuffles() {
                let (n_alive, dst) = {
                    let alive = state.alive();
                    (alive.len(), pick_dst_in(alive, att.node, att.seg.id))
                };
                if let Some(dst) = dst {
                    let frac = (n_alive - 1) as f64 / n_alive as f64;
                    let bytes = att.seg.bytes as f64 * frac;
                    self.start_shuffle_flow(att.node, dst, bytes, net, state);
                    self.shuffle_bytes += bytes;
                }
            }
        }
        // Pending work first (an idle slot prefers real segments),
        // speculation takes whatever slots are left over.
        self.pump(now, q, state);
        self.maybe_speculate(now, q, state);
        true
    }

    /// Scan in-flight attempts: launch a backup for any attempt past
    /// `threshold` × the running median, and schedule a re-check at
    /// the earliest future crossing so a stage whose only remaining
    /// work is straggling still speculates without new completions.
    fn maybe_speculate(&mut self, now: f64, q: &mut EventQueue<CoEv>, state: &FaultState) {
        if !self.speculative || self.durations.len() < SPEC_MIN_SAMPLES {
            return;
        }
        let median = crate::util::stats::median_nearest_rank(&self.durations);
        if !(median > 0.0) {
            return;
        }
        let cutoff = self.threshold * median;
        let (launch, cross) = self.spec.scan(
            now,
            cutoff,
            self.inflight.iter().map(|(&gen, att)| SpecCand {
                gen,
                unit: att.seg.id,
                started: att.started,
                speculative: att.speculative,
            }),
        );
        for gen in launch {
            self.launch_backup(gen, now, q, state);
        }
        self.spec
            .schedule_recheck(cross, now, q, || JobEv::SpecCheck.into());
    }

    /// Dispatch a backup attempt of `gen`'s segment to another live
    /// replica holder with a free SPE slot (no holder free: skip — a
    /// later scan will retry).
    fn launch_backup(&mut self, gen: u64, now: f64, q: &mut EventQueue<CoEv>, state: &FaultState) {
        let (seg, primary_node) = {
            let att = &self.inflight[&gen];
            (att.seg.clone(), att.node)
        };
        let spes = self.spes();
        let backup = seg
            .locations
            .iter()
            .map(|&l| l as usize)
            .find(|&l| l != primary_node && !state.dead[l] && self.running[l] < spes);
        let Some(backup) = backup else {
            return;
        };
        if !self.sched.speculate(&seg, backup as u32) {
            return;
        }
        self.tracer
            .task_mark(now, "speculate", backup, self.kinds[self.stage].name());
        self.spec.mark_speculated(seg.id);
        self.next_gen += 1;
        let bgen = self.next_gen;
        self.spec.register(seg.id, bgen);
        self.inflight.insert(
            bgen,
            Attempt {
                node: backup,
                seg,
                started: now,
                fid: None,
                speculative: true,
            },
        );
        self.running[backup] += 1;
        q.push_at(now + self.coord_secs, JobEv::SegStart { gen: bgen }.into());
    }

    /// The driving loop applied a crash to the shared state: cancel
    /// this node's attempts (re-queue the segment unless a sibling
    /// attempt survives elsewhere — its attempt count is preserved in
    /// the scheduler's id-keyed map) and re-route transfers toward it.
    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let stale: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, a)| a.node == node)
            .map(|(&g, _)| g)
            .collect();
        for g in stale {
            let att = self.inflight.remove(&g).expect("stale gen exists");
            if let Some(fid) = att.fid {
                self.flows.remove(&fid);
                net.try_cancel_flow(fid);
                self.tracer.flow_cancel(fid, now);
            }
            let siblings = self.spec.drop_attempt(att.seg.id, g);
            if siblings > 0 {
                // The other attempt (primary or backup) lives on: no
                // re-assignment happens, so none is counted.
                self.sched.cancel_attempt(&att.seg);
            } else {
                let id = att.seg.id;
                if !self.sched.fail(att.seg) {
                    return Err(format!(
                        "job failed: segment {id} exhausted its {} attempts \
                         after node {node} crashed",
                        self.sched.max_attempts
                    ));
                }
                self.reassignments += 1;
            }
        }
        self.running[node] = 0;
        // Re-route shuffle transfers headed for the dead node.
        let redirect: Vec<(FlowId, usize, usize)> = self
            .flows
            .iter()
            .filter_map(|(&f, fl)| match fl {
                JobFlow::Shuffle { src, dst } if *dst == node => Some((f, *src, *dst)),
                _ => None,
            })
            .collect();
        for (fid, src, dst) in redirect {
            self.flows.remove(&fid);
            let left = net.cancel_flow(fid);
            self.tracer.flow_cancel(fid, now);
            let new_dst = {
                let alive = state.alive();
                pick_dst_in(alive, src, dst + 1)
            };
            if let Some(nd) = new_dst {
                self.start_shuffle_flow(src, nd, left, net, state);
            }
            self.reassignments += 1;
        }
        self.pump(now, q, state);
        Ok(())
    }

    /// Stage fully drained (segments, attempts and shuffle flows)?
    fn stage_idle(&self) -> bool {
        !self.done
            && self.sched.is_drained()
            && self.inflight.is_empty()
            && self.flows.is_empty()
    }

    /// Close the current stage; open the next (or finish the job).
    fn finish_stage(
        &mut self,
        now: f64,
        q: &mut EventQueue<CoEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        debug_assert!(self.sched.exhausted().is_empty(), "exhaustion aborts earlier");
        self.local_assignments += self.sched.local_assignments;
        self.remote_assignments += self.sched.remote_assignments;
        self.spec_launched += self.sched.speculative_launched;
        self.spec_won += self.sched.speculative_won;
        self.tracer.stage_mark(now, self.kinds[self.stage].name());
        self.stage_ends
            .push((self.kinds[self.stage].name().to_string(), now));
        self.stage += 1;
        if self.stage >= self.kinds.len() {
            self.done = true;
            self.makespan = now;
            return Ok(());
        }
        let spes = self.spes();
        let segments =
            build_stage_segments(self.testbed, self.cfg, state, self.bytes_per_node, spes)?;
        let mut sched = Scheduler::new(segments, self.cfg.sphere.locality_scheduling);
        sched.max_attempts = self.sched.max_attempts;
        self.sched = sched;
        self.durations.clear();
        self.spec.clear_stage();
        self.pump(now, q, state);
        Ok(())
    }
}

// ------------------------------------------------------------ driver

/// Both halves of a colocated run plugged into the shared engine core:
/// flow completions try the job side first (its flow map answers), a
/// crash hits the service THEN the job (the job's recovery may abort),
/// and the post-wave hook closes a drained batch stage.
struct CoHarness<'r, 'a> {
    job: &'r mut JobSide<'a>,
    svc: &'r mut TrafficEngine<'a>,
}

impl<'r, 'a> Harness for CoHarness<'r, 'a> {
    type Ev = CoEv;

    fn finished(&self, net: &NetSim) -> bool {
        self.job.done && self.svc.done() && net.active_flows() == 0
    }

    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        if !self.job.flow_done(fid, now, net, q, state) {
            self.svc.flow_done(fid, now, net, q, state);
        }
        Ok(())
    }

    fn handle(
        &mut self,
        ev: CoEv,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        match ev {
            CoEv::Svc(other) => self.svc.handle_event(other, now, net, q, state),
            CoEv::Job(JobEv::SegStart { gen }) => self.job.start_segment_flow(gen, net, state),
            CoEv::Job(JobEv::SpecCheck) => {
                self.job.spec.recheck_fired();
                self.job.maybe_speculate(now, q, state);
            }
        }
        Ok(())
    }

    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.svc.on_crash(node, now, net, q);
        self.job.on_crash(node, now, net, q, state)
    }

    fn on_join(
        &mut self,
        _node: usize,
        now: f64,
        _net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        // The re-joined node's SPE slots are idle: offer it pending work.
        self.job.pump(now, q, state);
        Ok(())
    }

    fn on_master(
        &mut self,
        up: bool,
        now: f64,
        _net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        // Recovery resumes batch dispatch; client traffic never stopped
        // (metadata is cached client-side, paper §4).
        if up {
            self.job.pump(now, q, state);
        }
        Ok(())
    }

    fn after_wave(
        &mut self,
        now: f64,
        _drained: bool,
        _net: &mut NetSim,
        q: &mut EventQueue<CoEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        if self.job.stage_idle() {
            self.job.finish_stage(now, q, state)?;
        }
        Ok(())
    }

    fn gauges(&self) -> HarnessGauges {
        let svc = self.svc.gauges();
        HarnessGauges {
            occupancy: svc.occupancy + self.job.running.iter().map(|&r| r as u64).sum::<u64>(),
            queued: svc.queued + self.job.sched.pending_count() as u64,
            spec_inflight: self
                .job
                .inflight
                .values()
                .filter(|a| a.speculative)
                .count() as u64,
            replicas: svc.replicas,
        }
    }
}

/// Run a colocated scenario to completion.  Deterministic: the spec is
/// the only input — including the embedded uncolocated baseline run.
pub(crate) fn run_colocated(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<ScenarioReport, String> {
    let workload = spec
        .workload
        .as_ref()
        .ok_or("colocated run requires a [workload] block")?;
    let tspec = spec
        .traffic
        .as_ref()
        .ok_or("colocated run requires a [traffic] block")?;
    tspec.validate()?;

    // Uncolocated baseline: the identical traffic alone on an identical
    // substrate, so the report can state what colocation cost each
    // tenant.  Deterministic, so the joint report stays byte-stable.
    let baseline = {
        let mut solo = spec.clone();
        solo.workload = None;
        crate::service::run_traffic(&solo, testbed, rec)?
    };
    let baseline_traffic = baseline.traffic.expect("traffic-only run reports SLOs");

    let n = testbed.nodes();
    let mut state = FaultState::for_run(spec, testbed);
    let mut net =
        NetSim::with_capacity(4 * n + 2 * testbed.racks() + 2 * testbed.site_names.len());
    let links = testbed.build_network(&mut net);
    let mut q: EventQueue<CoEv> = EventQueue::with_capacity(4096);
    let tracer = rec.tracer("colocate");
    let mut svc = TrafficEngine::new(
        spec,
        tspec,
        testbed,
        &mut net,
        links.clone(),
        &state,
        tracer.clone(),
    )?;
    let mut job = JobSide::new(
        spec,
        workload,
        testbed,
        links.clone(),
        svc.disk_read.clone(),
        svc.disk_write.clone(),
        svc.nominal_caps.clone(),
        &state,
        tracer.clone(),
    )?;

    core::schedule_faults(&mut state, &mut q, 0.0);
    svc.schedule_arrivals(&mut q);
    job.pump(0.0, &mut q, &state);

    let out = {
        let mut h = CoHarness {
            job: &mut job,
            svc: &mut svc,
        };
        core::drive(&mut h, &mut net, &mut q, &mut state, &links, testbed, &tracer)?
    };
    let events = out.events;

    let mut job_makespan = job.makespan;
    if workload.kind == WorkloadKind::Angle {
        // Legacy colocated Angle: extraction on the substrate plus the
        // Table 3 clustering scalar.  The staged five-stage pipeline
        // (DESIGN.md §13) does not colocate yet — `[angle]` + `[traffic]`
        // is rejected at validation so the difference stays explicit.
        let records = workload.bytes_per_node * testbed.nodes() as f64 / PACKET_BYTES as f64;
        job_makespan += simulate_angle_clustering(records, job.segments as f64);
    }
    let traffic = svc.traffic_report();
    let tenant_deltas: Vec<TenantSloDelta> = traffic
        .tenants
        .iter()
        .zip(&baseline_traffic.tenants)
        .map(|(c, b)| TenantSloDelta {
            name: c.name.clone(),
            p50_delta_ms: c.p50_ms - b.p50_ms,
            p95_delta_ms: c.p95_ms - b.p95_ms,
            p99_delta_ms: c.p99_ms - b.p99_ms,
        })
        .collect();
    let assignments = job.local_assignments + job.remote_assignments;
    Ok(ScenarioReport {
        name: spec.name.clone(),
        workload: colocated_name(workload.kind),
        nodes: testbed.nodes(),
        racks: testbed.racks(),
        sites: testbed.site_names.len(),
        makespan_secs: job_makespan.max(traffic.makespan_secs),
        events,
        segments: job.segments,
        reassignments: job.reassignments + svc.reassignments,
        locality_fraction: if assignments == 0 {
            0.0
        } else {
            job.local_assignments as f64 / assignments as f64
        },
        shuffle_gbytes: job.shuffle_bytes / 1e9,
        faults_injected: state.injected,
        nodes_crashed: state.crashes,
        speculative_launched: job.spec_launched,
        speculative_won: job.spec_won,
        traffic: Some(traffic),
        colocation: Some(ColocationReport {
            job_makespan_secs: job_makespan,
            stage_ends: job.stage_ends,
            tenant_deltas,
        }),
        comparison: None,
        angle: None,
        elasticity: None,
        trace_digest: String::new(),
    })
}

fn colocated_name(kind: WorkloadKind) -> &'static str {
    match kind {
        WorkloadKind::Terasort => "terasort+traffic",
        WorkloadKind::Filegen => "filegen+traffic",
        WorkloadKind::Angle => "angle+traffic",
        WorkloadKind::Terasplit | WorkloadKind::Kmeans => {
            unreachable!("analytic workloads are rejected before a colocated run")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{ColocationSpec, FaultSpec, run_scenario};
    use crate::service::{ArrivalProcess, ArrivalShape, TenantSpec, TrafficSpec};
    use crate::topology::TopologySpec;
    use crate::util::bytes::GB;

    /// Small colocated scenario: 8 nodes, 2 sites, terasort + 2 tenants.
    fn co_spec(requests: u64, rps: f64) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(2, 2, 2);
        spec.name = "colocate-test".into();
        spec.workload.as_mut().unwrap().bytes_per_node = 0.5 * GB as f64;
        spec.traffic = Some(TrafficSpec {
            clients: 1000,
            requests,
            files: 64,
            zipf_theta: 0.9,
            arrival: ArrivalProcess::Open { rps },
            shape: ArrivalShape::Flat,
            tenants: vec![
                TenantSpec {
                    name: "web".into(),
                    weight: 0.8,
                    write_fraction: 0.1,
                    object_bytes: 1.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "bulk".into(),
                    weight: 0.2,
                    write_fraction: 0.5,
                    object_bytes: 8.0e6,
                    priority: 0,
                },
            ],
        });
        spec
    }

    #[test]
    fn colocated_run_completes_and_is_deterministic() {
        let spec = co_spec(1500, 400.0);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "same spec, same joint report");
        assert_eq!(a.workload, "terasort+traffic");
        let t = a.traffic.as_ref().expect("SLO table present");
        assert_eq!(t.requests, 1500);
        assert_eq!(t.completed + t.rejected + t.unavailable, 1500);
        let co = a.colocation.as_ref().expect("joint view present");
        assert!(co.job_makespan_secs > 0.0);
        assert_eq!(co.stage_ends.len(), 2, "terasort reports both stages");
        assert!(co.stage_ends[0].1 <= co.stage_ends[1].1);
        assert_eq!(co.tenant_deltas.len(), 2);
        assert!(a.segments > 0, "job segments completed");
        assert!(a.shuffle_gbytes > 0.0, "stage A shuffled");
        assert!(
            a.makespan_secs >= co.job_makespan_secs,
            "joint makespan covers the job"
        );
    }

    #[test]
    fn colocation_slows_the_job_and_the_tenants() {
        // The same job alone (batch engine), then colocated with heavy
        // traffic: contention must show on BOTH sides of the report.
        let spec = co_spec(2500, 1200.0);
        let mut solo = spec.clone();
        solo.traffic = None;
        let solo_r = run_scenario(&solo).unwrap();
        let co_r = run_scenario(&spec).unwrap();
        let co = co_r.colocation.as_ref().unwrap();
        assert!(
            co.job_makespan_secs > solo_r.makespan_secs,
            "tenant I/O on the same disks must slow the job: {} vs {}",
            co.job_makespan_secs,
            solo_r.makespan_secs
        );
        assert!(
            co.tenant_deltas.iter().any(|d| d.p99_delta_ms > 0.0),
            "the job must damage some tenant p99 vs the uncolocated \
             baseline: {:?}",
            co.tenant_deltas
        );
    }

    #[test]
    fn speculation_beats_a_straggler() {
        let mut spec = co_spec(1000, 300.0);
        spec.faults.push(FaultSpec::Straggler {
            node: 1,
            factor: 0.25,
        });
        spec.colocation = ColocationSpec {
            speculative: true,
            threshold: 1.75,
            job_share: 1.0,
        };
        let with = run_scenario(&spec).unwrap();
        spec.colocation.speculative = false;
        let without = run_scenario(&spec).unwrap();
        assert!(with.speculative_launched > 0, "straggler must trigger backups");
        assert!(
            with.speculative_won > 0,
            "a backup on a healthy node must beat the 4x-slow primary"
        );
        assert_eq!(without.speculative_launched, 0, "knob off means no backups");
        assert!(
            with.colocation.as_ref().unwrap().job_makespan_secs
                < without.colocation.as_ref().unwrap().job_makespan_secs,
            "speculation must cut the straggler's tail: {} vs {}",
            with.colocation.as_ref().unwrap().job_makespan_secs,
            without.colocation.as_ref().unwrap().job_makespan_secs
        );
    }

    #[test]
    fn job_share_throttles_the_job() {
        let mut spec = co_spec(800, 200.0);
        spec.colocation.job_share = 0.25;
        let throttled = run_scenario(&spec).unwrap();
        spec.colocation.job_share = 1.0;
        let full = run_scenario(&spec).unwrap();
        assert!(
            throttled.colocation.as_ref().unwrap().job_makespan_secs
                > full.colocation.as_ref().unwrap().job_makespan_secs,
            "a 25% disk reservation must slow the job: {} vs {}",
            throttled.colocation.as_ref().unwrap().job_makespan_secs,
            full.colocation.as_ref().unwrap().job_makespan_secs
        );
    }

    #[test]
    fn crash_recovers_on_both_sides() {
        let mut spec = co_spec(1500, 400.0);
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 2.0,
            node: 1,
        });
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "faulted colocated runs stay deterministic");
        assert_eq!(a.nodes_crashed, 1);
        assert!(a.reassignments > 0, "both sides re-route off the dead node");
        let t = a.traffic.as_ref().unwrap();
        assert_eq!(t.completed + t.rejected + t.unavailable, 1500);
        assert!(a.segments > 0, "job still completes every segment");
    }

    #[test]
    fn exhausted_retries_fail_the_colocated_job() {
        // Same regression as the batch engine: a crash past the
        // attempt budget is an explicit failure on the colocated path.
        let mut spec = co_spec(300, 100.0);
        spec.cfg.sphere.max_attempts = 1;
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 2.0,
            node: 1,
        });
        let err = run_scenario(&spec).unwrap_err();
        assert!(err.contains("exhausted"), "{err}");
    }

    #[test]
    fn filegen_colocates_single_stage() {
        let mut spec = co_spec(500, 150.0);
        spec.workload.as_mut().unwrap().kind = WorkloadKind::Filegen;
        let r = run_scenario(&spec).unwrap();
        assert_eq!(r.workload, "filegen+traffic");
        let co = r.colocation.as_ref().unwrap();
        assert_eq!(co.stage_ends.len(), 1);
        assert_eq!(r.shuffle_gbytes, 0.0, "filegen has no shuffle stage");
    }

    #[test]
    fn colocate_preset_smoke() {
        // The full colocate_scale128 preset is exercised (twice) by
        // benches/bench_colocate.rs and the golden determinism suite;
        // here just check a scaled-down clone completes with both
        // halves reported.
        let mut spec = ScenarioSpec::colocate_scale128();
        spec.topology = TopologySpec::scale_out(2, 2, 4);
        spec.workload.as_mut().unwrap().bytes_per_node = 0.25 * GB as f64;
        {
            let t = spec.traffic.as_mut().unwrap();
            t.requests = 2_000;
            t.clients = 5_000;
            t.arrival = ArrivalProcess::Open { rps: 600.0 };
        }
        // scale the fault plan's node ids into the smaller topology
        spec.faults = vec![
            FaultSpec::Straggler { node: 3, factor: 0.25 },
            FaultSpec::SlaveCrash { at_secs: 3.0, node: 9 },
            FaultSpec::LinkDegrade {
                at_secs: 5.0,
                duration_secs: 20.0,
                site: 1,
                factor: 0.25,
            },
        ];
        let r = run_scenario(&spec).unwrap();
        assert!(r.colocation.is_some());
        assert!(r.traffic.is_some());
        assert_eq!(r.nodes_crashed, 1);
    }
}

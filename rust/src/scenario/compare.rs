//! Sphere-vs-Hadoop head-to-head driver (DESIGN.md §12).
//!
//! The paper's closing claim (§7) is an experimental comparison:
//! Terasort and Terasplit on the SAME physical testbed, first under
//! Sector/Sphere, then under Hadoop 0.16, with the ratio of makespans
//! as the headline.  The companion papers (arXiv:0809.1181, the Open
//! Cloud Testbed report arXiv:0907.4810) center the same methodology.
//!
//! A `ScenarioSpec` carrying a `[compare]` block runs here: the
//! `[workload]` goes through the Sphere batch engine
//! (`engine::run_batch`) AND the event-driven Hadoop baseline
//! (`hadoop::engine::run_hadoop`), each on a substrate built from the
//! SAME `TopologySpec`-derived testbed with the SAME fault plan — a
//! crash, WAN brown-out or straggler hits both systems at the same
//! virtual time on the same node/site.  This mirrors the paper's
//! procedure (back-to-back runs on one testbed); the two systems do
//! not contend with each other — for that deployment class see the
//! colocation engine (DESIGN.md §11).
//!
//! The joint [`ComparisonReport`] carries, per system: makespan, stage
//! breakdown, task counts, locality fraction, bytes moved per link
//! tier (node NIC / rack uplink / site WAN), speculation counters and
//! fault re-assignments, plus the Sphere/Hadoop speedup ratio.
//! Deterministic end to end: same spec, byte-identical report — the
//! contract `benches/bench_compare.rs` and the golden suite gate.

use crate::hadoop::engine::run_hadoop;
use crate::topology::Testbed;

use super::engine::{run_batch, ScenarioReport, TierBytes};
use super::trace::TraceRecorder;
use super::{ScenarioSpec, WorkloadKind};

/// One system's half of the head-to-head.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemOutcome {
    pub system: &'static str,
    pub makespan_secs: f64,
    /// (stage name, end time) in execution order.
    pub stage_ends: Vec<(String, f64)>,
    pub events: u64,
    /// Sphere segments / Hadoop map+reduce tasks completed.
    pub tasks: usize,
    pub locality_fraction: f64,
    pub shuffle_gbytes: f64,
    /// Bytes moved between nodes, by deepest link tier crossed.
    pub tier: TierBytes,
    pub speculative_launched: u64,
    pub speculative_won: u64,
    pub reassignments: u64,
}

/// The head-to-head view a `[compare]` scenario adds to
/// [`ScenarioReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct ComparisonReport {
    pub sphere: SystemOutcome,
    pub hadoop: SystemOutcome,
    /// Hadoop makespan / Sphere makespan (> 1: Sphere finished first —
    /// the paper reports 2.4–2.6× on the WAN sort).
    pub speedup: f64,
}

/// Run the head-to-head to completion.  Deterministic: the spec is the
/// only input to both engine runs.
pub(crate) fn run_compare(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<ScenarioReport, String> {
    let workload = spec
        .workload
        .as_ref()
        .ok_or("[compare] requires a [workload] block")?;

    let sphere_run = run_batch(spec, testbed, rec)?;
    let hadoop_run = run_hadoop(spec, testbed, rec)?;

    let sphere = SystemOutcome {
        system: "sphere",
        makespan_secs: sphere_run.makespan,
        stage_ends: sphere_run.agg.stage_ends.clone(),
        events: sphere_run.agg.events,
        tasks: sphere_run.agg.segments,
        locality_fraction: sphere_run.agg.locality_fraction(),
        shuffle_gbytes: sphere_run.agg.shuffle_bytes / 1e9,
        tier: sphere_run.agg.tier,
        speculative_launched: 0,
        speculative_won: 0,
        reassignments: sphere_run.agg.reassignments,
    };
    let hadoop = SystemOutcome {
        system: "hadoop",
        makespan_secs: hadoop_run.makespan_secs,
        stage_ends: hadoop_run.stage_ends,
        events: hadoop_run.events,
        tasks: hadoop_run.tasks_completed,
        locality_fraction: hadoop_run.local_fraction,
        shuffle_gbytes: hadoop_run.shuffle_gbytes,
        tier: hadoop_run.tier,
        speculative_launched: hadoop_run.speculative_launched,
        speculative_won: hadoop_run.speculative_won,
        reassignments: hadoop_run.reassignments,
    };
    let speedup = hadoop.makespan_secs / sphere.makespan_secs.max(1e-9);

    Ok(ScenarioReport {
        name: spec.name.clone(),
        workload: compared_name(workload.kind),
        nodes: testbed.nodes(),
        racks: testbed.racks(),
        sites: testbed.site_names.len(),
        // The headline row stays the Sphere run; the Hadoop half lives
        // in `comparison`.
        makespan_secs: sphere.makespan_secs,
        events: sphere.events + hadoop.events,
        segments: sphere.tasks,
        reassignments: sphere.reassignments + hadoop.reassignments,
        locality_fraction: sphere.locality_fraction,
        shuffle_gbytes: sphere.shuffle_gbytes,
        faults_injected: sphere_run.state.injected,
        nodes_crashed: sphere_run.state.crashes,
        speculative_launched: 0,
        speculative_won: 0,
        traffic: None,
        colocation: None,
        comparison: Some(ComparisonReport {
            sphere,
            hadoop,
            speedup,
        }),
        angle: None,
        elasticity: None,
        trace_digest: String::new(),
    })
}

fn compared_name(kind: WorkloadKind) -> &'static str {
    match kind {
        WorkloadKind::Terasort => "terasort vs hadoop",
        WorkloadKind::Terasplit => "terasplit vs hadoop",
        WorkloadKind::Filegen => "filegen vs hadoop",
        WorkloadKind::Angle | WorkloadKind::Kmeans => {
            unreachable!("off-paper workloads are rejected before a compare run")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{run_scenario, CompareSpec, FaultSpec};
    use crate::topology::TopologySpec;
    use crate::util::bytes::GB;

    /// Small head-to-head: 8 nodes across 2 sites, 0.5 GB/node.
    fn cmp_spec(kind: WorkloadKind) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(2, 2, 2);
        spec.name = format!("compare-test-{}", kind.name());
        let w = spec.workload.as_mut().unwrap();
        w.kind = kind;
        w.bytes_per_node = 0.5 * GB as f64;
        spec.compare = Some(CompareSpec::default());
        spec
    }

    #[test]
    fn compare_runs_both_engines_deterministically() {
        let spec = cmp_spec(WorkloadKind::Terasort);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "same spec, same joint report");
        assert_eq!(a.workload, "terasort vs hadoop");
        let cmp = a.comparison.as_ref().expect("head-to-head present");
        assert_eq!(cmp.sphere.system, "sphere");
        assert_eq!(cmp.hadoop.system, "hadoop");
        assert!(cmp.sphere.tasks > 0 && cmp.hadoop.tasks > 0);
        assert!(cmp.sphere.makespan_secs > 0.0 && cmp.hadoop.makespan_secs > 0.0);
        assert!(
            (cmp.speedup - cmp.hadoop.makespan_secs / cmp.sphere.makespan_secs).abs() < 1e-9
        );
        assert_eq!(cmp.sphere.stage_ends.len(), 2, "terasort: two Sphere stages");
        assert_eq!(
            cmp.hadoop.stage_ends.len(),
            3,
            "hadoop terasort: map, shuffle, reduce"
        );
        assert!(cmp.hadoop.tier.total() > 0.0, "hadoop moved bytes");
        assert!(cmp.sphere.tier.total() > 0.0, "sphere moved bytes");
    }

    #[test]
    fn sphere_wins_the_paper_workloads() {
        // The paper's headline (§7): Sphere beats Hadoop on sort and
        // split, on LAN and WAN alike.  Gate the sign, not the exact
        // factor (benches record the trajectory).
        for kind in [WorkloadKind::Terasort, WorkloadKind::Terasplit] {
            let r = run_scenario(&cmp_spec(kind)).unwrap();
            let cmp = r.comparison.unwrap();
            assert!(
                cmp.speedup > 1.0,
                "{}: hadoop {:.1}s vs sphere {:.1}s",
                kind.name(),
                cmp.hadoop.makespan_secs,
                cmp.sphere.makespan_secs
            );
        }
    }

    #[test]
    fn wan_widens_the_gap() {
        // §7: the Sphere advantage grows on the wide area (UDT holds
        // the long fat pipe, Hadoop's 64 KB TCP windows do not).
        let mut lan = cmp_spec(WorkloadKind::Terasort);
        lan.topology = TopologySpec::scale_out(1, 2, 4);
        let mut wan = cmp_spec(WorkloadKind::Terasort);
        wan.topology = TopologySpec::scale_out(4, 1, 2);
        let lan_cmp = run_scenario(&lan).unwrap().comparison.unwrap();
        let wan_cmp = run_scenario(&wan).unwrap().comparison.unwrap();
        assert!(
            wan_cmp.speedup > lan_cmp.speedup,
            "WAN speedup {:.2} must exceed LAN speedup {:.2}",
            wan_cmp.speedup,
            lan_cmp.speedup
        );
        assert!(wan_cmp.hadoop.tier.wan > 0.0, "hadoop crossed the WAN");
    }

    #[test]
    fn faults_hit_both_systems() {
        let mut spec = cmp_spec(WorkloadKind::Terasort);
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 2.0,
            node: 1,
        });
        let clean = run_scenario(&cmp_spec(WorkloadKind::Terasort)).unwrap();
        let faulted = run_scenario(&spec).unwrap();
        assert_eq!(faulted, run_scenario(&spec).unwrap(), "faulted runs stay deterministic");
        assert_eq!(faulted.nodes_crashed, 1);
        let (c, f) = (
            clean.comparison.as_ref().unwrap(),
            faulted.comparison.as_ref().unwrap(),
        );
        assert!(
            f.sphere.makespan_secs > c.sphere.makespan_secs,
            "the crash must cost Sphere time"
        );
        assert!(
            f.hadoop.makespan_secs > c.hadoop.makespan_secs,
            "the crash must cost Hadoop time"
        );
        assert!(f.hadoop.reassignments > 0, "hadoop re-ran work off the dead node");
    }

    #[test]
    fn master_crash_hurts_hadoop_more_than_sphere() {
        // The availability asymmetry (paper §4, DESIGN.md §18): a
        // Sector master outage only pauses NEW dispatch — running SPEs
        // stream on and clients keep cached metadata — while a Hadoop
        // 0.16 JobTracker crash loses every in-flight attempt, which
        // re-runs from scratch after recovery.  The same outage at the
        // same virtual time must therefore cost Hadoop more wall-clock.
        let mut spec = cmp_spec(WorkloadKind::Terasort);
        spec.faults.push(FaultSpec::MasterCrash {
            at_secs: 2.0,
            down_secs: 10.0,
        });
        let clean = run_scenario(&cmp_spec(WorkloadKind::Terasort)).unwrap();
        let faulted = run_scenario(&spec).unwrap();
        assert_eq!(
            faulted,
            run_scenario(&spec).unwrap(),
            "failover runs stay deterministic"
        );
        let (c, f) = (
            clean.comparison.as_ref().unwrap(),
            faulted.comparison.as_ref().unwrap(),
        );
        let sphere_cost = f.sphere.makespan_secs - c.sphere.makespan_secs;
        let hadoop_cost = f.hadoop.makespan_secs - c.hadoop.makespan_secs;
        assert!(sphere_cost >= -1e-9, "the outage never speeds Sphere up");
        assert!(hadoop_cost > 0.0, "the JobTracker crash must cost Hadoop time");
        assert!(
            hadoop_cost > sphere_cost + 1e-9,
            "availability asymmetry: hadoop +{hadoop_cost:.1}s vs sphere +{sphere_cost:.1}s"
        );
        assert!(
            f.hadoop.reassignments > c.hadoop.reassignments,
            "hadoop re-ran the in-flight attempts the crash unwound"
        );
    }

    #[test]
    fn filegen_compares_write_pipelines() {
        // §6.3: Sphere wrote 10 GB in 68 s, Hadoop's HDFS client
        // pipeline took 212 s on the same disks.
        let r = run_scenario(&cmp_spec(WorkloadKind::Filegen)).unwrap();
        let cmp = r.comparison.unwrap();
        assert_eq!(r.workload, "filegen vs hadoop");
        assert!(
            cmp.speedup > 1.5,
            "HDFS write pipeline must lag well behind Sphere: {:.2}",
            cmp.speedup
        );
    }

    #[test]
    fn compare_presets_run() {
        let r = run_scenario(&ScenarioSpec::compare_wan4()).unwrap();
        let cmp = r.comparison.unwrap();
        assert_eq!(r.nodes, 4);
        assert!(
            cmp.speedup > 1.0,
            "Table 1 reproduction: Sphere wins ({:.2}x)",
            cmp.speedup
        );
        assert!(cmp.hadoop.tier.wan > 0.0, "the 4-node row spans two sites");
    }
}

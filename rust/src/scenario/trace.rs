//! Sim-time tracing + run-artifact observability (DESIGN.md §15).
//!
//! The paper's claims are timeline claims — where virtual time goes
//! across stages, links and speculative attempts — so the shared
//! engine core ([`super::core::drive`]) feeds every run through one
//! [`TraceRecorder`]:
//!
//! * **Spans and instants.**  Flow lifetimes (open → done/cancel),
//!   queue-event dispatches, fault applications (crash, brown-out
//!   start/end), task-attempt lifecycle marks and admission decisions,
//!   each tagged with the emitting harness (`sphere`, `traffic`,
//!   `colocate`, `hadoop`, `angle`), the node (mapped to rack/site at
//!   artifact-write time), the stage and the tenant.
//! * **Sampled gauges.**  On a configurable sim-time tick the core
//!   snapshots per-tier link utilization, active flows, event-queue
//!   depth, scheduler occupancy, speculation in-flight and live nodes
//!   ([`sample_gauges`]; the harness contributes [`HarnessGauges`]).
//! * **A streaming FNV-1a digest.**  Always on — even without `--trace`
//!   — over every timeline emission (samples excluded, so enabling
//!   capture never changes it).  `ScenarioReport.trace_digest` carries
//!   it, which makes the golden fixtures pin the *timeline*, not just
//!   the end-of-run aggregates.
//! * **Two artifacts** behind `--trace <path>` / the `[trace]` TOML
//!   block: a JSONL event log (one self-describing object per line,
//!   meta header first) and a Chrome `trace_event` file loadable in
//!   Perfetto (`pid` = site, `tid` = node; node-less events on a
//!   synthetic "global" process).
//!
//! Memory stays bounded on the `*_scale128` presets: retention is a
//! ring buffer of `max_events` (oldest dropped first, counted in the
//! meta line), the digest is O(1), and the open-flow map is bounded by
//! the number of concurrently active flows.

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use crate::config::Table;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::topology::{NetLinks, Testbed};

// ------------------------------------------------------------ spec

/// The `[trace]` TOML block / `--trace` CLI flag.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpec {
    /// Artifact base path.  `<path>` gets the Chrome `trace_event`
    /// file, a sibling `.jsonl` gets the event log; `None` captures
    /// in memory only (tests) — the digest is always computed.
    pub path: Option<String>,
    /// Gauge sampler tick in sim seconds; 0 disables sampling.
    pub sample_secs: f64,
    /// Ring-buffer capacity (events retained); 0 = unbounded.
    pub max_events: usize,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            path: None,
            sample_secs: 1.0,
            max_events: 200_000,
        }
    }
}

impl TraceSpec {
    pub(crate) fn from_table(t: &Table) -> Result<TraceSpec, String> {
        t.check_known_keys("trace", &["path", "sample_secs", "max_events"], &[])?;
        let d = TraceSpec::default();
        let spec = TraceSpec {
            path: t.get("trace.path").and_then(|v| v.as_str()).map(String::from),
            sample_secs: t.float_or("trace.sample_secs", d.sample_secs),
            max_events: t.int_or("trace.max_events", d.max_events as i64).max(0) as usize,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.sample_secs.is_finite() || self.sample_secs < 0.0 {
            return Err(format!(
                "trace: sample_secs must be finite and >= 0, got {}",
                self.sample_secs
            ));
        }
        Ok(())
    }
}

/// Derive the artifact pair from the `--trace` path: the Chrome file
/// keeps the given name, the JSONL log swaps a `.json` suffix for
/// `.jsonl` (or appends `.jsonl`).
pub fn artifact_paths(path: &str) -> (String, String) {
    let jsonl = match path.strip_suffix(".json") {
        Some(stem) => format!("{stem}.jsonl"),
        None => format!("{path}.jsonl"),
    };
    (path.to_string(), jsonl)
}

// ------------------------------------------------------------ events

/// Chrome-ish phase of a captured event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ph {
    /// Closed interval (`t` .. `t + dur`), emitted at its end.
    Span,
    /// Point event at `t`.
    Instant,
    /// Gauge sample at `t` (value in [`TraceEvent::value`]).
    Sample,
}

impl Ph {
    fn tag(self) -> &'static str {
        match self {
            Ph::Span => "X",
            Ph::Instant => "i",
            Ph::Sample => "C",
        }
    }
}

/// One captured trace event (the JSONL line, pre-serialization).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub t: f64,
    pub dur: f64,
    pub value: f64,
    pub ph: Ph,
    /// Taxonomy: `flow`, `ev`, `fault`, `task`, `admit`, `stage`, `sample`.
    pub kind: &'static str,
    pub name: String,
    pub harness: &'static str,
    /// Emitting node, or -1 for run-global events.
    pub node: i64,
    pub stage: String,
    pub tenant: String,
}

/// Borrowed form of an emission — lets digest-only runs skip every
/// `String` allocation.
struct Parts<'a> {
    ph: Ph,
    t: f64,
    dur: f64,
    value: f64,
    kind: &'static str,
    name: &'a str,
    harness: &'static str,
    node: i64,
    stage: &'a str,
    tenant: &'a str,
}

// ------------------------------------------------------------ recorder

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fold_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

fn fold_u64(h: &mut u64, v: u64) {
    fold_bytes(h, &v.to_le_bytes());
}

struct Inner {
    digest: u64,
    seen: u64,
    capture: bool,
    max_events: usize,
    dropped: u64,
    sample_secs: f64,
    buf: VecDeque<TraceEvent>,
    /// (harness, flow id) -> open time.  Maintained even without
    /// capture so the digest is invariant to `--trace`.
    open_flows: BTreeMap<(&'static str, u64), f64>,
    /// Per-harness high-water mark for central flow-open detection.
    open_wm: BTreeMap<&'static str, u64>,
}

impl Inner {
    fn push(&mut self, p: Parts<'_>) {
        if p.ph != Ph::Sample {
            self.seen += 1;
            let mut h = self.digest;
            fold_bytes(&mut h, p.harness.as_bytes());
            fold_bytes(&mut h, &[0x1f]);
            fold_bytes(&mut h, p.kind.as_bytes());
            fold_bytes(&mut h, &[0x1f]);
            fold_bytes(&mut h, p.name.as_bytes());
            fold_bytes(&mut h, &[0x1f]);
            fold_bytes(&mut h, p.stage.as_bytes());
            fold_bytes(&mut h, &[0x1f]);
            fold_bytes(&mut h, p.tenant.as_bytes());
            fold_u64(&mut h, p.t.to_bits());
            fold_u64(&mut h, p.dur.to_bits());
            fold_u64(&mut h, p.node as u64);
            self.digest = h;
        }
        if self.capture {
            if self.max_events > 0 && self.buf.len() >= self.max_events {
                self.buf.pop_front();
                self.dropped += 1;
            }
            self.buf.push_back(TraceEvent {
                t: p.t,
                dur: p.dur,
                value: p.value,
                ph: p.ph,
                kind: p.kind,
                name: p.name.to_string(),
                harness: p.harness,
                node: p.node,
                stage: p.stage.to_string(),
                tenant: p.tenant.to_string(),
            });
        }
    }

    fn flow_open(&mut self, harness: &'static str, fid: u64, t: f64) {
        self.open_flows.insert((harness, fid), t);
        self.push(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: fid as f64,
            kind: "flow",
            name: "open",
            harness,
            node: -1,
            stage: "",
            tenant: "",
        });
    }

    fn flow_close(&mut self, harness: &'static str, fid: u64, t: f64, name: &'static str) {
        let start = self.open_flows.remove(&(harness, fid));
        let (t0, dur, ph) = match start {
            Some(s) => (s, (t - s).max(0.0), Ph::Span),
            None => (t, 0.0, Ph::Instant),
        };
        self.push(Parts {
            ph,
            t: t0,
            dur,
            value: fid as f64,
            kind: "flow",
            name,
            harness,
            node: -1,
            stage: "",
            tenant: "",
        });
    }
}

/// Shared, cheaply clonable trace sink (the `metrics::Metrics` idiom):
/// one per run, handed to every engine as a labeled [`Tracer`].
#[derive(Clone)]
pub struct TraceRecorder {
    inner: Arc<Mutex<Inner>>,
}

impl TraceRecorder {
    pub fn new(capture: bool, max_events: usize, sample_secs: f64) -> TraceRecorder {
        TraceRecorder {
            inner: Arc::new(Mutex::new(Inner {
                digest: FNV_OFFSET,
                seen: 0,
                capture,
                max_events,
                dropped: 0,
                sample_secs,
                buf: VecDeque::new(),
                open_flows: BTreeMap::new(),
                open_wm: BTreeMap::new(),
            })),
        }
    }

    /// Digest-only recorder: no retention, no sampling.  What every
    /// run uses when no `[trace]` block / `--trace` flag is given.
    pub fn disabled() -> TraceRecorder {
        TraceRecorder::new(false, 0, 0.0)
    }

    /// Build the run's recorder from its (optional) trace spec.
    pub fn for_spec(spec: Option<&TraceSpec>) -> TraceRecorder {
        match spec {
            Some(ts) => TraceRecorder::new(true, ts.max_events, ts.sample_secs),
            None => TraceRecorder::disabled(),
        }
    }

    /// A harness-labeled emission handle over this recorder.
    pub fn tracer(&self, harness: &'static str) -> Tracer {
        Tracer {
            rec: self.clone(),
            harness,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("trace lock")
    }

    /// The streaming FNV-1a timeline digest, `{:016x}`-formatted.
    pub fn digest_hex(&self) -> String {
        format!("{:016x}", self.lock().digest)
    }

    /// Timeline emissions digested so far (samples excluded).
    pub fn events_seen(&self) -> u64 {
        self.lock().seen
    }

    /// Events currently retained in the ring buffer.
    pub fn captured(&self) -> usize {
        self.lock().buf.len()
    }

    /// Events evicted from the ring buffer.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    pub fn sample_secs(&self) -> f64 {
        self.lock().sample_secs
    }

    /// Copy of the retained events (tests, validation).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.lock().buf.iter().cloned().collect()
    }

    /// Write the JSONL event log and the Chrome `trace_event` file.
    /// Returns `(chrome_path, jsonl_path)`.
    pub fn write_artifacts(
        &self,
        run_name: &str,
        path: &str,
        testbed: &Testbed,
    ) -> Result<(String, String), String> {
        let (chrome_path, jsonl_path) = artifact_paths(path);
        let (jsonl, chrome) = {
            let g = self.lock();
            // Flows still open at write time (cancelled without a
            // tracer notification, or alive at run end) become
            // explicit `open_at_end` instants so every span in the
            // artifact is structurally closed.
            let tail: Vec<TraceEvent> = g
                .open_flows
                .iter()
                .map(|(&(harness, fid), &t)| TraceEvent {
                    t,
                    dur: 0.0,
                    value: fid as f64,
                    ph: Ph::Instant,
                    kind: "flow",
                    name: "open_at_end".to_string(),
                    harness,
                    node: -1,
                    stage: String::new(),
                    tenant: String::new(),
                })
                .collect();
            let jsonl = render_jsonl(run_name, &g, &tail, testbed);
            let chrome = render_chrome(g.buf.iter().chain(tail.iter()), testbed);
            (jsonl, chrome)
        };
        std::fs::write(&jsonl_path, jsonl)
            .map_err(|e| format!("trace: cannot write {jsonl_path}: {e}"))?;
        std::fs::write(&chrome_path, chrome)
            .map_err(|e| format!("trace: cannot write {chrome_path}: {e}"))?;
        Ok((chrome_path, jsonl_path))
    }
}

// ------------------------------------------------------------ tracer

/// Harness-labeled emission handle.  All methods take `&self` and are
/// cheap when capture is off (digest fold only, no allocation).
#[derive(Clone)]
pub struct Tracer {
    rec: TraceRecorder,
    harness: &'static str,
}

impl Tracer {
    pub fn harness(&self) -> &'static str {
        self.harness
    }

    pub fn sample_secs(&self) -> f64 {
        self.rec.sample_secs()
    }

    fn emit(&self, p: Parts<'_>) {
        self.rec.lock().push(p);
    }

    /// A queue event dispatched by the core loop.
    pub fn ev(&self, t: f64, name: &'static str) {
        self.emit(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: 0.0,
            kind: "ev",
            name,
            harness: self.harness,
            node: -1,
            stage: "",
            tenant: "",
        });
    }

    /// A run-global instant (fault application, stage boundary, ...).
    pub fn instant(&self, t: f64, kind: &'static str, name: &str) {
        self.emit(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: 0.0,
            kind,
            name,
            harness: self.harness,
            node: -1,
            stage: "",
            tenant: "",
        });
    }

    /// A node-tagged instant.
    pub fn instant_node(&self, t: f64, kind: &'static str, name: &str, node: usize) {
        self.emit(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: 0.0,
            kind,
            name,
            harness: self.harness,
            node: node as i64,
            stage: "",
            tenant: "",
        });
    }

    /// A closed task-attempt span on `node`, emitted at its end.
    pub fn task(&self, start: f64, end: f64, name: &str, node: usize, stage: &str) {
        self.emit(Parts {
            ph: Ph::Span,
            t: start,
            dur: (end - start).max(0.0),
            value: 0.0,
            kind: "task",
            name,
            harness: self.harness,
            node: node as i64,
            stage,
            tenant: "",
        });
    }

    /// A task-attempt lifecycle mark (placed / speculated / crashed /
    /// lost / won) on `node`.
    pub fn task_mark(&self, t: f64, name: &str, node: usize, stage: &str) {
        self.emit(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: 0.0,
            kind: "task",
            name,
            harness: self.harness,
            node: node as i64,
            stage,
            tenant: "",
        });
    }

    /// An admission decision (served / queued / rejected / unavailable)
    /// for `tenant` at slave `node` (-1 when no live replica existed).
    pub fn admission(&self, t: f64, verdict: &'static str, node: i64, tenant: &str) {
        self.emit(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: 0.0,
            kind: "admit",
            name: verdict,
            harness: self.harness,
            node,
            stage: "",
            tenant,
        });
    }

    /// A stage boundary (named after the finishing stage).
    pub fn stage_mark(&self, t: f64, name: &str) {
        self.emit(Parts {
            ph: Ph::Instant,
            t,
            dur: 0.0,
            value: 0.0,
            kind: "stage",
            name,
            harness: self.harness,
            node: -1,
            stage: name,
            tenant: "",
        });
    }

    /// A gauge sample (never digested: enabling the sampler must not
    /// move the timeline digest).
    pub fn sample(&self, t: f64, name: &'static str, value: f64) {
        self.emit(Parts {
            ph: Ph::Sample,
            t,
            dur: 0.0,
            value,
            kind: "sample",
            name,
            harness: self.harness,
            node: -1,
            stage: "",
            tenant: "",
        });
    }

    /// Re-align the flow-open watermark to `watermark` — the core
    /// calls this at drive entry so engines that rebuild their
    /// substrate between stages (fresh flow-id space) don't
    /// mis-attribute the new network's flow ids to the old one.
    pub fn reset_flow_watermark(&self, watermark: u64) {
        self.rec.lock().open_wm.insert(self.harness, watermark);
    }

    /// Record flow opens for every id in `[watermark seen last time,
    /// watermark)` at time `t` — the core calls this each loop turn so
    /// flow spans need no per-engine plumbing.
    pub fn open_new_flows(&self, watermark: u64, t: f64) {
        let mut g = self.rec.lock();
        let lo = {
            let wm = g.open_wm.entry(self.harness).or_insert(0);
            let lo = *wm;
            *wm = watermark.max(lo);
            lo
        };
        for fid in lo..watermark {
            g.flow_open(self.harness, fid, t);
        }
    }

    /// A flow completed: closes its span (or emits a bare instant if
    /// the open was never seen).
    pub fn flow_done(&self, fid: FlowId, t: f64) {
        self.rec.lock().flow_close(self.harness, fid.0, t, "done");
    }

    /// A flow was cancelled (speculation loser, crash re-route).
    pub fn flow_cancel(&self, fid: FlowId, t: f64) {
        self.rec.lock().flow_close(self.harness, fid.0, t, "cancel");
    }
}

// ------------------------------------------------------------ gauges

/// Harness-side gauges for the sim-time sampler; the core adds the
/// substrate-side ones (active flows, queue depth, live nodes, tier
/// utilizations).
#[derive(Clone, Copy, Debug, Default)]
pub struct HarnessGauges {
    /// Running attempts / busy service slots.
    pub occupancy: u64,
    /// Work units waiting to be placed (segments, requests).
    pub queued: u64,
    /// Speculative attempts currently in flight.
    pub spec_inflight: u64,
    /// Live data replicas across the catalog (elastic serving only;
    /// harnesses without replica arenas report 0 and the sample still
    /// emits, keeping the gauge set schema-stable across workloads).
    pub replicas: u64,
}

fn tier_util(net: &NetSim, loads: &[f64], up: &[LinkId], down: &[LinkId]) -> f64 {
    let mut load = 0.0;
    let mut cap = 0.0;
    for &l in up.iter().chain(down.iter()) {
        load += loads[l.0];
        cap += net.link_capacity(l);
    }
    if cap > 0.0 {
        load / cap
    } else {
        0.0
    }
}

/// One sampler tick: harness gauges plus the substrate-side gauges.
/// `t` is the tick instant; values reflect the state immediately
/// before the wave that crossed it (DESIGN.md §15).
pub(crate) fn sample_gauges(
    tracer: &Tracer,
    t: f64,
    g: &HarnessGauges,
    net: &mut NetSim,
    queue_depth: usize,
    live_nodes: usize,
    links: &NetLinks,
) {
    tracer.sample(t, "active_flows", net.active_flows() as f64);
    tracer.sample(t, "queue_depth", queue_depth as f64);
    tracer.sample(t, "live_nodes", live_nodes as f64);
    tracer.sample(t, "occupancy", g.occupancy as f64);
    tracer.sample(t, "work_queued", g.queued as f64);
    tracer.sample(t, "spec_inflight", g.spec_inflight as f64);
    tracer.sample(t, "replicas", g.replicas as f64);
    // One pass over the flow table covers all three tiers.
    let loads = net.link_loads();
    tracer.sample(t, "util_node", tier_util(net, &loads, &links.node_up, &links.node_down));
    tracer.sample(t, "util_rack", tier_util(net, &loads, &links.rack_up, &links.rack_down));
    tracer.sample(t, "util_wan", tier_util(net, &loads, &links.site_up, &links.site_down));
}

// ------------------------------------------------------------ artifacts

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn loc(node: i64, tb: &Testbed) -> (i64, i64) {
    let n = node as usize;
    if node >= 0 && n < tb.node_rack.len() {
        (tb.node_rack[n] as i64, tb.node_site[n] as i64)
    } else {
        (-1, -1)
    }
}

fn jsonl_line(ev: &TraceEvent, tb: &Testbed, out: &mut String) {
    let (rack, site) = loc(ev.node, tb);
    let _ = write!(
        out,
        "{{\"t\":{:.9},\"ph\":\"{}\",\"kind\":\"{}\",\"name\":\"{}\",\
         \"harness\":\"{}\",\"node\":{},\"rack\":{rack},\"site\":{site},\
         \"stage\":\"{}\",\"tenant\":\"{}\",\"dur\":{:.9},\"value\":{:.6}}}",
        ev.t,
        ev.ph.tag(),
        ev.kind,
        esc(&ev.name),
        ev.harness,
        ev.node,
        esc(&ev.stage),
        esc(&ev.tenant),
        ev.dur,
        ev.value,
    );
    out.push('\n');
}

fn render_jsonl(run_name: &str, g: &Inner, tail: &[TraceEvent], tb: &Testbed) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\"meta\":\"trace\",\"name\":\"{}\",\"events_seen\":{},\
         \"captured\":{},\"dropped\":{},\"open_at_end\":{},\
         \"sample_secs\":{:.6},\"digest\":\"{:016x}\"}}",
        esc(run_name),
        g.seen,
        g.buf.len() + tail.len(),
        g.dropped,
        tail.len(),
        g.sample_secs,
        g.digest,
    );
    out.push('\n');
    for ev in g.buf.iter().chain(tail.iter()) {
        jsonl_line(ev, tb, &mut out);
    }
    out
}

fn render_chrome<'a>(events: impl Iterator<Item = &'a TraceEvent>, tb: &Testbed) -> String {
    // pid = site; two synthetic processes past the real sites: GLOBAL
    // (node-less instants + counters) and FLOWS (flow spans).
    let sites = tb.site_names.len() as i64;
    let pid_global = sites;
    let pid_flows = sites + 1;
    let mut out = String::from("{\"traceEvents\":[\n");
    for (s, name) in tb.site_names.iter().enumerate() {
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{s},\"tid\":0,\
             \"args\":{{\"name\":\"site {}\"}}}},\n",
            esc(name)
        );
    }
    let _ = write!(
        out,
        "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{pid_global},\"tid\":0,\
         \"args\":{{\"name\":\"global\"}}}},\n\
         {{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{pid_flows},\"tid\":0,\
         \"args\":{{\"name\":\"flows\"}}}},\n"
    );
    for (n, (&rack, &site)) in tb.node_rack.iter().zip(tb.node_site.iter()).enumerate() {
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{site},\"tid\":{n},\
             \"args\":{{\"name\":\"node{n} rack{rack}\"}}}},\n"
        );
    }
    let mut first = true;
    for ev in events {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let ts = ev.t * 1e6;
        let (_, site) = loc(ev.node, tb);
        match ev.ph {
            Ph::Span if ev.kind == "flow" => {
                let _ = write!(
                    out,
                    "{{\"ph\":\"X\",\"name\":\"{}\",\"cat\":\"flow\",\"ts\":{ts:.3},\
                     \"dur\":{:.3},\"pid\":{pid_flows},\"tid\":0,\
                     \"args\":{{\"harness\":\"{}\",\"fid\":{:.0}}}}}",
                    esc(&ev.name),
                    ev.dur * 1e6,
                    ev.harness,
                    ev.value,
                );
            }
            Ph::Span => {
                let _ = write!(
                    out,
                    "{{\"ph\":\"X\",\"name\":\"{}\",\"cat\":\"{}\",\"ts\":{ts:.3},\
                     \"dur\":{:.3},\"pid\":{},\"tid\":{},\
                     \"args\":{{\"harness\":\"{}\",\"stage\":\"{}\"}}}}",
                    esc(&ev.name),
                    ev.kind,
                    ev.dur * 1e6,
                    if site >= 0 { site } else { pid_global },
                    ev.node.max(0),
                    ev.harness,
                    esc(&ev.stage),
                );
            }
            Ph::Instant => {
                let (pid, tid) = if ev.node >= 0 {
                    (site, ev.node)
                } else if ev.kind == "flow" {
                    (pid_flows, 0)
                } else {
                    (pid_global, 0)
                };
                let _ = write!(
                    out,
                    "{{\"ph\":\"i\",\"name\":\"{}\",\"cat\":\"{}\",\"ts\":{ts:.3},\
                     \"pid\":{pid},\"tid\":{tid},\"s\":\"t\",\
                     \"args\":{{\"harness\":\"{}\",\"tenant\":\"{}\"}}}}",
                    esc(&ev.name),
                    ev.kind,
                    ev.harness,
                    esc(&ev.tenant),
                );
            }
            Ph::Sample => {
                let _ = write!(
                    out,
                    "{{\"ph\":\"C\",\"name\":\"{}.{}\",\"ts\":{ts:.3},\
                     \"pid\":{pid_global},\"tid\":0,\
                     \"args\":{{\"value\":{:.6}}}}}",
                    ev.harness,
                    esc(&ev.name),
                    ev.value,
                );
            }
        }
    }
    out.push_str("\n]}\n");
    out
}

// ------------------------------------------------------------ validation

/// Schema sanity over captured events: finite non-negative times and
/// durations, nodes within the testbed, and per-(harness, node) track
/// monotone emission order (a span's emission instant is its end).
pub fn validate_events(events: &[TraceEvent], nodes: usize) -> Result<(), String> {
    let mut last: BTreeMap<(&'static str, i64), f64> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        if !ev.t.is_finite() || ev.t < 0.0 {
            return Err(format!("event {i}: bad time {}", ev.t));
        }
        if !ev.dur.is_finite() || ev.dur < 0.0 {
            return Err(format!("event {i}: bad duration {}", ev.dur));
        }
        if ev.node < -1 || ev.node >= nodes as i64 {
            return Err(format!("event {i}: node {} out of range", ev.node));
        }
        if ev.name == "open_at_end" {
            // Administratively closed at write time; its timestamp is
            // the open instant, which may precede later emissions.
            continue;
        }
        let end = ev.t + ev.dur;
        let key = (ev.harness, ev.node);
        if let Some(&prev) = last.get(&key) {
            if end + 1e-9 < prev {
                return Err(format!(
                    "event {i} ({}/{} {:?}): track ({}, {}) went backwards \
                     ({end} < {prev})",
                    ev.kind, ev.name, ev.ph, ev.harness, ev.node
                ));
            }
        }
        last.insert(key, end);
    }
    Ok(())
}

/// Pull `"key":value` out of one JSONL line without serde.
fn jfield<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\":");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    if let Some(stripped) = rest.strip_prefix('"') {
        stripped.split('"').next()
    } else {
        rest.split(&[',', '}'][..]).next().map(str::trim)
    }
}

/// Parse + sanity-check a JSONL artifact produced by
/// [`TraceRecorder::write_artifacts`].  Returns the event-line count.
/// Checks the meta header, every line's schema, and the per-track
/// monotonicity contract of [`validate_events`].
pub fn validate_jsonl(text: &str) -> Result<usize, String> {
    let mut lines = text.lines();
    let meta = lines.next().ok_or("empty trace file")?;
    if jfield(meta, "meta") != Some("trace") {
        return Err("first line is not a trace meta header".into());
    }
    let digest = jfield(meta, "digest").ok_or("meta line missing digest")?;
    if digest.len() != 16 || !digest.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(format!("meta digest {digest:?} is not 16 hex chars"));
    }
    let mut count = 0usize;
    let mut last: BTreeMap<(String, i64), f64> = BTreeMap::new();
    for (i, line) in lines.enumerate() {
        let num = |key: &str| -> Result<f64, String> {
            jfield(line, key)
                .and_then(|v| v.parse::<f64>().ok())
                .ok_or_else(|| format!("line {}: missing numeric {key:?}", i + 2))
        };
        let s = |key: &str| -> Result<&str, String> {
            jfield(line, key).ok_or_else(|| format!("line {}: missing {key:?}", i + 2))
        };
        let t = num("t")?;
        let dur = num("dur")?;
        let node = num("node")? as i64;
        let ph = s("ph")?;
        let name = s("name")?.to_string();
        let harness = s("harness")?.to_string();
        s("kind")?;
        s("stage")?;
        s("tenant")?;
        if !t.is_finite() || t < 0.0 || !dur.is_finite() || dur < 0.0 {
            return Err(format!("line {}: bad time/duration", i + 2));
        }
        if !matches!(ph, "X" | "i" | "C") {
            return Err(format!("line {}: bad ph {ph:?}", i + 2));
        }
        if name != "open_at_end" {
            let end = t + dur;
            let key = (harness, node);
            if let Some(&prev) = last.get(&key) {
                if end + 1e-9 < prev {
                    return Err(format!(
                        "line {}: track ({}, {node}) went backwards ({end} < {prev})",
                        i + 2,
                        key.0
                    ));
                }
            }
            last.insert(key, end);
        }
        count += 1;
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rec: &TraceRecorder) -> Tracer {
        rec.tracer("test")
    }

    #[test]
    fn digest_is_deterministic_and_capture_invariant() {
        let runs: Vec<String> = [false, true]
            .iter()
            .map(|&capture| {
                let rec = TraceRecorder::new(capture, 0, 0.0);
                let tr = t(&rec);
                tr.ev(0.5, "seg");
                tr.open_new_flows(2, 1.0);
                tr.flow_done(FlowId(0), 3.0);
                tr.task(1.0, 4.0, "map#1", 3, "map");
                tr.sample(2.0, "active_flows", 5.0);
                rec.digest_hex()
            })
            .collect();
        assert_eq!(runs[0], runs[1], "capture and samples must not move the digest");
        // A different timeline digests differently.
        let rec = TraceRecorder::disabled();
        let tr = t(&rec);
        tr.ev(0.5, "seg");
        tr.open_new_flows(2, 1.0);
        tr.flow_done(FlowId(1), 3.0);
        tr.task(1.0, 4.0, "map#1", 3, "map");
        assert_ne!(runs[0], rec.digest_hex());
    }

    #[test]
    fn ring_buffer_bounds_retention() {
        let rec = TraceRecorder::new(true, 4, 0.0);
        let tr = t(&rec);
        for i in 0..10 {
            tr.ev(i as f64, "tick");
        }
        assert_eq!(rec.captured(), 4);
        assert_eq!(rec.dropped(), 6);
        assert_eq!(rec.events_seen(), 10);
        let snap = rec.snapshot();
        assert_eq!(snap[0].t, 6.0, "oldest events evicted first");
    }

    #[test]
    fn flow_spans_close_and_unseen_opens_fall_back_to_instants() {
        let rec = TraceRecorder::new(true, 0, 0.0);
        let tr = t(&rec);
        tr.open_new_flows(1, 1.0);
        tr.flow_done(FlowId(0), 4.0);
        tr.flow_cancel(FlowId(9), 5.0);
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[1].ph, Ph::Span);
        assert_eq!(snap[1].t, 1.0);
        assert_eq!(snap[1].dur, 3.0);
        assert_eq!(snap[2].ph, Ph::Instant, "never-opened flow closes as instant");
        validate_events(&snap, 16).expect("well-formed");
    }

    #[test]
    fn open_new_flows_is_idempotent_across_watermarks() {
        let rec = TraceRecorder::new(true, 0, 0.0);
        let tr = t(&rec);
        tr.open_new_flows(2, 0.0);
        tr.open_new_flows(2, 1.0);
        tr.open_new_flows(3, 1.0);
        assert_eq!(rec.captured(), 3, "each flow opened exactly once");
    }

    #[test]
    fn validate_rejects_backwards_tracks() {
        let rec = TraceRecorder::new(true, 0, 0.0);
        let tr = t(&rec);
        tr.task_mark(5.0, "placed", 2, "map");
        tr.task_mark(1.0, "placed", 2, "map");
        assert!(validate_events(&rec.snapshot(), 16).is_err());
        // Different node: separate track, no violation.
        let rec = TraceRecorder::new(true, 0, 0.0);
        let tr = t(&rec);
        tr.task_mark(5.0, "placed", 2, "map");
        tr.task_mark(1.0, "placed", 3, "map");
        assert!(validate_events(&rec.snapshot(), 16).is_ok());
    }

    #[test]
    fn artifact_paths_derive_the_jsonl_sibling() {
        assert_eq!(
            artifact_paths("out.trace.json"),
            ("out.trace.json".to_string(), "out.trace.jsonl".to_string())
        );
        assert_eq!(
            artifact_paths("run"),
            ("run".to_string(), "run.jsonl".to_string())
        );
    }

    #[test]
    fn trace_spec_parses_and_validates() {
        let tab = Table::parse(
            "[trace]\npath = \"x.json\"\nsample_secs = 0.5\nmax_events = 10\n",
        )
        .unwrap();
        let spec = TraceSpec::from_table(&tab).unwrap();
        assert_eq!(spec.path.as_deref(), Some("x.json"));
        assert_eq!(spec.sample_secs, 0.5);
        assert_eq!(spec.max_events, 10);
        let bad = Table::parse("[trace]\nsample_secs = -1.0\n").unwrap();
        assert!(TraceSpec::from_table(&bad).is_err());
        let typo = Table::parse("[trace]\nsample_sec = 1.0\n").unwrap();
        assert!(TraceSpec::from_table(&typo).is_err());
    }

    #[test]
    fn jfield_extracts_strings_and_numbers() {
        let line = "{\"t\":1.500000000,\"ph\":\"i\",\"name\":\"open\",\"node\":-1}";
        assert_eq!(jfield(line, "t"), Some("1.500000000"));
        assert_eq!(jfield(line, "ph"), Some("i"));
        assert_eq!(jfield(line, "node"), Some("-1"));
        assert_eq!(jfield(line, "missing"), None);
    }
}

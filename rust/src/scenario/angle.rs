//! The Angle pipeline as a first-class scenario workload (DESIGN.md
//! §13).
//!
//! The paper's headline application (§7) is a four-stage wide-area
//! pipeline: sensors at each site stream anonymized pcap windows into
//! Sector, Sphere extracts per-source feature vectors, feature files
//! are aggregated into temporal windows and clustered, and the
//! emergent-cluster models are pushed back out to the sensor sites to
//! score live traffic.  Earlier revisions ran only the extraction
//! stage on the substrate and priced the entire mining half with the
//! Table 3 scalar (`mining::angle::simulate_angle_clustering`) — so a
//! crash, WAN brown-out or straggler could never touch clustering.
//!
//! This driver runs all five stages event-driven on the shared
//! substrate (one `FaultState`, per-stage `NetSim` links built once,
//! one `EventQueue`):
//!
//! 1. **sensor ingest** — each node's pcap share streams from its
//!    site's sensor head through the network and the node's disk-write
//!    link (per-node disk links, like the colocation engine's);
//! 2. **angle extract** — `StageKind::AngleExtract` segments placed by
//!    the real `sphere::Scheduler` (locality rules, crash re-queue);
//! 3. **window aggregate** — every node's feature slice shuffles to a
//!    deterministic window-home node over real `NetSim` flows (bytes
//!    accounted per link tier in `TierBytes`), then the home pays the
//!    per-file open/fetch cost of its window's Sector files;
//! 4. **window cluster** — one k-means task per temporal window,
//!    placed via a fresh `Scheduler` on the window's home/replica, with
//!    crash re-queue AND speculative backup attempts for straggling
//!    windows (first finisher wins, `Scheduler::complete` semantics);
//! 5. **model score** — the fitted cluster models replicate cross-site
//!    (write-local, one copy per other sensor site — the storage
//!    cloud's site-diverse placement) and each site representative
//!    scores its share, with model bytes reported per link tier.
//!
//! The *content* of the mining — delta_j series, emergent windows,
//! recall against the planted §7.1 regime shifts — is computed by the
//! real machinery (`TraceGen` → `extract_features` → windowed
//! `kmeans::fit` → `emergent_windows`) at the spec's model scale.
//! Faults perturb timing and placement, never the mined content: data
//! survives on replicas, and a run that actually loses a replica chain
//! errors out rather than reporting a normal makespan.  The staged
//! cost model is calibrated against the retained Table 3 oracle
//! (`staged_work_secs` vs `oracle_secs`; DESIGN.md §13).

use std::collections::BTreeMap;

use crate::config::SimConfig;
use crate::mining::angle::{simulate_angle_clustering, PER_FILE_SECS, PER_RECORD_SECS};
use crate::mining::emergent::{analyze_windows, emergent_windows};
use crate::mining::features::{
    extract_features, normalize, FeatureVector, FEATURE_DIM, FEATURE_RECORD_BYTES,
};
use crate::mining::pcap::{Regime, TraceGen, PACKET_BYTES};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::sphere::scheduler::Scheduler;
use crate::sphere::segment::Segment;
use crate::topology::{NetLinks, Testbed};
use crate::transport::TransportModels;

use super::core::{self, CoreEv, FaultEv, Harness, Speculation};
use super::engine::{
    build_stage_segments, coordination_secs, live_owner as walk_live_owner, replica_of,
    shuffle_rate_cap, Aggregate, BatchOutcome, FaultState, StageKind, TierBytes,
};
use super::trace::{HarnessGauges, TraceRecorder, Tracer};
use super::{AngleSpec, ScenarioSpec};

/// k-means iteration budget `analyze_windows` runs with; the oracle's
/// per-record constant prices a fully-spent budget, so the staged
/// cluster cost scales with the *observed* iteration count against it.
const NOMINAL_ITERS: f64 = 30.0;

/// A cluster attempt speculates once its nominal service time is
/// exceeded by this factor (a slow node shows up as elapsed > nominal).
const SPEC_THRESHOLD: f64 = 2.0;

/// What the Angle scenario adds to `ScenarioReport` (DESIGN.md §13).
#[derive(Clone, Debug, PartialEq)]
pub struct AngleReport {
    /// Temporal windows clustered.
    pub windows: usize,
    /// Sector files the run accounts (Table 3's x-axis).
    pub files: usize,
    /// delta_j series from the real windowed k-means (len = windows-1).
    pub deltas: Vec<f64>,
    /// Windows the detector flagged as emergent.
    pub emergent_found: Vec<usize>,
    /// Windows where regime shifts were planted (ground truth).
    pub emergent_planted: Vec<usize>,
    /// Fraction of planted windows flagged (1.0 = every shift found).
    pub recall: f64,
    /// Feature bytes shuffled into temporal windows (stage 3).
    pub feature_gbytes: f64,
    /// Cluster-model distribution bytes, by link tier crossed (stage 5).
    pub model_tier: TierBytes,
    /// Serialized staged mining work (per-file opens + cluster
    /// iterations) — the quantity calibrated against the oracle.
    pub staged_work_secs: f64,
    /// `simulate_angle_clustering` at the same (records, files) point.
    pub oracle_secs: f64,
}

// ------------------------------------------------------------ mining

/// The real mining result at model scale: deterministic in (spec,
/// seed), independent of the fault plan (replicas preserve content).
struct Mined {
    deltas: Vec<f64>,
    found: Vec<usize>,
    planted: Vec<usize>,
    recall: f64,
    /// Lloyd's iterations each window's fit actually spent.
    iterations: Vec<usize>,
}

/// Generate every sensor site's windows, extract features, cluster
/// each temporal window and flag emergent ones — the same machinery
/// `mining::angle::run_pipeline` drives, minus the in-process cloud.
fn mine(a: &AngleSpec, sensors: usize, seed: u64) -> Result<Mined, String> {
    let mut windows: Vec<Vec<FeatureVector>> = vec![Vec::new(); a.windows];
    for sensor in 0..sensors {
        let mut gen = TraceGen::new(sensor as u32, a.sources_per_sensor, seed);
        for (w, slot) in windows.iter_mut().enumerate() {
            let anomalous: Vec<(usize, Regime)> = a
                .anomalies
                .iter()
                .filter(|an| an.window == w)
                .map(|an| (an.source, an.regime))
                .collect();
            let pkts = gen.window(w as u64, a.packets_per_source, &anomalous);
            let mut feats = extract_features(&pkts, w as u64);
            normalize(&mut feats);
            slot.extend(feats);
        }
    }
    for w in windows.iter_mut() {
        // Cross-sensor deterministic order (each sensor's slice arrives
        // pre-sorted; the pooled window must be too).
        w.sort_by_key(|f| f.src);
    }
    let analysis = analyze_windows(&windows, a.k, seed, None)?;
    let found = emergent_windows(&analysis.deltas, a.warmup, a.z_thresh);
    let mut planted: Vec<usize> = a.anomalies.iter().map(|an| an.window).collect();
    planted.sort_unstable();
    planted.dedup();
    let hit = planted.iter().filter(|w| found.contains(w)).count();
    let recall = if planted.is_empty() {
        1.0
    } else {
        hit as f64 / planted.len() as f64
    };
    Ok(Mined {
        deltas: analysis.deltas,
        found,
        planted,
        recall,
        iterations: analysis.models.iter().map(|m| m.iterations).collect(),
    })
}

// ------------------------------------------------------------ driver

/// Run the staged Angle pipeline.  Called from `engine::run_batch` for
/// `WorkloadKind::Angle`; deterministic — the spec is the only input.
pub(crate) fn run_angle(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<BatchOutcome, String> {
    let workload = spec
        .workload
        .as_ref()
        .ok_or("angle run requires a [workload] block")?;
    let default_a = AngleSpec::default();
    let a = spec.angle.as_ref().unwrap_or(&default_a);
    let sensors = testbed.site_names.len().max(1);
    a.validate(sensors)?;
    let mined = mine(a, sensors, spec.cfg.seed)?;

    let n = testbed.nodes();
    let mut state = FaultState::for_run(spec, testbed);
    let (mut run, mut net, mut q) = AngleRun::new(
        testbed,
        &spec.cfg,
        a,
        workload.bytes_per_node,
        &mined,
        &state,
        rec.tracer("angle"),
    )?;
    run.execute(&mut net, &mut q, &mut state)?;

    let files = run.files;
    let records = workload.bytes_per_node * n as f64 / PACKET_BYTES as f64;
    let report = AngleReport {
        windows: a.windows,
        files,
        deltas: mined.deltas,
        emergent_found: mined.found,
        emergent_planted: mined.planted,
        recall: mined.recall,
        feature_gbytes: run.feature_total / 1e9,
        model_tier: run.model_tier,
        staged_work_secs: run.staged_work,
        oracle_secs: simulate_angle_clustering(records, files as f64),
    };
    let makespan = run.makespan;
    let agg = std::mem::take(&mut run.agg);
    drop(run);
    Ok(BatchOutcome {
        makespan,
        agg,
        state,
        angle: Some(report),
    })
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Stage {
    Ingest,
    Extract,
    Aggregate,
    Cluster,
    Score,
    Done,
}

enum AEv {
    /// An extract or cluster attempt finished its service time.
    Seg { gen: u64 },
    /// Re-check a cluster attempt for speculation.
    SpecCheck { gen: u64 },
    /// A window home finished its per-file open/fetch work.
    Open { window: usize, gen: u64 },
    /// A site representative finished scoring its share.
    Scored { site: usize, gen: u64 },
    /// The fault plan's shared events (intercepted by the core).
    Fault(FaultEv),
}

impl CoreEv for AEv {
    fn from_fault(f: FaultEv) -> AEv {
        AEv::Fault(f)
    }

    fn to_fault(&self) -> Option<FaultEv> {
        match self {
            AEv::Fault(f) => Some(*f),
            _ => None,
        }
    }

    fn trace_name(&self) -> &'static str {
        match self {
            AEv::Seg { .. } => "seg",
            AEv::SpecCheck { .. } => "spec_check",
            AEv::Open { .. } => "open",
            AEv::Scored { .. } => "scored",
            AEv::Fault(_) => "fault",
        }
    }
}

enum AFlow {
    /// Sensor stream toward `dst`'s spindle.
    Ingest { dst: usize },
    /// A node's feature slice for one temporal window.
    Feature { src: usize, window: usize },
    /// A window's cluster model toward a site representative.
    Model { src: usize, site: usize },
}

/// One running attempt (extract segment or cluster window task).
struct Attempt {
    node: usize,
    seg: Segment,
    speculative: bool,
    started: f64,
}

struct AngleRun<'a> {
    testbed: &'a Testbed,
    cfg: &'a SimConfig,
    a: &'a AngleSpec,
    bytes_per_node: f64,
    models: TransportModels,
    links: NetLinks,
    disk_read: Vec<LinkId>,
    disk_write: Vec<LinkId>,
    nominal_caps: Vec<f64>,
    flows: BTreeMap<FlowId, AFlow>,
    stage: Stage,
    coord_secs: f64,
    // scheduler-driven stages (extract, cluster)
    sched: Scheduler,
    inflight: BTreeMap<u64, Attempt>,
    /// Sibling-attempt bookkeeping (core-owned; engine keeps policy).
    spec: Speculation,
    next_gen: u64,
    running: Vec<usize>,
    // ingest
    ingest_pending: usize,
    // windows
    files: usize,
    win_home: Vec<usize>,
    win_inbound: Vec<usize>,
    win_files: Vec<usize>,
    win_bytes: Vec<f64>,
    win_secs: Vec<f64>,
    win_opened: Vec<bool>,
    open_gen: Vec<Option<u64>>,
    /// Current replica set of each window's feature file (home +
    /// rack-diverse replica, shrinking as nodes crash).
    win_locs: Vec<Vec<u32>>,
    /// Node whose attempt won each window's cluster task.
    win_node: Vec<usize>,
    // score
    site_rep: Vec<Option<usize>>,
    score_inbound: Vec<usize>,
    score_gen: Vec<Option<u64>>,
    scored: Vec<bool>,
    score_pending: usize,
    /// Per-site scoring share, fixed when the score stage opens.
    score_share: f64,
    // outputs
    feature_total: f64,
    model_tier: TierBytes,
    staged_work: f64,
    agg: Aggregate,
    makespan: f64,
    /// Sim-time trace hook (a disabled recorder's tracer is free).
    tracer: Tracer,
}

impl<'a> AngleRun<'a> {
    fn new(
        testbed: &'a Testbed,
        cfg: &'a SimConfig,
        a: &'a AngleSpec,
        bytes_per_node: f64,
        mined: &Mined,
        state: &FaultState,
        tracer: Tracer,
    ) -> Result<(AngleRun<'a>, NetSim, EventQueue<AEv>), String> {
        let n = testbed.nodes();
        let w = a.windows;
        let n_links = 4 * n + 2 * testbed.racks() + 2 * testbed.site_names.len();
        let mut net = NetSim::with_capacity(n_links);
        let links = testbed.build_network(&mut net);
        // Per-node disk links, straggler factors baked into capacity
        // (static for the whole run) — the colocation engine's model.
        let read_eff = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
        let write_eff = cfg.hardware.disk_write_bps * cfg.sphere.io_efficiency;
        let disk_read: Vec<LinkId> = (0..n)
            .map(|i| net.add_link((read_eff * state.factor[i]).max(1.0)))
            .collect();
        let disk_write: Vec<LinkId> = (0..n)
            .map(|i| net.add_link((write_eff * state.factor[i]).max(1.0)))
            .collect();
        let nominal_caps: Vec<f64> = (0..net.link_count())
            .map(|i| net.link_capacity(LinkId(i)))
            .collect();
        let sites = testbed.site_names.len();
        let sensors = sites.max(1);
        let files = if a.files > 0 { a.files } else { sensors * w };
        // Feature bytes: one FEATURE_RECORD per packets_per_source
        // packets — the extraction's compression ratio.
        let feature_total = bytes_per_node * n as f64 * FEATURE_RECORD_BYTES as f64
            / (PACKET_BYTES as f64 * a.packets_per_source as f64);
        let records = bytes_per_node * n as f64 / PACKET_BYTES as f64;
        let win_files: Vec<usize> = (0..w)
            .map(|i| files / w + usize::from(i < files % w))
            .collect();
        // Per-window cluster cost: the oracle's per-record constant,
        // half fixed (aggregation/scan) and half scaled by the
        // iterations the real fit spent against its 30-iteration
        // budget — so converging early is cheaper, like the real code.
        let win_secs: Vec<f64> = (0..w)
            .map(|i| {
                let iters = mined.iterations[i] as f64;
                (records / w as f64)
                    * PER_RECORD_SECS
                    * (0.5 + 0.5 * (iters / NOMINAL_ITERS).min(1.0))
            })
            .collect();
        let staged_work: f64 = win_files
            .iter()
            .map(|&f| f as f64 * PER_FILE_SECS)
            .sum::<f64>()
            + win_secs.iter().sum::<f64>();
        let q: EventQueue<AEv> = EventQueue::with_capacity(2 * n + 4 * w + 16);
        let run = AngleRun {
            testbed,
            cfg,
            a,
            bytes_per_node,
            models: TransportModels::default(),
            links,
            disk_read,
            disk_write,
            nominal_caps,
            flows: BTreeMap::new(),
            stage: Stage::Ingest,
            coord_secs: coordination_secs(testbed),
            sched: Scheduler::new(Vec::new(), cfg.sphere.locality_scheduling),
            inflight: BTreeMap::new(),
            spec: Speculation::new(),
            next_gen: 0,
            running: vec![0; n],
            ingest_pending: 0,
            files,
            win_home: vec![0; w],
            win_inbound: vec![0; w],
            win_files,
            win_bytes: vec![feature_total / w as f64; w],
            win_secs,
            win_opened: vec![false; w],
            open_gen: vec![None; w],
            win_locs: vec![Vec::new(); w],
            win_node: vec![0; w],
            site_rep: vec![None; sites],
            score_inbound: vec![0; sites],
            score_gen: vec![None; sites],
            scored: vec![false; sites],
            score_pending: 0,
            score_share: 0.0,
            feature_total,
            model_tier: TierBytes::default(),
            staged_work,
            agg: Aggregate::default(),
            makespan: 0.0,
            tracer,
        };
        Ok((run, net, q))
    }

    fn spes(&self) -> usize {
        self.cfg.sphere.spes_per_node.max(1)
    }

    /// Walk a node's replica chain to a live owner (the shared
    /// `engine::live_owner`, bound to this run's fault state).
    fn live_owner(&self, state: &FaultState, home: usize) -> Result<usize, String> {
        walk_live_owner(self.testbed, state, home)
    }

    /// First live node of a site, if any.
    fn site_head(&self, state: &FaultState, site: usize) -> Option<usize> {
        (0..self.testbed.nodes())
            .find(|&nd| self.testbed.node_site[nd] == site && !state.dead[nd])
    }

    /// Wire size of one window's fitted cluster model: k centers of
    /// FEATURE_DIM f32s plus a header — used by both the initial
    /// distribution and the crash-path re-replication.
    fn model_bytes(&self) -> f64 {
        (self.a.k * FEATURE_DIM * 4 + 64) as f64
    }

    fn transfer_cap(&self, path: &[LinkId], src: usize, dst: usize, src_factor: f64) -> f64 {
        shuffle_rate_cap(
            self.cfg,
            &self.models,
            &self.nominal_caps,
            path,
            self.testbed.nic_bps,
            self.testbed.rtt_secs(src, dst),
            src_factor,
        )
    }

    // -------------------------------------------------- stage 1: ingest

    /// Every node's pcap share streams from its site's sensor head
    /// through the network into the node's disk-write link.
    fn start_ingest(&mut self, net: &mut NetSim, state: &FaultState) -> Result<(), String> {
        for home in 0..self.testbed.nodes() {
            let owner = self.live_owner(state, home)?;
            let head = self
                .site_head(state, self.testbed.node_site[owner])
                .expect("owner is alive, so its site has a live node");
            self.start_ingest_flow(head, owner, self.bytes_per_node, net);
            self.agg
                .tier
                .add(self.testbed, head, owner, self.bytes_per_node);
        }
        Ok(())
    }

    fn start_ingest_flow(&mut self, head: usize, dst: usize, bytes: f64, net: &mut NetSim) {
        let mut path = if head == dst {
            Vec::with_capacity(1)
        } else {
            self.testbed.path(&self.links, head, dst)
        };
        path.push(self.disk_write[dst]);
        // The sensor stream is not disk-bound at the source; the
        // destination spindle (straggler factor baked into its link)
        // and the transport cap bound it.
        let cap = self.transfer_cap(&path, head, dst, 1.0);
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, AFlow::Ingest { dst });
        self.ingest_pending += 1;
    }

    // -------------------------------------------------- stage 2: extract

    fn start_extract(
        &mut self,
        now: f64,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let segments = build_stage_segments(
            self.testbed,
            self.cfg,
            state,
            self.bytes_per_node,
            self.spes(),
        )?;
        self.sched = Scheduler::new(segments, self.cfg.sphere.locality_scheduling);
        self.sched.max_attempts = self.cfg.sphere.max_attempts;
        self.pump_extract(now, q, state);
        Ok(())
    }

    fn pump_extract(&mut self, now: f64, q: &mut EventQueue<AEv>, state: &FaultState) {
        let spes = self.spes();
        for node in 0..self.testbed.nodes() {
            if state.dead[node] {
                continue;
            }
            while self.running[node] < spes {
                let Some(seg) = self.sched.assign(node as u32) else {
                    break;
                };
                let secs = StageKind::AngleExtract.service_secs(self.cfg, seg.bytes as f64)
                    / state.factor[node]
                    + self.coord_secs;
                self.next_gen += 1;
                self.inflight.insert(
                    self.next_gen,
                    Attempt {
                        node,
                        seg,
                        speculative: false,
                        started: now,
                    },
                );
                self.running[node] += 1;
                q.push_at(now + secs, AEv::Seg { gen: self.next_gen });
            }
        }
    }

    // ------------------------------------------------ stage 3: aggregate

    /// Pick window homes among the live nodes (spread across racks) and
    /// start every node's per-window feature flow.
    fn start_aggregate(
        &mut self,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) {
        let alive = state.alive().to_vec();
        let w_count = self.a.windows;
        let spread = (alive.len() / w_count).max(1);
        for w in 0..w_count {
            let home = alive[(w * spread) % alive.len()];
            self.win_home[w] = home;
            let share = self.win_bytes[w] / alive.len() as f64;
            for &src in &alive {
                self.agg.tier.add(self.testbed, src, home, share);
                if src == home {
                    continue;
                }
                self.start_feature_flow(src, w, share, net, state);
                self.agg.shuffle_bytes += share;
            }
            if self.win_inbound[w] == 0 {
                self.schedule_open(w, now, q);
            }
        }
    }

    fn start_feature_flow(
        &mut self,
        src: usize,
        window: usize,
        bytes: f64,
        net: &mut NetSim,
        state: &FaultState,
    ) {
        let home = self.win_home[window];
        let mut path = Vec::with_capacity(8);
        path.push(self.disk_read[src]);
        path.extend(self.testbed.path(&self.links, src, home));
        path.push(self.disk_write[home]);
        let cap = self.transfer_cap(&path, src, home, state.factor[src]);
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, AFlow::Feature { src, window });
        self.win_inbound[window] += 1;
    }

    /// All of a window's feature slices landed: the home pays the
    /// per-file lookup + connection + open + read cost of the window's
    /// Sector files (Table 3's dominant term).  Deliberately NOT scaled
    /// by the straggler factor: the per-file cost is RTT/connection
    /// dominated, and no speculation exists for opens — a 4x-scaled
    /// open on one slow home would stall the whole aggregate barrier
    /// (DESIGN.md §13).
    fn schedule_open(&mut self, window: usize, now: f64, q: &mut EventQueue<AEv>) {
        let secs = self.win_files[window] as f64 * PER_FILE_SECS;
        self.next_gen += 1;
        self.open_gen[window] = Some(self.next_gen);
        q.push_at(
            now + secs,
            AEv::Open {
                window,
                gen: self.next_gen,
            },
        );
    }

    // -------------------------------------------------- stage 4: cluster

    fn start_cluster(
        &mut self,
        now: f64,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let mut segments = Vec::with_capacity(self.a.windows);
        for w in 0..self.a.windows {
            let home = self.win_home[w];
            let replica = replica_of(self.testbed, home);
            let mut locations: Vec<u32> = [home, replica]
                .into_iter()
                .filter(|&x| !state.dead[x])
                .map(|x| x as u32)
                .collect();
            locations.dedup();
            self.win_locs[w] = locations.clone();
            segments.push(Segment {
                id: w,
                file: format!("angle/w{w:04}.feat"),
                first_record: 0,
                n_records: 1,
                bytes: self.win_bytes[w].max(1.0) as u64,
                locations,
                whole_file: true,
            });
        }
        self.sched = Scheduler::new(segments, self.cfg.sphere.locality_scheduling);
        self.sched.max_attempts = self.cfg.sphere.max_attempts;
        self.pump_cluster(now, q, state)
    }

    /// Cluster tasks run where their window's feature file lives
    /// (`assign_filtered(_, true)` — the delay-scheduling knob), so a
    /// 128-node cloud does not steal 16 window tasks onto random nodes.
    fn pump_cluster(
        &mut self,
        now: f64,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let spes = self.spes();
        for node in 0..self.testbed.nodes() {
            if state.dead[node] {
                continue;
            }
            while self.running[node] < spes {
                let Some(seg) = self.sched.assign_filtered(node as u32, true) else {
                    break;
                };
                self.dispatch_cluster(seg, node, false, now, q, state);
            }
        }
        // A pending window whose whole replica set is dead can never be
        // assigned under locality — that data is gone, and the run must
        // say so (matching `build_stage_segments`).  Sorted so the
        // reported window is deterministic when several die at once.
        let mut pending: Vec<usize> = self.sched.pending_ids().into_iter().collect();
        pending.sort_unstable();
        for id in pending {
            if self.win_locs[id].iter().all(|&l| state.dead[l as usize]) {
                return Err(format!(
                    "window {id}'s feature data lost: home and replica both crashed"
                ));
            }
        }
        Ok(())
    }

    fn dispatch_cluster(
        &mut self,
        seg: Segment,
        node: usize,
        speculative: bool,
        now: f64,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) {
        let id = seg.id;
        let secs = self.win_secs[id] / state.factor[node] + self.coord_secs;
        self.next_gen += 1;
        let gen = self.next_gen;
        self.spec.register(id, gen);
        self.inflight.insert(
            gen,
            Attempt {
                node,
                seg,
                speculative,
                started: now,
            },
        );
        self.running[node] += 1;
        q.push_at(now + secs, AEv::Seg { gen });
        if !speculative {
            let nominal = self.win_secs[id] + self.coord_secs;
            q.push_at(now + SPEC_THRESHOLD * nominal, AEv::SpecCheck { gen });
        }
    }

    /// The primary attempt outlived `SPEC_THRESHOLD` × its nominal
    /// service time (it is on a straggler, or a degraded path): grant
    /// one backup on another live holder of the window's feature file
    /// and let the first finisher win.  A node WITHOUT the data is
    /// never picked — running there would be free, unpriced I/O; if no
    /// holder has a free SPE right now, re-check while the attempt is
    /// still running.
    fn spec_check(&mut self, gen: u64, now: f64, q: &mut EventQueue<AEv>, state: &FaultState) {
        let Some(att) = self.inflight.get(&gen) else {
            return; // completed or pre-empted: nothing to speculate on
        };
        let id = att.seg.id;
        let primary = att.node;
        if self.spec.is_speculated(id) || self.spec.attempts(id) > 1 || !self.sched.speculatable(id)
        {
            return;
        }
        let spes = self.spes();
        let backup = att
            .seg
            .locations
            .iter()
            .map(|&l| l as usize)
            .find(|&l| l != primary && !state.dead[l] && self.running[l] < spes);
        let Some(backup) = backup else {
            let retry = 0.25 * (self.win_secs[id] + self.coord_secs);
            q.push_at(now + retry, AEv::SpecCheck { gen });
            return;
        };
        let seg = att.seg.clone();
        if !self.sched.speculate(&seg, backup as u32) {
            return;
        }
        self.spec.mark_speculated(id);
        self.tracer
            .task_mark(now, "speculate", backup, "window cluster");
        self.dispatch_cluster(seg, backup, true, now, q, state);
    }

    /// An extract or cluster attempt finished its service time.
    fn seg_done(
        &mut self,
        gen: u64,
        now: f64,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let Some(att) = self.inflight.remove(&gen) else {
            return Ok(()); // pre-empted by a crash or a speculation win
        };
        self.running[att.node] -= 1;
        let first = self.sched.complete(&att.seg);
        if self.stage == Stage::Extract {
            debug_assert!(first, "extract never speculates");
            self.agg.segments += 1;
            self.tracer
                .task(att.started, now, "segment", att.node, "angle extract");
            self.pump_extract(now, q, state);
            return Ok(());
        }
        // Cluster: first finisher wins, siblings are cancelled.
        for g in self.spec.take_losers(att.seg.id, gen) {
            if let Some(loser) = self.inflight.remove(&g) {
                self.running[loser.node] -= 1;
                self.sched.cancel_attempt(&loser.seg);
            }
        }
        if first {
            self.tracer
                .task(att.started, now, "cluster", att.node, "window cluster");
            if att.speculative {
                self.sched.record_speculative_win();
                self.tracer
                    .task_mark(now, "spec won", att.node, "window cluster");
            }
            self.win_node[att.seg.id] = att.node;
            self.agg.segments += 1;
        } else {
            self.sched.cancel_attempt(&att.seg);
        }
        self.pump_cluster(now, q, state)
    }

    // ---------------------------------------------------- stage 5: score

    /// Replicate every window's fitted model to one representative per
    /// sensor site (write-local at the winner, one copy per other site
    /// — the storage cloud's site-diverse placement), then each site
    /// scores its share of the feature stream.
    fn start_score(
        &mut self,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let model_bytes = self.model_bytes();
        let sites = self.testbed.site_names.len();
        for s in 0..sites {
            self.site_rep[s] = self.site_head(state, s);
            if self.site_rep[s].is_some() {
                self.score_pending += 1;
            } else {
                self.scored[s] = true; // site fully offline: nothing to score
            }
        }
        self.score_share = self.feature_total / self.score_pending.max(1) as f64;
        for w in 0..self.a.windows {
            // The cluster winner may have crashed since its attempt
            // completed: the model ships from its surviving replica
            // copy, and a fully-dead chain is data loss.
            let src = self.live_owner(state, self.win_node[w])?;
            for s in 0..sites {
                let Some(rep) = self.site_rep[s] else { continue };
                self.model_tier.add(self.testbed, src, rep, model_bytes);
                self.agg.tier.add(self.testbed, src, rep, model_bytes);
                if rep == src {
                    continue;
                }
                self.start_model_flow(src, rep, s, model_bytes, net, state);
            }
        }
        for s in 0..sites {
            if self.site_rep[s].is_some() && self.score_inbound[s] == 0 && !self.scored[s] {
                self.schedule_scored(s, now, q, state);
            }
        }
        Ok(())
    }

    fn start_model_flow(
        &mut self,
        src: usize,
        rep: usize,
        site: usize,
        bytes: f64,
        net: &mut NetSim,
        state: &FaultState,
    ) {
        let path = self.testbed.path(&self.links, src, rep);
        let cap = self.transfer_cap(&path, src, rep, state.factor[src]);
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, AFlow::Model { src, site });
        self.score_inbound[site] += 1;
    }

    fn schedule_scored(
        &mut self,
        site: usize,
        now: f64,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) {
        let rep = self.site_rep[site].expect("scored sites have a representative");
        // Fixed per-site share set once at score start — a scan
        // rescheduled after other sites finished must not be charged
        // their shares too.
        let secs = self.score_share / (self.cfg.cpu.scan_bps * state.factor[rep]);
        self.next_gen += 1;
        self.score_gen[site] = Some(self.next_gen);
        q.push_at(
            now + secs,
            AEv::Scored {
                site,
                gen: self.next_gen,
            },
        );
    }

    // ------------------------------------------------------------ faults

    /// A crash fault named a live node (the core already applied the
    /// shared prologue: fault consumed, node marked dead).
    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        // Attempts running on the dead node: re-queue unless a sibling
        // attempt survives (its attempt count is preserved by the
        // scheduler's id-keyed map).
        let stale: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, a)| a.node == node)
            .map(|(&g, _)| g)
            .collect();
        for g in stale {
            let mut att = self.inflight.remove(&g).expect("stale gen exists");
            let siblings = self.spec.drop_attempt(att.seg.id, g);
            if self.stage == Stage::Cluster && siblings > 0 {
                self.sched.cancel_attempt(&att.seg);
                if att.speculative {
                    // The BACKUP died, not the primary: lift the
                    // one-backup-per-window latch and re-check the
                    // surviving attempt immediately, so a straggling
                    // window is not stranded by its rescuer's crash
                    // (the scheduler's attempt budget still applies).
                    self.spec.unmark_speculated(att.seg.id);
                    if let Some(survivor) = self.spec.first_attempt(att.seg.id) {
                        q.push_at(now, AEv::SpecCheck { gen: survivor });
                    }
                }
                continue;
            }
            if self.stage == Stage::Cluster {
                // Refresh the segment's replica set: the re-queued task
                // must be assignable to the surviving holder.
                self.win_locs[att.seg.id].retain(|&l| !state.dead[l as usize]);
                att.seg.locations = self.win_locs[att.seg.id].clone();
            }
            let id = att.seg.id;
            if !self.sched.fail(att.seg) {
                return Err(format!(
                    "job failed: segment {id} exhausted its {} attempts \
                     after node {node} crashed",
                    self.sched.max_attempts
                ));
            }
            self.agg.reassignments += 1;
        }
        self.running[node] = 0;
        // Shrink every window's surviving replica set.
        for locs in self.win_locs.iter_mut() {
            locs.retain(|&l| !state.dead[l as usize]);
        }

        // Transfers toward the dead node re-route (transfers leaving it
        // are assumed salvageable from the replica, like the batch
        // engine); ingest redirects to the replica chain, feature flows
        // follow their window's new home, model flows follow the new
        // site representative.
        let toward: Vec<(FlowId, AFlowInfo)> = self
            .flows
            .iter()
            .filter_map(|(&f, fl)| match fl {
                AFlow::Ingest { dst } if *dst == node => Some((f, AFlowInfo::Ingest)),
                AFlow::Feature { src, window } if self.win_home[*window] == node => {
                    Some((f, AFlowInfo::Feature { src: *src, window: *window }))
                }
                AFlow::Model { src, site } if self.site_rep[*site] == Some(node) => {
                    Some((f, AFlowInfo::Model { src: *src, site: *site }))
                }
                _ => None,
            })
            .collect();

        // Re-home windows and site representatives before restarting
        // the redirected remainders.
        if matches!(self.stage, Stage::Aggregate) {
            for w in 0..self.a.windows {
                if self.win_home[w] == node && !self.win_opened[w] {
                    let new_home = self.live_owner(state, replica_of(self.testbed, node))?;
                    self.win_home[w] = new_home;
                    self.agg.reassignments += 1;
                    // A pending per-file Open at the dead home restarts
                    // in full at the new home (pessimistic; §13).
                    if self.open_gen[w].take().is_some() && self.win_inbound[w] == 0 {
                        self.schedule_open(w, now, q);
                    }
                }
            }
        }
        let mut resent_sites: Vec<usize> = Vec::new();
        if matches!(self.stage, Stage::Score) {
            let sites = self.testbed.site_names.len();
            for s in 0..sites {
                if self.site_rep[s] == Some(node) && !self.scored[s] {
                    match self.site_head(state, s) {
                        Some(new_rep) => {
                            self.site_rep[s] = Some(new_rep);
                            self.score_gen[s] = None;
                            self.agg.reassignments += 1;
                            // The dead representative took its delivered
                            // models with it: re-replicate every window's
                            // model from its surviving copy to the new
                            // rep (real, counted re-distribution traffic)
                            // — the scan restarts once they land.
                            let model_bytes = self.model_bytes();
                            for w in 0..self.a.windows {
                                let src = self.live_owner(state, self.win_node[w])?;
                                self.model_tier
                                    .add(self.testbed, src, new_rep, model_bytes);
                                self.agg
                                    .tier
                                    .add(self.testbed, src, new_rep, model_bytes);
                                if src != new_rep {
                                    self.start_model_flow(
                                        src,
                                        new_rep,
                                        s,
                                        model_bytes,
                                        net,
                                        state,
                                    );
                                }
                            }
                            resent_sites.push(s);
                            if self.score_inbound[s] == 0 {
                                // Every surviving model copy was already
                                // local to the new rep.
                                self.schedule_scored(s, now, q, state);
                            }
                        }
                        None => {
                            // The whole sensor site is offline.
                            self.site_rep[s] = None;
                            self.score_gen[s] = None;
                            self.scored[s] = true;
                            self.score_pending -= 1;
                            self.agg.reassignments += 1;
                        }
                    }
                }
            }
        }

        // The rerouted remainders are not re-counted in tier/shuffle
        // byte totals — those count each payload once, at its first
        // send (the batch engine's convention); only the score-stage
        // model RE-replication above is new traffic and counted.
        for (fid, info) in toward {
            self.flows.remove(&fid);
            self.tracer.flow_cancel(fid, now);
            let left = net.cancel_flow(fid);
            match info {
                AFlowInfo::Ingest => {
                    self.ingest_pending -= 1;
                    let owner = self.live_owner(state, replica_of(self.testbed, node))?;
                    let head = self
                        .site_head(state, self.testbed.node_site[owner])
                        .expect("owner is alive");
                    self.start_ingest_flow(head, owner, left, net);
                }
                AFlowInfo::Feature { src, window } => {
                    self.win_inbound[window] -= 1;
                    if !state.dead[src] {
                        self.start_feature_flow(src, window, left, net, state);
                    } else if self.win_inbound[window] == 0 && !self.win_opened[window] {
                        self.schedule_open(window, now, q);
                    }
                }
                AFlowInfo::Model { src, site } => {
                    self.score_inbound[site] -= 1;
                    if resent_sites.contains(&site) {
                        // The full model set was already re-replicated
                        // to the replacement rep: drop the stale
                        // remainder, and start the scan if this was the
                        // last outstanding flow.
                        if self.score_inbound[site] == 0 && !self.scored[site] {
                            self.schedule_scored(site, now, q, state);
                        }
                    } else if let Some(rep) = self.site_rep[site] {
                        if !self.scored[site] {
                            // Resend from the model's surviving copy
                            // (the winner node, or its replica).
                            let src = self.live_owner(state, src)?;
                            self.start_model_flow(src, rep, site, left, net, state);
                        }
                    }
                }
            }
            self.agg.reassignments += 1;
        }

        match self.stage {
            Stage::Extract => self.pump_extract(now, q, state),
            Stage::Cluster => self.pump_cluster(now, q, state)?,
            _ => {}
        }
        Ok(())
    }

    // ------------------------------------------------------------ loop

    /// Advance the stage machine whenever the current stage drained.
    fn advance(
        &mut self,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        loop {
            match self.stage {
                Stage::Ingest if self.ingest_pending == 0 => {
                    self.agg.stage_ends.push(("sensor ingest".to_string(), now));
                    self.tracer.stage_mark(now, "sensor ingest");
                    self.stage = Stage::Extract;
                    self.start_extract(now, q, state)?;
                }
                Stage::Extract if self.sched.is_drained() && self.inflight.is_empty() => {
                    self.harvest_sched();
                    self.agg.stage_ends.push(("angle extract".to_string(), now));
                    self.tracer.stage_mark(now, "angle extract");
                    self.stage = Stage::Aggregate;
                    self.start_aggregate(now, net, q, state);
                }
                Stage::Aggregate if self.win_opened.iter().all(|&o| o) => {
                    self.agg
                        .stage_ends
                        .push(("window aggregate".to_string(), now));
                    self.tracer.stage_mark(now, "window aggregate");
                    self.stage = Stage::Cluster;
                    self.start_cluster(now, q, state)?;
                }
                Stage::Cluster if self.sched.is_drained() && self.inflight.is_empty() => {
                    self.harvest_sched();
                    self.agg.stage_ends.push(("window cluster".to_string(), now));
                    self.tracer.stage_mark(now, "window cluster");
                    self.stage = Stage::Score;
                    self.start_score(now, net, q, state)?;
                }
                Stage::Score if self.score_pending == 0 => {
                    self.agg.stage_ends.push(("model score".to_string(), now));
                    self.tracer.stage_mark(now, "model score");
                    self.stage = Stage::Done;
                    self.makespan = now;
                }
                _ => return Ok(()),
            }
        }
    }

    fn harvest_sched(&mut self) {
        self.agg.local_assignments += self.sched.local_assignments;
        self.agg.remote_assignments += self.sched.remote_assignments;
        self.agg.speculative_launched += self.sched.speculative_launched;
        self.agg.speculative_won += self.sched.speculative_won;
    }

    fn flow_done(&mut self, fid: FlowId, now: f64, q: &mut EventQueue<AEv>, state: &FaultState) {
        let Some(flow) = self.flows.remove(&fid) else {
            return;
        };
        match flow {
            AFlow::Ingest { .. } => self.ingest_pending -= 1,
            AFlow::Feature { window, .. } => {
                self.win_inbound[window] -= 1;
                if self.win_inbound[window] == 0 && !self.win_opened[window] {
                    self.schedule_open(window, now, q);
                }
            }
            AFlow::Model { site, .. } => {
                self.score_inbound[site] -= 1;
                if self.score_inbound[site] == 0
                    && !self.scored[site]
                    && self.site_rep[site].is_some()
                {
                    self.schedule_scored(site, now, q, state);
                }
            }
        }
    }

    fn execute(
        &mut self,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        core::schedule_faults(state, q, 0.0);
        self.start_ingest(net, state)?;
        self.advance(0.0, net, q, state)?;
        let links = self.links.clone();
        let testbed = self.testbed;
        let tracer = self.tracer.clone();
        let out = {
            let mut h = AngleHarness { run: self };
            core::drive(&mut h, net, q, state, &links, testbed, &tracer)?
        };
        self.agg.events += out.events;
        Ok(())
    }
}

/// Plugs the staged pipeline into the shared engine core: the stage
/// machine decides when the run is finished, and a drained queue before
/// `Stage::Done` is a bug, not an exit.
struct AngleHarness<'r, 'a> {
    run: &'r mut AngleRun<'a>,
}

impl<'r, 'a> Harness for AngleHarness<'r, 'a> {
    type Ev = AEv;

    fn finished(&self, _net: &NetSim) -> bool {
        self.run.stage == Stage::Done
    }

    fn on_stall(&mut self) -> Result<(), String> {
        Err("angle pipeline stalled before completing".into())
    }

    fn gauges(&self) -> HarnessGauges {
        HarnessGauges {
            occupancy: self.run.running.iter().map(|&r| r as u64).sum(),
            queued: self.run.sched.pending_count() as u64,
            spec_inflight: self
                .run
                .inflight
                .values()
                .filter(|a| a.speculative)
                .count() as u64,
            replicas: 0,
        }
    }

    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        _net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.run.flow_done(fid, now, q, state);
        Ok(())
    }

    fn handle(
        &mut self,
        ev: AEv,
        now: f64,
        _net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        match ev {
            AEv::Seg { gen } => self.run.seg_done(gen, now, q, state)?,
            AEv::SpecCheck { gen } => self.run.spec_check(gen, now, q, state),
            AEv::Open { window, gen } => {
                if self.run.open_gen[window] == Some(gen) {
                    self.run.open_gen[window] = None;
                    self.run.win_opened[window] = true;
                    self.run.tracer.task_mark(
                        now,
                        "window open",
                        self.run.win_home[window],
                        "window aggregate",
                    );
                }
            }
            AEv::Scored { site, gen } => {
                if self.run.score_gen[site] == Some(gen) {
                    self.run.score_gen[site] = None;
                    self.run.scored[site] = true;
                    self.run.score_pending -= 1;
                    if let Some(rep) = self.run.site_rep[site] {
                        self.run.tracer.task_mark(now, "site scored", rep, "model score");
                    }
                }
            }
            AEv::Fault(_) => {} // intercepted by the core
        }
        Ok(())
    }

    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.run.on_crash(node, now, net, q, state)
    }

    fn after_wave(
        &mut self,
        now: f64,
        _drained: bool,
        net: &mut NetSim,
        q: &mut EventQueue<AEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.run.advance(now, net, q, state)
    }
}

/// Redirect bookkeeping captured before mutating the flow table.
enum AFlowInfo {
    Ingest,
    Feature { src: usize, window: usize },
    Model { src: usize, site: usize },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{run_scenario, ScenarioSpec, WorkloadKind};
    use crate::topology::TopologySpec;
    use crate::util::bytes::GB;

    /// Four sensor sites (the proven detection shape: 4 sensors x 25
    /// sources = 100 points per window) x `nodes_per_rack` nodes each.
    fn angle_spec(nodes_per_rack: usize) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(4, 1, nodes_per_rack);
        spec.name = "angle-test".into();
        let w = spec.workload.as_mut().unwrap();
        w.kind = WorkloadKind::Angle;
        w.bytes_per_node = 0.25 * GB as f64;
        spec.angle = Some(AngleSpec::default());
        spec
    }

    #[test]
    fn staged_pipeline_runs_all_five_stages() {
        let spec = angle_spec(2);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "same spec, same report");
        let an = a.angle.as_ref().expect("angle report present");
        assert_eq!(an.windows, 8);
        assert_eq!(an.deltas.len(), 7);
        assert!(an.feature_gbytes > 0.0);
        assert!(an.staged_work_secs > 0.0);
        assert!(an.oracle_secs > 0.0);
        assert!(a.segments > spec.topology.nodes(), "extract + cluster tasks");
        assert!(a.shuffle_gbytes > 0.0, "feature shuffle crossed the network");
        assert!(an.model_tier.total() > 0.0, "models were distributed");
        assert!(an.model_tier.wan > 0.0, "models crossed sites");
        // Every stage ran on the substrate, in order.
        let testbed = spec.topology.generate().unwrap();
        let out = run_angle(&spec, &testbed, &TraceRecorder::disabled()).unwrap();
        let names: Vec<&str> = out.agg.stage_ends.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "sensor ingest",
                "angle extract",
                "window aggregate",
                "window cluster",
                "model score"
            ]
        );
        let ends: Vec<f64> = out.agg.stage_ends.iter().map(|(_, t)| *t).collect();
        assert!(ends.windows(2).all(|p| p[0] <= p[1]), "stages end in order");
        assert!((out.makespan - ends[4]).abs() < 1e-9, "score ends the run");
    }

    #[test]
    fn detection_finds_planted_scan_and_exfil() {
        let spec = angle_spec(2);
        let r = run_scenario(&spec).unwrap();
        let an = r.angle.unwrap();
        assert_eq!(an.emergent_planted, vec![4, 6], "scan at 4, exfil at 6");
        assert_eq!(an.recall, 1.0, "found {:?}", an.emergent_found);
    }

    #[test]
    fn crash_rehomes_windows_and_still_detects() {
        let mut spec = angle_spec(2);
        let baseline = run_scenario(&spec).unwrap();
        // Crash mid-run: late enough to land after ingest on this size.
        spec.faults.push(crate::scenario::FaultSpec::SlaveCrash {
            at_secs: 2.0,
            node: 1,
        });
        let r = run_scenario(&spec).unwrap();
        assert_eq!(r, run_scenario(&spec).unwrap(), "faulted run stays deterministic");
        assert_eq!(r.nodes_crashed, 1);
        assert!(r.reassignments > 0, "the crash re-assigned work");
        let an = r.angle.unwrap();
        assert_eq!(an.recall, 1.0, "content survives on replicas");
        assert_eq!(
            an.deltas,
            baseline.angle.as_ref().unwrap().deltas,
            "faults perturb timing, never the mined content"
        );
    }

    #[test]
    fn straggler_triggers_speculation_on_its_window() {
        // 16 nodes, 8 windows -> spread 2: homes 0,2,4,...  Node 2
        // hosts a window; make it 4x slow so its cluster task crosses
        // the 2x-nominal speculation threshold and the backup wins.
        let mut spec = angle_spec(4);
        spec.faults.push(crate::scenario::FaultSpec::Straggler {
            node: 2,
            factor: 0.25,
        });
        let r = run_scenario(&spec).unwrap();
        assert!(
            r.speculative_launched >= 1,
            "the 4x straggler must trigger a backup"
        );
        assert!(r.speculative_won >= 1, "the backup must win");
        let no_straggler = run_scenario(&angle_spec(4)).unwrap();
        assert!(
            r.makespan_secs >= no_straggler.makespan_secs,
            "a straggler never speeds the run up"
        );
    }

    #[test]
    fn staged_work_tracks_the_oracle() {
        let r = run_scenario(&angle_spec(2)).unwrap();
        let an = r.angle.unwrap();
        let ratio = an.staged_work_secs / an.oracle_secs;
        assert!(
            (0.5..=1.25).contains(&ratio),
            "staged/oracle = {ratio:.3} outside the documented band"
        );
    }

    #[test]
    fn single_site_runs_without_wan() {
        let mut spec = angle_spec(2);
        spec.topology = TopologySpec::paper_lan(4);
        let r = run_scenario(&spec).unwrap();
        let an = r.angle.unwrap();
        assert_eq!(an.model_tier.wan, 0.0, "one site, no WAN crossing");
        assert!(r.makespan_secs > 0.0);
    }

    #[test]
    fn losing_a_window_replica_chain_fails_the_run() {
        // scale_out(1,2,2): replica pairs 0<->2, 1<->3.  Crashing both
        // ends of a pair during the long cluster stage destroys that
        // window data; the run must error, not report a makespan.
        let mut spec = angle_spec(2);
        spec.topology = TopologySpec::scale_out(1, 2, 2);
        spec.faults.push(crate::scenario::FaultSpec::SlaveCrash {
            at_secs: 10.0,
            node: 0,
        });
        spec.faults.push(crate::scenario::FaultSpec::SlaveCrash {
            at_secs: 11.0,
            node: 2,
        });
        let err = run_scenario(&spec).unwrap_err();
        assert!(err.contains("lost"), "{err}");
    }
}

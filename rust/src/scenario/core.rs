//! Shared engine core (DESIGN.md §14).
//!
//! Five workloads run event loops over the same substrate — batch
//! stages (`scenario::engine`), client traffic (`service::engine`),
//! colocated batch+traffic (`scenario::colocate`), the Hadoop baseline
//! (`hadoop::engine`) and the staged Angle pipeline
//! (`scenario::angle`).  Their loops were near-copies: pick the next
//! instant from `min(EventQueue, NetSim)`, advance the network,
//! dispatch completed flows, drain the simultaneous event wave, apply
//! faults, run a post-wave hook.  This module owns that skeleton once:
//!
//! * [`drive`] is the loop.  An engine implements [`Harness`] — its
//!   workload semantics (what a finished flow means, what a
//!   non-fault event does, how to recover from a crash, what runs
//!   after each wave) — and the core owns time selection, flow
//!   dispatch, wave draining, event counting and fault application.
//! * [`FaultEv`]/[`CoreEv`] make the fault plan's events a shared
//!   vocabulary: each engine's event enum embeds them, the core
//!   intercepts them, so crash/brown-out handling cannot drift apart
//!   per engine again.
//! * [`schedule_faults`] is the one copy of fault-plan scheduling
//!   (crash instants, degrade windows with their end events, expired
//!   windows consumed) that every engine calls at setup.
//! * [`FaultState`] carries fault-plan progress; the degrade handlers
//!   apply brown-outs as shared-link capacity changes so max-min
//!   sharing redistributes the loss (and the repair) immediately.
//! * [`Speculation`] is the sibling-attempt bookkeeping behind
//!   speculative re-execution (DESIGN.md §11): live attempts per work
//!   unit, the one-backup latch, first-finisher-wins loser lists, and
//!   the deduplicated re-check scan.  Engines keep only their cutoff
//!   policy (threshold x median, 1.2 x mean, 2 x nominal).
//!
//! Determinism is inherited, not re-proven: the loop preserves the
//! exact dispatch order the engines used (flows in id order, then the
//! FIFO event wave, then the post-wave hook), so a spec's report is
//! byte-identical through the refactor — pinned by the golden fixture
//! suite in rust/tests/scenario_golden.rs.

use std::collections::{BTreeMap, HashSet};

use crate::routing::{hash_name, ChordRing, Id};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, NetSim};
use crate::topology::{NetLinks, Testbed};

use super::trace::{sample_gauges, HarnessGauges, Tracer};
use super::{FaultSpec, ScenarioSpec};

// ------------------------------------------------------------ fault state

/// Fault plan progress carried across workload stages.  Shared by
/// every engine; the traffic engine composes the same plan with a
/// request stream instead of a batch job.
pub(crate) struct FaultState {
    pub(crate) faults: Vec<FaultSpec>,
    /// crash applied / degrade window fully elapsed.
    pub(crate) consumed: Vec<bool>,
    /// fault counted in `injected` (a degrade window can re-fire its
    /// start event in a later stage; it must not count twice).
    counted: Vec<bool>,
    pub(crate) dead: Vec<bool>,
    /// Live node ids in order — cached because the hot loop asks on
    /// every segment completion and the set only changes on a crash.
    alive_list: Vec<usize>,
    /// Straggler speed multiplier per node (1.0 = nominal).
    pub(crate) factor: Vec<f64>,
    pub(crate) injected: usize,
    pub(crate) crashes: usize,
    /// Standing per-site weather factor (DESIGN.md §18): the latest
    /// `WeatherSet` point applied per site.  Grown lazily so plans
    /// without weather never allocate; out-of-range reads are 1.0.
    weather: Vec<f64>,
    /// Master/NameNode down (a `MasterCrash` window is open): engines
    /// gate new task assignments on this.
    pub(crate) master_down: bool,
    /// The Chord ring membership walks through on every leave/join —
    /// built by [`FaultState::for_run`] only when the plan has churn.
    pub(crate) ring: Option<ChordRing>,
    /// node index -> ring id (FNV of the slave name), parallel to
    /// `dead`; empty when no ring is maintained.
    ring_ids: Vec<Id>,
}

impl FaultState {
    pub(crate) fn new(faults: &[FaultSpec], nodes: usize) -> FaultState {
        let mut s = FaultState {
            faults: faults.to_vec(),
            consumed: vec![false; faults.len()],
            counted: vec![false; faults.len()],
            dead: vec![false; nodes],
            alive_list: (0..nodes).collect(),
            factor: vec![1.0; nodes],
            injected: 0,
            crashes: 0,
            weather: Vec::new(),
            master_down: false,
            ring: None,
            ring_ids: Vec::new(),
        };
        for (i, f) in faults.iter().enumerate() {
            if let FaultSpec::Straggler { node, factor } = f {
                s.factor[*node] *= factor;
                s.consumed[i] = true;
                s.counted[i] = true;
                s.injected += 1;
            }
        }
        s
    }

    /// The run-time fault prologue every engine shares (DESIGN.md §18):
    /// the *effective* plan (explicit faults + the expanded churn
    /// episode + the weather trace), per-site disk-speed multipliers
    /// folded into the node factors, and — when the plan has churn —
    /// the Chord ring that membership maintenance walks through on
    /// every leave/join.
    pub(crate) fn for_run(spec: &ScenarioSpec, testbed: &Testbed) -> FaultState {
        let faults = spec.effective_faults();
        let mut s = FaultState::new(&faults, testbed.nodes());
        for node in 0..testbed.nodes() {
            s.factor[node] *= testbed.disk_mult(node);
        }
        let churns = faults
            .iter()
            .any(|f| matches!(f, FaultSpec::NodeLeave { .. } | FaultSpec::NodeJoin { .. }));
        if churns {
            let ids: Vec<Id> = (0..testbed.nodes())
                .map(|i| hash_name(&format!("slave{i:04}")))
                .collect();
            s.ring = Some(ChordRing::build(&ids));
            s.ring_ids = ids;
        }
        s
    }

    pub(crate) fn count_once(&mut self, fault: usize) {
        if !self.counted[fault] {
            self.counted[fault] = true;
            self.injected += 1;
        }
    }

    pub(crate) fn alive(&self) -> &[usize] {
        &self.alive_list
    }

    pub(crate) fn crash(&mut self, node: usize) {
        if !self.dead[node] {
            self.dead[node] = true;
            self.alive_list.retain(|&n| n != node);
            self.crashes += 1;
            self.injected += 1;
            if let Some(ring) = self.ring.as_mut() {
                ring.leave(self.ring_ids[node]);
            }
        }
    }

    /// A departed node re-joins (churn `NodeJoin`): live again, back in
    /// the Chord ring, and a placement target from the next pump.  The
    /// `crashes` counter is cumulative departures — a re-join does not
    /// roll it back.
    pub(crate) fn revive(&mut self, node: usize) {
        if self.dead[node] {
            self.dead[node] = false;
            let pos = self.alive_list.partition_point(|&x| x < node);
            self.alive_list.insert(pos, node);
            if let Some(ring) = self.ring.as_mut() {
                ring.join(self.ring_ids[node]);
            }
        }
    }

    /// Record a site's standing weather factor (latest point wins).
    pub(crate) fn set_weather(&mut self, site: usize, factor: f64) {
        if self.weather.len() <= site {
            self.weather.resize(site + 1, 1.0);
        }
        self.weather[site] = factor;
    }

    /// The standing weather factor for `site` (1.0 when no point set).
    pub(crate) fn weather_factor(&self, site: usize) -> f64 {
        self.weather.get(site).copied().unwrap_or(1.0)
    }

    /// Apply every crash scheduled at or before `now` (analytic
    /// workloads advance in rounds rather than per-event).
    pub(crate) fn apply_crashes_due(&mut self, now: f64) {
        for i in 0..self.faults.len() {
            if self.consumed[i] {
                continue;
            }
            if let FaultSpec::SlaveCrash { at_secs, node } = self.faults[i] {
                if at_secs <= now {
                    self.consumed[i] = true;
                    self.crash(node);
                }
            }
        }
    }

    /// WAN degradation factor applying to `site` at time `now`.
    pub(crate) fn degrade_factor_at(&self, site: usize, now: f64) -> f64 {
        let mut f = 1.0;
        for fault in &self.faults {
            if let FaultSpec::LinkDegrade {
                at_secs,
                duration_secs,
                site: s,
                factor,
            } = fault
            {
                if *s == site && *at_secs <= now && now < at_secs + duration_secs {
                    f *= factor;
                }
            }
        }
        f
    }

    /// Like `degrade_factor_at`, but records the matched windows in
    /// `faults_injected` — the analytic workloads have no Degrade
    /// events, so this is where their faults get counted.
    pub(crate) fn degrade_factor_counting(&mut self, site: usize, now: f64) -> f64 {
        let mut f = 1.0;
        for i in 0..self.faults.len() {
            if let FaultSpec::LinkDegrade {
                at_secs,
                duration_secs,
                site: s,
                factor,
            } = self.faults[i]
            {
                if s == site && at_secs <= now && now < at_secs + duration_secs {
                    f *= factor;
                    self.count_once(i);
                }
            }
        }
        f
    }
}

// ------------------------------------------------------------ fault events

/// The fault plan's discrete events — the shared vocabulary every
/// engine's event type embeds and the core intercepts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum FaultEv {
    Crash { fault: usize },
    DegradeStart { fault: usize },
    DegradeEnd { fault: usize },
    /// Churn: a node leaves the system (crash semantics + ring
    /// maintenance).
    Leave { fault: usize },
    /// Churn: a previously departed node re-joins.
    Join { fault: usize },
    /// Network weather: a site's standing WAN capacity factor steps.
    Weather { fault: usize },
    /// Master failover window opens / closes (DESIGN.md §18).
    MasterDown { fault: usize },
    MasterUp { fault: usize },
}

/// An engine event type that can carry the shared fault events.
pub(crate) trait CoreEv: Sized {
    fn from_fault(f: FaultEv) -> Self;
    /// Inverse of `from_fault`: the core intercepts and applies these
    /// instead of handing them to the harness.
    fn to_fault(&self) -> Option<FaultEv>;
    /// Short static label the trace records for this event's dispatch
    /// (DESIGN.md §15).  Engines override it with per-variant names.
    fn trace_name(&self) -> &'static str {
        "ev"
    }
}

/// Schedule the not-yet-consumed fault plan into an engine's queue.
/// `start` is the engine's epoch (a later batch stage re-schedules the
/// remaining plan from its own start time; single-epoch engines pass
/// 0.0): crashes clamp to it, and a degrade window that already closed
/// is consumed without firing.
pub(crate) fn schedule_faults<E: CoreEv>(
    state: &mut FaultState,
    q: &mut EventQueue<E>,
    start: f64,
) {
    for i in 0..state.faults.len() {
        if state.consumed[i] {
            continue;
        }
        match state.faults[i] {
            FaultSpec::SlaveCrash { at_secs, .. } => {
                q.push_at(at_secs.max(start), E::from_fault(FaultEv::Crash { fault: i }));
            }
            FaultSpec::LinkDegrade {
                at_secs,
                duration_secs,
                ..
            } => {
                let end = at_secs + duration_secs;
                if end <= start {
                    state.consumed[i] = true;
                    continue;
                }
                q.push_at(
                    at_secs.max(start),
                    E::from_fault(FaultEv::DegradeStart { fault: i }),
                );
                if end.is_finite() {
                    q.push_at(end, E::from_fault(FaultEv::DegradeEnd { fault: i }));
                }
            }
            FaultSpec::NodeLeave { at_secs, .. } => {
                q.push_at(at_secs.max(start), E::from_fault(FaultEv::Leave { fault: i }));
            }
            FaultSpec::NodeJoin { at_secs, .. } => {
                q.push_at(at_secs.max(start), E::from_fault(FaultEv::Join { fault: i }));
            }
            // Weather points are standing state, not windows: a later
            // stage's fresh NetSim must re-learn every point already
            // passed.  They are never consumed — past points fire again
            // at the stage epoch in plan (= time) order, so the latest
            // point per site wins.
            FaultSpec::WeatherSet { at_secs, .. } => {
                q.push_at(
                    at_secs.max(start),
                    E::from_fault(FaultEv::Weather { fault: i }),
                );
            }
            FaultSpec::MasterCrash { at_secs, down_secs } => {
                let end = at_secs + down_secs;
                if end <= start {
                    state.consumed[i] = true;
                    continue;
                }
                q.push_at(
                    at_secs.max(start),
                    E::from_fault(FaultEv::MasterDown { fault: i }),
                );
                if end.is_finite() {
                    q.push_at(end, E::from_fault(FaultEv::MasterUp { fault: i }));
                }
            }
            FaultSpec::Straggler { .. } => {}
        }
    }
}

/// Re-derive a site's full-duplex WAN uplink capacity from everything
/// that scales it — the per-site nominal rate (heterogeneous sites),
/// the degradation windows active at `now`, and the standing weather
/// factor — and apply it as one capacity change no matter which engine
/// owns the links.  Overlapping degradations compound; weather
/// multiplies on top.
pub(crate) fn apply_site_uplink(
    state: &FaultState,
    net: &mut NetSim,
    links: &NetLinks,
    testbed: &Testbed,
    site: usize,
    now: f64,
) {
    let f = state.degrade_factor_at(site, now) * state.weather_factor(site);
    let cap = (testbed.site_wan_bps(site) * f).max(1.0);
    net.set_link_capacity(links.site_up[site], cap);
    net.set_link_capacity(links.site_down[site], cap);
}

/// A degradation window opened: count it once and squeeze the site's
/// uplinks to the combined factor of every window active at `now`.
pub(crate) fn handle_degrade_start(
    state: &mut FaultState,
    net: &mut NetSim,
    links: &NetLinks,
    testbed: &Testbed,
    fault: usize,
    now: f64,
) {
    if let FaultSpec::LinkDegrade { site, .. } = state.faults[fault] {
        state.count_once(fault);
        apply_site_uplink(state, net, links, testbed, site, now);
    }
}

/// A degradation window closed: restore the site's uplinks to whatever
/// the *remaining* windows (and weather) dictate, not blindly to 1.0.
pub(crate) fn handle_degrade_end(
    state: &mut FaultState,
    net: &mut NetSim,
    links: &NetLinks,
    testbed: &Testbed,
    fault: usize,
    now: f64,
) {
    state.consumed[fault] = true;
    if let FaultSpec::LinkDegrade { site, .. } = state.faults[fault] {
        apply_site_uplink(state, net, links, testbed, site, now);
    }
}

/// A weather point fired: record the site's standing factor and
/// re-derive its uplink capacity (composed with any open degradation
/// windows).
pub(crate) fn handle_weather_set(
    state: &mut FaultState,
    net: &mut NetSim,
    links: &NetLinks,
    testbed: &Testbed,
    fault: usize,
    now: f64,
) {
    if let FaultSpec::WeatherSet { site, factor, .. } = state.faults[fault] {
        state.count_once(fault);
        state.set_weather(site, factor);
        apply_site_uplink(state, net, links, testbed, site, now);
    }
}

// ------------------------------------------------------------ the loop

/// What [`drive`] returns: the events it dispatched (flow completions,
/// queue events, fault injections — every engine counts them the same
/// way) and the virtual time of the last wave.
pub(crate) struct DriveOutcome {
    pub(crate) events: u64,
    pub(crate) end: f64,
}

/// One engine plugged into the shared loop.  The core owns time
/// selection, flow-completion dispatch, wave draining, event counting
/// and fault application; the harness owns workload semantics.
pub(crate) trait Harness {
    type Ev: CoreEv;

    /// Loop-top exit test.  Engines that must also drain the network
    /// include `net.active_flows() == 0` here; the staged Angle
    /// pipeline exits on its own stage machine instead.
    fn finished(&self, net: &NetSim) -> bool;

    /// Queue and network both exhausted before [`Harness::finished`]:
    /// `Ok(())` ends the run (batch/traffic semantics — everything
    /// outstanding was already accounted), `Err` aborts (the Angle
    /// pipeline treats a stall as a bug).
    fn on_stall(&mut self) -> Result<(), String> {
        Ok(())
    }

    /// A network flow completed at `now`.
    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Self::Ev>,
        state: &mut FaultState,
    ) -> Result<(), String>;

    /// A non-fault event fired at `now` (fault events never reach
    /// this: the core intercepts them).
    fn handle(
        &mut self,
        ev: Self::Ev,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Self::Ev>,
        state: &mut FaultState,
    ) -> Result<(), String>;

    /// A crash fault named a live node.  The core already marked the
    /// fault consumed and the node dead (the shared prologue); the
    /// harness re-queues the node's work and re-routes its transfers.
    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Self::Ev>,
        state: &mut FaultState,
    ) -> Result<(), String>;

    /// A join fault revived a departed node (the core already marked it
    /// live and re-inserted it into the ring).  Default: nothing —
    /// engines whose `after_wave` re-pumps on drained waves resume
    /// assignment to the node automatically; engines that pump from
    /// completions only (Hadoop) override this to pump.
    fn on_join(
        &mut self,
        _node: usize,
        _now: f64,
        _net: &mut NetSim,
        _q: &mut EventQueue<Self::Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        Ok(())
    }

    /// The master went down (`up == false`) or recovered (`up ==
    /// true`).  The core already flipped `state.master_down`; engines
    /// gate their pump on that flag and use this hook for transition
    /// work (Hadoop loses its in-flight attempts on the way down and
    /// re-pumps on the way up; Sector's slaves keep working).
    fn on_master(
        &mut self,
        _up: bool,
        _now: f64,
        _net: &mut NetSim,
        _q: &mut EventQueue<Self::Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        Ok(())
    }

    /// End of a wave at `now`; `drained` says whether queue events
    /// fired this wave (the batch engine only re-pumps its SPEs then;
    /// the colocation and Angle engines act every wave).
    fn after_wave(
        &mut self,
        now: f64,
        drained: bool,
        net: &mut NetSim,
        q: &mut EventQueue<Self::Ev>,
        state: &mut FaultState,
    ) -> Result<(), String>;

    /// Harness-side gauges for the sim-time sampler (DESIGN.md §15).
    /// The default reports idle; engines with schedulers override it.
    fn gauges(&self) -> HarnessGauges {
        HarnessGauges::default()
    }
}

/// The shared event loop: `next = min(queue, network)`, advance the
/// network and dispatch completed flows in id order, drain the
/// simultaneous event wave FIFO, intercept fault events, then the
/// post-wave hook.  Returns the event count and end time.
///
/// Tracing (DESIGN.md §15) rides the loop: flow opens are detected
/// centrally from the monotone flow-id watermark (every engine's
/// starts land between two waves), completions close their spans,
/// fault applications and event dispatches emit instants, and the
/// sim-time sampler fires on every tick crossed by a wave — sampling
/// the state immediately *before* the wave that crossed it.
pub(crate) fn drive<H: Harness>(
    h: &mut H,
    net: &mut NetSim,
    q: &mut EventQueue<H::Ev>,
    state: &mut FaultState,
    links: &NetLinks,
    testbed: &Testbed,
    tracer: &Tracer,
) -> Result<DriveOutcome, String> {
    let mut events: u64 = 0;
    let mut now = net.now();
    let mut batch: Vec<H::Ev> = Vec::new();
    let tick = tracer.sample_secs();
    let mut next_tick = if tick > 0.0 {
        (now / tick).floor() * tick + tick
    } else {
        f64::INFINITY
    };
    // Engines that rebuild their substrate between stages restart the
    // flow-id space; re-anchor the open-flow watermark to this net.
    tracer.reset_flow_watermark(net.flow_id_watermark());
    loop {
        // Flows the harness started since the last turn opened at the
        // previous wave's instant (`now` still holds it here).
        tracer.open_new_flows(net.flow_id_watermark(), now);
        if h.finished(net) {
            break;
        }
        let tq = q.peek_time();
        let tn = net.next_completion().map(|(t, _)| t);
        let next = match (tq, tn) {
            (None, None) => {
                h.on_stall()?;
                break;
            }
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (Some(a), Some(b)) => a.min(b),
        };
        while next_tick <= next {
            let g = h.gauges();
            sample_gauges(tracer, next_tick, &g, net, q.len(), state.alive().len(), links);
            next_tick += tick;
        }
        now = next;
        for fid in net.advance_to(next) {
            events += 1;
            tracer.flow_done(fid, now);
            h.flow_done(fid, now, net, q, state)?;
        }
        let mut drained = false;
        if q.peek_time() == Some(next) {
            drained = true;
            batch.clear();
            q.pop_simultaneous(&mut batch);
            for ev in batch.drain(..) {
                events += 1;
                match ev.to_fault() {
                    Some(FaultEv::Crash { fault }) => {
                        state.consumed[fault] = true;
                        if let FaultSpec::SlaveCrash { node, .. } = state.faults[fault] {
                            if !state.dead[node] {
                                tracer.instant_node(now, "fault", "crash", node);
                                state.crash(node);
                                h.on_crash(node, now, net, q, state)?;
                            }
                        }
                    }
                    Some(FaultEv::DegradeStart { fault }) => {
                        if let FaultSpec::LinkDegrade { site, factor, .. } = state.faults[fault]
                        {
                            tracer.instant(
                                now,
                                "fault",
                                &format!("degrade site{site} x{factor}"),
                            );
                        }
                        handle_degrade_start(state, net, links, testbed, fault, now)
                    }
                    Some(FaultEv::DegradeEnd { fault }) => {
                        if let FaultSpec::LinkDegrade { site, .. } = state.faults[fault] {
                            tracer.instant(now, "fault", &format!("restore site{site}"));
                        }
                        handle_degrade_end(state, net, links, testbed, fault, now)
                    }
                    Some(FaultEv::Leave { fault }) => {
                        state.consumed[fault] = true;
                        if let FaultSpec::NodeLeave { node, .. } = state.faults[fault] {
                            if !state.dead[node] {
                                tracer.instant_node(now, "fault", "leave", node);
                                state.crash(node);
                                h.on_crash(node, now, net, q, state)?;
                            }
                        }
                    }
                    Some(FaultEv::Join { fault }) => {
                        state.consumed[fault] = true;
                        if let FaultSpec::NodeJoin { node, .. } = state.faults[fault] {
                            if state.dead[node] {
                                state.count_once(fault);
                                tracer.instant_node(now, "fault", "join", node);
                                state.revive(node);
                                h.on_join(node, now, net, q, state)?;
                            }
                        }
                    }
                    Some(FaultEv::Weather { fault }) => {
                        if let FaultSpec::WeatherSet { site, factor, .. } = state.faults[fault] {
                            tracer.instant(
                                now,
                                "fault",
                                &format!("weather site{site} x{factor}"),
                            );
                        }
                        handle_weather_set(state, net, links, testbed, fault, now)
                    }
                    Some(FaultEv::MasterDown { fault }) => {
                        state.count_once(fault);
                        if !state.master_down {
                            state.master_down = true;
                            tracer.instant(now, "fault", "master down");
                            h.on_master(false, now, net, q, state)?;
                        }
                    }
                    Some(FaultEv::MasterUp { fault }) => {
                        state.consumed[fault] = true;
                        if state.master_down {
                            state.master_down = false;
                            tracer.instant(now, "fault", "master up");
                            h.on_master(true, now, net, q, state)?;
                        }
                    }
                    None => {
                        tracer.ev(now, ev.trace_name());
                        h.handle(ev, now, net, q, state)?;
                    }
                }
            }
        }
        h.after_wave(now, drained, net, q, state)?;
    }
    // Flows started by the final wave (or left mid-transfer) get their
    // opens recorded before the run's artifacts are written.
    tracer.open_new_flows(net.flow_id_watermark(), now);
    Ok(DriveOutcome { events, end: now })
}

// ------------------------------------------------------------ speculation

/// A live attempt as the speculation scanner sees it.
pub(crate) struct SpecCand {
    pub(crate) gen: u64,
    /// Work-unit id (segment / task / window) the attempt executes.
    pub(crate) unit: usize,
    pub(crate) started: f64,
    pub(crate) speculative: bool,
}

/// Sibling-attempt bookkeeping behind speculative re-execution,
/// shared by the colocation, Hadoop and Angle engines.  The engines
/// keep only their cutoff policy; launch mechanics (one backup per
/// unit, first-finisher-wins, deduplicated re-check scheduling) live
/// here.
#[derive(Default)]
pub(crate) struct Speculation {
    /// Live attempt gens per work-unit id.
    by_unit: BTreeMap<usize, Vec<u64>>,
    /// Units that already got their one backup.
    speculated: HashSet<usize>,
    /// Earliest pending re-check (dedup so scans don't flood the queue).
    check_at: Option<f64>,
}

impl Speculation {
    pub(crate) fn new() -> Speculation {
        Speculation::default()
    }

    /// Record a live attempt of `unit`.
    pub(crate) fn register(&mut self, unit: usize, gen: u64) {
        self.by_unit.entry(unit).or_default().push(gen);
    }

    /// Number of live attempts of `unit`.
    pub(crate) fn attempts(&self, unit: usize) -> usize {
        self.by_unit.get(&unit).map_or(0, Vec::len)
    }

    /// An attempt finished first: forget the unit and return every
    /// sibling attempt (the speculation loser, or the original when
    /// the backup won) for cancellation.
    pub(crate) fn take_losers(&mut self, unit: usize, winner: u64) -> Vec<u64> {
        self.by_unit
            .remove(&unit)
            .map(|gens| gens.into_iter().filter(|&g| g != winner).collect())
            .unwrap_or_default()
    }

    /// An attempt died (crash): drop it and return how many sibling
    /// attempts of the unit remain (0 = the unit must be re-queued).
    pub(crate) fn drop_attempt(&mut self, unit: usize, gen: u64) -> usize {
        let remaining = {
            let v = self.by_unit.entry(unit).or_default();
            v.retain(|&x| x != gen);
            v.len()
        };
        if remaining == 0 {
            self.by_unit.remove(&unit);
        }
        remaining
    }

    /// Latch `unit` as having received its one backup attempt.
    pub(crate) fn mark_speculated(&mut self, unit: usize) {
        self.speculated.insert(unit);
    }

    /// Has `unit` already received its one backup?
    pub(crate) fn is_speculated(&self, unit: usize) -> bool {
        self.speculated.contains(&unit)
    }

    /// A backup attempt died before finishing: lift the latch so the
    /// surviving attempt may earn a new backup.
    pub(crate) fn unmark_speculated(&mut self, unit: usize) {
        self.speculated.remove(&unit);
    }

    /// First live attempt of `unit` in registration order, if any.
    pub(crate) fn first_attempt(&self, unit: usize) -> Option<u64> {
        self.by_unit.get(&unit).and_then(|v| v.first().copied())
    }

    /// Reset per-stage state (a new stage gets fresh backups).
    pub(crate) fn clear_stage(&mut self) {
        self.by_unit.clear();
        self.speculated.clear();
        self.check_at = None;
    }

    /// The shared speculation check: given the in-flight attempts (in
    /// deterministic gen order) and the engine's cutoff, return the
    /// attempts to back up now plus the earliest future crossing (for
    /// a re-check).  Backup-ineligible attempts — already speculative,
    /// unit latched, or a sibling already live — are skipped.
    pub(crate) fn scan(
        &self,
        now: f64,
        cutoff: f64,
        inflight: impl Iterator<Item = SpecCand>,
    ) -> (Vec<u64>, Option<f64>) {
        let mut launch: Vec<u64> = Vec::new();
        let mut earliest_cross: Option<f64> = None;
        for cand in inflight {
            if cand.speculative
                || self.speculated.contains(&cand.unit)
                || self.attempts(cand.unit) > 1
            {
                continue;
            }
            if now - cand.started >= cutoff {
                launch.push(cand.gen);
            } else {
                let t = cand.started + cutoff;
                earliest_cross = Some(earliest_cross.map_or(t, |e: f64| e.min(t)));
            }
        }
        (launch, earliest_cross)
    }

    /// Schedule a re-check at `t` unless an earlier one is already
    /// pending (`mk` builds the engine's re-check event).
    pub(crate) fn schedule_recheck<E>(
        &mut self,
        t: Option<f64>,
        now: f64,
        q: &mut EventQueue<E>,
        mk: impl FnOnce() -> E,
    ) {
        let Some(t) = t else {
            return;
        };
        let t = t.max(now);
        let stale = match self.check_at {
            None => true,
            Some(at) => at <= now || t < at,
        };
        if stale {
            self.check_at = Some(t);
            q.push_at(t, mk());
        }
    }

    /// The pending re-check fired; allow the next one to schedule.
    pub(crate) fn recheck_fired(&mut self) {
        self.check_at = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_faults_consumes_expired_windows_and_clamps() {
        let faults = vec![
            FaultSpec::SlaveCrash {
                at_secs: 1.0,
                node: 0,
            },
            FaultSpec::LinkDegrade {
                at_secs: 0.0,
                duration_secs: 2.0,
                site: 0,
                factor: 0.5,
            },
            FaultSpec::LinkDegrade {
                at_secs: 4.0,
                duration_secs: 2.0,
                site: 0,
                factor: 0.5,
            },
            FaultSpec::Straggler {
                node: 1,
                factor: 0.5,
            },
        ];
        let mut state = FaultState::new(&faults, 2);
        let mut q: EventQueue<FaultEv> = EventQueue::new();
        // Epoch 3.0: the crash clamps forward, the first window is
        // already over (consumed silently), the second fires whole.
        schedule_faults(&mut state, &mut q, 3.0);
        assert!(state.consumed[1], "expired window consumed");
        let mut evs = Vec::new();
        while let Some((t, e)) = q.pop() {
            evs.push((t, e));
        }
        assert_eq!(
            evs,
            vec![
                (3.0, FaultEv::Crash { fault: 0 }),
                (4.0, FaultEv::DegradeStart { fault: 2 }),
                (6.0, FaultEv::DegradeEnd { fault: 2 }),
            ]
        );
    }

    #[test]
    fn churn_weather_and_master_faults_schedule_like_their_primitives() {
        let faults = vec![
            FaultSpec::NodeLeave {
                at_secs: 1.0,
                node: 0,
            },
            FaultSpec::NodeJoin {
                at_secs: 5.0,
                node: 0,
            },
            FaultSpec::WeatherSet {
                at_secs: 2.0,
                site: 0,
                factor: 0.5,
            },
            FaultSpec::MasterCrash {
                at_secs: 0.5,
                down_secs: 1.0,
            },
            FaultSpec::MasterCrash {
                at_secs: 4.0,
                down_secs: 2.0,
            },
        ];
        let mut state = FaultState::new(&faults, 2);
        let mut q: EventQueue<FaultEv> = EventQueue::new();
        // Epoch 3.0: the leave clamps forward like a crash, the already
        // passed weather point re-fires at the epoch (standing state),
        // the first master window is over (consumed silently), the
        // second fires whole.
        schedule_faults(&mut state, &mut q, 3.0);
        assert!(state.consumed[3], "expired master window consumed");
        let mut evs = Vec::new();
        while let Some((t, e)) = q.pop() {
            evs.push((t, e));
        }
        assert_eq!(
            evs,
            vec![
                (3.0, FaultEv::Leave { fault: 0 }),
                (3.0, FaultEv::Weather { fault: 2 }),
                (4.0, FaultEv::MasterDown { fault: 4 }),
                (5.0, FaultEv::Join { fault: 1 }),
                (6.0, FaultEv::MasterUp { fault: 4 }),
            ]
        );
        // Weather is never consumed: a later epoch re-schedules it so a
        // fresh NetSim re-learns the standing factor.
        let mut q2: EventQueue<FaultEv> = EventQueue::new();
        schedule_faults(&mut state, &mut q2, 10.0);
        let mut seen_weather = false;
        while let Some((t, e)) = q2.pop() {
            if e == (FaultEv::Weather { fault: 2 }) {
                assert_eq!(t, 10.0);
                seen_weather = true;
            }
        }
        assert!(seen_weather, "weather point re-fires at the new epoch");
    }

    #[test]
    fn weather_factor_defaults_and_latest_point_wins() {
        let mut state = FaultState::new(&[], 2);
        assert_eq!(state.weather_factor(3), 1.0, "unset sites read nominal");
        state.set_weather(1, 0.5);
        assert_eq!(state.weather_factor(1), 0.5);
        assert_eq!(state.weather_factor(0), 1.0);
        state.set_weather(1, 0.8);
        assert_eq!(state.weather_factor(1), 0.8, "latest point wins");
    }

    #[test]
    fn revive_restores_membership_and_ring() {
        let ids: Vec<Id> = (0..4).map(|i| hash_name(&format!("slave{i:04}"))).collect();
        let mut state = FaultState::new(&[], 4);
        state.ring = Some(ChordRing::build(&ids));
        state.ring_ids = ids.clone();
        state.crash(2);
        assert_eq!(state.alive(), &[0, 1, 3]);
        assert!(!state.ring.as_ref().unwrap().contains(ids[2]));
        assert_eq!(state.crashes, 1);
        state.revive(2);
        assert_eq!(state.alive(), &[0, 1, 2, 3]);
        assert!(state.ring.as_ref().unwrap().contains(ids[2]));
        assert_eq!(state.crashes, 1, "re-join never rolls back departures");
        state.revive(2);
        assert_eq!(state.alive(), &[0, 1, 2, 3], "double revive is a no-op");
    }

    #[test]
    fn speculation_one_backup_per_unit_and_recheck_dedup() {
        let mut spec = Speculation::new();
        spec.register(7, 1);
        // One young attempt: nothing launches, a crossing is reported.
        let (launch, cross) = spec.scan(
            1.0,
            10.0,
            std::iter::once(SpecCand {
                gen: 1,
                unit: 7,
                started: 0.0,
                speculative: false,
            }),
        );
        assert!(launch.is_empty());
        assert_eq!(cross, Some(10.0));
        // Past the cutoff it launches; once a sibling is live or the
        // unit is latched, it never launches again.
        let cand = |spec_flag| SpecCand {
            gen: 1,
            unit: 7,
            started: 0.0,
            speculative: spec_flag,
        };
        let (launch, _) = spec.scan(11.0, 10.0, std::iter::once(cand(false)));
        assert_eq!(launch, vec![1]);
        spec.mark_speculated(7);
        spec.register(7, 2);
        let (launch, _) = spec.scan(11.0, 10.0, std::iter::once(cand(false)));
        assert!(launch.is_empty(), "latched unit never re-speculates");
        // First-finisher-wins: the loser list is every sibling.
        assert_eq!(spec.take_losers(7, 2), vec![1]);
        // Re-check dedup: an earlier pending check swallows later ones.
        let mut q: EventQueue<u8> = EventQueue::new();
        spec.schedule_recheck(Some(5.0), 1.0, &mut q, || 0);
        spec.schedule_recheck(Some(6.0), 1.0, &mut q, || 1);
        assert_eq!(q.len(), 1, "later check deduplicated");
        spec.schedule_recheck(Some(4.0), 1.0, &mut q, || 2);
        assert_eq!(q.len(), 2, "earlier check replaces the pending one");
    }

    #[test]
    fn drop_attempt_reports_remaining_siblings() {
        let mut spec = Speculation::new();
        spec.register(3, 10);
        spec.register(3, 11);
        assert_eq!(spec.drop_attempt(3, 10), 1, "backup lives on");
        assert_eq!(spec.drop_attempt(3, 11), 0, "unit must re-queue");
        assert_eq!(spec.attempts(3), 0);
    }
}

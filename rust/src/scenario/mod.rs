//! Scenario engine — TOML-described, reproducible paper-scale runs
//! (DESIGN.md §4).
//!
//! The paper evaluates Sector/Sphere on two fixed physical testbeds; the
//! companion Open Cloud Testbed papers scale the same stack across a
//! growing multi-site deployment.  A `ScenarioSpec` composes the three
//! ingredients of such an experiment into one run description:
//!
//! * a topology — a `topology::TopologySpec` (paper presets or any
//!   racks × nodes-per-rack × sites layout with three link tiers);
//! * a workload — terasort, terasplit, filegen, angle or kmeans at a
//!   chosen bytes-per-node, on a named hardware profile;
//! * a fault plan — slave crashes, WAN link degradation windows and
//!   stragglers, each at a virtual time.
//!
//! `engine::run_scenario` executes the description deterministically
//! (same spec, same report — byte for byte) against the discrete-event
//! substrate in `sim`, driving the real `sphere::Scheduler` for segment
//! placement so locality and re-assignment behaviour come from the
//! production code path, not a copy of it.
//!
//! Specs parse from TOML (`config/scenarios/*.toml` in the repo root)
//! or come from the named presets used by `examples/scenario_suite.rs`
//! and `benches/bench_scale.rs`.

pub mod engine;

pub use engine::{run_scenario, ScenarioReport};

use crate::config::{SimConfig, Table};
use crate::service::{ArrivalProcess, TenantSpec, TrafficSpec};
use crate::topology::TopologySpec;
use crate::util::bytes::{parse_bytes, GB};

/// Which workload the scenario runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Two-stage distributed sort: partition + shuffle, then local sort.
    Terasort,
    /// Single client streams every node's data through the entropy scan.
    Terasplit,
    /// Every node writes synthetic records locally (§6.3).
    Filegen,
    /// Sphere feature extraction over packet traces + clustering tail (§7).
    Angle,
    /// Iterative distributed k-means: local scans + per-round synchronization.
    Kmeans,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "terasort" => Ok(WorkloadKind::Terasort),
            "terasplit" => Ok(WorkloadKind::Terasplit),
            "filegen" => Ok(WorkloadKind::Filegen),
            "angle" => Ok(WorkloadKind::Angle),
            "kmeans" => Ok(WorkloadKind::Kmeans),
            other => Err(format!(
                "unknown workload {other:?} (terasort|terasplit|filegen|angle|kmeans)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Terasort => "terasort",
            WorkloadKind::Terasplit => "terasplit",
            WorkloadKind::Filegen => "filegen",
            WorkloadKind::Angle => "angle",
            WorkloadKind::Kmeans => "kmeans",
        }
    }
}

/// Workload parameters.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub kind: WorkloadKind,
    pub bytes_per_node: f64,
    /// Rounds for iterative workloads (kmeans); ignored otherwise.
    pub iterations: usize,
}

/// One planned fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultSpec {
    /// Slave `node` dies at `at_secs`: its queued and running segments
    /// re-assign to survivors, transfers toward it re-route.
    SlaveCrash { at_secs: f64, node: usize },
    /// Site `site`'s WAN uplinks run at `factor` (< 1.0) capacity from
    /// `at_secs` for `duration_secs`.
    LinkDegrade {
        at_secs: f64,
        duration_secs: f64,
        site: usize,
        factor: f64,
    },
    /// `node` runs all local work at `factor` (< 1.0) speed throughout.
    Straggler { node: usize, factor: f64 },
}

/// A complete, reproducible run description.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    pub topology: TopologySpec,
    pub cfg: SimConfig,
    pub workload: WorkloadSpec,
    pub faults: Vec<FaultSpec>,
    /// When present, the service-layer traffic engine runs instead of
    /// the batch workload (the `[traffic]` TOML block; DESIGN.md §10).
    pub traffic: Option<TrafficSpec>,
}

impl ScenarioSpec {
    /// Parse a scenario TOML document (see config/scenarios/ for the
    /// format: `[topology]`, `[hardware] profile`, `[workload]`, and
    /// `[faults.<label>]` sections; any `SimConfig` override also
    /// applies).
    pub fn from_toml(text: &str) -> Result<ScenarioSpec, String> {
        let t = Table::parse(text).map_err(|e| e.to_string())?;
        Self::from_table(&t)
    }

    pub fn from_table(t: &Table) -> Result<ScenarioSpec, String> {
        let topology = TopologySpec::from_table(t)?;
        let cfg = SimConfig::profile(t.str_or("hardware.profile", "lan"))?.apply_table(t)?;
        let kind = WorkloadKind::parse(t.str_or("workload.kind", "terasort"))?;
        let bytes_per_node = parse_bytes(t.str_or("workload.bytes_per_node", "10GB"))? as f64;
        let iterations = t.int_or("workload.iterations", 10).max(1) as usize;
        let mut faults = Vec::new();
        for label in t.subsections("faults") {
            let k = |field: &str| format!("faults.{label}.{field}");
            let (fault, allowed): (FaultSpec, &[&str]) = match t.str_or(&k("kind"), "") {
                "crash" => (
                    FaultSpec::SlaveCrash {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        node: t.int_or(&k("node"), 0) as usize,
                    },
                    &["kind", "at_secs", "node"],
                ),
                "link_degrade" => (
                    FaultSpec::LinkDegrade {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        duration_secs: t.float_or(&k("duration_secs"), f64::INFINITY),
                        site: t.int_or(&k("site"), 0) as usize,
                        factor: t.float_or(&k("factor"), 0.5),
                    },
                    &["kind", "at_secs", "duration_secs", "site", "factor"],
                ),
                "straggler" => (
                    FaultSpec::Straggler {
                        node: t.int_or(&k("node"), 0) as usize,
                        factor: t.float_or(&k("factor"), 0.5),
                    },
                    &["kind", "node", "factor"],
                ),
                other => {
                    return Err(format!(
                        "fault {label:?}: unknown kind {other:?} \
                         (crash|link_degrade|straggler)"
                    ))
                }
            };
            // A typo'd field name must not silently become a default
            // value — reject anything this fault kind doesn't read.
            let section = format!("faults.{label}");
            for key in t.section_keys(&section) {
                let field = key.rsplit('.').next().unwrap_or(key);
                if !allowed.contains(&field) {
                    return Err(format!(
                        "fault {label:?} ({}): unknown field {field:?} \
                         (expected one of {allowed:?})",
                        t.str_or(&k("kind"), "?"),
                    ));
                }
            }
            faults.push(fault);
        }
        let traffic = TrafficSpec::from_table(t)?;
        if traffic.is_some() && t.section_keys("workload").next().is_some() {
            return Err(
                "[traffic] and [workload] are mutually exclusive: the traffic \
                 engine replaces the batch workload"
                    .into(),
            );
        }
        Ok(ScenarioSpec {
            name: t.str_or("name", &topology.name).to_string(),
            topology,
            cfg,
            workload: WorkloadSpec {
                kind,
                bytes_per_node,
                iterations,
            },
            faults,
            traffic,
        })
    }

    /// Check fault references against the topology before running.
    pub fn validate(&self) -> Result<(), String> {
        let nodes = self.topology.nodes();
        let sites = self.topology.sites.len();
        if let Some(traffic) = &self.traffic {
            traffic.validate()?;
        }
        let mut crash_nodes: Vec<usize> = Vec::new();
        for f in &self.faults {
            match f {
                FaultSpec::SlaveCrash { node, at_secs } => {
                    if *node >= nodes {
                        return Err(format!("crash fault: node {node} >= {nodes}"));
                    }
                    if !at_secs.is_finite() || *at_secs < 0.0 {
                        return Err("crash fault: at_secs must be >= 0".into());
                    }
                    crash_nodes.push(*node);
                }
                FaultSpec::LinkDegrade { site, factor, .. } => {
                    if sites < 2 {
                        return Err(
                            "link_degrade fault: single-site topology has no WAN uplink \
                             in any path, the fault would be silently inert"
                                .into(),
                        );
                    }
                    if self.workload.kind == WorkloadKind::Kmeans {
                        return Err(
                            "link_degrade fault: kmeans is compute/latency-bound (its \
                             center exchanges are tiny), a bandwidth fault would be \
                             silently inert"
                                .into(),
                        );
                    }
                    if *site >= sites {
                        return Err(format!("link_degrade fault: site {site} >= {sites}"));
                    }
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err("link_degrade fault: factor must be in (0, 1]".into());
                    }
                }
                FaultSpec::Straggler { node, factor } => {
                    if *node >= nodes {
                        return Err(format!("straggler fault: node {node} >= {nodes}"));
                    }
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err("straggler fault: factor must be in (0, 1]".into());
                    }
                }
            }
        }
        crash_nodes.sort_unstable();
        crash_nodes.dedup();
        if crash_nodes.len() >= nodes {
            return Err(format!("fault plan crashes all {nodes} nodes"));
        }
        Ok(())
    }

    // ---------------------------------------------------- presets

    /// The paper's Table 1 headline run: 6-node 3-site WAN Terasort at
    /// 10 GB/node, no faults.
    pub fn paper_wan6() -> ScenarioSpec {
        ScenarioSpec {
            name: "paper-wan6-terasort".into(),
            topology: TopologySpec::paper_wan(),
            cfg: SimConfig::wan_default(),
            workload: WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 10.0 * GB as f64,
                iterations: 10,
            },
            faults: Vec::new(),
            traffic: None,
        }
    }

    /// The paper's Table 2 headline run: 8-node rack Terasort at
    /// 10 GB/node, no faults.
    pub fn paper_lan8() -> ScenarioSpec {
        ScenarioSpec {
            name: "paper-lan8-terasort".into(),
            topology: TopologySpec::paper_lan(8),
            cfg: SimConfig::lan_default(),
            workload: WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 10.0 * GB as f64,
                iterations: 10,
            },
            faults: Vec::new(),
            traffic: None,
        }
    }

    /// Scale-out stress preset: 128 nodes (4 sites × 4 racks × 8 nodes)
    /// running Terasort at 1 GB/node through a crash, a WAN brown-out
    /// and a straggler — the scenario `examples/scenario_suite.rs` and
    /// `benches/bench_scale.rs` exercise.
    pub fn scale128() -> ScenarioSpec {
        ScenarioSpec {
            name: "scale128-terasort-faults".into(),
            topology: TopologySpec::scale_out(4, 4, 8),
            cfg: SimConfig::lan_default(),
            workload: WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 1.0 * GB as f64,
                iterations: 10,
            },
            faults: vec![
                FaultSpec::Straggler {
                    node: 17,
                    factor: 0.5,
                },
                FaultSpec::SlaveCrash {
                    at_secs: 3.0,
                    node: 40,
                },
                FaultSpec::LinkDegrade {
                    at_secs: 5.0,
                    duration_secs: 20.0,
                    site: 2,
                    factor: 0.25,
                },
            ],
            traffic: None,
        }
    }

    /// Service-layer stress preset: the scale128 cloud serving 150k
    /// requests from a 200k-client population across three tenants,
    /// through the same fault plan (the straggler, crash and WAN
    /// brown-out now show up as per-tenant p99 damage instead of
    /// makespan).  Mirrors config/scenarios/traffic_scale128.toml.
    pub fn traffic_scale128() -> ScenarioSpec {
        let mut spec = ScenarioSpec::scale128();
        spec.name = "traffic-scale128".into();
        spec.traffic = Some(TrafficSpec {
            clients: 200_000,
            requests: 150_000,
            files: 65_536,
            zipf_theta: 0.9,
            arrival: ArrivalProcess::Open { rps: 4_000.0 },
            tenants: vec![
                TenantSpec {
                    name: "interactive".into(),
                    weight: 0.70,
                    write_fraction: 0.05,
                    object_bytes: 1.0e6,
                },
                TenantSpec {
                    name: "analytics".into(),
                    weight: 0.25,
                    write_fraction: 0.10,
                    object_bytes: 8.0e6,
                },
                TenantSpec {
                    name: "ingest".into(),
                    weight: 0.05,
                    write_fraction: 0.90,
                    object_bytes: 16.0e6,
                },
            ],
        });
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_scenario_toml() {
        let spec = ScenarioSpec::from_toml(
            r#"
            name = "toml-run"
            [topology]
            sites = 2
            racks_per_site = 2
            nodes_per_rack = 4
            [hardware]
            profile = "wan"
            [workload]
            kind = "terasort"
            bytes_per_node = "2GB"
            [faults.crash1]
            kind = "crash"
            at_secs = 10.0
            node = 3
            [faults.slow]
            kind = "straggler"
            node = 7
            factor = 0.25
            [faults.wanout]
            kind = "link_degrade"
            at_secs = 4.0
            duration_secs = 8.0
            site = 1
            factor = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "toml-run");
        assert_eq!(spec.topology.nodes(), 16);
        assert_eq!(spec.cfg.hardware.cores, 4, "wan profile");
        assert_eq!(spec.workload.kind, WorkloadKind::Terasort);
        assert!((spec.workload.bytes_per_node - 2.0e9).abs() < 1.0);
        assert_eq!(spec.faults.len(), 3);
        assert!(spec.validate().is_ok());
        assert!(matches!(
            spec.faults[0],
            FaultSpec::SlaveCrash { node: 3, .. }
        ));
    }

    #[test]
    fn rejects_bad_faults_and_workloads() {
        assert!(WorkloadKind::parse("sort-of").is_err());
        let bad_kind =
            ScenarioSpec::from_toml("[faults.x]\nkind = \"meteor\"").unwrap_err();
        assert!(bad_kind.contains("meteor"), "{bad_kind}");
        // A typo'd field must error, not silently fall back to defaults.
        let typo = ScenarioSpec::from_toml(
            "[faults.c]\nkind = \"crash\"\nat_secs = 10.0\nnodes = 3",
        )
        .unwrap_err();
        assert!(typo.contains("nodes"), "{typo}");
        let mut spec = ScenarioSpec::paper_lan8();
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 1.0,
            node: 99,
        });
        assert!(spec.validate().is_err());
        let mut spec = ScenarioSpec::paper_lan8();
        spec.faults.push(FaultSpec::Straggler {
            node: 0,
            factor: 2.0,
        });
        assert!(spec.validate().is_err());
        // A WAN brown-out on a single-site rack can never bite: reject
        // it instead of reporting a fault that did nothing.
        let mut spec = ScenarioSpec::paper_lan8();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: 10.0,
            site: 0,
            factor: 0.5,
        });
        let err = spec.validate().unwrap_err();
        assert!(err.contains("single-site"), "{err}");
    }

    #[test]
    fn presets_validate() {
        for spec in [
            ScenarioSpec::paper_wan6(),
            ScenarioSpec::paper_lan8(),
            ScenarioSpec::scale128(),
        ] {
            spec.validate().unwrap();
            assert!(spec.topology.generate().is_ok());
        }
        assert_eq!(ScenarioSpec::scale128().topology.nodes(), 128);
    }

    #[test]
    fn crashing_every_node_is_rejected() {
        let mut spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 1\nracks_per_site = 1\nnodes_per_rack = 2",
        )
        .unwrap();
        spec.faults = vec![
            FaultSpec::SlaveCrash { at_secs: 1.0, node: 0 },
            FaultSpec::SlaveCrash { at_secs: 2.0, node: 1 },
        ];
        assert!(spec.validate().is_err());
        // ...but crashing the SAME node twice leaves a survivor: legal
        // (distinct nodes are what count, not fault entries).
        spec.faults = vec![
            FaultSpec::SlaveCrash { at_secs: 1.0, node: 0 },
            FaultSpec::SlaveCrash { at_secs: 2.0, node: 0 },
        ];
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn traffic_block_parses_into_scenario() {
        let spec = ScenarioSpec::from_toml(
            r#"
            [topology]
            sites = 2
            racks_per_site = 2
            nodes_per_rack = 4
            [traffic]
            clients = 5000
            requests = 2000
            rps = 400.0
            [traffic.tenants.web]
            weight = 1.0
            object_bytes = "2MB"
            [faults.crash1]
            kind = "crash"
            at_secs = 1.0
            node = 3
            "#,
        )
        .unwrap();
        let traffic = spec.traffic.as_ref().expect("traffic block parsed");
        assert_eq!(traffic.clients, 5000);
        assert_eq!(traffic.tenants[0].name, "web");
        assert_eq!(spec.faults.len(), 1, "faults compose with traffic");
        spec.validate().unwrap();
    }

    #[test]
    fn traffic_and_workload_are_mutually_exclusive() {
        let err = ScenarioSpec::from_toml(
            "[workload]\nkind = \"terasort\"\n[traffic]\nrequests = 10",
        )
        .unwrap_err();
        assert!(err.contains("mutually exclusive"), "{err}");
        // Any [workload] key conflicts, not just `kind` — sizing must
        // not be silently discarded by the traffic engine.
        let err = ScenarioSpec::from_toml(
            "[workload]\nbytes_per_node = \"50GB\"\n[traffic]\nrequests = 10",
        )
        .unwrap_err();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn traffic_preset_validates() {
        let spec = ScenarioSpec::traffic_scale128();
        spec.validate().unwrap();
        assert_eq!(spec.topology.nodes(), 128);
        let traffic = spec.traffic.unwrap();
        assert!(traffic.requests >= 100_000, "acceptance floor");
        assert_eq!(traffic.tenants.len(), 3);
    }

    #[test]
    fn invalid_traffic_fails_scenario_validation() {
        let mut spec = ScenarioSpec::traffic_scale128();
        spec.traffic.as_mut().unwrap().tenants.clear();
        assert!(spec.validate().is_err());
    }

    #[test]
    fn kmeans_rejects_inert_bandwidth_faults() {
        let mut spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [workload]\nkind = \"kmeans\"",
        )
        .unwrap();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: 5.0,
            site: 0,
            factor: 0.5,
        });
        let err = spec.validate().unwrap_err();
        assert!(err.contains("kmeans"), "{err}");
    }
}

//! Scenario engine — TOML-described, reproducible paper-scale runs
//! (DESIGN.md §4).
//!
//! The paper evaluates Sector/Sphere on two fixed physical testbeds; the
//! companion Open Cloud Testbed papers scale the same stack across a
//! growing multi-site deployment.  A `ScenarioSpec` composes the three
//! ingredients of such an experiment into one run description:
//!
//! * a topology — a `topology::TopologySpec` (paper presets or any
//!   racks × nodes-per-rack × sites layout with three link tiers);
//! * a workload — terasort, terasplit, filegen, angle or kmeans at a
//!   chosen bytes-per-node, on a named hardware profile;
//! * a fault plan — slave crashes, WAN link degradation windows and
//!   stragglers, each at a virtual time.
//!
//! `engine::run_scenario` executes the description deterministically
//! (same spec, same report — byte for byte) against the discrete-event
//! substrate in `sim`, driving the real `sphere::Scheduler` for segment
//! placement so locality and re-assignment behaviour come from the
//! production code path, not a copy of it.  A `[traffic]` block runs
//! the service engine instead (DESIGN.md §10); `[workload]` +
//! `[traffic]` together run colocated on one shared substrate with
//! speculative re-execution (`colocate`, DESIGN.md §11); a `[compare]`
//! block runs the workload through BOTH the Sphere engine and the
//! Hadoop baseline engine under the same fault plan and reports the
//! speedup ratio (`compare`, DESIGN.md §12); an angle workload runs
//! the full five-stage Angle pipeline — ingest, extract, aggregate,
//! cluster, score — event-driven on the substrate, parameterized by
//! the `[angle]` block (`angle`, DESIGN.md §13).
//!
//! Specs parse from TOML (`config/scenarios/*.toml` in the repo root)
//! or come from the named presets used by `examples/scenario_suite.rs`
//! and `benches/bench_scale.rs`.

pub mod angle;
pub mod colocate;
pub mod compare;
pub(crate) mod core;
pub mod engine;
pub mod sweep;
pub mod trace;

pub use angle::AngleReport;
pub use colocate::{ColocationReport, TenantSloDelta};
pub use compare::{ComparisonReport, SystemOutcome};
pub use engine::{run_scenario, ScenarioReport, TierBytes};
pub use sweep::{run_sweep, Axis, PointRecord, SweepPoint, SweepReport, SweepSpec};
pub use trace::{TraceRecorder, TraceSpec};

use crate::config::{SimConfig, Table, TransportKind};
use crate::mining::pcap::Regime;
use crate::service::{ArrivalProcess, ArrivalShape, ReplicationSpec, ScalerPolicy, TenantSpec, TrafficSpec};
use crate::topology::TopologySpec;
use crate::util::bytes::{parse_bytes, GB, MB};
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Which workload the scenario runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Two-stage distributed sort: partition + shuffle, then local sort.
    Terasort,
    /// Single client streams every node's data through the entropy scan.
    Terasplit,
    /// Every node writes synthetic records locally (§6.3).
    Filegen,
    /// Sphere feature extraction over packet traces + clustering tail (§7).
    Angle,
    /// Iterative distributed k-means: local scans + per-round synchronization.
    Kmeans,
}

impl WorkloadKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "terasort" => Ok(WorkloadKind::Terasort),
            "terasplit" => Ok(WorkloadKind::Terasplit),
            "filegen" => Ok(WorkloadKind::Filegen),
            "angle" => Ok(WorkloadKind::Angle),
            "kmeans" => Ok(WorkloadKind::Kmeans),
            other => Err(format!(
                "unknown workload {other:?} (terasort|terasplit|filegen|angle|kmeans)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Terasort => "terasort",
            WorkloadKind::Terasplit => "terasplit",
            WorkloadKind::Filegen => "filegen",
            WorkloadKind::Angle => "angle",
            WorkloadKind::Kmeans => "kmeans",
        }
    }
}

/// Workload parameters.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub kind: WorkloadKind,
    pub bytes_per_node: f64,
    /// Rounds for iterative workloads (kmeans); ignored otherwise.
    pub iterations: usize,
}

/// One planned fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultSpec {
    /// Slave `node` dies at `at_secs`: its queued and running segments
    /// re-assign to survivors, transfers toward it re-route.
    SlaveCrash { at_secs: f64, node: usize },
    /// Site `site`'s WAN uplinks run at `factor` (< 1.0) capacity from
    /// `at_secs` for `duration_secs`.
    LinkDegrade {
        at_secs: f64,
        duration_secs: f64,
        site: usize,
        factor: f64,
    },
    /// `node` runs all local work at `factor` (< 1.0) speed throughout.
    Straggler { node: usize, factor: f64 },
    /// Churn: `node` departs at `at_secs` — crash semantics plus Chord
    /// ring maintenance (DESIGN.md §18).  Usually expanded from a
    /// `[churn]` block rather than written by hand.
    NodeLeave { at_secs: f64, node: usize },
    /// Churn: a previously departed `node` re-joins at `at_secs`,
    /// re-enters the ring and becomes a placement target again.
    NodeJoin { at_secs: f64, node: usize },
    /// Network weather: site `site`'s WAN uplink capacity steps to
    /// `factor` of nominal at `at_secs` and stays there until the
    /// site's next point.  Usually expanded from a `[weather]` block.
    WeatherSet {
        at_secs: f64,
        site: usize,
        factor: f64,
    },
    /// The master/NameNode crashes at `at_secs` and recovers
    /// `down_secs` later: no NEW work is assigned while it is down;
    /// in-flight work keeps running (DESIGN.md §18).
    MasterCrash { at_secs: f64, down_secs: f64 },
}

/// The injection instant of a fault (stragglers are standing state and
/// sort first).
fn fault_at(f: &FaultSpec) -> f64 {
    match f {
        FaultSpec::SlaveCrash { at_secs, .. }
        | FaultSpec::LinkDegrade { at_secs, .. }
        | FaultSpec::NodeLeave { at_secs, .. }
        | FaultSpec::NodeJoin { at_secs, .. }
        | FaultSpec::WeatherSet { at_secs, .. }
        | FaultSpec::MasterCrash { at_secs, .. } => *at_secs,
        FaultSpec::Straggler { .. } => 0.0,
    }
}

/// Colocation knobs (the `[colocation]` TOML block; DESIGN.md §11).
/// Only read when a scenario carries BOTH a `[workload]` and a
/// `[traffic]` block — the colocated engine runs them on one shared
/// network/disk/event substrate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColocationSpec {
    /// Launch backup attempts for straggling segments (§3.2 fault
    /// handling generalized to slow nodes — speculative execution).
    pub speculative: bool,
    /// A segment speculates once its elapsed time exceeds this multiple
    /// of the running median segment duration.  Must be > 1.
    pub threshold: f64,
    /// Fraction of each node's disk bandwidth the batch job may use
    /// while tenants contend (1.0 = pure max-min fair sharing, no
    /// reservation for tenant I/O).  In (0, 1].
    pub job_share: f64,
}

impl Default for ColocationSpec {
    fn default() -> Self {
        ColocationSpec {
            speculative: true,
            threshold: 2.0,
            job_share: 1.0,
        }
    }
}

impl ColocationSpec {
    fn from_table(t: &Table) -> Result<ColocationSpec, String> {
        t.check_known_keys("colocation", &["speculative", "threshold", "job_share"], &[])?;
        Ok(ColocationSpec {
            speculative: t.bool_or("colocation.speculative", true),
            threshold: t.float_or("colocation.threshold", 2.0),
            job_share: t.float_or("colocation.job_share", 1.0),
        })
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.threshold > 1.0) {
            return Err(format!(
                "colocation: threshold must be > 1 (a backup at <= 1x the \
                 median would speculate on healthy segments), got {}",
                self.threshold
            ));
        }
        if !(self.job_share > 0.0 && self.job_share <= 1.0) {
            return Err(format!(
                "colocation: job_share must be in (0, 1], got {}",
                self.job_share
            ));
        }
        Ok(())
    }
}

/// One planted regime shift: every sensor site's source `source`
/// switches to `regime` inside window `window` — the ground truth the
/// emergent-cluster detector must find (paper §7.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AnomalySpec {
    pub window: usize,
    pub source: usize,
    pub regime: Regime,
}

/// The `[angle]` TOML block (DESIGN.md §13): parameters of the staged
/// Angle pipeline.  Only read when `[workload] kind = "angle"` — the
/// temporal-window structure, the model-scale detection stream fed to
/// the real mining machinery, and the Table 3 file-count accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct AngleSpec {
    /// Temporal windows w_1..w_j the feature stream aggregates into.
    pub windows: usize,
    /// Monitored sources per sensor site (model-scale stream).
    pub sources_per_sensor: usize,
    /// Packets per source per window in the model-scale stream; also
    /// sets the extraction compression ratio (one feature record per
    /// `packets_per_source` packets).
    pub packets_per_source: usize,
    /// k-means cluster count per window.
    pub k: usize,
    /// Sector file count for the cost accounting (Table 3's x-axis);
    /// 0 = one file per (sensor site, window).
    pub files: usize,
    /// Emergent-window z-score threshold (paper Figs 5–6).
    pub z_thresh: f64,
    /// Delta samples the detector's trailing baseline needs first.
    pub warmup: usize,
    /// Planted regime shifts; defaults plant a §7.1 port scan and an
    /// exfiltration so recall has ground truth to measure against.
    pub anomalies: Vec<AnomalySpec>,
}

impl Default for AngleSpec {
    fn default() -> Self {
        AngleSpec {
            windows: 8,
            sources_per_sensor: 25,
            packets_per_source: 40,
            k: 6,
            files: 0,
            z_thresh: 3.0,
            warmup: 2,
            anomalies: vec![
                AnomalySpec { window: 4, source: 3, regime: Regime::Scan },
                AnomalySpec { window: 4, source: 7, regime: Regime::Scan },
                AnomalySpec { window: 6, source: 11, regime: Regime::Exfil },
                AnomalySpec { window: 6, source: 19, regime: Regime::Exfil },
            ],
        }
    }
}

impl AngleSpec {
    fn from_table(t: &Table) -> Result<AngleSpec, String> {
        t.check_known_keys(
            "angle",
            &[
                "windows",
                "sources_per_sensor",
                "packets_per_source",
                "k",
                "files",
                "z_thresh",
                "warmup",
            ],
            &["anomalies"],
        )?;
        let mut anomalies = Vec::new();
        for label in t.subsections("angle.anomalies") {
            let key = |field: &str| format!("angle.anomalies.{label}.{field}");
            let section = format!("angle.anomalies.{label}");
            for k in t.section_keys(&section) {
                let field = k.rsplit('.').next().unwrap_or(k);
                if !["window", "source", "regime"].contains(&field) {
                    return Err(format!(
                        "anomaly {label:?}: unknown field {field:?} \
                         (expected window|source|regime)"
                    ));
                }
            }
            // Every anomaly field must be explicit AND well-typed: a
            // forgotten or mistyped window silently planting the shift
            // at window 0 (undetectable before warmup), or a regime
            // silently becoming a scan, would corrupt the ground truth
            // without a hint.
            for required in ["window", "source", "regime"] {
                if t.get(&key(required)).is_none() {
                    return Err(format!(
                        "anomaly {label:?}: missing required field {required:?}"
                    ));
                }
            }
            let int_field = |field: &str| -> Result<usize, String> {
                t.get(&key(field))
                    .and_then(crate::config::Value::as_int)
                    .filter(|&v| v >= 0)
                    .map(|v| v as usize)
                    .ok_or_else(|| {
                        format!(
                            "anomaly {label:?}: {field} must be a non-negative integer"
                        )
                    })
            };
            let regime = match t.get(&key("regime")).and_then(crate::config::Value::as_str)
            {
                Some("scan") => Regime::Scan,
                Some("exfil") => Regime::Exfil,
                other => {
                    return Err(format!(
                        "anomaly {label:?}: regime must be \"scan\" or \"exfil\", \
                         got {other:?}"
                    ))
                }
            };
            anomalies.push(AnomalySpec {
                window: int_field("window")?,
                source: int_field("source")?,
                regime,
            });
        }
        let d = AngleSpec::default();
        // No [angle.anomalies.*] sections: keep the default plants so a
        // minimal [angle] block still has recall ground truth.
        let anomalies = if anomalies.is_empty() { d.anomalies } else { anomalies };
        Ok(AngleSpec {
            windows: t.int_or("angle.windows", d.windows as i64).max(0) as usize,
            sources_per_sensor: t
                .int_or("angle.sources_per_sensor", d.sources_per_sensor as i64)
                .max(0) as usize,
            packets_per_source: t
                .int_or("angle.packets_per_source", d.packets_per_source as i64)
                .max(0) as usize,
            k: t.int_or("angle.k", d.k as i64).max(0) as usize,
            files: t.int_or("angle.files", 0).max(0) as usize,
            z_thresh: t.float_or("angle.z_thresh", d.z_thresh),
            warmup: t.int_or("angle.warmup", d.warmup as i64).max(0) as usize,
            anomalies,
        })
    }

    /// Check internal consistency; `sensors` is the sensor-site count
    /// (one sensor per topology site).
    pub fn validate(&self, sensors: usize) -> Result<(), String> {
        if self.windows < self.warmup + 2 {
            return Err(format!(
                "angle: windows ({}) must exceed warmup + 1 ({}) — the detector \
                 needs a trailing baseline before any window can flag",
                self.windows,
                self.warmup + 1
            ));
        }
        if self.k < 2 {
            return Err("angle: k must be >= 2 (one cluster has no emergent structure)".into());
        }
        if self.sources_per_sensor * sensors.max(1) < self.k {
            return Err(format!(
                "angle: {} sources across {} sensor sites cannot fill k = {} clusters",
                self.sources_per_sensor, sensors, self.k
            ));
        }
        if self.packets_per_source == 0 {
            return Err("angle: packets_per_source must be >= 1".into());
        }
        if !self.z_thresh.is_finite() || self.z_thresh <= 0.0 {
            return Err("angle: z_thresh must be > 0".into());
        }
        for an in &self.anomalies {
            if an.window >= self.windows {
                return Err(format!(
                    "angle: anomaly window {} >= windows {}",
                    an.window, self.windows
                ));
            }
            // The detector needs `warmup` baseline deltas before any
            // window can flag, so a shift planted at or before window
            // `warmup` is mathematically undetectable — the run would
            // silently report recall < 1.0.
            if an.window <= self.warmup {
                return Err(format!(
                    "angle: anomaly window {} is undetectable — the first \
                     flaggable window is warmup + 1 = {}",
                    an.window,
                    self.warmup + 1
                ));
            }
            if an.source >= self.sources_per_sensor {
                return Err(format!(
                    "angle: anomaly source {} >= sources_per_sensor {}",
                    an.source, self.sources_per_sensor
                ));
            }
        }
        Ok(())
    }
}

/// Head-to-head knobs (the `[compare]` TOML block; DESIGN.md §12).
/// When present, the scenario's `[workload]` runs through BOTH the
/// Sphere engine and the Hadoop baseline engine on substrates built
/// from the same topology under the same fault plan, and the report
/// carries a [`ComparisonReport`].  Note: the TOML parser only sees
/// sections that carry at least one key, so write `enabled = true`
/// rather than a bare `[compare]` header.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompareSpec {
    /// Hadoop's speculative execution (mapred.speculative.execution;
    /// on by default in 0.16 — parity with Sphere's PR-3 speculation).
    pub hadoop_speculative: bool,
}

impl Default for CompareSpec {
    fn default() -> Self {
        CompareSpec {
            hadoop_speculative: true,
        }
    }
}

/// The `[churn]` TOML block (DESIGN.md §18): a seeded Poisson episode
/// of node departures and re-joins, expanded deterministically into
/// `NodeLeave`/`NodeJoin` faults by [`ChurnSpec::expand`].  Rate 0 (or
/// duration 0) expands to NO faults, so the run is byte-identical to
/// the same scenario without the block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnSpec {
    /// Mean departures per 100 s of episode (Poisson arrivals).
    pub rate_per_100s: f64,
    /// Episode start (virtual seconds).
    pub start_secs: f64,
    /// Episode length; departures are only generated inside
    /// `[start_secs, start_secs + duration_secs)`.
    pub duration_secs: f64,
    /// Each departed node re-joins this long after it left; 0 = never.
    pub rejoin_secs: f64,
    /// Seed for the churn stream, independent of the scenario seed.
    pub seed: u64,
    /// At most this fraction of the cluster may be absent at once —
    /// further departures are suppressed until someone re-joins.
    pub max_fraction: f64,
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec {
            rate_per_100s: 4.0,
            start_secs: 0.0,
            duration_secs: 60.0,
            rejoin_secs: 30.0,
            seed: 11,
            max_fraction: 0.25,
        }
    }
}

impl ChurnSpec {
    fn from_table(t: &Table) -> Result<Option<ChurnSpec>, String> {
        if t.section_keys("churn").next().is_none() {
            return Ok(None);
        }
        t.check_known_keys(
            "churn",
            &[
                "rate_per_100s",
                "start_secs",
                "duration_secs",
                "rejoin_secs",
                "seed",
                "max_fraction",
            ],
            &[],
        )?;
        let d = ChurnSpec::default();
        Ok(Some(ChurnSpec {
            rate_per_100s: t.float_or("churn.rate_per_100s", d.rate_per_100s),
            start_secs: t.float_or("churn.start_secs", d.start_secs),
            duration_secs: t.float_or("churn.duration_secs", d.duration_secs),
            rejoin_secs: t.float_or("churn.rejoin_secs", d.rejoin_secs),
            seed: t.int_or("churn.seed", d.seed as i64).max(0) as u64,
            max_fraction: t.float_or("churn.max_fraction", d.max_fraction),
        }))
    }

    pub fn validate(&self) -> Result<(), String> {
        for (label, v) in [
            ("rate_per_100s", self.rate_per_100s),
            ("start_secs", self.start_secs),
            ("duration_secs", self.duration_secs),
            ("rejoin_secs", self.rejoin_secs),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!(
                    "churn: {label} must be finite and >= 0, got {v}"
                ));
            }
        }
        if !(self.max_fraction > 0.0 && self.max_fraction < 1.0) {
            return Err(format!(
                "churn: max_fraction must be in (0, 1) — 1.0 could empty \
                 the cluster mid-run — got {}",
                self.max_fraction
            ));
        }
        Ok(())
    }

    /// Deterministically expand the episode into explicit
    /// `NodeLeave`/`NodeJoin` faults for an `nodes`-slave cluster.
    pub fn expand(&self, nodes: usize) -> Vec<FaultSpec> {
        if self.rate_per_100s <= 0.0 || self.duration_secs <= 0.0 || nodes == 0 {
            return Vec::new();
        }
        let mut rng = Pcg64::new(self.seed);
        let lambda = self.rate_per_100s / 100.0;
        let max_out = ((nodes as f64 * self.max_fraction) as usize).max(1);
        let end = self.start_secs + self.duration_secs;
        // node -> when it comes back (INFINITY = never).
        let mut away: BTreeMap<usize, f64> = BTreeMap::new();
        let mut out = Vec::new();
        let mut t = self.start_secs + rng.next_exp(lambda);
        while t < end {
            away.retain(|_, back| *back > t);
            if away.len() < max_out {
                let present: Vec<usize> =
                    (0..nodes).filter(|n| !away.contains_key(n)).collect();
                let victim = present[rng.gen_range(present.len() as u64) as usize];
                out.push(FaultSpec::NodeLeave { at_secs: t, node: victim });
                let back = if self.rejoin_secs > 0.0 {
                    t + self.rejoin_secs
                } else {
                    f64::INFINITY
                };
                if back.is_finite() {
                    out.push(FaultSpec::NodeJoin { at_secs: back, node: victim });
                }
                away.insert(victim, back);
            }
            t += rng.next_exp(lambda);
        }
        out.sort_by(|a, b| {
            fault_at(a)
                .partial_cmp(&fault_at(b))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        out
    }
}

/// One explicit weather point: site `site`'s WAN capacity steps to
/// `factor` of nominal at `at_secs`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeatherPoint {
    pub at_secs: f64,
    pub site: usize,
    pub factor: f64,
}

/// The `[weather]` TOML block (DESIGN.md §18): a deterministic
/// time-varying WAN capacity trace — explicit `[weather.points.*]`
/// replayed as given, plus an optional seeded piecewise generator
/// (`amplitude` > 0, `steps` > 0) that redraws every site's capacity
/// each `period_secs`.  Amplitude 0 with no points expands to NO
/// faults, so the run is byte-identical to the same scenario without
/// the block.
#[derive(Clone, Debug, PartialEq)]
pub struct WeatherSpec {
    /// Explicit trace points, replayed verbatim.
    pub points: Vec<WeatherPoint>,
    /// Seed for the generated part of the trace.
    pub seed: u64,
    /// Generated trace epoch length (virtual seconds).
    pub period_secs: f64,
    /// Generated capacity factors are drawn uniformly from
    /// `[1 - amplitude, 1)`; 0 disables generation.
    pub amplitude: f64,
    /// Number of generated epochs (at `period_secs`, `2*period_secs`, …).
    pub steps: usize,
}

impl Default for WeatherSpec {
    fn default() -> Self {
        WeatherSpec {
            points: Vec::new(),
            seed: 7,
            period_secs: 10.0,
            amplitude: 0.0,
            steps: 0,
        }
    }
}

impl WeatherSpec {
    fn from_table(t: &Table) -> Result<Option<WeatherSpec>, String> {
        if t.section_keys("weather").next().is_none() {
            return Ok(None);
        }
        t.check_known_keys(
            "weather",
            &["seed", "period_secs", "amplitude", "steps"],
            &["points"],
        )?;
        let mut points = Vec::new();
        for label in t.subsections("weather.points") {
            let k = |field: &str| format!("weather.points.{label}.{field}");
            let section = format!("weather.points.{label}");
            for key in t.section_keys(&section) {
                let field = key.rsplit('.').next().unwrap_or(key);
                if !["at_secs", "site", "factor"].contains(&field) {
                    return Err(format!(
                        "weather point {label:?}: unknown field {field:?} \
                         (expected at_secs|site|factor)"
                    ));
                }
            }
            points.push(WeatherPoint {
                at_secs: t.float_or(&k("at_secs"), 0.0),
                site: t.int_or(&k("site"), 0) as usize,
                factor: t.float_or(&k("factor"), 1.0),
            });
        }
        let d = WeatherSpec::default();
        Ok(Some(WeatherSpec {
            points,
            seed: t.int_or("weather.seed", d.seed as i64).max(0) as u64,
            period_secs: t.float_or("weather.period_secs", d.period_secs),
            amplitude: t.float_or("weather.amplitude", d.amplitude),
            steps: t.int_or("weather.steps", 0).max(0) as usize,
        }))
    }

    pub fn validate(&self, sites: usize) -> Result<(), String> {
        if sites < 2 {
            return Err(
                "weather: single-site topology has no WAN uplinks — the \
                 trace would be silently inert"
                    .into(),
            );
        }
        if !(self.amplitude >= 0.0 && self.amplitude < 1.0) {
            return Err(format!(
                "weather: amplitude must be in [0, 1) so generated factors \
                 stay positive, got {}",
                self.amplitude
            ));
        }
        if !self.period_secs.is_finite() || self.period_secs <= 0.0 {
            return Err(format!(
                "weather: period_secs must be finite and > 0, got {}",
                self.period_secs
            ));
        }
        for p in &self.points {
            if p.site >= sites {
                return Err(format!(
                    "weather: point site {} out of range (sites: {sites})",
                    p.site
                ));
            }
            if !(p.factor > 0.0 && p.factor <= 1.0) {
                return Err(format!(
                    "weather: point factor must be in (0, 1], got {}",
                    p.factor
                ));
            }
            if !p.at_secs.is_finite() || p.at_secs < 0.0 {
                return Err(format!(
                    "weather: point at_secs must be finite and >= 0, got {}",
                    p.at_secs
                ));
            }
        }
        Ok(())
    }

    /// Deterministically expand the trace into explicit `WeatherSet`
    /// faults for a `sites`-site topology.  Generated factors within
    /// 1e-9 of 1.0 are elided, so amplitude 0 yields an empty plan.
    pub fn expand(&self, sites: usize) -> Vec<FaultSpec> {
        let mut raw: Vec<(f64, usize, f64)> = self
            .points
            .iter()
            .map(|p| (p.at_secs, p.site, p.factor))
            .collect();
        if self.amplitude > 0.0 && self.steps > 0 {
            let mut rng = Pcg64::new(self.seed);
            for k in 1..=self.steps {
                let t = k as f64 * self.period_secs;
                for site in 0..sites {
                    let factor = 1.0 - self.amplitude * rng.next_f64();
                    raw.push((t, site, factor));
                }
            }
        }
        raw.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        raw.into_iter()
            .filter(|(_, _, f)| (f - 1.0).abs() > 1e-9)
            .map(|(at_secs, site, factor)| FaultSpec::WeatherSet {
                at_secs,
                site,
                factor,
            })
            .collect()
    }
}

/// A complete, reproducible run description.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    pub topology: TopologySpec,
    pub cfg: SimConfig,
    /// The batch workload (the `[workload]` TOML block).  `None` for
    /// service-only scenarios.
    pub workload: Option<WorkloadSpec>,
    pub faults: Vec<FaultSpec>,
    /// Seeded churn episode (the `[churn]` TOML block; DESIGN.md §18).
    /// Expanded into explicit leave/join faults by
    /// [`ScenarioSpec::effective_faults`].
    pub churn: Option<ChurnSpec>,
    /// Network-weather trace (the `[weather]` TOML block; DESIGN.md
    /// §18).  Expanded into explicit `WeatherSet` faults by
    /// [`ScenarioSpec::effective_faults`].
    pub weather: Option<WeatherSpec>,
    /// The service-layer traffic stream (the `[traffic]` TOML block;
    /// DESIGN.md §10).  Alone it replaces the batch workload; together
    /// with `[workload]` the two colocate on one shared substrate
    /// (DESIGN.md §11).
    pub traffic: Option<TrafficSpec>,
    /// Elastic replica management for the serving tier (the
    /// `[replication]` TOML block; DESIGN.md §16).  Only legal on a
    /// service-only scenario (`[traffic]` without `[workload]`).
    pub replication: Option<ReplicationSpec>,
    /// Colocation knobs; only read when both blocks are present.
    pub colocation: ColocationSpec,
    /// The Sphere-vs-Hadoop head-to-head (the `[compare]` TOML block;
    /// DESIGN.md §12).  Mutually exclusive with `[traffic]`.
    pub compare: Option<CompareSpec>,
    /// Staged Angle pipeline parameters (the `[angle]` TOML block;
    /// DESIGN.md §13).  Only legal with `[workload] kind = "angle"`;
    /// an angle workload without the block runs with
    /// `AngleSpec::default()`.
    pub angle: Option<AngleSpec>,
    /// Sim-time trace capture (the `[trace]` TOML block / `--trace`
    /// CLI flag; DESIGN.md §15).  `None` still computes the timeline
    /// digest, but retains and writes nothing.
    pub trace: Option<TraceSpec>,
}

impl ScenarioSpec {
    /// Parse a scenario TOML document (see config/scenarios/ for the
    /// format: `[topology]`, `[hardware] profile`, `[workload]`, and
    /// `[faults.<label>]` sections; any `SimConfig` override also
    /// applies).
    pub fn from_toml(text: &str) -> Result<ScenarioSpec, String> {
        let t = Table::parse(text).map_err(|e| e.to_string())?;
        Self::from_table(&t)
    }

    pub fn from_table(t: &Table) -> Result<ScenarioSpec, String> {
        if t.section_keys("sweep").next().is_some() {
            return Err(
                "[sweep]: this document describes a parameter sweep — run it \
                 through the `sweep` subcommand (or scenario::SweepSpec)"
                    .into(),
            );
        }
        Self::from_table_base(t)
    }

    /// The body of [`ScenarioSpec::from_table`] without the `[sweep]`
    /// rejection — how [`sweep::SweepSpec`] parses the base scenario
    /// out of a sweep document.
    pub(crate) fn from_table_base(t: &Table) -> Result<ScenarioSpec, String> {
        let topology = TopologySpec::from_table(t)?;
        let mut cfg = SimConfig::profile(t.str_or("hardware.profile", "lan"))?.apply_table(t)?;
        // Top-level `transport = "udt" | "tcp"` is scenario-facing sugar
        // over `[sphere] transport` — it picks the WAN flow-throughput
        // model for the run (DESIGN.md §18).
        if let Some(v) = t.get("transport") {
            let s = v
                .as_str()
                .ok_or("transport must be a string (udt|tcp)")?;
            cfg.sphere_transport = TransportKind::parse(s)?;
        }
        let kind = WorkloadKind::parse(t.str_or("workload.kind", "terasort"))?;
        let bytes_per_node = parse_bytes(t.str_or("workload.bytes_per_node", "10GB"))? as f64;
        let iterations = t.int_or("workload.iterations", 10).max(1) as usize;
        let has_workload_block = t.section_keys("workload").next().is_some();
        let has_colocation_block = t.section_keys("colocation").next().is_some();
        let mut faults = Vec::new();
        for label in t.subsections("faults") {
            let k = |field: &str| format!("faults.{label}.{field}");
            let (fault, allowed): (FaultSpec, &[&str]) = match t.str_or(&k("kind"), "") {
                "crash" => (
                    FaultSpec::SlaveCrash {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        node: t.int_or(&k("node"), 0) as usize,
                    },
                    &["kind", "at_secs", "node"],
                ),
                "link_degrade" => (
                    FaultSpec::LinkDegrade {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        duration_secs: t.float_or(&k("duration_secs"), f64::INFINITY),
                        site: t.int_or(&k("site"), 0) as usize,
                        factor: t.float_or(&k("factor"), 0.5),
                    },
                    &["kind", "at_secs", "duration_secs", "site", "factor"],
                ),
                "straggler" => (
                    FaultSpec::Straggler {
                        node: t.int_or(&k("node"), 0) as usize,
                        factor: t.float_or(&k("factor"), 0.5),
                    },
                    &["kind", "node", "factor"],
                ),
                "leave" => (
                    FaultSpec::NodeLeave {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        node: t.int_or(&k("node"), 0) as usize,
                    },
                    &["kind", "at_secs", "node"],
                ),
                "join" => (
                    FaultSpec::NodeJoin {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        node: t.int_or(&k("node"), 0) as usize,
                    },
                    &["kind", "at_secs", "node"],
                ),
                "weather_set" => (
                    FaultSpec::WeatherSet {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        site: t.int_or(&k("site"), 0) as usize,
                        factor: t.float_or(&k("factor"), 1.0),
                    },
                    &["kind", "at_secs", "site", "factor"],
                ),
                "master_crash" => (
                    FaultSpec::MasterCrash {
                        at_secs: t.float_or(&k("at_secs"), 0.0),
                        down_secs: t.float_or(&k("down_secs"), 10.0),
                    },
                    &["kind", "at_secs", "down_secs"],
                ),
                other => {
                    return Err(format!(
                        "fault {label:?}: unknown kind {other:?} \
                         (crash|link_degrade|straggler|leave|join|\
                         weather_set|master_crash)"
                    ))
                }
            };
            // A typo'd field name must not silently become a default
            // value — reject anything this fault kind doesn't read.
            let section = format!("faults.{label}");
            for key in t.section_keys(&section) {
                let field = key.rsplit('.').next().unwrap_or(key);
                if !allowed.contains(&field) {
                    return Err(format!(
                        "fault {label:?} ({}): unknown field {field:?} \
                         (expected one of {allowed:?})",
                        t.str_or(&k("kind"), "?"),
                    ));
                }
            }
            faults.push(fault);
        }
        let churn = ChurnSpec::from_table(t)?;
        let weather = WeatherSpec::from_table(t)?;
        let traffic = TrafficSpec::from_table(t)?;
        let replication = ReplicationSpec::from_table(t)?;
        // [traffic] + [workload] used to be mutually exclusive; since
        // the colocation engine (DESIGN.md §11) the combination runs
        // both on one shared substrate.  A [traffic]-only document
        // still means "service scenario, no batch job".
        let workload = if has_workload_block || traffic.is_none() {
            Some(WorkloadSpec {
                kind,
                bytes_per_node,
                iterations,
            })
        } else {
            None
        };
        let colocation = ColocationSpec::from_table(t)?;
        if has_colocation_block && (workload.is_none() || traffic.is_none()) {
            return Err(
                "[colocation] only applies when both [workload] and [traffic] \
                 are present — it tunes how the two share the cloud"
                    .into(),
            );
        }
        let compare = if t.section_keys("compare").next().is_some() {
            t.check_known_keys("compare", &["enabled", "hadoop_speculative"], &[])?;
            if t.bool_or("compare.enabled", true) {
                Some(CompareSpec {
                    hadoop_speculative: t.bool_or("compare.hadoop_speculative", true),
                })
            } else {
                None
            }
        } else {
            None
        };
        let angle = if t.section_keys("angle").next().is_some() {
            Some(AngleSpec::from_table(t)?)
        } else {
            None
        };
        let trace = if t.section_keys("trace").next().is_some() {
            Some(TraceSpec::from_table(t)?)
        } else {
            None
        };
        Ok(ScenarioSpec {
            name: t.str_or("name", &topology.name).to_string(),
            topology,
            cfg,
            workload,
            faults,
            churn,
            weather,
            traffic,
            replication,
            colocation,
            compare,
            angle,
            trace,
        })
    }

    /// The full fault plan the engines execute: the explicit
    /// `[faults.*]` list plus the deterministic expansions of the
    /// `[churn]` and `[weather]` blocks (DESIGN.md §18).  Same spec,
    /// same plan — byte for byte.
    pub fn effective_faults(&self) -> Vec<FaultSpec> {
        let mut out = self.faults.clone();
        if let Some(churn) = &self.churn {
            out.extend(churn.expand(self.topology.nodes()));
        }
        if let Some(weather) = &self.weather {
            out.extend(weather.expand(self.topology.sites.len()));
        }
        out
    }

    /// Check fault references against the topology before running.
    pub fn validate(&self) -> Result<(), String> {
        let nodes = self.topology.nodes();
        let sites = self.topology.sites.len();
        if self.workload.is_none() && self.traffic.is_none() {
            return Err("scenario has neither a workload nor a traffic stream".into());
        }
        if let Some(traffic) = &self.traffic {
            traffic.validate()?;
        }
        if let Some(r) = &self.replication {
            r.validate()?;
            if self.traffic.is_none() {
                return Err(
                    "[replication] only applies to a [traffic] scenario — it \
                     manages the serving tier's replica sets"
                        .into(),
                );
            }
            if self.workload.is_some() {
                return Err(
                    "[replication] does not colocate with [workload] yet: \
                     elastic replica management runs in the service-only engine"
                        .into(),
                );
            }
        }
        if let Some(trace) = &self.trace {
            trace.validate()?;
        }
        self.colocation.validate()?;
        if let Some(angle) = &self.angle {
            if self.workload.as_ref().map(|w| w.kind) != Some(WorkloadKind::Angle) {
                return Err(
                    "[angle] only applies to [workload] kind = \"angle\" — it \
                     parameterizes the staged Angle pipeline"
                        .into(),
                );
            }
            if self.traffic.is_some() {
                return Err(
                    "[angle] does not colocate with [traffic] yet: the staged \
                     pipeline owns its substrate end to end (a bare angle \
                     [workload] still colocates via the legacy extract + \
                     clustering-tail model)"
                        .into(),
                );
            }
            angle.validate(sites)?;
        }
        if self.compare.is_some() {
            if self.traffic.is_some() {
                return Err(
                    "[compare] runs the batch workload through both engines; it \
                     cannot combine with [traffic] (drop one of the blocks)"
                        .into(),
                );
            }
            let w = self
                .workload
                .as_ref()
                .ok_or("[compare] requires a [workload] block")?;
            if !matches!(
                w.kind,
                WorkloadKind::Terasort | WorkloadKind::Terasplit | WorkloadKind::Filegen
            ) {
                return Err(format!(
                    "compare: {} is not part of the paper's Sphere-vs-Hadoop \
                     head-to-head (terasort|terasplit|filegen)",
                    w.kind.name()
                ));
            }
        }
        if self.traffic.is_some() {
            if let Some(w) = &self.workload {
                // The colocated engine is event-driven end to end; the
                // analytic workloads (closed-form round models) have no
                // event stream to interleave with client traffic.
                if matches!(w.kind, WorkloadKind::Terasplit | WorkloadKind::Kmeans) {
                    return Err(format!(
                        "colocation: {} is an analytic workload and cannot share \
                         the event substrate with [traffic] \
                         (terasort|filegen|angle colocate)",
                        w.kind.name()
                    ));
                }
            }
        }
        let analytic = matches!(
            self.workload.as_ref().map(|w| w.kind),
            Some(WorkloadKind::Terasplit) | Some(WorkloadKind::Kmeans)
        );
        if let Some(churn) = &self.churn {
            churn.validate()?;
            if analytic {
                return Err(
                    "churn: terasplit/kmeans are analytic workloads — ring \
                     maintenance and re-joins have no event path there and \
                     the episode would be silently distorted"
                        .into(),
                );
            }
        }
        if let Some(weather) = &self.weather {
            weather.validate(sites)?;
            if analytic {
                return Err(
                    "weather: terasplit/kmeans are analytic workloads — the \
                     trace acts on NetSim link capacities, which the \
                     closed-form models never touch, so it would be \
                     silently inert"
                        .into(),
                );
            }
        }
        let effective = self.effective_faults();
        let mut crash_nodes: Vec<usize> = Vec::new();
        for f in &effective {
            match f {
                FaultSpec::SlaveCrash { node, at_secs } => {
                    if *node >= nodes {
                        return Err(format!("crash fault: node {node} >= {nodes}"));
                    }
                    if !at_secs.is_finite() || *at_secs < 0.0 {
                        return Err("crash fault: at_secs must be >= 0".into());
                    }
                    crash_nodes.push(*node);
                }
                FaultSpec::LinkDegrade { site, factor, .. } => {
                    if sites < 2 {
                        return Err(
                            "link_degrade fault: single-site topology has no WAN uplink \
                             in any path, the fault would be silently inert"
                                .into(),
                        );
                    }
                    if self.workload.as_ref().map(|w| w.kind) == Some(WorkloadKind::Kmeans) {
                        return Err(
                            "link_degrade fault: kmeans is compute/latency-bound (its \
                             center exchanges are tiny), a bandwidth fault would be \
                             silently inert"
                                .into(),
                        );
                    }
                    if *site >= sites {
                        return Err(format!("link_degrade fault: site {site} >= {sites}"));
                    }
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err("link_degrade fault: factor must be in (0, 1]".into());
                    }
                }
                FaultSpec::Straggler { node, factor } => {
                    if *node >= nodes {
                        return Err(format!("straggler fault: node {node} >= {nodes}"));
                    }
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err("straggler fault: factor must be in (0, 1]".into());
                    }
                }
                FaultSpec::NodeLeave { node, at_secs } => {
                    if *node >= nodes {
                        return Err(format!("leave fault: node {node} >= {nodes}"));
                    }
                    if !at_secs.is_finite() || *at_secs < 0.0 {
                        return Err("leave fault: at_secs must be >= 0".into());
                    }
                    // A departure with a LATER matching join is transient
                    // and cannot contribute to emptying the cluster.
                    let returns = effective.iter().any(|g| {
                        matches!(g, FaultSpec::NodeJoin { node: n2, at_secs: a2 }
                                 if n2 == node && *a2 > *at_secs)
                    });
                    if !returns {
                        crash_nodes.push(*node);
                    }
                }
                FaultSpec::NodeJoin { node, at_secs } => {
                    if *node >= nodes {
                        return Err(format!("join fault: node {node} >= {nodes}"));
                    }
                    if !at_secs.is_finite() || *at_secs < 0.0 {
                        return Err("join fault: at_secs must be >= 0".into());
                    }
                }
                FaultSpec::WeatherSet { site, factor, at_secs } => {
                    if sites < 2 {
                        return Err(
                            "weather_set fault: single-site topology has no WAN \
                             uplink in any path, the fault would be silently inert"
                                .into(),
                        );
                    }
                    if analytic {
                        return Err(
                            "weather_set fault: terasplit/kmeans never touch the \
                             NetSim links the fault acts on — it would be \
                             silently inert"
                                .into(),
                        );
                    }
                    if *site >= sites {
                        return Err(format!("weather_set fault: site {site} >= {sites}"));
                    }
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err("weather_set fault: factor must be in (0, 1]".into());
                    }
                    if !at_secs.is_finite() || *at_secs < 0.0 {
                        return Err("weather_set fault: at_secs must be >= 0".into());
                    }
                }
                FaultSpec::MasterCrash { at_secs, down_secs } => {
                    if !at_secs.is_finite() || *at_secs < 0.0 {
                        return Err("master_crash fault: at_secs must be >= 0".into());
                    }
                    // An infinite outage would let the event queue drain
                    // with work still pending and end the run silently.
                    if !down_secs.is_finite() || !(*down_secs > 0.0) {
                        return Err(
                            "master_crash fault: down_secs must be finite and > 0"
                                .into(),
                        );
                    }
                    match self.workload.as_ref().map(|w| w.kind) {
                        Some(WorkloadKind::Terasort) | Some(WorkloadKind::Filegen) => {}
                        Some(other) => {
                            return Err(format!(
                                "master_crash fault: {} does not dispatch through \
                                 the master's assignment loop (terasort|filegen)",
                                other.name()
                            ))
                        }
                        None => {
                            return Err(
                                "master_crash fault: a traffic-only scenario is \
                                 unaffected — clients cache file metadata and \
                                 read from slaves directly (paper §4)"
                                    .into(),
                            )
                        }
                    }
                }
            }
        }
        crash_nodes.sort_unstable();
        crash_nodes.dedup();
        if crash_nodes.len() >= nodes {
            return Err(format!("fault plan crashes all {nodes} nodes"));
        }
        Ok(())
    }

    // ---------------------------------------------------- presets

    /// The paper's Table 1 headline run: 6-node 3-site WAN Terasort at
    /// 10 GB/node, no faults.
    pub fn paper_wan6() -> ScenarioSpec {
        ScenarioSpec {
            name: "paper-wan6-terasort".into(),
            topology: TopologySpec::paper_wan(),
            cfg: SimConfig::wan_default(),
            workload: Some(WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 10.0 * GB as f64,
                iterations: 10,
            }),
            faults: Vec::new(),
            churn: None,
            weather: None,
            traffic: None,
            replication: None,
            colocation: ColocationSpec::default(),
            compare: None,
            angle: None,
            trace: None,
        }
    }

    /// The paper's Table 2 headline run: 8-node rack Terasort at
    /// 10 GB/node, no faults.
    pub fn paper_lan8() -> ScenarioSpec {
        ScenarioSpec {
            name: "paper-lan8-terasort".into(),
            topology: TopologySpec::paper_lan(8),
            cfg: SimConfig::lan_default(),
            workload: Some(WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 10.0 * GB as f64,
                iterations: 10,
            }),
            faults: Vec::new(),
            churn: None,
            weather: None,
            traffic: None,
            replication: None,
            colocation: ColocationSpec::default(),
            compare: None,
            angle: None,
            trace: None,
        }
    }

    /// Scale-out stress preset: 128 nodes (4 sites × 4 racks × 8 nodes)
    /// running Terasort at 1 GB/node through a crash, a WAN brown-out
    /// and a straggler — the scenario `examples/scenario_suite.rs` and
    /// `benches/bench_scale.rs` exercise.
    pub fn scale128() -> ScenarioSpec {
        ScenarioSpec {
            name: "scale128-terasort-faults".into(),
            topology: TopologySpec::scale_out(4, 4, 8),
            cfg: SimConfig::lan_default(),
            workload: Some(WorkloadSpec {
                kind: WorkloadKind::Terasort,
                bytes_per_node: 1.0 * GB as f64,
                iterations: 10,
            }),
            faults: vec![
                FaultSpec::Straggler {
                    node: 17,
                    factor: 0.5,
                },
                FaultSpec::SlaveCrash {
                    at_secs: 3.0,
                    node: 40,
                },
                FaultSpec::LinkDegrade {
                    at_secs: 5.0,
                    duration_secs: 20.0,
                    site: 2,
                    factor: 0.25,
                },
            ],
            churn: None,
            weather: None,
            traffic: None,
            replication: None,
            colocation: ColocationSpec::default(),
            compare: None,
            angle: None,
            trace: None,
        }
    }

    /// Service-layer stress preset: the scale128 cloud serving 150k
    /// requests from a 200k-client population across three tenants,
    /// through the same fault plan (the straggler, crash and WAN
    /// brown-out now show up as per-tenant p99 damage instead of
    /// makespan).  Mirrors config/scenarios/traffic_scale128.toml.
    pub fn traffic_scale128() -> ScenarioSpec {
        let mut spec = ScenarioSpec::scale128();
        spec.name = "traffic-scale128".into();
        // Service-only: the batch workload is replaced, not colocated.
        spec.workload = None;
        spec.traffic = Some(TrafficSpec {
            clients: 200_000,
            requests: 150_000,
            files: 65_536,
            zipf_theta: 0.9,
            arrival: ArrivalProcess::Open { rps: 4_000.0 },
            shape: ArrivalShape::Flat,
            tenants: vec![
                TenantSpec {
                    name: "interactive".into(),
                    weight: 0.70,
                    write_fraction: 0.05,
                    object_bytes: 1.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "analytics".into(),
                    weight: 0.25,
                    write_fraction: 0.10,
                    object_bytes: 8.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "ingest".into(),
                    weight: 0.05,
                    write_fraction: 0.90,
                    object_bytes: 16.0e6,
                    priority: 0,
                },
            ],
        });
        spec
    }

    /// Million-client elastic-serving preset: a 512-node cloud (4
    /// sites × 8 racks × 16 nodes) serving 10^6 requests from a
    /// 1.2M-client lazy-session population under bursty arrivals and a
    /// hard Zipf skew, with the watermark scaler re-replicating hot
    /// files against the same-seed static baseline (DESIGN.md §16).
    /// Tenants carry distinct priority classes, and the fault plan
    /// crashes a replica holder mid-scaling.  Mirrors
    /// config/scenarios/traffic_elastic512.toml;
    /// `benches/bench_elastic.rs` gates its hot-tenant p99 win.
    pub fn traffic_elastic512() -> ScenarioSpec {
        let mut spec = ScenarioSpec::scale128();
        spec.name = "traffic-elastic512".into();
        spec.topology = TopologySpec::scale_out(4, 8, 16);
        // Service-only: the batch workload is replaced, not colocated.
        spec.workload = None;
        spec.faults = vec![
            FaultSpec::Straggler {
                node: 33,
                factor: 0.5,
            },
            FaultSpec::SlaveCrash {
                at_secs: 30.0,
                node: 100,
            },
        ];
        spec.traffic = Some(TrafficSpec {
            clients: 1_200_000,
            requests: 1_000_000,
            files: 65_536,
            zipf_theta: 1.1,
            arrival: ArrivalProcess::Open { rps: 8_000.0 },
            shape: ArrivalShape::Bursty {
                period_secs: 20.0,
                burst_secs: 5.0,
                amplitude: 1.5,
            },
            tenants: vec![
                TenantSpec {
                    name: "interactive".into(),
                    weight: 0.70,
                    write_fraction: 0.02,
                    object_bytes: 1.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "analytics".into(),
                    weight: 0.25,
                    write_fraction: 0.10,
                    object_bytes: 8.0e6,
                    priority: 1,
                },
                TenantSpec {
                    name: "ingest".into(),
                    weight: 0.05,
                    write_fraction: 0.90,
                    object_bytes: 16.0e6,
                    priority: 2,
                },
            ],
        });
        spec.replication = Some(ReplicationSpec {
            policy: ScalerPolicy::Watermark,
            min_replicas: 2,
            max_replicas: 6,
            interval_secs: 1.0,
            high_reads_per_sec: 8.0,
            low_reads_per_sec: 0.5,
            max_grows_per_tick: 64,
            max_sheds_per_tick: 64,
        });
        spec
    }

    /// The paper's headline deployment class (§1: one cloud that
    /// archives, analyzes AND serves): the scale128 Terasort — same
    /// fault plan, straggler included — colocated with a three-tenant
    /// client request stream on the same disks and WAN tiers, with
    /// speculative re-execution enabled.  Mirrors
    /// config/scenarios/colocate_scale128.toml.
    pub fn colocate_scale128() -> ScenarioSpec {
        let mut spec = ScenarioSpec::scale128();
        spec.name = "colocate-scale128".into();
        // Same plan as scale128 but a harsher straggler (4x slow): at
        // 2x a backup finishes in a dead heat with the primary; at 4x
        // speculation visibly cuts the makespan tail, which is the
        // preset's acceptance property (bench_colocate gates it).
        spec.faults = vec![
            FaultSpec::Straggler {
                node: 17,
                factor: 0.25,
            },
            FaultSpec::SlaveCrash {
                at_secs: 3.0,
                node: 40,
            },
            FaultSpec::LinkDegrade {
                at_secs: 5.0,
                duration_secs: 20.0,
                site: 2,
                factor: 0.25,
            },
        ];
        spec.traffic = Some(TrafficSpec {
            clients: 100_000,
            requests: 30_000,
            files: 65_536,
            zipf_theta: 0.9,
            arrival: ArrivalProcess::Open { rps: 2_500.0 },
            shape: ArrivalShape::Flat,
            tenants: vec![
                TenantSpec {
                    name: "interactive".into(),
                    weight: 0.75,
                    write_fraction: 0.05,
                    object_bytes: 1.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "analytics".into(),
                    weight: 0.20,
                    write_fraction: 0.10,
                    object_bytes: 8.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "ingest".into(),
                    weight: 0.05,
                    write_fraction: 0.90,
                    object_bytes: 16.0e6,
                    priority: 0,
                },
            ],
        });
        spec.colocation = ColocationSpec {
            speculative: true,
            threshold: 1.75,
            job_share: 0.8,
        };
        spec
    }

    /// The paper's §7 multi-site head-to-head: Terasort at 10 GB/node
    /// on the Table 1 four-node row (2× Chicago + 2× Pasadena, 55 ms
    /// RTT between them) through BOTH the Sphere engine and the Hadoop
    /// baseline engine on identically built substrates, no faults —
    /// the clean reproduction of the 1-site-vs-multi-site comparison.
    /// Mirrors config/scenarios/compare_wan4.toml.
    pub fn compare_wan4() -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_wan6();
        spec.name = "compare-wan4".into();
        spec.topology =
            TopologySpec::paper_wan_prefix(4).expect("4 nodes is a valid Table 1 prefix");
        spec.compare = Some(CompareSpec::default());
        spec
    }

    /// The scale-out head-to-head: the scale128 Terasort (128 nodes,
    /// 1 GB/node) with its full fault plan — straggler, crash, WAN
    /// brown-out — hitting both engines identically, Hadoop speculation
    /// enabled.  Mirrors config/scenarios/compare_scale128.toml.
    pub fn compare_scale128() -> ScenarioSpec {
        let mut spec = ScenarioSpec::scale128();
        spec.name = "compare-scale128".into();
        spec.compare = Some(CompareSpec::default());
        spec
    }

    /// The paper's §7 deployment: Angle across four sensor sites on the
    /// wide area, fault-free — the clean run whose planted scan and
    /// exfiltration shifts must be detected with recall 1.0 (the
    /// acceptance gate `benches/bench_angle.rs` enforces).  Mirrors
    /// config/scenarios/angle_wan4.toml.
    pub fn angle_wan4() -> ScenarioSpec {
        ScenarioSpec {
            name: "angle-wan4".into(),
            topology: TopologySpec::scale_out(4, 1, 2),
            cfg: SimConfig::wan_default(),
            workload: Some(WorkloadSpec {
                kind: WorkloadKind::Angle,
                bytes_per_node: 250.0 * MB as f64,
                iterations: 10,
            }),
            faults: Vec::new(),
            churn: None,
            weather: None,
            traffic: None,
            replication: None,
            colocation: ColocationSpec::default(),
            compare: None,
            angle: Some(AngleSpec::default()),
            trace: None,
        }
    }

    /// Table 3's 300,000-file scale on the 128-node cloud under the
    /// full scale128-class fault plan: 10^8 packet records (25 MB/node)
    /// aggregated into 16 temporal windows, a 4x straggler hosting one
    /// window (node 16 is a window home, so its cluster task must be
    /// rescued by speculation), a crash at t = 30 s — safely inside the
    /// hours-long aggregate stage — that re-homes window 5, and a WAN
    /// brown-out squeezing the feature shuffle.  Mirrors
    /// config/scenarios/angle_scale128.toml.
    pub fn angle_scale128() -> ScenarioSpec {
        ScenarioSpec {
            name: "angle-scale128".into(),
            topology: TopologySpec::scale_out(4, 4, 8),
            cfg: SimConfig::lan_default(),
            workload: Some(WorkloadSpec {
                kind: WorkloadKind::Angle,
                bytes_per_node: 25.0 * MB as f64,
                iterations: 10,
            }),
            faults: vec![
                FaultSpec::Straggler {
                    node: 16,
                    factor: 0.25,
                },
                FaultSpec::SlaveCrash {
                    at_secs: 30.0,
                    node: 40,
                },
                FaultSpec::LinkDegrade {
                    at_secs: 5.0,
                    duration_secs: 20.0,
                    site: 2,
                    factor: 0.25,
                },
            ],
            churn: None,
            weather: None,
            traffic: None,
            replication: None,
            colocation: ColocationSpec::default(),
            compare: None,
            angle: Some(AngleSpec {
                windows: 16,
                files: 300_000,
                anomalies: vec![
                    AnomalySpec { window: 5, source: 3, regime: Regime::Scan },
                    AnomalySpec { window: 5, source: 7, regime: Regime::Scan },
                    AnomalySpec { window: 11, source: 11, regime: Regime::Exfil },
                    AnomalySpec { window: 11, source: 19, regime: Regime::Exfil },
                ],
                ..AngleSpec::default()
            }),
            trace: None,
        }
    }

    /// Wide-area churn preset (DESIGN.md §18): a 32-node 4-site WAN
    /// Terasort at 1 GB/node through a seeded churn episode — Poisson
    /// departures at 4 per 100 s for the first minute, each node
    /// re-joining 30 s later, at most a quarter of the cluster absent
    /// at once.  Mirrors config/scenarios/churn_wan32.toml.
    pub fn churn_wan32() -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_wan6();
        spec.name = "churn-wan32".into();
        spec.topology = TopologySpec::scale_out(4, 2, 4);
        spec.workload = Some(WorkloadSpec {
            kind: WorkloadKind::Terasort,
            bytes_per_node: 1.0 * GB as f64,
            iterations: 10,
        });
        spec.churn = Some(ChurnSpec {
            rate_per_100s: 4.0,
            start_secs: 5.0,
            duration_secs: 60.0,
            rejoin_secs: 30.0,
            seed: 11,
            max_fraction: 0.25,
        });
        spec
    }

    /// Network-weather head-to-head preset (DESIGN.md §18): a 16-node
    /// 2-site WAN Terasort at 1 GB/node through BOTH engines while a
    /// seeded piecewise trace redraws every site's WAN capacity from
    /// [0.5, 1) each 10 s epoch for 6 epochs.  Mirrors
    /// config/scenarios/weather_compare16.toml.
    pub fn weather_compare16() -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_wan6();
        spec.name = "weather-compare16".into();
        spec.topology = TopologySpec::scale_out(2, 2, 4);
        spec.workload = Some(WorkloadSpec {
            kind: WorkloadKind::Terasort,
            bytes_per_node: 1.0 * GB as f64,
            iterations: 10,
        });
        spec.compare = Some(CompareSpec::default());
        spec.weather = Some(WeatherSpec {
            points: Vec::new(),
            seed: 7,
            period_secs: 10.0,
            amplitude: 0.5,
            steps: 6,
        });
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_scenario_toml() {
        let spec = ScenarioSpec::from_toml(
            r#"
            name = "toml-run"
            [topology]
            sites = 2
            racks_per_site = 2
            nodes_per_rack = 4
            [hardware]
            profile = "wan"
            [workload]
            kind = "terasort"
            bytes_per_node = "2GB"
            [faults.crash1]
            kind = "crash"
            at_secs = 10.0
            node = 3
            [faults.slow]
            kind = "straggler"
            node = 7
            factor = 0.25
            [faults.wanout]
            kind = "link_degrade"
            at_secs = 4.0
            duration_secs = 8.0
            site = 1
            factor = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(spec.name, "toml-run");
        assert_eq!(spec.topology.nodes(), 16);
        assert_eq!(spec.cfg.hardware.cores, 4, "wan profile");
        let workload = spec.workload.as_ref().expect("workload block parsed");
        assert_eq!(workload.kind, WorkloadKind::Terasort);
        assert!((workload.bytes_per_node - 2.0e9).abs() < 1.0);
        assert_eq!(spec.faults.len(), 3);
        assert!(spec.validate().is_ok());
        assert!(matches!(
            spec.faults[0],
            FaultSpec::SlaveCrash { node: 3, .. }
        ));
    }

    #[test]
    fn rejects_bad_faults_and_workloads() {
        assert!(WorkloadKind::parse("sort-of").is_err());
        let bad_kind =
            ScenarioSpec::from_toml("[faults.x]\nkind = \"meteor\"").unwrap_err();
        assert!(bad_kind.contains("meteor"), "{bad_kind}");
        // A typo'd field must error, not silently fall back to defaults.
        let typo = ScenarioSpec::from_toml(
            "[faults.c]\nkind = \"crash\"\nat_secs = 10.0\nnodes = 3",
        )
        .unwrap_err();
        assert!(typo.contains("nodes"), "{typo}");
        let mut spec = ScenarioSpec::paper_lan8();
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 1.0,
            node: 99,
        });
        assert!(spec.validate().is_err());
        let mut spec = ScenarioSpec::paper_lan8();
        spec.faults.push(FaultSpec::Straggler {
            node: 0,
            factor: 2.0,
        });
        assert!(spec.validate().is_err());
        // A WAN brown-out on a single-site rack can never bite: reject
        // it instead of reporting a fault that did nothing.
        let mut spec = ScenarioSpec::paper_lan8();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: 10.0,
            site: 0,
            factor: 0.5,
        });
        let err = spec.validate().unwrap_err();
        assert!(err.contains("single-site"), "{err}");
    }

    #[test]
    fn presets_validate() {
        for spec in [
            ScenarioSpec::paper_wan6(),
            ScenarioSpec::paper_lan8(),
            ScenarioSpec::scale128(),
            ScenarioSpec::churn_wan32(),
            ScenarioSpec::weather_compare16(),
        ] {
            spec.validate().unwrap();
            assert!(spec.topology.generate().is_ok());
        }
        assert_eq!(ScenarioSpec::scale128().topology.nodes(), 128);
        assert_eq!(ScenarioSpec::churn_wan32().topology.nodes(), 32);
        assert_eq!(ScenarioSpec::weather_compare16().topology.nodes(), 16);
    }

    #[test]
    fn crashing_every_node_is_rejected() {
        let mut spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 1\nracks_per_site = 1\nnodes_per_rack = 2",
        )
        .unwrap();
        spec.faults = vec![
            FaultSpec::SlaveCrash { at_secs: 1.0, node: 0 },
            FaultSpec::SlaveCrash { at_secs: 2.0, node: 1 },
        ];
        assert!(spec.validate().is_err());
        // ...but crashing the SAME node twice leaves a survivor: legal
        // (distinct nodes are what count, not fault entries).
        spec.faults = vec![
            FaultSpec::SlaveCrash { at_secs: 1.0, node: 0 },
            FaultSpec::SlaveCrash { at_secs: 2.0, node: 0 },
        ];
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn leaves_with_rejoins_do_not_count_as_crashes() {
        let mut spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 1\nracks_per_site = 1\nnodes_per_rack = 2",
        )
        .unwrap();
        // Both nodes depart but both come back: the cluster is never
        // permanently empty, so the plan is legal.
        spec.faults = vec![
            FaultSpec::NodeLeave { at_secs: 1.0, node: 0 },
            FaultSpec::NodeJoin { at_secs: 5.0, node: 0 },
            FaultSpec::NodeLeave { at_secs: 2.0, node: 1 },
            FaultSpec::NodeJoin { at_secs: 6.0, node: 1 },
        ];
        assert!(spec.validate().is_ok());
        // Drop one of the joins: that node never returns, and together
        // with a permanent crash the plan empties the cluster.
        spec.faults = vec![
            FaultSpec::NodeLeave { at_secs: 1.0, node: 0 },
            FaultSpec::SlaveCrash { at_secs: 2.0, node: 1 },
        ];
        let err = spec.validate().unwrap_err();
        assert!(err.contains("crashes all"), "{err}");
    }

    #[test]
    fn churn_block_parses_and_validates() {
        let spec = ScenarioSpec::from_toml(
            r#"
            [topology]
            sites = 2
            racks_per_site = 2
            nodes_per_rack = 4
            [churn]
            rate_per_100s = 8.0
            start_secs = 2.0
            duration_secs = 30.0
            rejoin_secs = 10.0
            seed = 42
            max_fraction = 0.5
            "#,
        )
        .unwrap();
        let churn = spec.churn.expect("churn block parsed");
        assert_eq!(churn.seed, 42);
        assert!((churn.rate_per_100s - 8.0).abs() < 1e-12);
        assert!(spec.validate().is_ok());
        // Typo'd key must error, not silently default.
        let err = ScenarioSpec::from_toml("[churn]\nrate = 4.0").unwrap_err();
        assert!(err.contains("rate"), "{err}");
        // Bad max_fraction is rejected at validate time.
        let mut bad = ScenarioSpec::churn_wan32();
        bad.churn.as_mut().unwrap().max_fraction = 1.0;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("max_fraction"), "{err}");
        // Analytic workloads cannot host a churn episode.
        let mut bad = ScenarioSpec::churn_wan32();
        bad.workload.as_mut().unwrap().kind = WorkloadKind::Kmeans;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("analytic"), "{err}");
    }

    #[test]
    fn churn_expansion_is_deterministic_and_bounded() {
        let churn = ChurnSpec {
            rate_per_100s: 20.0,
            start_secs: 1.0,
            duration_secs: 50.0,
            rejoin_secs: 5.0,
            seed: 9,
            max_fraction: 0.25,
        };
        let a = churn.expand(16);
        let b = churn.expand(16);
        assert_eq!(a, b, "same spec, same plan");
        assert!(!a.is_empty(), "a 20/100s rate over 50 s should fire");
        let mut leaves = 0usize;
        let mut prev = f64::NEG_INFINITY;
        for f in &a {
            let at = match f {
                FaultSpec::NodeLeave { at_secs, node } => {
                    leaves += 1;
                    assert!(*node < 16);
                    *at_secs
                }
                FaultSpec::NodeJoin { at_secs, node } => {
                    assert!(*node < 16);
                    *at_secs
                }
                other => panic!("unexpected fault in churn expansion: {other:?}"),
            };
            assert!(at >= prev, "plan must be time-sorted: {a:?}");
            prev = at;
        }
        // Every leave has its matching rejoin (rejoin_secs > 0).
        assert_eq!(a.len(), leaves * 2);
        // A different seed moves the instants.
        let other = ChurnSpec { seed: 10, ..churn }.expand(16);
        assert_ne!(a, other, "seed must matter");
        // Rate 0 expands to nothing at all.
        assert!(ChurnSpec { rate_per_100s: 0.0, ..churn }.expand(16).is_empty());
    }

    #[test]
    fn churn_respects_max_fraction() {
        // Never-rejoining churn at a huge rate: the absent set is
        // capped at floor(8 * 0.25) = 2 nodes, so exactly 2 leaves.
        let churn = ChurnSpec {
            rate_per_100s: 10_000.0,
            start_secs: 0.0,
            duration_secs: 100.0,
            rejoin_secs: 0.0,
            seed: 3,
            max_fraction: 0.25,
        };
        let plan = churn.expand(8);
        let leaves = plan
            .iter()
            .filter(|f| matches!(f, FaultSpec::NodeLeave { .. }))
            .count();
        assert_eq!(leaves, 2, "{plan:?}");
        assert_eq!(plan.len(), leaves, "rejoin_secs = 0 emits no joins");
        // Distinct victims.
        let mut nodes: Vec<usize> = plan
            .iter()
            .filter_map(|f| match f {
                FaultSpec::NodeLeave { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        nodes.dedup();
        assert_eq!(nodes.len(), 2);
    }

    #[test]
    fn weather_block_parses_and_expands() {
        let spec = ScenarioSpec::from_toml(
            r#"
            [topology]
            sites = 2
            racks_per_site = 1
            nodes_per_rack = 4
            [weather]
            seed = 21
            period_secs = 5.0
            amplitude = 0.4
            steps = 3
            [weather.points.squeeze]
            at_secs = 2.0
            site = 1
            factor = 0.3
            "#,
        )
        .unwrap();
        let weather = spec.weather.clone().expect("weather block parsed");
        assert_eq!(weather.points.len(), 1);
        assert_eq!(weather.steps, 3);
        assert!(spec.validate().is_ok());
        let plan = weather.expand(2);
        // 1 explicit point + 3 epochs x 2 sites generated (all factors
        // < 1 since amplitude > 0 draws from [0.6, 1)).
        assert_eq!(plan.len(), 1 + 3 * 2, "{plan:?}");
        assert_eq!(plan, weather.expand(2), "same spec, same plan");
        let mut prev = f64::NEG_INFINITY;
        for f in &plan {
            match f {
                FaultSpec::WeatherSet { at_secs, site, factor } => {
                    assert!(*site < 2);
                    assert!(*factor > 0.0 && *factor <= 1.0);
                    assert!(*at_secs >= prev);
                    prev = *at_secs;
                }
                other => panic!("unexpected fault in weather expansion: {other:?}"),
            }
        }
        // Seed sensitivity on the generated part.
        let other = WeatherSpec { seed: 22, ..weather.clone() }.expand(2);
        assert_ne!(plan, other);
        // Amplitude 0 with no points expands to nothing.
        let flat = WeatherSpec { amplitude: 0.0, points: Vec::new(), ..weather };
        assert!(flat.expand(2).is_empty());
        // Typo'd point field must error.
        let err = ScenarioSpec::from_toml(
            "[weather.points.p]\nat = 1.0\nsite = 0\nfactor = 0.5",
        )
        .unwrap_err();
        assert!(err.contains("unknown field"), "{err}");
        // Single-site topology rejects the trace.
        let mut bad = ScenarioSpec::paper_lan8();
        bad.weather = Some(WeatherSpec::default());
        let err = bad.validate().unwrap_err();
        assert!(err.contains("single-site"), "{err}");
    }

    #[test]
    fn effective_faults_with_inert_blocks_match_base_plan() {
        // Churn at rate 0 plus a flat weather trace must reproduce the
        // plain fault plan byte-identically (the acceptance criterion
        // that makes the blocks safe to leave in a spec).
        let base = ScenarioSpec::scale128();
        let mut decorated = base.clone();
        decorated.churn = Some(ChurnSpec {
            rate_per_100s: 0.0,
            ..ChurnSpec::default()
        });
        decorated.weather = Some(WeatherSpec::default());
        assert!(decorated.validate().is_ok());
        assert_eq!(
            format!("{:?}", base.effective_faults()),
            format!("{:?}", decorated.effective_faults()),
        );
    }

    #[test]
    fn top_level_transport_key_picks_the_flow_model() {
        let toml = |transport: &str| {
            format!(
                "name = \"t\"\ntransport = {transport}\n\
                 [topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
                 [hardware]\nprofile = \"wan\""
            )
        };
        let udt = ScenarioSpec::from_toml(&toml("\"udt\"")).unwrap();
        assert_eq!(udt.cfg.sphere_transport, TransportKind::Udt);
        let tcp = ScenarioSpec::from_toml(&toml("\"tcp\"")).unwrap();
        assert_eq!(tcp.cfg.sphere_transport, TransportKind::Tcp);
        let err = ScenarioSpec::from_toml(&toml("\"carrier-pigeon\"")).unwrap_err();
        assert!(err.contains("carrier-pigeon"), "{err}");
        let err = ScenarioSpec::from_toml(&toml("3")).unwrap_err();
        assert!(err.contains("string"), "{err}");
    }

    #[test]
    fn new_fault_kinds_parse_from_toml() {
        let spec = ScenarioSpec::from_toml(
            r#"
            [topology]
            sites = 2
            racks_per_site = 1
            nodes_per_rack = 4
            [faults.away]
            kind = "leave"
            at_secs = 3.0
            node = 1
            [faults.back]
            kind = "join"
            at_secs = 9.0
            node = 1
            [faults.storm]
            kind = "weather_set"
            at_secs = 4.0
            site = 1
            factor = 0.6
            [faults.outage]
            kind = "master_crash"
            at_secs = 5.0
            down_secs = 2.5
            "#,
        )
        .unwrap();
        assert_eq!(spec.faults.len(), 4);
        assert!(spec.validate().is_ok());
        assert!(matches!(
            spec.faults[3],
            FaultSpec::MasterCrash { down_secs, .. } if (down_secs - 2.5).abs() < 1e-12
        ));
        // master_crash needs a batch workload to bite.
        let mut bad = spec.clone();
        bad.workload = None;
        bad.traffic = ScenarioSpec::traffic_scale128().traffic;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("cache file metadata"), "{err}");
        // ...and a finite, positive outage.
        let mut bad = spec.clone();
        bad.faults = vec![FaultSpec::MasterCrash {
            at_secs: 1.0,
            down_secs: f64::INFINITY,
        }];
        let err = bad.validate().unwrap_err();
        assert!(err.contains("down_secs"), "{err}");
    }

    #[test]
    fn traffic_block_parses_into_scenario() {
        let spec = ScenarioSpec::from_toml(
            r#"
            [topology]
            sites = 2
            racks_per_site = 2
            nodes_per_rack = 4
            [traffic]
            clients = 5000
            requests = 2000
            rps = 400.0
            [traffic.tenants.web]
            weight = 1.0
            object_bytes = "2MB"
            [faults.crash1]
            kind = "crash"
            at_secs = 1.0
            node = 3
            "#,
        )
        .unwrap();
        let traffic = spec.traffic.as_ref().expect("traffic block parsed");
        assert_eq!(traffic.clients, 5000);
        assert_eq!(traffic.tenants[0].name, "web");
        assert!(spec.workload.is_none(), "traffic-only spec has no workload");
        assert_eq!(spec.faults.len(), 1, "faults compose with traffic");
        spec.validate().unwrap();
    }

    #[test]
    fn traffic_and_workload_now_colocate() {
        // The old mutual-exclusion error is gone: both blocks in one
        // document describe a colocated run (DESIGN.md §11).
        let spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
             [workload]\nkind = \"terasort\"\nbytes_per_node = \"1GB\"\n\
             [traffic]\nrequests = 10",
        )
        .unwrap();
        assert!(spec.workload.is_some(), "workload survives alongside traffic");
        assert!(spec.traffic.is_some());
        assert_eq!(spec.colocation, ColocationSpec::default(), "knobs default");
        spec.validate().unwrap();
    }

    #[test]
    fn colocation_block_parses_and_rejects_typos() {
        let base = "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
                    [workload]\nkind = \"terasort\"\n[traffic]\nrequests = 10\n";
        let spec = ScenarioSpec::from_toml(&format!(
            "{base}[colocation]\nspeculative = false\nthreshold = 3.0\njob_share = 0.5"
        ))
        .unwrap();
        assert!(!spec.colocation.speculative);
        assert_eq!(spec.colocation.threshold, 3.0);
        assert_eq!(spec.colocation.job_share, 0.5);
        spec.validate().unwrap();
        // Unknown keys error via check_known_keys, never silently default.
        let err = ScenarioSpec::from_toml(&format!("{base}[colocation]\nthreshhold = 2.0"))
            .unwrap_err();
        assert!(err.contains("threshhold"), "{err}");
    }

    #[test]
    fn colocation_rejects_bad_values_and_lonely_blocks() {
        let base = "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
                    [workload]\nkind = \"terasort\"\n[traffic]\nrequests = 10\n";
        // threshold <= 1 would speculate on healthy segments.
        let spec = ScenarioSpec::from_toml(&format!("{base}[colocation]\nthreshold = 1.0"))
            .unwrap();
        let err = spec.validate().unwrap_err();
        assert!(err.contains("threshold"), "{err}");
        let spec = ScenarioSpec::from_toml(&format!("{base}[colocation]\njob_share = 0.0"))
            .unwrap();
        assert!(spec.validate().unwrap_err().contains("job_share"));
        let spec = ScenarioSpec::from_toml(&format!("{base}[colocation]\njob_share = 1.5"))
            .unwrap();
        assert!(spec.validate().unwrap_err().contains("job_share"));
        // A [colocation] block without both workloads is a mistake.
        let err = ScenarioSpec::from_toml(
            "[traffic]\nrequests = 10\n[colocation]\nthreshold = 2.0",
        )
        .unwrap_err();
        assert!(err.contains("[colocation]"), "{err}");
        let err = ScenarioSpec::from_toml(
            "[workload]\nkind = \"terasort\"\n[colocation]\nthreshold = 2.0",
        )
        .unwrap_err();
        assert!(err.contains("[colocation]"), "{err}");
    }

    #[test]
    fn analytic_workloads_refuse_to_colocate() {
        for kind in ["terasplit", "kmeans"] {
            let spec = ScenarioSpec::from_toml(&format!(
                "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
                 [workload]\nkind = \"{kind}\"\n[traffic]\nrequests = 10"
            ))
            .unwrap();
            let err = spec.validate().unwrap_err();
            assert!(err.contains(kind), "{err}");
        }
    }

    #[test]
    fn traffic_preset_validates() {
        let spec = ScenarioSpec::traffic_scale128();
        spec.validate().unwrap();
        assert_eq!(spec.topology.nodes(), 128);
        assert!(spec.workload.is_none(), "service-only preset");
        let traffic = spec.traffic.unwrap();
        assert!(traffic.requests >= 100_000, "acceptance floor");
        assert_eq!(traffic.tenants.len(), 3);
    }

    #[test]
    fn colocate_preset_validates() {
        let spec = ScenarioSpec::colocate_scale128();
        spec.validate().unwrap();
        assert_eq!(spec.topology.nodes(), 128);
        assert!(spec.workload.is_some(), "carries the batch job");
        assert!(spec.traffic.is_some(), "…and the client stream");
        assert!(spec.colocation.speculative);
        assert!(
            spec.faults.iter().any(|f| matches!(f, FaultSpec::Straggler { .. })),
            "the straggler is what speculation must beat"
        );
    }

    #[test]
    fn invalid_traffic_fails_scenario_validation() {
        let mut spec = ScenarioSpec::traffic_scale128();
        spec.traffic.as_mut().unwrap().tenants.clear();
        assert!(spec.validate().is_err());
    }

    #[test]
    fn kmeans_rejects_inert_bandwidth_faults() {
        let mut spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [workload]\nkind = \"kmeans\"",
        )
        .unwrap();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: 5.0,
            site: 0,
            factor: 0.5,
        });
        let err = spec.validate().unwrap_err();
        assert!(err.contains("kmeans"), "{err}");
    }

    #[test]
    fn compare_block_parses_and_defaults_workload() {
        // A [compare] document without [workload] defaults to terasort,
        // exactly like a bare batch scenario.
        let spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [compare]\nenabled = true",
        )
        .unwrap();
        let cmp = spec.compare.expect("compare block parsed");
        assert!(cmp.hadoop_speculative, "0.16 default: speculation on");
        assert_eq!(
            spec.workload.as_ref().map(|w| w.kind),
            Some(WorkloadKind::Terasort)
        );
        spec.validate().unwrap();
        // enabled = false switches the head-to-head off.
        let spec = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [compare]\nenabled = false",
        )
        .unwrap();
        assert!(spec.compare.is_none());
        // Typo'd keys error, never silently default.
        let err = ScenarioSpec::from_toml("[compare]\nspeculative = true").unwrap_err();
        assert!(err.contains("speculative"), "{err}");
    }

    #[test]
    fn compare_rejects_traffic_and_offpaper_workloads() {
        let err = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [compare]\nenabled = true\n[traffic]\nrequests = 10",
        )
        .unwrap()
        .validate()
        .unwrap_err();
        assert!(err.contains("[compare]"), "{err}");
        for kind in ["angle", "kmeans"] {
            let err = ScenarioSpec::from_toml(&format!(
                "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
                 [workload]\nkind = \"{kind}\"\n[compare]\nenabled = true"
            ))
            .unwrap()
            .validate()
            .unwrap_err();
            assert!(err.contains(kind), "{err}");
        }
    }

    #[test]
    fn compare_presets_validate() {
        let wan4 = ScenarioSpec::compare_wan4();
        wan4.validate().unwrap();
        assert_eq!(wan4.topology.nodes(), 4);
        assert_eq!(wan4.topology.sites.len(), 2, "Chicago + Pasadena");
        assert!(wan4.compare.is_some());
        assert!(wan4.faults.is_empty(), "the paper's tables are fault-free");
        let s128 = ScenarioSpec::compare_scale128();
        s128.validate().unwrap();
        assert_eq!(s128.topology.nodes(), 128);
        assert_eq!(
            s128.faults.len(),
            3,
            "both engines face the scale128 fault plan"
        );
    }

    #[test]
    fn angle_block_parses_and_rejects_typos() {
        let base = "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
                    [workload]\nkind = \"angle\"\n";
        let spec = ScenarioSpec::from_toml(&format!(
            "{base}[angle]\nwindows = 12\nk = 4\nfiles = 4800\n\
             [angle.anomalies.scan]\nwindow = 6\nsource = 2\nregime = \"scan\"\n\
             [angle.anomalies.exfil]\nwindow = 9\nsource = 5\nregime = \"exfil\""
        ))
        .unwrap();
        let a = spec.angle.as_ref().expect("angle block parsed");
        assert_eq!(a.windows, 12);
        assert_eq!(a.k, 4);
        assert_eq!(a.files, 4800);
        assert_eq!(a.anomalies.len(), 2);
        assert_eq!(a.anomalies[1].regime, Regime::Scan, "sorted by label");
        spec.validate().unwrap();
        // Unknown keys error, never silently default.
        let err =
            ScenarioSpec::from_toml(&format!("{base}[angle]\nwindos = 8")).unwrap_err();
        assert!(err.contains("windos"), "{err}");
        let err = ScenarioSpec::from_toml(&format!(
            "{base}[angle]\nwindows = 8\n[angle.anomalies.a]\nwndow = 3"
        ))
        .unwrap_err();
        assert!(err.contains("wndow"), "{err}");
        let err = ScenarioSpec::from_toml(&format!(
            "{base}[angle]\nwindows = 8\n\
             [angle.anomalies.a]\nwindow = 3\nsource = 1\nregime = \"meteor\""
        ))
        .unwrap_err();
        assert!(err.contains("meteor"), "{err}");
        // A forgotten field must error, not silently plant the shift at
        // window 0 (undetectable before warmup) or default to a scan.
        let err = ScenarioSpec::from_toml(&format!(
            "{base}[angle]\nwindows = 8\n[angle.anomalies.a]\nsource = 3\nregime = \"scan\""
        ))
        .unwrap_err();
        assert!(err.contains("window"), "{err}");
        let err = ScenarioSpec::from_toml(&format!(
            "{base}[angle]\nwindows = 8\n[angle.anomalies.a]\nwindow = 3\nsource = 1"
        ))
        .unwrap_err();
        assert!(err.contains("regime"), "{err}");
    }

    #[test]
    fn angle_block_requires_angle_workload_and_no_traffic() {
        // [angle] next to a terasort workload is a mistake.
        let err = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [workload]\nkind = \"terasort\"\n[angle]\nwindows = 8",
        )
        .unwrap()
        .validate()
        .unwrap_err();
        assert!(err.contains("[angle]"), "{err}");
        // The staged pipeline does not colocate (a bare angle workload
        // with [traffic] still runs the legacy colocated model).
        let err = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [workload]\nkind = \"angle\"\n[angle]\nwindows = 8\n\
             [traffic]\nrequests = 10",
        )
        .unwrap()
        .validate()
        .unwrap_err();
        assert!(err.contains("[angle]"), "{err}");
        let legacy = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 1\nnodes_per_rack = 2\n\
             [workload]\nkind = \"angle\"\n[traffic]\nrequests = 10",
        )
        .unwrap();
        legacy.validate().unwrap();
    }

    #[test]
    fn angle_spec_validates_shape() {
        let mut a = AngleSpec::default();
        a.validate(4).unwrap();
        a.windows = 3;
        assert!(a.validate(4).unwrap_err().contains("windows"));
        let mut a = AngleSpec { k: 1, ..AngleSpec::default() };
        assert!(a.validate(4).unwrap_err().contains("k must be"));
        a.k = 60;
        a.sources_per_sensor = 10;
        a.anomalies.clear();
        assert!(a.validate(1).unwrap_err().contains("clusters"));
        let a = AngleSpec {
            anomalies: vec![AnomalySpec { window: 99, source: 0, regime: Regime::Scan }],
            ..AngleSpec::default()
        };
        assert!(a.validate(4).unwrap_err().contains("anomaly window"));
        let a = AngleSpec {
            anomalies: vec![AnomalySpec { window: 4, source: 99, regime: Regime::Scan }],
            ..AngleSpec::default()
        };
        assert!(a.validate(4).unwrap_err().contains("anomaly source"));
    }

    #[test]
    fn angle_presets_validate() {
        let wan4 = ScenarioSpec::angle_wan4();
        wan4.validate().unwrap();
        assert_eq!(wan4.topology.sites.len(), 4, "the paper's four sensor sites");
        assert!(wan4.faults.is_empty(), "the recall gate runs fault-free");
        let a = wan4.angle.as_ref().expect("angle block present");
        assert!(
            a.anomalies.iter().any(|an| an.regime == Regime::Scan)
                && a.anomalies.iter().any(|an| an.regime == Regime::Exfil),
            "both §7.1 regime shifts are planted"
        );
        let s128 = ScenarioSpec::angle_scale128();
        s128.validate().unwrap();
        assert_eq!(s128.topology.nodes(), 128);
        assert_eq!(s128.faults.len(), 3, "full fault plan");
        let a = s128.angle.as_ref().unwrap();
        assert_eq!(a.files, 300_000, "Table 3's file count");
        // The straggler must host a window so speculation is exercised:
        // 128 alive / 16 windows = spread 8 -> homes 0, 8, 16, ...
        assert!(
            s128.faults
                .iter()
                .any(|f| matches!(f, FaultSpec::Straggler { node: 16, .. })),
            "node 16 is a window home"
        );
        let records =
            s128.workload.as_ref().unwrap().bytes_per_node * 128.0 / 32.0;
        assert!((records - 1.0e8).abs() < 1.0, "Table 3's 10^8 records");
    }

    #[test]
    fn elastic_preset_validates_at_million_scale() {
        let spec = ScenarioSpec::traffic_elastic512();
        spec.validate().unwrap();
        assert_eq!(spec.topology.nodes(), 512);
        assert!(spec.workload.is_none(), "service-only preset");
        let t = spec.traffic.as_ref().unwrap();
        assert!(t.clients >= 1_000_000, "10^6+ lazy sessions");
        assert!(t.requests >= 1_000_000, "10^6+ requests");
        assert!(matches!(t.shape, ArrivalShape::Bursty { .. }));
        let prios: Vec<u8> = t.tenants.iter().map(|x| x.priority).collect();
        assert_eq!(prios, vec![0, 1, 2], "distinct priority classes");
        let r = spec.replication.as_ref().expect("watermark scaler on");
        assert_eq!(r.policy, ScalerPolicy::Watermark);
        assert!(r.min_replicas >= 2 && r.max_replicas > r.min_replicas);
    }

    #[test]
    fn replication_block_parses_and_rejects_typos() {
        let base = "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
                    [traffic]\nrequests = 10\n";
        let spec = ScenarioSpec::from_toml(&format!(
            "{base}[replication]\npolicy = \"watermark\"\nmin_replicas = 2\n\
             max_replicas = 4\ninterval_secs = 0.5\nhigh_reads_per_sec = 5.0\n\
             low_reads_per_sec = 0.5"
        ))
        .unwrap();
        let r = spec.replication.as_ref().expect("replication block parsed");
        assert_eq!(r.policy, ScalerPolicy::Watermark);
        assert_eq!((r.min_replicas, r.max_replicas), (2, 4));
        spec.validate().unwrap();
        // Unknown keys error, never silently default.
        let err = ScenarioSpec::from_toml(&format!(
            "{base}[replication]\nmax_replikas = 4"
        ))
        .unwrap_err();
        assert!(err.contains("max_replikas"), "{err}");
    }

    #[test]
    fn replication_requires_a_service_only_scenario() {
        // [replication] without [traffic] manages nothing.
        let err = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
             [workload]\nkind = \"terasort\"\n[replication]\npolicy = \"static\"",
        )
        .unwrap()
        .validate()
        .unwrap_err();
        assert!(err.contains("[replication]"), "{err}");
        // ...and it does not colocate with a batch workload either.
        let err = ScenarioSpec::from_toml(
            "[topology]\nsites = 2\nracks_per_site = 2\nnodes_per_rack = 2\n\
             [workload]\nkind = \"terasort\"\n[traffic]\nrequests = 10\n\
             [replication]\npolicy = \"static\"",
        )
        .unwrap()
        .validate()
        .unwrap_err();
        assert!(err.contains("[replication]"), "{err}");
    }
}

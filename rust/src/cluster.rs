//! High-level facade: an in-process Sector/Sphere cluster with the
//! standard workloads wired up.  This is the API the examples and the
//! CLI drive; everything underneath is the real coordination stack
//! (Sector replication, Chord lookup, Sphere SPEs, shuffle).

use std::path::PathBuf;

use crate::mining::terasort::{
    self, validate_sorted, TeraPartitionOp, TeraSortOp, RECORD_BYTES,
};
use crate::mining::terasplit;
use crate::runtime::Runtime;
use crate::sector::{DiskStorage, MemStorage, SectorCloud, Storage};
use crate::sphere::{run_job, FaultPlan, JobSpec, Stream};

/// An in-process cluster.
pub struct Cluster {
    pub cloud: SectorCloud,
    pub runtime: Option<Runtime>,
    /// Temp dir for disk-backed clusters (removed on drop).
    temp_root: Option<PathBuf>,
}

pub struct ClusterBuilder {
    nodes: usize,
    replicas: usize,
    seed: u64,
    on_disk: bool,
    load_runtime: bool,
}

impl Default for ClusterBuilder {
    fn default() -> Self {
        Self {
            nodes: 4,
            replicas: 2,
            seed: 20080824,
            on_disk: false,
            load_runtime: false,
        }
    }
}

impl ClusterBuilder {
    pub fn nodes(mut self, n: usize) -> Self {
        self.nodes = n;
        self
    }

    pub fn replicas(mut self, r: usize) -> Self {
        self.replicas = r;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Back slaves with real files under a temp dir (the e2e examples).
    pub fn on_disk(mut self, yes: bool) -> Self {
        self.on_disk = yes;
        self
    }

    /// Load the PJRT artifacts (requires `make artifacts`).
    pub fn with_runtime(mut self, yes: bool) -> Self {
        self.load_runtime = yes;
        self
    }

    pub fn build(self) -> Result<Cluster, String> {
        let temp_root = if self.on_disk {
            let mut p = std::env::temp_dir();
            p.push(format!(
                "sector-cluster-{}-{}",
                std::process::id(),
                self.seed
            ));
            Some(p)
        } else {
            None
        };
        let root = temp_root.clone();
        let cloud = SectorCloud::builder()
            .nodes(self.nodes)
            .replicas(self.replicas)
            .seed(self.seed)
            .storage_factory(move |id| -> Box<dyn Storage> {
                match &root {
                    Some(r) => Box::new(
                        DiskStorage::new(r.join(format!("slave{id:03}")))
                            .expect("create slave dir"),
                    ),
                    None => Box::new(MemStorage::new()),
                }
            })
            .build()?;
        let runtime = if self.load_runtime {
            Some(
                Runtime::load(&Runtime::default_dir())
                    .map_err(|e| format!("load PJRT artifacts: {e:#}"))?,
            )
        } else {
            None
        };
        Ok(Cluster {
            cloud,
            runtime,
            temp_root,
        })
    }
}

/// Result of a full two-stage Terasort + Terasplit run.
pub struct TerasortReport {
    pub records: usize,
    pub bucket_files: usize,
    pub sorted_files: Vec<String>,
    pub globally_sorted: bool,
    pub split_gain_bits: f64,
    pub split_index: usize,
    pub partition_locality: f64,
    pub wall_secs: f64,
}

impl Cluster {
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    pub fn nodes(&self) -> usize {
        self.cloud.n_slaves()
    }

    /// Upload `records_per_node` gensort records per node as one file
    /// each (the Terasort input layout).
    pub fn load_terasort_input(&self, records_per_node: usize) -> Result<Vec<String>, String> {
        let ip = "10.0.0.30".parse().unwrap();
        let mut names = Vec::new();
        for node in 0..self.cloud.n_slaves() as u32 {
            let data = terasort::generate_records(
                records_per_node,
                0x7e5a_0000 + node as u64,
            );
            let idx = terasort::record_index(&data);
            let name = format!("tera/input{node:03}.dat");
            self.cloud.upload(ip, &name, &data, Some(&idx), Some(node))?;
            names.push(name);
        }
        Ok(names)
    }

    /// Run the full Terasort (partition+shuffle, local sort) followed by
    /// Terasplit, validating global order. This is the end-to-end driver
    /// the paper's Tables 1-2 time at 10 GB/node scale.
    pub fn terasort_e2e(&self, records_per_node: usize) -> Result<TerasortReport, String> {
        let t0 = std::time::Instant::now();
        let inputs = self.load_terasort_input(records_per_node)?;
        let stream = Stream::from_cloud(&self.cloud, &inputs)?;
        let buckets = (self.nodes() * 4) as u32;

        // Stage A: range-partition into bucket files across the cloud.
        let partition = run_job(
            &self.cloud,
            &TeraPartitionOp { buckets },
            &stream,
            &JobSpec {
                output_name: "tera/bucket".into(),
                seg_min_bytes: 16 * RECORD_BYTES as u64,
                seg_max_bytes: 4096 * RECORD_BYTES as u64,
                ..JobSpec::default()
            },
            &FaultPlan::default(),
        )?;

        // Stage B: sort each bucket locally.
        let bucket_stream = Stream::from_cloud(&self.cloud, &partition.output_files)?;
        let sort = run_job(
            &self.cloud,
            &TeraSortOp,
            &bucket_stream,
            &JobSpec {
                output_name: "tera/sorted".into(),
                // one segment per bucket file: sort needs the whole bucket
                seg_min_bytes: u64::MAX / 4,
                seg_max_bytes: u64::MAX / 2,
                ..JobSpec::default()
            },
            &FaultPlan::default(),
        )?;

        // Validate: each output sorted, and bucket boundaries ordered.
        let mut globally_sorted = true;
        let mut last_key: Option<Vec<u8>> = None;
        let mut total_records = 0usize;
        let mut sorted_files = sort.output_files.clone();
        sorted_files.sort(); // seg ids follow bucket order
        let mut all_labels = Vec::new();
        for name in &sorted_files {
            let bytes = self.cloud.download(0, name)?;
            total_records += validate_sorted(&bytes)?;
            if let (Some(prev), Some(first)) = (&last_key, terasort::first_key(&bytes)) {
                if prev.as_slice() > first {
                    globally_sorted = false;
                }
            }
            last_key = terasort::last_key(&bytes).map(|k| k.to_vec());
            all_labels.extend(terasplit::labels_of(&bytes, 8));
        }
        if total_records != records_per_node * self.nodes() {
            return Err(format!(
                "record loss: {total_records} of {}",
                records_per_node * self.nodes()
            ));
        }

        // Terasplit over the sorted stream (PJRT artifact when loaded).
        let (gain, idx) = match &self.runtime {
            Some(rt) => {
                let (agg, factor) =
                    terasplit::aggregate_labels(&all_labels, 8, rt.shapes.n_labels);
                let (g, i) = rt.split_gain(&agg).map_err(|e| format!("{e:#}"))?;
                (g as f64, i * factor)
            }
            None => terasplit::best_split_host(&all_labels, 8),
        };

        Ok(TerasortReport {
            records: total_records,
            bucket_files: partition.output_files.len(),
            sorted_files,
            globally_sorted,
            split_gain_bits: gain,
            split_index: idx,
            partition_locality: partition.locality_fraction,
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        if let Some(root) = &self.temp_root {
            std::fs::remove_dir_all(root).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terasort_e2e_in_memory() {
        let cluster = Cluster::builder().nodes(3).seed(5).build().unwrap();
        let report = cluster.terasort_e2e(500).unwrap();
        assert_eq!(report.records, 1500);
        assert!(report.globally_sorted, "range partition + local sorts");
        assert!(report.bucket_files > 1);
        assert!(report.split_gain_bits >= 0.0);
        assert!(report.split_index < 1500);
    }

    #[test]
    fn terasort_e2e_on_disk() {
        let cluster = Cluster::builder()
            .nodes(2)
            .seed(6)
            .on_disk(true)
            .build()
            .unwrap();
        let report = cluster.terasort_e2e(300).unwrap();
        assert_eq!(report.records, 600);
        assert!(report.globally_sorted);
        // temp dir cleaned up on drop
        let root = cluster.temp_root.clone().unwrap();
        drop(cluster);
        assert!(!root.exists());
    }
}

//! Hadoop 0.16 baseline (paper §2, §6): an HDFS-like block store and a
//! MapReduce engine, implemented so the comparison in Tables 1–2 runs
//! against a real competitor rather than a strawman.  `hdfs` and
//! `mapreduce` are runnable (threads + bytes); `simjob` carries the
//! cost structure to paper scale.

pub mod hdfs;
pub mod mapreduce;
pub mod simjob;

pub use hdfs::{BlockId, BlockMeta, DataNodeId, Hdfs, HdfsFileMeta};
pub use mapreduce::{run_mapreduce, JobStats, Kv, MapReduceJob};
pub use simjob::{
    simulate_hadoop_filegen, simulate_hadoop_row, simulate_hadoop_terasort,
    simulate_hadoop_terasplit, HadoopSimResult,
};

//! Hadoop 0.16 baseline (paper §2, §6): an HDFS-like block store and a
//! MapReduce engine, implemented so the comparison in Tables 1–2 runs
//! against a real competitor rather than a strawman.  Three layers:
//! `hdfs` and `mapreduce` are runnable (threads + bytes); `simjob`
//! carries the closed-form cost structure to paper scale; `engine` is
//! the event-driven baseline that runs on the SAME scenario substrate
//! as the Sphere scheduler (shared topology, fault plan, disk links)
//! for the `[compare]` head-to-head (DESIGN.md §12).

pub mod engine;
pub mod hdfs;
pub mod mapreduce;
pub mod simjob;

pub use engine::{run_hadoop, HadoopRun};
pub use hdfs::{BlockId, BlockMeta, DataNodeId, Hdfs, HdfsFileMeta, Placement, ReReplication};
pub use mapreduce::{run_mapreduce, JobStats, Kv, MapReduceJob};
pub use simjob::{
    simulate_hadoop_filegen, simulate_hadoop_row, simulate_hadoop_terasort,
    simulate_hadoop_terasplit, HadoopSimResult,
};

//! Event-driven Hadoop 0.16 baseline on the shared scenario substrate
//! (DESIGN.md §12).
//!
//! Before this engine the `hadoop` module held a byte-level MapReduce
//! (threads + real bytes, for correctness cross-checks) and a
//! closed-form cost model (`simjob`, the Table 1/2 columns) — neither
//! reachable from the scenario layer, so every fault scenario was
//! Sphere-only.  This engine runs the baseline on the EXACT substrate
//! the Sphere scenario engine uses: a `TopologySpec`-derived `NetSim`
//! (topology links plus per-node disk links), one `EventQueue`, and
//! the scenario `FaultState` — so a crash, WAN brown-out or straggler
//! hits Hadoop at the same virtual time, on the same node or site, as
//! it hits Sphere in a `[compare]` run.
//!
//! Model (0.16 structure, event granularity):
//!
//! * **HDFS block map** — `hdfs::Placement` scatters
//!   `bytes_per_node / hadoop.block` blocks per node with the NameNode
//!   placement rule (write-local first replica, off-rack second).  A
//!   DataNode death triggers re-replication flows that contend with
//!   the job on the same links; a block losing its last replica fails
//!   the run (matching the Sphere engine's data-loss semantics).
//! * **Map** — one task per block, `hadoop.map_slots` concurrent per
//!   TaskTracker, a JVM fork (`task_startup_secs`) before each, I/O at
//!   `hadoop.io_efficiency` through the node's shared disk links
//!   (read + spill).  Placement is the real `sphere::Scheduler` with
//!   locality on — Hadoop's JobTracker also preferred data-local maps.
//! * **Shuffle** — a completed map's output rides TCP with untuned
//!   2008 socket buffers (64 KB windows; §6.3: "Hadoop may not have
//!   been [tested] using 10 Gb/s NICs") from the mapper's spill disk
//!   to a reducer's disk — MATERIALIZED intermediates, so shuffles and
//!   maps contend for spindles.  Fetches overlap the map tail; the
//!   map → reduce BARRIER waits for every map AND every fetch.
//! * **Reduce** — one partition per live node, `reduce_slots` per
//!   node: multi-pass merge, reduce CPU, then the job output through
//!   the HDFS client write pipeline (`hdfs_write_efficiency`).
//! * **Speculative execution** — per Hadoop's rule: once enough tasks
//!   completed, an attempt running [`SPEC_SLOWDOWN`]× past the mean
//!   completed-task duration gets a backup on another live holder with
//!   a free slot (first finisher wins, via the scheduler's
//!   first-completion contract — parity with Sphere's PR-3
//!   speculation).
//! * **Crash semantics** — the famous asymmetry: map outputs are NOT
//!   replicated, so a crash that kills a mapper mid-fetch forces the
//!   map to RE-EXECUTE from a surviving input replica (`map_reruns`),
//!   while Sphere re-reads the replicated stage output.  Fetches
//!   toward the dead node re-route; its queued/running tasks re-enter
//!   the scheduler under the shared `max_attempts` budget.
//!
//! Terasplit maps the same machinery to a map-only scan streaming
//! every block through one client's entropy scanner (a dedicated scan
//! link serializes the client side); Filegen is a write-only job
//! through the HDFS client pipeline.  Deterministic end to end: the
//! spec is the only input.

use std::collections::BTreeMap;

use crate::config::SimConfig;
use crate::scenario::core::{self, CoreEv, FaultEv, Harness, SpecCand, Speculation};
use crate::scenario::engine::{pick_dst_in, FaultState, TierBytes};
use crate::scenario::trace::{HarnessGauges, TraceRecorder, Tracer};
use crate::scenario::{ScenarioSpec, WorkloadKind};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::sphere::scheduler::Scheduler;
use crate::sphere::segment::Segment;
use crate::topology::{rack_diverse_replica, NetLinks, Testbed};
use crate::transport::TcpModel;

use super::hdfs::Placement;

/// Hadoop's speculation rule: a task whose elapsed time exceeds this
/// multiple of the mean completed-task duration gets one backup
/// attempt (0.16's "20% behind the average progress" rule).
const SPEC_SLOWDOWN: f64 = 1.2;

/// Completed tasks before the running mean is trusted.
const SPEC_MIN_SAMPLES: usize = 5;

/// What one Hadoop baseline run produced (the `hadoop` half of a
/// `scenario::ComparisonReport`).
#[derive(Clone, Debug)]
pub struct HadoopRun {
    pub makespan_secs: f64,
    /// (stage name, end time): map / shuffle / reduce for terasort,
    /// scan for terasplit, write for filegen.
    pub stage_ends: Vec<(String, f64)>,
    pub events: u64,
    pub map_tasks: usize,
    pub reduce_tasks: usize,
    /// Tasks completed exactly once (reruns and speculation losers not
    /// double-counted).
    pub tasks_completed: usize,
    /// Fraction of scheduler assignments that were data-local.
    pub local_fraction: f64,
    pub shuffle_gbytes: f64,
    /// Bytes moved between nodes, by deepest link tier crossed.
    pub tier: TierBytes,
    pub speculative_launched: u64,
    pub speculative_won: u64,
    pub reassignments: u64,
    /// Map tasks re-executed because their spilled output died with
    /// its node (Hadoop intermediates are not replicated).
    pub map_reruns: u64,
    /// NameNode re-replication traffic after DataNode deaths.
    pub re_replicated_gbytes: f64,
    pub faults_injected: usize,
    pub nodes_crashed: usize,
}

// ------------------------------------------------------------ events

enum HEv {
    /// JVM fork finished: start the attempt's I/O flow.
    TaskStart { gen: u64 },
    /// Re-scan in-flight attempts for speculation candidates.
    SpecCheck,
    /// The fault plan's shared events (intercepted by the core).
    Fault(FaultEv),
}

impl CoreEv for HEv {
    fn from_fault(f: FaultEv) -> HEv {
        HEv::Fault(f)
    }

    fn to_fault(&self) -> Option<FaultEv> {
        match self {
            HEv::Fault(f) => Some(*f),
            _ => None,
        }
    }

    fn trace_name(&self) -> &'static str {
        match self {
            HEv::TaskStart { .. } => "task_start",
            HEv::SpecCheck => "spec_check",
            HEv::Fault(_) => "fault",
        }
    }
}

#[derive(Clone, Copy)]
enum HFlow {
    /// A task attempt's I/O pipeline.
    Task { gen: u64 },
    /// Map-output fetch toward a reducer node; `block` identifies the
    /// producing map so a source crash can re-execute it.
    Shuffle { src: usize, dst: usize, block: usize },
    /// Job-output replication (dfs.replication > 1); blocks the phase.
    Output { dst: usize },
    /// NameNode re-replication restoring `block` onto `dst`; becomes a
    /// usable replica only when it lands.  Does NOT block the barrier.
    ReRep { block: usize, src: usize, dst: usize },
}

/// One running (or JVM-forking) attempt.
struct Attempt {
    node: usize,
    seg: Segment,
    started: f64,
    fid: Option<FlowId>,
    speculative: bool,
    /// Map re-execution after output loss — tracked outside the
    /// scheduler, whose first completion is already recorded.
    rerun: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Map,
    Reduce,
    Scan,
    Write,
}

impl Phase {
    fn name(self) -> &'static str {
        match self {
            Phase::Map => "map",
            Phase::Reduce => "reduce",
            Phase::Scan => "scan",
            Phase::Write => "write",
        }
    }

    fn shuffles(self) -> bool {
        self == Phase::Map
    }

    /// Phases whose tasks read HDFS input blocks (block ids = seg ids).
    fn reads_blocks(self) -> bool {
        matches!(self, Phase::Map | Phase::Scan)
    }
}

// ------------------------------------------------------------ engine

struct HadoopEngine<'a> {
    testbed: &'a Testbed,
    cfg: &'a SimConfig,
    phases: &'static [Phase],
    phase_idx: usize,
    bytes_per_node: f64,
    block_bytes: f64,
    placement: Placement,
    links: NetLinks,
    disk_read: Vec<LinkId>,
    disk_write: Vec<LinkId>,
    /// Terasplit only: the client's scan stage, shared by every stream.
    scan_link: Option<LinkId>,
    client: usize,
    nominal_caps: Vec<f64>,
    tcp_shuffle: TcpModel,
    tcp_bulk: TcpModel,
    sched: Scheduler,
    inflight: BTreeMap<u64, Attempt>,
    /// Sibling-attempt bookkeeping (core-owned; engine keeps policy).
    spec: Speculation,
    /// Maps awaiting re-execution after output loss.
    rerun_queue: Vec<Segment>,
    dur_sum: f64,
    dur_n: usize,
    next_gen: u64,
    running: Vec<usize>,
    flows: BTreeMap<FlowId, HFlow>,
    speculative_enabled: bool,
    // ---- counters
    tasks_completed: usize,
    reduce_tasks: usize,
    reassignments: u64,
    map_reruns: u64,
    shuffle_bytes: f64,
    re_rep_bytes: f64,
    tier: TierBytes,
    acc_local: u64,
    acc_remote: u64,
    acc_spec_launched: u64,
    acc_spec_won: u64,
    stage_ends: Vec<(String, f64)>,
    last_task_done: f64,
    done: bool,
    makespan: f64,
    /// Observability feed for task spans, speculation marks and
    /// cancelled flows.
    tracer: Tracer,
}

/// Run the Hadoop baseline to completion on a substrate built from
/// `testbed` under the spec's fault plan.  Deterministic: the spec is
/// the only input.
pub fn run_hadoop(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<HadoopRun, String> {
    let workload = spec
        .workload
        .as_ref()
        .ok_or("hadoop baseline requires a [workload] block")?;
    let phases: &'static [Phase] = match workload.kind {
        WorkloadKind::Terasort => &[Phase::Map, Phase::Reduce],
        WorkloadKind::Terasplit => &[Phase::Scan],
        WorkloadKind::Filegen => &[Phase::Write],
        other => {
            return Err(format!(
                "hadoop baseline does not run {} (terasort|terasplit|filegen)",
                other.name()
            ))
        }
    };
    let cfg = &spec.cfg;
    let h = &cfg.hadoop;
    let n = testbed.nodes();
    let mut state = FaultState::for_run(spec, testbed);

    let mut net = NetSim::with_capacity(
        4 * n + 2 * testbed.racks() + 2 * testbed.site_names.len() + 1,
    );
    let links = testbed.build_network(&mut net);
    // Per-node disk links with the straggler factor baked in (same
    // construction as the service/colocation engines).
    let read_eff = cfg.hardware.disk_read_bps * h.io_efficiency;
    let write_eff = cfg.hardware.disk_write_bps * h.io_efficiency;
    let disk_read: Vec<LinkId> = (0..n)
        .map(|i| net.add_link((read_eff * state.factor[i]).max(1.0)))
        .collect();
    let disk_write: Vec<LinkId> = (0..n)
        .map(|i| net.add_link((write_eff * state.factor[i]).max(1.0)))
        .collect();
    let client = *state.alive().first().ok_or("no live node for the client")?;
    let scan_link = if phases[0] == Phase::Scan {
        // The Java client scans slower than the native one (§6.2
        // calibration); one shared link serializes the client side.
        let scan = (cfg.cpu.scan_bps * 0.75 * state.factor[client]).max(1.0);
        Some(net.add_link(scan))
    } else {
        None
    };
    let nominal_caps: Vec<f64> = (0..net.link_count())
        .map(|i| net.link_capacity(LinkId(i)))
        .collect();

    let blocks_per_node = (workload.bytes_per_node / h.block_bytes as f64).ceil().max(1.0);
    let block_bytes = workload.bytes_per_node / blocks_per_node;
    let placement = Placement::build(
        &testbed.node_rack,
        blocks_per_node as usize,
        h.replication_in.min(n),
        cfg.seed,
    );

    let map_segments = block_segments(&placement, block_bytes, &state);
    let mut sched = Scheduler::new(map_segments, true);
    sched.max_attempts = cfg.sphere.max_attempts;

    let mut eng = HadoopEngine {
        testbed,
        cfg,
        phases,
        phase_idx: 0,
        bytes_per_node: workload.bytes_per_node,
        block_bytes,
        placement,
        links: links.clone(),
        disk_read,
        disk_write,
        scan_link,
        client,
        nominal_caps,
        tcp_shuffle: TcpModel {
            wnd_max: 64.0 * 1024.0, // untuned 2008 defaults
            ..TcpModel::hadoop_shuffle()
        },
        tcp_bulk: TcpModel::default(),
        sched,
        inflight: BTreeMap::new(),
        spec: Speculation::new(),
        rerun_queue: Vec::new(),
        dur_sum: 0.0,
        dur_n: 0,
        next_gen: 0,
        running: vec![0; n],
        flows: BTreeMap::new(),
        speculative_enabled: match spec.compare {
            Some(c) => c.hadoop_speculative,
            None => true,
        },
        tasks_completed: 0,
        reduce_tasks: 0,
        reassignments: 0,
        map_reruns: 0,
        shuffle_bytes: 0.0,
        re_rep_bytes: 0.0,
        tier: TierBytes::default(),
        acc_local: 0,
        acc_remote: 0,
        acc_spec_launched: 0,
        acc_spec_won: 0,
        stage_ends: Vec::new(),
        last_task_done: 0.0,
        done: false,
        makespan: 0.0,
        tracer: rec.tracer("hadoop"),
    };

    let mut q: EventQueue<HEv> =
        EventQueue::with_capacity(n * h.map_slots.max(1) + 2 * state.faults.len() + 8);
    core::schedule_faults(&mut state, &mut q, 0.0);
    eng.pump(0.0, &mut q, &state);

    let out = {
        let tracer = rec.tracer("hadoop");
        let mut har = HadoopHarness { eng: &mut eng };
        core::drive(&mut har, &mut net, &mut q, &mut state, &links, testbed, &tracer)?
    };

    Ok(HadoopRun {
        makespan_secs: eng.makespan,
        stage_ends: eng.stage_ends,
        events: out.events,
        map_tasks: eng.placement.blocks(),
        reduce_tasks: eng.reduce_tasks,
        tasks_completed: eng.tasks_completed,
        local_fraction: if eng.acc_local + eng.acc_remote == 0 {
            0.0
        } else {
            eng.acc_local as f64 / (eng.acc_local + eng.acc_remote) as f64
        },
        shuffle_gbytes: eng.shuffle_bytes / 1e9,
        tier: eng.tier,
        speculative_launched: eng.acc_spec_launched,
        speculative_won: eng.acc_spec_won,
        reassignments: eng.reassignments,
        map_reruns: eng.map_reruns,
        re_replicated_gbytes: eng.re_rep_bytes / 1e9,
        faults_injected: state.injected,
        nodes_crashed: state.crashes,
    })
}

/// One block's task segment, located at the block's LIVE replica
/// holders.  Each block is its own "file" — Hadoop has no same-file
/// anti-affinity, so Sphere's rule 3 must stay inert in the reused
/// scheduler.  The single builder serves both the initial task list
/// and crash-time re-executions, so the two can never drift apart.
fn block_segment(
    placement: &Placement,
    block: usize,
    block_bytes: f64,
    state: &FaultState,
) -> Segment {
    let locations: Vec<u32> = placement
        .replicas_of(block)
        .iter()
        .copied()
        .filter(|&r| !state.dead[r as usize])
        .collect();
    Segment {
        id: block,
        file: format!("hdfs/block{block:06}"),
        first_record: 0,
        n_records: 1,
        bytes: block_bytes as u64,
        locations,
        whole_file: false,
    }
}

/// The full map-task list: one segment per HDFS block.
fn block_segments(placement: &Placement, block_bytes: f64, state: &FaultState) -> Vec<Segment> {
    (0..placement.blocks())
        .map(|b| block_segment(placement, b, block_bytes, state))
        .collect()
}

/// The Hadoop engine plugged into the shared core loop: the exit test
/// is the phase machine alone (every flow the barrier waits on is in
/// `phase_idle`), a stall with work pending is an error, and the
/// post-wave hook releases phase barriers.
struct HadoopHarness<'e, 'a> {
    eng: &'e mut HadoopEngine<'a>,
}

impl<'e, 'a> Harness for HadoopHarness<'e, 'a> {
    type Ev = HEv;

    fn finished(&self, _net: &NetSim) -> bool {
        self.eng.done
    }

    fn on_stall(&mut self) -> Result<(), String> {
        Err("hadoop engine stalled with work pending".into())
    }

    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.eng.flow_done(fid, now, net, q, state);
        Ok(())
    }

    fn handle(
        &mut self,
        ev: HEv,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        match ev {
            HEv::TaskStart { gen } => self.eng.start_task_flow(gen, net, state),
            HEv::SpecCheck => {
                self.eng.spec.recheck_fired();
                self.eng.maybe_speculate(now, q, state);
                Ok(())
            }
            HEv::Fault(_) => Ok(()), // intercepted by the core
        }
    }

    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.eng.on_crash(node, now, net, q, state)
    }

    fn on_join(
        &mut self,
        _node: usize,
        now: f64,
        _net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        // The re-joined TaskTracker heartbeats in with free slots.
        self.eng.pump(now, q, state);
        Ok(())
    }

    fn on_master(
        &mut self,
        up: bool,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        if up {
            // Recovered JobTracker re-dispatches from scheduler state.
            self.eng.pump(now, q, state);
            return Ok(());
        }
        self.eng.on_master_down(now, net, q, state)
    }

    fn after_wave(
        &mut self,
        now: f64,
        _drained: bool,
        _net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        if self.eng.phase_idle() {
            self.eng.finish_phase(now, q, state)?;
        }
        Ok(())
    }

    fn gauges(&self) -> HarnessGauges {
        HarnessGauges {
            occupancy: self.eng.running.iter().map(|&r| r as u64).sum(),
            queued: (self.eng.sched.pending_count() + self.eng.rerun_queue.len()) as u64,
            spec_inflight: self
                .eng
                .inflight
                .values()
                .filter(|a| a.speculative)
                .count() as u64,
            replicas: 0,
        }
    }
}

impl<'a> HadoopEngine<'a> {
    fn phase(&self) -> Phase {
        self.phases[self.phase_idx]
    }

    fn slots(&self) -> usize {
        match self.phase() {
            Phase::Reduce => self.cfg.hadoop.reduce_slots.max(1),
            _ => self.cfg.hadoop.map_slots.max(1),
        }
    }

    /// Nominal single-task pipeline time for `bytes` of this phase's
    /// work on an unloaded node (the flow's rate cap derives from it).
    fn service_secs(&self, phase: Phase, bytes: f64) -> f64 {
        let cfg = self.cfg;
        let h = &cfg.hadoop;
        let read = cfg.hardware.disk_read_bps * h.io_efficiency;
        let write = cfg.hardware.disk_write_bps * h.io_efficiency;
        match phase {
            Phase::Map => {
                let io = bytes / read + bytes / write;
                let cpu = bytes / cfg.cpu.hadoop_map_bps;
                io.max(cpu)
            }
            Phase::Reduce => {
                let merge = h.merge_passes.max(1.0) * (bytes / read + bytes / write);
                let cpu = bytes / cfg.cpu.hadoop_sort_bps;
                let hdfs_write = cfg.hardware.disk_write_bps * h.hdfs_write_efficiency;
                let out = h.replication_out.max(1) as f64 * bytes / hdfs_write;
                merge.max(cpu) + out
            }
            // The client-side scan link enforces the aggregate limit.
            Phase::Scan => bytes / read,
            Phase::Write => {
                let hdfs_write = cfg.hardware.disk_write_bps * h.hdfs_write_efficiency;
                h.replication_out.max(1) as f64 * bytes / hdfs_write
            }
        }
    }

    fn net_bottleneck(&self, path: &[LinkId]) -> f64 {
        path.iter()
            .map(|l| self.nominal_caps[l.0])
            .fold(f64::INFINITY, f64::min)
            .min(self.testbed.nic_bps)
    }

    /// Hand pending tasks to every idle slot (re-executions first —
    /// they block the barrier).
    fn pump(&mut self, now: f64, q: &mut EventQueue<HEv>, state: &FaultState) {
        // JobTracker down: nobody is running the assignment loop.  The
        // crash itself already unwound in-flight attempts (see
        // `on_master_down`); recovery re-pumps on `MasterUp`
        // (DESIGN.md §18).
        if state.master_down {
            return;
        }
        let slots = self.slots();
        for node in 0..self.testbed.nodes() {
            if state.dead[node] {
                continue;
            }
            while self.running[node] < slots {
                if let Some(seg) = self.take_rerun(node as u32) {
                    self.launch(node, seg, false, true, now, q);
                    continue;
                }
                let Some(seg) = self.sched.assign(node as u32) else {
                    break;
                };
                self.launch(node, seg, false, false, now, q);
            }
        }
    }

    /// Pull a map re-execution for `node`, preferring blocks it holds.
    fn take_rerun(&mut self, node: u32) -> Option<Segment> {
        if self.rerun_queue.is_empty() {
            return None;
        }
        let pos = self
            .rerun_queue
            .iter()
            .position(|s| s.locations.contains(&node))
            .unwrap_or(0);
        Some(self.rerun_queue.remove(pos))
    }

    fn launch(
        &mut self,
        node: usize,
        seg: Segment,
        speculative: bool,
        rerun: bool,
        now: f64,
        q: &mut EventQueue<HEv>,
    ) {
        self.next_gen += 1;
        let gen = self.next_gen;
        if !rerun {
            self.spec.register(seg.id, gen);
        }
        self.inflight.insert(
            gen,
            Attempt {
                node,
                seg,
                started: now,
                fid: None,
                speculative,
                rerun,
            },
        );
        self.running[node] += 1;
        // The per-task JVM fork (Hadoop 0.16 forked one per task).
        q.push_at(now + self.cfg.hadoop.task_startup_secs, HEv::TaskStart { gen });
    }

    /// JVM up: start the attempt's I/O flow on the shared substrate.
    fn start_task_flow(
        &mut self,
        gen: u64,
        net: &mut NetSim,
        state: &FaultState,
    ) -> Result<(), String> {
        let Some((node, block, bytes)) = self
            .inflight
            .get(&gen)
            .map(|a| (a.node, a.seg.id, a.seg.bytes as f64))
        else {
            return Ok(()); // pre-empted by a crash or a speculation win
        };
        let phase = self.phase();
        let nominal = self.service_secs(phase, bytes).max(1e-9);
        let mut cap = (bytes / nominal) * state.factor[node];
        let mut path: Vec<LinkId> = Vec::with_capacity(6);
        match phase {
            Phase::Map => {
                let local = self
                    .placement
                    .replicas_of(block)
                    .iter()
                    .any(|&r| r as usize == node);
                if local {
                    path.push(self.disk_read[node]);
                } else {
                    // Remote map: stream the block from a live holder.
                    let src = self
                        .placement
                        .replicas_of(block)
                        .iter()
                        .copied()
                        .find(|&r| !state.dead[r as usize])
                        .ok_or_else(|| {
                            format!("job failed: block {block} has no live replica")
                        })? as usize;
                    let net_path = self.testbed.path(&self.links, src, node);
                    let rtt = self.testbed.rtt_secs(src, node);
                    cap = cap.min(self.tcp_bulk.rate_cap(self.net_bottleneck(&net_path), rtt));
                    path.push(self.disk_read[src]);
                    path.extend_from_slice(&net_path);
                    self.tier.add(self.testbed, src, node, bytes);
                }
                path.push(self.disk_write[node]);
            }
            Phase::Reduce => {
                path.push(self.disk_read[node]);
                path.push(self.disk_write[node]);
            }
            Phase::Scan => {
                let net_path = self.testbed.path(&self.links, node, self.client);
                if node != self.client {
                    let rtt = self.testbed.rtt_secs(node, self.client);
                    cap = cap.min(self.tcp_bulk.rate_cap(self.net_bottleneck(&net_path), rtt));
                }
                path.push(self.disk_read[node]);
                path.extend_from_slice(&net_path);
                path.push(self.scan_link.expect("scan phase built its link"));
                self.tier.add(self.testbed, node, self.client, bytes);
            }
            Phase::Write => {
                path.push(self.disk_write[node]);
            }
        }
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, HFlow::Task { gen });
        if let Some(att) = self.inflight.get_mut(&gen) {
            att.fid = Some(fid);
        }
        Ok(())
    }

    /// A flow landed.
    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &FaultState,
    ) {
        let Some(flow) = self.flows.remove(&fid) else {
            return;
        };
        let gen = match flow {
            HFlow::Task { gen } => gen,
            HFlow::ReRep { block, dst, .. } => {
                // The rescue copy landed: the target now serves reads.
                self.placement.add_replica(block, dst as u32);
                return;
            }
            HFlow::Shuffle { .. } | HFlow::Output { .. } => return,
        };
        let Some(att) = self.inflight.remove(&gen) else {
            return;
        };
        self.running[att.node] -= 1;
        if att.rerun {
            // Lost map output restored: re-shuffle the whole output.
            self.tracer
                .task(att.started, now, "map rerun", att.node, self.phase().name());
            self.last_task_done = now;
            if self.phase().shuffles() {
                self.start_shuffle(att.node, att.seg.id, att.seg.bytes as f64, net, state);
            }
            self.pump(now, q, state);
            return;
        }
        let first = self.sched.complete(&att.seg);
        // First-finisher-wins: cancel the speculation sibling.
        for g in self.spec.take_losers(att.seg.id, gen) {
            if let Some(loser) = self.inflight.remove(&g) {
                self.running[loser.node] -= 1;
                if let Some(lfid) = loser.fid {
                    self.flows.remove(&lfid);
                    net.try_cancel_flow(lfid);
                    self.tracer.flow_cancel(lfid, now);
                }
                self.sched.cancel_attempt(&loser.seg);
            }
        }
        if first {
            let stage_name = self.phase().name();
            self.tracer.task(att.started, now, "task", att.node, stage_name);
            if att.speculative {
                self.sched.record_speculative_win();
                self.tracer.task_mark(now, "spec won", att.node, stage_name);
            }
            self.tasks_completed += 1;
            self.last_task_done = now;
            self.dur_sum += (now - att.started).max(0.0);
            self.dur_n += 1;
            if self.phase().shuffles() {
                self.start_shuffle(att.node, att.seg.id, att.seg.bytes as f64, net, state);
            }
            let repl_out = self.cfg.hadoop.replication_out;
            if matches!(self.phase(), Phase::Reduce | Phase::Write) && repl_out >= 2 {
                // dfs.replication > 1: the output pipeline also crosses
                // the network to the rack-diverse partner.
                let partner = rack_diverse_replica(self.testbed, att.node);
                if partner != att.node && !state.dead[partner] {
                    let bytes = att.seg.bytes as f64 * (repl_out - 1) as f64;
                    let mut path = self.testbed.path(&self.links, att.node, partner);
                    path.push(self.disk_write[partner]);
                    let hdfs_write =
                        self.cfg.hardware.disk_write_bps * self.cfg.hadoop.hdfs_write_efficiency;
                    let fid = net.start_flow(&path, bytes.max(1.0), hdfs_write.max(1.0));
                    self.flows.insert(fid, HFlow::Output { dst: partner });
                    self.tier.add(self.testbed, att.node, partner, bytes);
                }
            }
        }
        self.pump(now, q, state);
        self.maybe_speculate(now, q, state);
    }

    /// Fetch a completed map's output toward its reducer-to-be: the
    /// remote fraction to a deterministic partner, over 2008-default
    /// TCP, spill disk to merge disk.
    fn start_shuffle(
        &mut self,
        src: usize,
        block: usize,
        out_bytes: f64,
        net: &mut NetSim,
        state: &FaultState,
    ) {
        let (n_alive, dst) = {
            let alive = state.alive();
            (alive.len(), pick_dst_in(alive, src, block))
        };
        let Some(dst) = dst else {
            return; // single live node: everything is already local
        };
        let bytes = out_bytes * (n_alive - 1) as f64 / n_alive as f64;
        self.shuffle_bytes += bytes;
        // Counted once at first send; a crash-time reroute re-sends a
        // remainder without re-counting (matching `shuffle_bytes`).
        self.tier.add(self.testbed, src, dst, bytes);
        self.start_shuffle_to(src, dst, block, bytes, net, state);
    }

    fn start_shuffle_to(
        &mut self,
        src: usize,
        dst: usize,
        block: usize,
        bytes: f64,
        net: &mut NetSim,
        state: &FaultState,
    ) {
        let net_path = self.testbed.path(&self.links, src, dst);
        let rtt = self.testbed.rtt_secs(src, dst);
        let read = self.cfg.hardware.disk_read_bps * self.cfg.hadoop.io_efficiency;
        let cap = self
            .tcp_shuffle
            .rate_cap(self.net_bottleneck(&net_path), rtt)
            .min(read * state.factor[src]);
        let mut path = Vec::with_capacity(net_path.len() + 2);
        path.push(self.disk_read[src]);
        path.extend_from_slice(&net_path);
        path.push(self.disk_write[dst]);
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, HFlow::Shuffle { src, dst, block });
    }

    /// Launch backups for attempts running past Hadoop's slowdown rule,
    /// scheduling a re-check at the earliest future crossing.
    fn maybe_speculate(&mut self, now: f64, q: &mut EventQueue<HEv>, state: &FaultState) {
        if !self.speculative_enabled || self.dur_n < SPEC_MIN_SAMPLES {
            return;
        }
        let mean = self.dur_sum / self.dur_n as f64;
        if !(mean > 0.0) {
            return;
        }
        let cutoff = SPEC_SLOWDOWN * mean;
        // Re-executions and scheduler-retired tasks never speculate;
        // the core scan skips siblinged/latched/backup attempts.
        let (launch, cross) = self.spec.scan(
            now,
            cutoff,
            self.inflight
                .iter()
                .filter(|(_, att)| !att.rerun && self.sched.speculatable(att.seg.id))
                .map(|(&gen, att)| SpecCand {
                    gen,
                    unit: att.seg.id,
                    started: att.started,
                    speculative: att.speculative,
                }),
        );
        for gen in launch {
            self.launch_backup(gen, now, q, state);
        }
        self.spec.schedule_recheck(cross, now, q, || HEv::SpecCheck);
    }

    /// Dispatch a backup attempt to another live node with a free slot
    /// (preferring an input-replica holder for block-reading phases).
    fn launch_backup(&mut self, gen: u64, now: f64, q: &mut EventQueue<HEv>, state: &FaultState) {
        let (seg, primary) = {
            let att = &self.inflight[&gen];
            (att.seg.clone(), att.node)
        };
        let slots = self.slots();
        let free = |l: usize| l != primary && !state.dead[l] && self.running[l] < slots;
        let backup = if self.phase().reads_blocks() {
            self.placement
                .replicas_of(seg.id)
                .iter()
                .map(|&l| l as usize)
                .find(|&l| free(l))
                .or_else(|| (0..self.testbed.nodes()).find(|&l| free(l)))
        } else {
            (0..self.testbed.nodes()).find(|&l| free(l))
        };
        let Some(backup) = backup else {
            return; // no free slot anywhere; a later scan retries
        };
        if !self.sched.speculate(&seg, backup as u32) {
            return;
        }
        self.tracer
            .task_mark(now, "speculate", backup, self.phase().name());
        self.spec.mark_speculated(seg.id);
        self.launch(backup, seg, true, false, now, q);
    }

    /// The driver applied a crash to the shared fault state: unwind the
    /// dead node's tasks, re-execute lost map outputs, re-route fetches
    /// and re-replicate its HDFS blocks.
    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<HEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        // Attempts running on the dead TaskTracker.
        let stale: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, a)| a.node == node)
            .map(|(&g, _)| g)
            .collect();
        for g in stale {
            let att = self.inflight.remove(&g).expect("stale gen exists");
            if let Some(fid) = att.fid {
                self.flows.remove(&fid);
                net.try_cancel_flow(fid);
                self.tracer.flow_cancel(fid, now);
            }
            if att.rerun {
                self.rerun_queue.push(self.block_segment(att.seg.id, state));
                self.reassignments += 1;
                continue;
            }
            let siblings = self.spec.drop_attempt(att.seg.id, g);
            if siblings > 0 {
                self.sched.cancel_attempt(&att.seg);
            } else {
                let id = att.seg.id;
                if !self.sched.fail(att.seg) {
                    return Err(format!(
                        "job failed: {} task {id} exhausted its {} attempts \
                         after node {node} crashed",
                        self.phase().name(),
                        self.sched.max_attempts
                    ));
                }
                self.reassignments += 1;
            }
        }
        self.running[node] = 0;

        // NameNode pass first: drop the dead DataNode's copies so every
        // decision below sees the surviving replica map.
        let rescue = if self.phase().reads_blocks() {
            self.placement.re_replicate(node as u32, &state.dead)
        } else {
            Default::default()
        };

        // Flow triage: spills on the dead node are GONE (the map
        // re-execution penalty Sphere's replicated stage outputs
        // avoid); fetches toward it re-route; interrupted rescue
        // copies restart from another live holder.
        let doomed: Vec<(FlowId, HFlow)> = self
            .flows
            .iter()
            .filter(|(_, fl)| match fl {
                HFlow::Shuffle { src, dst, .. } => *src == node || *dst == node,
                HFlow::Output { dst } => *dst == node,
                HFlow::ReRep { src, dst, .. } => *src == node || *dst == node,
                HFlow::Task { .. } => false,
            })
            .map(|(&f, &fl)| (f, fl))
            .collect();
        for (fid, fl) in doomed {
            self.flows.remove(&fid);
            let left = net.try_cancel_flow(fid).unwrap_or(0.0);
            self.tracer.flow_cancel(fid, now);
            match fl {
                HFlow::Shuffle { src, dst, block } => {
                    if src == node {
                        // Spill lost with its node: the map re-executes
                        // on a surviving input replica, re-shuffles.
                        self.rerun_queue.push(self.block_segment(block, state));
                        self.map_reruns += 1;
                    } else {
                        let new_dst = {
                            let alive = state.alive();
                            pick_dst_in(alive, src, block + 1)
                        };
                        if let Some(nd) = new_dst {
                            self.start_shuffle_to(src, nd, block, left.max(1.0), net, state);
                        }
                    }
                    self.reassignments += 1;
                }
                HFlow::ReRep { block, .. } => {
                    // Retry the rescue from another live holder.
                    if let Some((src, dst)) = self.placement.propose_copy(block, &state.dead) {
                        self.start_rerep(block, src as usize, dst as usize, net);
                    } else if self.block_needed(block) {
                        return Err(format!(
                            "job failed: block {block} lost its last replica when \
                             node {node} crashed mid-rescue"
                        ));
                    }
                }
                HFlow::Output { .. } | HFlow::Task { .. } => {}
            }
        }

        // Blocks whose whole replica set is dead: fatal if still needed
        // (matching the Sphere engine's data-loss semantics).
        for &b in &rescue.lost {
            if self.block_needed(b) {
                return Err(format!(
                    "job failed: block {b} lost its last replica when node \
                     {node} crashed"
                ));
            }
        }
        for (block, src, dst) in rescue.moved {
            self.start_rerep(block, src as usize, dst as usize, net);
        }

        // Terasplit: the scan client itself died — the job restarts the
        // gather on the next live node and re-streams in-flight blocks.
        if self.phase() == Phase::Scan && node == self.client {
            self.client = *state
                .alive()
                .first()
                .ok_or("no live node to host the scan client")?;
            // The scan stage now runs on the new client's hardware:
            // re-rate the shared scan link (the dead client may have
            // been a straggler — its factor must not outlive it).
            let link = self.scan_link.expect("scan phase built its link");
            let scan = (self.cfg.cpu.scan_bps * 0.75 * state.factor[self.client]).max(1.0);
            net.set_link_capacity(link, scan);
            let restart: Vec<u64> = self.inflight.keys().copied().collect();
            for gen in restart {
                if let Some(att) = self.inflight.get_mut(&gen) {
                    if let Some(fid) = att.fid.take() {
                        self.flows.remove(&fid);
                        net.try_cancel_flow(fid);
                        self.tracer.flow_cancel(fid, now);
                        q.push_at(now, HEv::TaskStart { gen });
                        self.reassignments += 1;
                    }
                }
            }
        }
        self.pump(now, q, state);
        Ok(())
    }

    /// The JobTracker crashed.  Unlike Sector's master — whose outage
    /// only pauses NEW dispatch while running SPEs stream on (paper §4,
    /// modelled by the `pump` gate in the Sphere engines) — Hadoop 0.16
    /// kept all in-flight task state in JobTracker memory, so every
    /// running attempt is lost and re-queued, paying its work again
    /// after recovery.  Data-plane transfers (shuffle fetches, HDFS
    /// output pipelines, rescue copies) ride on TaskTrackers/DataNodes
    /// and survive the outage.  This is the availability asymmetry the
    /// `master_crash` fault exists to surface (DESIGN.md §18).
    fn on_master_down(
        &mut self,
        now: f64,
        net: &mut NetSim,
        _q: &mut EventQueue<HEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        let stale: Vec<u64> = self.inflight.keys().copied().collect();
        for g in stale {
            let att = self.inflight.remove(&g).expect("inflight gen exists");
            if let Some(fid) = att.fid {
                self.flows.remove(&fid);
                net.try_cancel_flow(fid);
                self.tracer.flow_cancel(fid, now);
            }
            if att.rerun {
                self.rerun_queue.push(self.block_segment(att.seg.id, state));
                self.reassignments += 1;
                continue;
            }
            let siblings = self.spec.drop_attempt(att.seg.id, g);
            if siblings > 0 {
                self.sched.cancel_attempt(&att.seg);
            } else {
                let id = att.seg.id;
                if !self.sched.fail(att.seg) {
                    return Err(format!(
                        "job failed: {} task {id} exhausted its {} attempts \
                         when the JobTracker crashed",
                        self.phase().name(),
                        self.sched.max_attempts
                    ));
                }
                self.reassignments += 1;
            }
        }
        for r in self.running.iter_mut() {
            *r = 0;
        }
        Ok(())
    }

    /// Is any not-yet-finished work still going to read `block`?
    fn block_needed(&self, block: usize) -> bool {
        self.phase().reads_blocks()
            && (self.sched.pending_ids().contains(&block)
                || self.spec.attempts(block) > 0
                || self.rerun_queue.iter().any(|s| s.id == block))
    }

    /// Start one NameNode rescue copy (background: does not gate the
    /// map → reduce barrier, but contends on disks and uplinks).
    fn start_rerep(&mut self, block: usize, src: usize, dst: usize, net: &mut NetSim) {
        let bytes = self.block_bytes;
        let net_path = self.testbed.path(&self.links, src, dst);
        let rtt = self.testbed.rtt_secs(src, dst);
        let cap = self.tcp_bulk.rate_cap(self.net_bottleneck(&net_path), rtt);
        let mut path = Vec::with_capacity(net_path.len() + 2);
        path.push(self.disk_read[src]);
        path.extend_from_slice(&net_path);
        path.push(self.disk_write[dst]);
        let fid = net.start_flow(&path, bytes.max(1.0), cap.max(1.0));
        self.flows.insert(fid, HFlow::ReRep { block, src, dst });
        self.re_rep_bytes += bytes;
        self.tier.add(self.testbed, src, dst, bytes);
    }

    /// Rebuild a block's segment with its current live holders.
    fn block_segment(&self, block: usize, state: &FaultState) -> Segment {
        block_segment(&self.placement, block, self.block_bytes, state)
    }

    /// Flows that gate the map → reduce barrier (background
    /// re-replication does not).
    fn blocking_flows(&self) -> usize {
        self.flows
            .values()
            .filter(|f| !matches!(f, HFlow::ReRep { .. }))
            .count()
    }

    fn phase_idle(&self) -> bool {
        !self.done
            && self.sched.is_drained()
            && self.inflight.is_empty()
            && self.rerun_queue.is_empty()
            && self.blocking_flows() == 0
    }

    /// Close the current phase; open the next (or finish the job).
    fn finish_phase(
        &mut self,
        now: f64,
        q: &mut EventQueue<HEv>,
        state: &FaultState,
    ) -> Result<(), String> {
        self.acc_local += self.sched.local_assignments;
        self.acc_remote += self.sched.remote_assignments;
        self.acc_spec_launched += self.sched.speculative_launched;
        self.acc_spec_won += self.sched.speculative_won;
        if self.phase() == Phase::Map {
            // The map tail and the fetch tail end at different times;
            // report both (the barrier released at `now`).  Both trace
            // marks land at `now` so per-track emission stays monotone.
            self.tracer.stage_mark(now, "map");
            self.tracer.stage_mark(now, "shuffle");
            self.stage_ends.push(("map".to_string(), self.last_task_done));
            self.stage_ends.push(("shuffle".to_string(), now));
        } else {
            self.tracer.stage_mark(now, self.phase().name());
            self.stage_ends.push((self.phase().name().to_string(), now));
        }
        self.phase_idx += 1;
        if self.phase_idx >= self.phases.len() {
            self.done = true;
            self.makespan = now;
            return Ok(());
        }
        debug_assert_eq!(self.phase(), Phase::Reduce, "only terasort is two-phase");
        // One reduce partition per live node, served where its fetched
        // data sits.
        let alive = state.alive().to_vec();
        let r = alive.len().max(1);
        self.reduce_tasks = r;
        let total = self.bytes_per_node * self.testbed.nodes() as f64;
        let part_bytes = total / r as f64;
        let segments: Vec<Segment> = alive
            .iter()
            .enumerate()
            .map(|(i, &node)| Segment {
                id: self.placement.blocks() + i,
                file: format!("hdfs/part{i:05}"),
                first_record: 0,
                n_records: 1,
                bytes: part_bytes as u64,
                locations: vec![node as u32],
                whole_file: false,
            })
            .collect();
        let mut sched = Scheduler::new(segments, true);
        sched.max_attempts = self.sched.max_attempts;
        self.sched = sched;
        self.spec.clear_stage();
        self.dur_sum = 0.0;
        self.dur_n = 0;
        self.pump(now, q, state);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{CompareSpec, ScenarioSpec};
    use crate::topology::TopologySpec;
    use crate::util::bytes::GB;

    fn spec(kind: WorkloadKind, sites: usize, racks: usize, npr: usize) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(sites, racks, npr);
        spec.name = format!("hadoop-test-{}", kind.name());
        let w = spec.workload.as_mut().unwrap();
        w.kind = kind;
        w.bytes_per_node = 0.5 * GB as f64;
        spec.compare = Some(CompareSpec::default());
        spec
    }

    fn run(spec: &ScenarioSpec) -> HadoopRun {
        let testbed = spec.topology.generate().unwrap();
        run_hadoop(spec, &testbed, &TraceRecorder::disabled()).unwrap()
    }

    #[test]
    fn terasort_runs_all_three_stages_deterministically() {
        let s = spec(WorkloadKind::Terasort, 2, 2, 2);
        let a = run(&s);
        let b = run(&s);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same spec, same run");
        assert!(a.makespan_secs > 0.0);
        assert_eq!(a.map_tasks, 8 * 4, "0.5 GB / 128 MB = 4 blocks per node");
        assert_eq!(a.reduce_tasks, 8);
        assert_eq!(a.tasks_completed, a.map_tasks + a.reduce_tasks);
        let names: Vec<&str> = a.stage_ends.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["map", "shuffle", "reduce"]);
        assert!(a.stage_ends[0].1 <= a.stage_ends[1].1);
        assert!(a.stage_ends[1].1 <= a.stage_ends[2].1);
        assert!(a.shuffle_gbytes > 0.0);
        assert!(a.tier.total() > 0.0);
        assert!(
            a.local_fraction > 0.8,
            "block placement keeps maps data-local ({})",
            a.local_fraction
        );
    }

    #[test]
    fn crash_forces_map_reruns_and_re_replication() {
        let mut s = spec(WorkloadKind::Terasort, 1, 2, 3);
        let clean = run(&s);
        s.faults.push(crate::scenario::FaultSpec::SlaveCrash {
            at_secs: 6.0,
            node: 1,
        });
        let faulted = run(&s);
        assert_eq!(faulted.nodes_crashed, 1);
        assert!(faulted.reassignments > 0, "work must move off the dead node");
        assert!(
            faulted.re_replicated_gbytes > 0.0,
            "the NameNode must restore the dead DataNode's blocks"
        );
        assert!(
            faulted.makespan_secs > clean.makespan_secs,
            "the crash must cost time: {} vs {}",
            faulted.makespan_secs,
            clean.makespan_secs
        );
        assert_eq!(
            faulted.tasks_completed,
            faulted.map_tasks + faulted.reduce_tasks,
            "every task still completes exactly once"
        );
    }

    #[test]
    fn straggler_triggers_hadoop_speculation() {
        let mut s = spec(WorkloadKind::Terasort, 1, 2, 3);
        s.faults.push(crate::scenario::FaultSpec::Straggler {
            node: 1,
            factor: 0.2,
        });
        let with = run(&s);
        assert!(
            with.speculative_launched > 0,
            "a 5x straggler must trip the 1.2x-mean rule"
        );
        assert!(with.speculative_won > 0, "backups on healthy nodes win");
        let mut off = s.clone();
        off.compare = Some(CompareSpec {
            hadoop_speculative: false,
        });
        let without = run(&off);
        assert_eq!(without.speculative_launched, 0, "knob off means no backups");
        assert!(
            with.makespan_secs < without.makespan_secs,
            "speculation must cut the straggler tail: {} vs {}",
            with.makespan_secs,
            without.makespan_secs
        );
    }

    #[test]
    fn terasplit_streams_through_one_client() {
        let s = spec(WorkloadKind::Terasplit, 2, 1, 2);
        let a = run(&s);
        assert_eq!(a.stage_ends.len(), 1);
        assert_eq!(a.stage_ends[0].0, "scan");
        assert_eq!(a.reduce_tasks, 0);
        assert!(a.shuffle_gbytes == 0.0, "scan jobs do not shuffle");
        assert!(a.tier.wan > 0.0, "remote sites stream to the client");
        // The single scan client gates the aggregate: makespan is at
        // least total bytes / client scan rate.
        let total = 4.0 * 0.5 * GB as f64;
        let scan = s.cfg.cpu.scan_bps * 0.75;
        assert!(a.makespan_secs > total / scan * 0.9);
    }

    #[test]
    fn filegen_pays_the_hdfs_write_pipeline() {
        let s = spec(WorkloadKind::Filegen, 1, 1, 4);
        let a = run(&s);
        assert_eq!(a.stage_ends[0].0, "write");
        // §6.3's contrast: the HDFS client pipeline lands far below the
        // raw spindle (paper: 440 Mb/s on a ~1.2 Gb/s disk).
        let b = 0.5 * GB as f64;
        let raw = s.cfg.hardware.disk_write_bps;
        assert!(
            a.makespan_secs > 2.0 * b / raw,
            "writes must pay the pipeline overhead ({} vs raw {})",
            a.makespan_secs,
            b / raw
        );
    }

    #[test]
    fn losing_every_replica_fails_the_run() {
        // With 4 nodes at R=2, killing 3 of them faster than a 128 MB
        // rescue copy can land (the source disk alone needs >1 s)
        // guarantees some block's whole replica set dies while work
        // still needs it — the run must error, not report a makespan.
        let mut s = spec(WorkloadKind::Terasort, 1, 2, 2);
        for (i, node) in [0usize, 2, 1].into_iter().enumerate() {
            s.faults.push(crate::scenario::FaultSpec::SlaveCrash {
                at_secs: 0.5 + i as f64 * 0.1,
                node,
            });
        }
        let testbed = s.topology.generate().unwrap();
        let err = run_hadoop(&s, &testbed, &TraceRecorder::disabled()).unwrap_err();
        assert!(
            err.contains("lost") || err.contains("exhausted") || err.contains("replica"),
            "{err}"
        );
    }

    #[test]
    fn brownout_slows_the_cross_site_shuffle() {
        let mut s = spec(WorkloadKind::Terasort, 2, 1, 2);
        let clean = run(&s);
        s.faults.push(crate::scenario::FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.02,
        });
        let braked = run(&s);
        assert!(
            braked.makespan_secs > clean.makespan_secs,
            "a choked uplink must slow the shuffle: {} vs {}",
            braked.makespan_secs,
            clean.makespan_secs
        );
    }
}

//! MapReduce over HDFS — the baseline execution engine (paper §2):
//! "i) relevant data is extracted in parallel over multiple nodes using
//! a common 'map' operation; ii) the data is then transported to other
//! nodes as required (this is referred to as a shuffle); and iii) the
//! data is then processed over multiple nodes using a common 'reduce'
//! operation".
//!
//! This is a real runnable engine (threads, real bytes) with Hadoop
//! 0.16's structure: block-granular map tasks with locality preference,
//! hash partitioning into R reduce partitions, per-partition sort by
//! key, then reduce.  The examples use it to cross-check that Sphere
//! and the baseline compute identical results.

use std::collections::HashMap;
use std::sync::Mutex;

use super::hdfs::Hdfs;

/// Key-value record.
pub type Kv = (Vec<u8>, Vec<u8>);

/// The user's job definition.
pub trait MapReduceJob: Send + Sync {
    /// Parse a raw input block into records and emit intermediate KVs.
    fn map(&self, block: &[u8], emit: &mut dyn FnMut(Kv));
    /// Reduce one key group (values arrive sorted by insertion order).
    fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: &mut dyn FnMut(Kv));
    /// Partition function (default: FNV hash of the key mod R).
    fn partition(&self, key: &[u8], r: u32) -> u32 {
        (crate::routing::hash_name(&String::from_utf8_lossy(key)) % r as u64) as u32
    }
}

/// Engine statistics for the comparison benches.
#[derive(Clone, Debug, Default)]
pub struct JobStats {
    pub map_tasks: usize,
    pub local_map_tasks: usize,
    pub shuffled_records: u64,
    pub shuffled_bytes: u64,
    pub reduce_tasks: usize,
}

/// Run a MapReduce job over `input` files; returns per-partition sorted
/// reduce output plus stats.
pub fn run_mapreduce(
    hdfs: &Hdfs,
    job: &dyn MapReduceJob,
    inputs: &[String],
    n_reducers: u32,
) -> Result<(Vec<Vec<Kv>>, JobStats), String> {
    assert!(n_reducers > 0);
    // ---- plan map tasks: one per block, locality-preferring ----
    let mut tasks = Vec::new(); // (block id, preferred node)
    for name in inputs {
        let meta = hdfs
            .stat(name)
            .ok_or_else(|| format!("no such input {name:?}"))?;
        for id in meta.blocks {
            let bm = hdfs.block_meta(id).ok_or("dangling block")?;
            let prefer = *bm.replicas.first().ok_or("no replicas")?;
            tasks.push((id, prefer));
        }
    }
    let stats = Mutex::new(JobStats {
        map_tasks: tasks.len(),
        reduce_tasks: n_reducers as usize,
        ..JobStats::default()
    });

    // ---- map phase (parallel over blocks) ----
    let partitions: Vec<Mutex<Vec<Kv>>> =
        (0..n_reducers).map(|_| Mutex::new(Vec::new())).collect();
    let task_queue = Mutex::new(tasks);
    let error: Mutex<Option<String>> = Mutex::new(None);
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(8);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let task_queue = &task_queue;
            let partitions = &partitions;
            let stats = &stats;
            let error = &error;
            scope.spawn(move || loop {
                let task = task_queue.lock().unwrap().pop();
                let Some((block, prefer)) = task else { return };
                match hdfs.read_block(block, prefer) {
                    Ok((bytes, local)) => {
                        let mut emitted: Vec<Kv> = Vec::new();
                        job.map(&bytes, &mut |kv| emitted.push(kv));
                        {
                            let mut s = stats.lock().unwrap();
                            if local {
                                s.local_map_tasks += 1;
                            }
                            s.shuffled_records += emitted.len() as u64;
                            s.shuffled_bytes += emitted
                                .iter()
                                .map(|(k, v)| (k.len() + v.len()) as u64)
                                .sum::<u64>();
                        }
                        // spill to partitions (the "shuffle")
                        let mut grouped: HashMap<u32, Vec<Kv>> = HashMap::new();
                        for (k, v) in emitted {
                            let p = job.partition(&k, n_reducers);
                            grouped.entry(p).or_default().push((k, v));
                        }
                        for (p, kvs) in grouped {
                            partitions[p as usize].lock().unwrap().extend(kvs);
                        }
                    }
                    Err(e) => {
                        *error.lock().unwrap() = Some(e);
                        return;
                    }
                }
            });
        }
    });
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }

    // ---- sort + reduce phase (parallel over partitions) ----
    let outputs: Vec<Mutex<Vec<Kv>>> =
        (0..n_reducers).map(|_| Mutex::new(Vec::new())).collect();
    std::thread::scope(|scope| {
        for (p, part) in partitions.iter().enumerate() {
            let outputs = &outputs;
            scope.spawn(move || {
                let mut kvs = std::mem::take(&mut *part.lock().unwrap());
                // Hadoop's merge-sort by key (stable for value order).
                kvs.sort_by(|a, b| a.0.cmp(&b.0));
                let mut out = outputs[p].lock().unwrap();
                let mut i = 0;
                while i < kvs.len() {
                    let mut j = i + 1;
                    while j < kvs.len() && kvs[j].0 == kvs[i].0 {
                        j += 1;
                    }
                    let values: Vec<Vec<u8>> =
                        kvs[i..j].iter().map(|(_, v)| v.clone()).collect();
                    job.reduce(&kvs[i].0, &values, &mut |kv| out.push(kv));
                    i = j;
                }
            });
        }
    });

    Ok((
        outputs.into_iter().map(|m| m.into_inner().unwrap()).collect(),
        stats.into_inner().unwrap(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs() -> Hdfs {
        Hdfs::new(64, 1, vec![0, 0, 1, 1], 7)
    }

    /// Classic word count over whitespace-separated tokens.
    struct WordCount;

    impl MapReduceJob for WordCount {
        fn map(&self, block: &[u8], emit: &mut dyn FnMut(Kv)) {
            for tok in block.split(|&b| b == b' ' || b == b'\n') {
                if !tok.is_empty() {
                    emit((tok.to_vec(), vec![1]));
                }
            }
        }

        fn reduce(&self, key: &[u8], values: &[Vec<u8>], emit: &mut dyn FnMut(Kv)) {
            let n: u64 = values.iter().map(|v| v[0] as u64).sum();
            emit((key.to_vec(), n.to_string().into_bytes()));
        }
    }

    #[test]
    fn word_count_end_to_end() {
        let h = fs();
        h.put(0, "doc", b"the quick fox the lazy fox the end").unwrap();
        let (parts, stats) = run_mapreduce(&h, &WordCount, &["doc".into()], 4).unwrap();
        let mut all: Vec<(String, String)> = parts
            .iter()
            .flatten()
            .map(|(k, v)| {
                (
                    String::from_utf8_lossy(k).to_string(),
                    String::from_utf8_lossy(v).to_string(),
                )
            })
            .collect();
        all.sort();
        assert!(all.contains(&("the".into(), "3".into())));
        assert!(all.contains(&("fox".into(), "2".into())));
        assert_eq!(stats.map_tasks, 1);
        assert_eq!(stats.reduce_tasks, 4);
        assert_eq!(stats.shuffled_records, 8);
    }

    #[test]
    fn multi_block_input_and_partition_determinism() {
        let h = fs();
        // 200 bytes -> 4 blocks of 64; note a token may straddle blocks —
        // keep tokens short and block-aligned for the test's purposes.
        let text = "aa bb cc dd ee ff gg hh ".repeat(9); // 216 bytes
        h.put(1, "big", text.as_bytes()).unwrap();
        let (parts, stats) = run_mapreduce(&h, &WordCount, &["big".into()], 3).unwrap();
        assert!(stats.map_tasks >= 3);
        // same key never lands in two partitions
        let mut seen: HashMap<String, usize> = HashMap::new();
        for (p, kvs) in parts.iter().enumerate() {
            for (k, _) in kvs {
                let key = String::from_utf8_lossy(k).to_string();
                if let Some(prev) = seen.insert(key.clone(), p) {
                    assert_eq!(prev, p, "key {key} split across partitions");
                }
            }
        }
    }

    #[test]
    fn reduce_outputs_sorted_within_partition() {
        let h = fs();
        h.put(0, "doc", b"zz aa mm aa zz bb").unwrap();
        let (parts, _) = run_mapreduce(&h, &WordCount, &["doc".into()], 1).unwrap();
        let keys: Vec<String> = parts[0]
            .iter()
            .map(|(k, _)| String::from_utf8_lossy(k).to_string())
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn missing_input_is_an_error() {
        let h = fs();
        assert!(run_mapreduce(&h, &WordCount, &["nope".into()], 1).is_err());
    }
}
